//! Regenerates the data behind the paper's **Figures 1–10** (experiments
//! F1–F10 in DESIGN.md §4): for each of the five workloads, the
//! size-frequency histogram plus the old/new class-boundary verticals,
//! written as `results/fig{1..10}.csv` (`kind` column: `hist` rows are
//! the curve, `class` rows are the vertical lines).
//!
//! ```bash
//! cargo bench --bench bench_figures            # writes results/fig*.csv
//! ```

use slabforge::benchkit::paper::{
    experiment_histogram, run_experiment_with, write_figure_csvs,
};
use slabforge::config::cli::Args;
use slabforge::config::settings::Algorithm;
use slabforge::optimizer::engine::RustBackend;
use slabforge::optimizer::waste::WasteMap;
use slabforge::workload::PAPER_EXPERIMENTS;
use std::path::Path;

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["bench"]).unwrap();
    let items: usize = args.flag_or("items", 200_000).unwrap();
    let seed: u64 = args.flag_or("seed", 2020).unwrap();
    let out = Path::new("results");

    println!("# bench_figures: Figures 1-10 data at {items} items/experiment\n");
    for e in &PAPER_EXPERIMENTS {
        let hist = experiment_histogram(e, items, seed + e.table as u64);
        let backend = RustBackend::new(WasteMap::from_histogram(&hist));
        let row = run_experiment_with(e, &hist, &backend, Algorithm::SteepestDescent, seed);
        let (old_fig, new_fig) = write_figure_csvs(e, &hist, &row, out).unwrap();
        println!(
            "fig{}/fig{}: {} histogram rows, {}->{} class lines  ({}, {})",
            2 * e.table - 1,
            2 * e.table,
            hist.distinct_sizes(),
            row.old_span.len(),
            row.new_span.len(),
            old_fig.display(),
            new_fig.display(),
        );
        // sanity: the new boundaries crowd around the median (paper §6.4)
        let median = hist.percentile(0.5) as f64;
        let old_spread: f64 = row
            .old_span
            .iter()
            .map(|&c| (c as f64 - median).abs())
            .sum::<f64>()
            / row.old_span.len() as f64;
        let new_spread: f64 = row
            .new_span
            .iter()
            .map(|&c| (c as f64 - median).abs())
            .sum::<f64>()
            / row.new_span.len().max(1) as f64;
        println!(
            "  class spread around median: {old_spread:.0} -> {new_spread:.0} bytes (tighter = learned)"
        );
    }
    println!("\nplot with e.g.: python3 -c \"import csv; ...\" or any CSV plotter.");
}
