//! Regenerates the paper's **Tables 1–5** (experiments T1–T5 + claim D1
//! in DESIGN.md §4) and times the optimization for each.
//!
//! ```bash
//! cargo bench --bench bench_tables                     # 200k items/table
//! cargo bench --bench bench_tables -- --items 1000000  # paper scale
//! cargo bench --bench bench_tables -- --algorithm paper
//! ```

use slabforge::benchkit::paper::{experiment_histogram, run_experiment_with};
use slabforge::benchkit::{bench, BenchOpts, Summary};
use slabforge::config::cli::Args;
use slabforge::config::settings::Algorithm;
use slabforge::optimizer::engine::RustBackend;
use slabforge::optimizer::waste::WasteMap;
use slabforge::workload::PAPER_EXPERIMENTS;

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["bench"]).unwrap();
    let items: usize = args.flag_or("items", 200_000).unwrap();
    let seed: u64 = args.flag_or("seed", 2020).unwrap();
    let algorithm = args
        .flag("algorithm")
        .and_then(Algorithm::parse)
        .unwrap_or(Algorithm::SteepestDescent);

    println!("# bench_tables: Tables 1-5 at {items} items/table ({algorithm:?})\n");
    println!("| table | old waste | new waste | recovery | paper | waste/item old (paper) | optimize time |");
    println!("|---|---|---|---|---|---|---|");

    let mut hole_fracs = Vec::new();
    let mut timings: Vec<Summary> = Vec::new();
    for e in &PAPER_EXPERIMENTS {
        let hist = experiment_histogram(e, items, seed + e.table as u64);
        let backend = RustBackend::new(WasteMap::from_histogram(&hist));

        // timed: the optimization itself (the paper's algorithm run)
        let mut row = None;
        let t = bench(
            &format!("T{}", e.table),
            &BenchOpts {
                warmup: 1,
                iters: 5,
                units_per_iter: 1.0,
            },
            || {
                row = Some(run_experiment_with(e, &hist, &backend, algorithm, seed));
            },
        );
        let row = row.unwrap();
        let (old_per, _) = row.waste_per_item();
        let paper_per = e.paper_old_waste as f64 / 1e6;
        println!(
            "| T{} | {} | {} | {:.2}% | {:.2}% | {:.1} B ({:.1} B) | {} |",
            e.table,
            row.old_waste,
            row.new_waste,
            row.recovery * 100.0,
            row.paper_recovery * 100.0,
            old_per,
            paper_per,
            slabforge::util::fmt::human_duration(t.mean),
        );

        // D1: default-config hole fraction ≈ 10 %
        let stored = hist.total_bytes() as f64;
        hole_fracs.push(row.old_waste as f64 / (stored + row.old_waste as f64));
        timings.push(t);
    }

    let avg = hole_fracs.iter().sum::<f64>() / hole_fracs.len() as f64;
    println!(
        "\nD1 (§1 claim): default-config wastage per table: {:?} — average {:.2}% (paper: ~10%)",
        hole_fracs
            .iter()
            .map(|f| format!("{:.1}%", f * 100.0))
            .collect::<Vec<_>>(),
        avg * 100.0
    );
    println!("{}", slabforge::benchkit::table("optimization timings", &timings));
}
