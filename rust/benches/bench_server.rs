//! End-to-end TCP serving benchmarks (P3 in DESIGN.md §4): pipelined
//! set throughput, request/response get throughput and latency
//! percentiles, multi-connection scaling — the numbers `live_retune`
//! reports, measured rigorously.
//!
//! ```bash
//! cargo bench --bench bench_server
//! ```

use slabforge::benchkit::{bench, table, write_json, BenchOpts, Summary};
use slabforge::client::Client;
use slabforge::config::settings::{Algorithm, Backend, OptimizerSettings};
use slabforge::optimizer::autotune::AutoTuner;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::server::{Server, ServerHandle};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use slabforge::store::{spawn_maintainer, MaintainerConfig};
use slabforge::util::fmt::human_duration;
use slabforge::util::rng::Pcg64;
use slabforge::workload::gen::value_len_for_total;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

const N_SET: usize = 50_000;
const N_GET: usize = 20_000;

/// `SLABFORGE_BENCH_SMOKE=1` shrinks the workload so CI can execute the
/// full scenario matrix (including the 256-connection sweep) in seconds.
fn smoke() -> bool {
    std::env::var("SLABFORGE_BENCH_SMOKE").map_or(false, |v| v != "0")
}

fn start_server() -> (ServerHandle, Arc<ShardedStore>) {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            256 << 20,
            true,
            4,
            Clock::System,
        )
        .unwrap(),
    );
    let h = Server::new(store.clone())
        .max_conns(4096)
        .start("127.0.0.1:0")
        .unwrap();
    (h, store)
}

fn main() {
    let (n_set, n_get, iters) = if smoke() {
        (5_000, 2_000, 2)
    } else {
        (N_SET, N_GET, 5)
    };
    let (handle, store) = start_server();
    let addr = handle.addr();
    let mut rows: Vec<Summary> = Vec::new();

    let mut rng = Pcg64::new(3);
    let values: Vec<Vec<u8>> = (0..n_set)
        .map(|_| {
            let t = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16_000);
            vec![b'x'; value_len_for_total(t, true).unwrap()]
        })
        .collect();

    // ---- pipelined sets (noreply) ---------------------------------------
    let mut c = Client::connect(addr).unwrap();
    rows.push(bench(
        "tcp set noreply pipeline",
        &BenchOpts {
            warmup: 1,
            iters,
            units_per_iter: n_set as f64,
        },
        || {
            for (i, v) in values.iter().enumerate() {
                c.set_noreply(&format!("k{i:08}"), v, 0, 0).unwrap();
            }
            c.version().unwrap(); // drain
        },
    ));

    // ---- request/response gets ------------------------------------------
    let mut lat = Vec::with_capacity(n_get);
    rows.push(bench(
        "tcp get roundtrip",
        &BenchOpts {
            warmup: 1,
            iters,
            units_per_iter: n_get as f64,
        },
        || {
            lat.clear();
            let mut rng = Pcg64::new(4);
            for _ in 0..n_get {
                let key = format!("k{:08}", rng.gen_range(n_set as u64));
                let t = Instant::now();
                assert!(c.get(&key).unwrap().is_some());
                lat.push(t.elapsed());
            }
        },
    ));
    lat.sort_unstable();
    println!(
        "get latency: p50 {}  p95 {}  p99 {}",
        human_duration(lat[lat.len() / 2]),
        human_duration(lat[lat.len() * 95 / 100]),
        human_duration(lat[lat.len() * 99 / 100]),
    );

    // ---- deeply pipelined gets --------------------------------------------
    // many get lines per socket write: exercises the cursor receive
    // buffer (no per-command memmove) and the zero-copy response path
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        const DEPTH: usize = 64;
        let mut resp = vec![0u8; 256 * 1024];
        rows.push(bench(
            "tcp get pipeline x64",
            &BenchOpts {
                warmup: 1,
                iters,
                units_per_iter: (n_get / DEPTH * DEPTH) as f64,
            },
            || {
                let mut rng = Pcg64::new(6);
                let mut req = Vec::with_capacity(DEPTH * 24);
                for _ in 0..n_get / DEPTH {
                    req.clear();
                    for _ in 0..DEPTH {
                        req.extend_from_slice(
                            format!("get k{:08}\r\n", rng.gen_range(n_set as u64)).as_bytes(),
                        );
                    }
                    s.write_all(&req).unwrap();
                    // drain until all DEPTH responses ended; count the
                    // "END\r\n" markers with a 4-byte chunk overlap
                    let mut ends = 0usize;
                    let mut carry = [0u8; 4];
                    let mut carry_len = 0usize;
                    while ends < DEPTH {
                        let n = s.read(&mut resp).unwrap();
                        assert!(n > 0, "server closed mid-pipeline");
                        let mut window = Vec::with_capacity(carry_len + n);
                        window.extend_from_slice(&carry[..carry_len]);
                        window.extend_from_slice(&resp[..n]);
                        ends += window.windows(5).filter(|w| *w == b"END\r\n").count();
                        let keep = window.len().min(4);
                        carry[..keep].copy_from_slice(&window[window.len() - keep..]);
                        carry_len = keep;
                    }
                }
            },
        ));
    }

    // ---- multi-get batches ------------------------------------------------
    rows.push(bench(
        "tcp multi-get x16",
        &BenchOpts {
            warmup: 1,
            iters,
            units_per_iter: (n_get / 16 * 16) as f64,
        },
        || {
            let mut rng = Pcg64::new(5);
            for _ in 0..n_get / 16 {
                let keys: Vec<String> = (0..16)
                    .map(|_| format!("k{:08}", rng.gen_range(n_set as u64)))
                    .collect();
                let refs: Vec<&str> = keys.iter().map(String::as_str).collect();
                let m = c.get_multi(&refs, false).unwrap();
                assert!(!m.is_empty());
            }
        },
    ));

    // ---- meta quiet-miss pipeline (mg ... q + mn barrier) ------------------
    // The meta dialect's signature workload: deep pipelines of quiet
    // gets where misses produce NO response bytes at all, terminated by
    // an mn barrier. Half the keys miss, so the reactor serves a
    // response stream much smaller than the request stream — a shape
    // the classic dialect cannot express (every classic get answers).
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        const DEPTH: usize = 64;
        let mut resp = vec![0u8; 256 * 1024];
        rows.push(
            bench(
                "meta mg quiet pipeline x64",
                &BenchOpts {
                    warmup: 1,
                    iters,
                    units_per_iter: (n_get / DEPTH * DEPTH) as f64,
                },
                || {
                    let mut rng = Pcg64::new(7);
                    let mut req = Vec::with_capacity(DEPTH * 32);
                    for _ in 0..n_get / DEPTH {
                        req.clear();
                        for _ in 0..DEPTH {
                            // ~50% misses: the "m" prefix never collides
                            // with the seeded k-keys
                            let id = rng.gen_range(n_set as u64);
                            if rng.chance(0.5) {
                                req.extend_from_slice(
                                    format!("mg k{id:08} v q\r\n").as_bytes(),
                                );
                            } else {
                                req.extend_from_slice(
                                    format!("mg m{id:08} v q\r\n").as_bytes(),
                                );
                            }
                        }
                        req.extend_from_slice(b"mn\r\n");
                        s.write_all(&req).unwrap();
                        // drain until the barrier: quiet misses emit
                        // nothing, so MN is the only completion signal
                        let mut done = false;
                        let mut carry = [0u8; 3];
                        let mut carry_len = 0usize;
                        while !done {
                            let n = s.read(&mut resp).unwrap();
                            assert!(n > 0, "server closed mid-pipeline");
                            let mut window = Vec::with_capacity(carry_len + n);
                            window.extend_from_slice(&carry[..carry_len]);
                            window.extend_from_slice(&resp[..n]);
                            done = window.windows(4).any(|w| w == b"MN\r\n");
                            let keep = window.len().min(3);
                            carry[..keep].copy_from_slice(&window[window.len() - keep..]);
                            carry_len = keep;
                        }
                    }
                },
            )
            .with_dim("meta_pipeline", DEPTH as f64),
        );
    }

    // ---- connection scaling -----------------------------------------------
    for conns in [1usize, 4, 8] {
        let per = n_get / conns;
        rows.push(
            bench(
                &format!("tcp get {conns} conns"),
                &BenchOpts {
                    warmup: 1,
                    iters: iters.min(3),
                    units_per_iter: (per * conns) as f64,
                },
                || {
                    let threads: Vec<_> = (0..conns)
                        .map(|t| {
                            std::thread::spawn(move || {
                                let mut c = Client::connect(addr).unwrap();
                                let mut rng = Pcg64::new(10 + t as u64);
                                for _ in 0..per {
                                    let key =
                                        format!("k{:08}", rng.gen_range(n_set as u64));
                                    c.get(&key).unwrap();
                                }
                            })
                        })
                        .collect();
                    for t in threads {
                        t.join().unwrap();
                    }
                },
            )
            .with_dim("connections", conns as f64),
        );
    }

    // ---- many-connection pipelined gets (reactor scaling) -----------------
    // 256 concurrent sockets, a handful of reactor threads: each round
    // writes a DEPTH-deep get pipeline to every socket, then drains all
    // responses. This is the scenario thread-per-connection cannot
    // reach (256 idle-heavy threads) and the epoll reactor is built for.
    {
        use std::io::{Read, Write};
        const CONNS: usize = 256;
        const DEPTH: usize = 8;
        let rounds = (n_get / (CONNS * DEPTH)).max(1);
        let mut socks: Vec<std::net::TcpStream> = (0..CONNS)
            .map(|_| {
                let s = std::net::TcpStream::connect(addr).unwrap();
                s.set_nodelay(true).unwrap();
                s
            })
            .collect();
        let mut resp = vec![0u8; 64 * 1024];
        rows.push(
            bench(
                &format!("tcp get pipeline {CONNS} conns"),
                &BenchOpts {
                    warmup: 1,
                    iters: iters.min(3),
                    units_per_iter: (rounds * CONNS * DEPTH) as f64,
                },
                || {
                    let mut rng = Pcg64::new(12);
                    let mut req = Vec::with_capacity(DEPTH * 24);
                    for _ in 0..rounds {
                        for s in socks.iter_mut() {
                            req.clear();
                            for _ in 0..DEPTH {
                                req.extend_from_slice(
                                    format!("get k{:08}\r\n", rng.gen_range(n_set as u64))
                                        .as_bytes(),
                                );
                            }
                            s.write_all(&req).unwrap();
                        }
                        for s in socks.iter_mut() {
                            let mut ends = 0usize;
                            let mut carry = [0u8; 4];
                            let mut carry_len = 0usize;
                            while ends < DEPTH {
                                let n = s.read(&mut resp).unwrap();
                                assert!(n > 0, "server closed mid-pipeline");
                                let mut window = Vec::with_capacity(carry_len + n);
                                window.extend_from_slice(&carry[..carry_len]);
                                window.extend_from_slice(&resp[..n]);
                                ends +=
                                    window.windows(5).filter(|w| *w == b"END\r\n").count();
                                let keep = window.len().min(4);
                                carry[..keep].copy_from_slice(&window[window.len() - keep..]);
                                carry_len = keep;
                            }
                        }
                    }
                },
            )
            .with_dim("connections", CONNS as f64),
        );
    }

    // ---- reconfigure under load (incremental migration) -------------------
    // While this connection hammers gets, a live slab migration drains
    // every shard in bounded steps from a background thread (the same
    // shape the auto-tuner uses). `reconfig_stall_us` records the worst
    // response gap the client saw mid-drain — the paper's central
    // reconfiguration operation, now bounded-pause instead of
    // stop-the-world.
    {
        // kick off before spawning the driver so the measurement loop
        // is guaranteed to observe the drain in flight
        store.set_migrate_batch(256);
        store
            .begin_reconfigure(ChunkSizePolicy::Explicit(vec![
                464, 505, 543, 584, 636, 728, 944, 1424, 2912, 5840, 11664,
            ]))
            .expect("kick off migration");
        let drv = store.clone();
        let driver = std::thread::spawn(move || {
            while drv.migration_step_all() {
                std::thread::yield_now();
            }
        });
        let mut rng = Pcg64::new(21);
        let t0 = Instant::now();
        let mut last = Instant::now();
        let mut max_gap = std::time::Duration::ZERO;
        let mut ops = 0usize;
        while store.migration_active() || ops == 0 {
            let key = format!("k{:08}", rng.gen_range(n_set as u64));
            c.get(&key).unwrap();
            let now = Instant::now();
            max_gap = max_gap.max(now.duration_since(last));
            last = now;
            ops += 1;
        }
        driver.join().unwrap();
        let gauges = store.migration_gauges();
        println!(
            "reconfigure under load: {} gets during drain, max stall {}µs, {} items migrated",
            ops,
            max_gap.as_micros(),
            gauges.moved
        );
        rows.push(
            Summary::from_samples(
                "tcp get during reconfigure",
                vec![t0.elapsed()],
                ops as f64,
            )
            .with_dim("reconfig_stall_us", max_gap.as_micros() as f64)
            .with_dim("items_migrated", gauges.moved as f64),
        );
    }

    // ---- set storm at full memory + async optimize -------------------------
    // A dedicated small server filled past capacity: every set evicts,
    // the background maintainer owns the LRU demotion work, and an
    // async `slabs optimize` (OPTIMIZING immediately, drain pumped by
    // the tuner thread) runs under the storm. `set_p99_us` is the
    // steady-state eviction-path set latency; `optimize_stall_us` is
    // the worst per-set gap the client saw while the optimize pass and
    // its drain ran — the cost the issuing connection used to pay in
    // full, now spread invisibly across the background threads.
    {
        let storm_store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                64 << 10, // small pages so every engaged class has some
                2 << 20,  // 2 MiB: the keyspace oversubscribes it ~2-10x
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let collector = Arc::new(SizeCollector::default());
        storm_store.set_observer(collector.clone());
        let tuner = AutoTuner::new(
            storm_store.clone(),
            collector,
            OptimizerSettings {
                enabled: true,
                min_samples: 500,
                min_improvement: 0.0,
                algorithm: Algorithm::SteepestDescent,
                backend: Backend::Rust,
                ..Default::default()
            },
            64 << 10,
        )
        .unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let tuner_thread = tuner.spawn(stop.clone());
        let maint_thread = spawn_maintainer(
            storm_store.clone(),
            MaintainerConfig {
                // the tuner thread is the designated migration driver
                pump_migration: false,
                ..MaintainerConfig::default()
            },
            stop.clone(),
        );
        let storm_handle = Server::with_control(storm_store.clone(), tuner.clone())
            .start("127.0.0.1:0")
            .unwrap();
        let storm_addr = storm_handle.addr();
        let mut sc = Client::connect(storm_addr).unwrap();

        let n_storm = if smoke() { 6_000 } else { 40_000 };
        let keyspace = n_storm as u64; // every set a distinct key: ~2-10x memory

        let mut rng = Pcg64::new(31);
        let storm_val = |rng: &mut Pcg64| {
            let t = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 8_000);
            vec![b'x'; value_len_for_total(t, true).unwrap()]
        };
        // phase 1: fill past capacity and measure per-set latency
        let mut lats = Vec::with_capacity(n_storm);
        let t0 = Instant::now();
        for i in 0..n_storm {
            let v = storm_val(&mut rng);
            let key = format!("s{:07}", (i as u64) % keyspace);
            let t = Instant::now();
            // OutOfMemory is legal early on (fresh class, no page, no
            // victim); the storm keeps pounding
            let _ = sc.set(&key, &v, 0, 0);
            lats.push(t.elapsed());
        }
        let storm_elapsed = t0.elapsed();
        lats.sort_unstable();
        let p99 = lats[lats.len() * 99 / 100];
        let evictions = storm_store.stats().evictions;
        assert!(evictions > 0, "storm must run at full memory");

        // phase 2: async optimize under continued storm
        let msg = sc.slabs_optimize().unwrap();
        assert!(msg.starts_with("OPTIMIZING"), "{msg}");
        let mut max_gap = std::time::Duration::ZERO;
        let mut last = Instant::now();
        let mut ops = 0usize;
        loop {
            let v = storm_val(&mut rng);
            let key = format!("s{:07}", rng.gen_range(keyspace));
            let _ = sc.set(&key, &v, 0, 0);
            let now = Instant::now();
            max_gap = max_gap.max(now.duration_since(last));
            last = now;
            ops += 1;
            if ops % 64 == 0 {
                let slabs = sc.stats(Some("slabs")).unwrap();
                if slabs["optimize_pending"] == "0"
                    && slabs["optimize_runs"] != "0"
                    && slabs["migration_active"] == "0"
                {
                    break;
                }
            }
        }
        println!(
            "set storm: p99 {}  evictions {}  optimize stall {}µs over {} sets",
            human_duration(p99),
            evictions,
            max_gap.as_micros(),
            ops
        );
        rows.push(
            Summary::from_samples(
                "set storm at full memory",
                vec![storm_elapsed],
                n_storm as f64,
            )
            .with_dim("set_p99_us", p99.as_micros() as f64)
            .with_dim("optimize_stall_us", max_gap.as_micros() as f64),
        );
        stop.store(true, Ordering::SeqCst);
        tuner_thread.join().unwrap();
        maint_thread.join().unwrap();
        storm_handle.shutdown();
    }

    // ---- stalled readers at the buffer budget (overload shedding) ----------
    // A dedicated server with a small global connection-buffer budget
    // (`memory.conn_buffer_budget`). Stalled readers pipeline
    // large-value gets and never read a byte: their pending output
    // accumulates until the reactors shed them and the gauge falls back
    // under budget — while a healthy connection keeps doing small gets
    // the whole time. `shed_connections` is how many victims the budget
    // claimed; `degraded_get_p99_us` is the healthy connection's get
    // p99 while the storm was in flight (the price of degradation,
    // which must stay a latency tax and never a hang).
    {
        use std::io::Write;
        let shed_store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                64 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let budget = 128 << 10;
        let shed_handle = Server::new(shed_store.clone())
            .conn_buffer_budget(budget)
            .start("127.0.0.1:0")
            .unwrap();
        let shed_addr = shed_handle.addr();
        // healthy conn first: accepts pause while the gauge is over
        // budget, so late connections could wait out the storm
        let mut hc = Client::connect(shed_addr).unwrap();
        // 64 KiB value: big enough to clog a stalled socket fast, small
        // enough that the healthy conn's own responses stay under budget
        hc.set("big", &vec![b'B'; 64 << 10], 0, 0).unwrap();
        for i in 0..256 {
            hc.set(&format!("h{i:03}"), &vec![b'h'; 300], 0, 0).unwrap();
        }

        let n_stalled = 4usize;
        let stalled: Vec<std::net::TcpStream> = (0..n_stalled)
            .map(|_| {
                let mut s = std::net::TcpStream::connect(shed_addr).unwrap();
                // 400 × 64 KiB demanded ≫ kernel buffering: pending
                // output must pile up far past the budget
                s.write_all("get big\r\n".repeat(400).as_bytes()).unwrap();
                s
            })
            .collect();

        let mut rng = Pcg64::new(41);
        let mut lats = Vec::with_capacity(8_192);
        let cap = if smoke() { 10_000 } else { 50_000 };
        let t0 = Instant::now();
        let mut ops = 0usize;
        let shed_seen = loop {
            let key = format!("h{:03}", rng.gen_range(256));
            let t = Instant::now();
            assert!(hc.get(&key).unwrap().is_some());
            lats.push(t.elapsed());
            ops += 1;
            let shed = shed_handle.metrics.shed_connections.load(Ordering::Relaxed);
            // keep measuring a little past the first shed so the p99
            // covers the whole degraded window, not just its onset
            if shed > 0 && ops >= 2_000 {
                break shed;
            }
            if ops >= cap {
                break shed;
            }
        };
        let elapsed = t0.elapsed();
        assert!(shed_seen > 0, "budget never shed a stalled reader");
        lats.sort_unstable();
        let p99 = lats[lats.len() * 99 / 100];
        println!(
            "stalled readers at budget: {} shed, healthy get p99 {} over {} gets",
            shed_seen,
            human_duration(p99),
            ops
        );
        rows.push(
            Summary::from_samples("stalled readers at budget", vec![elapsed], ops as f64)
                .with_dim("shed_connections", shed_seen as f64)
                .with_dim("degraded_get_p99_us", p99.as_micros() as f64),
        );
        drop(stalled);
        shed_handle.shutdown();
    }

    // ---- hot-shard read scalability (optimistic seqlock gets) --------------
    // A single-shard store: every reader thread probes the same seqlock
    // stripes and bucket array, so sharding cannot spread the load and
    // the curve isolates how the lock-free get path itself scales.
    // Uncontended rows carry `hot_shard_get_mops`; rows with a
    // concurrent writer hammering the same 256 keys carry
    // `get_p99_contended_us` — the reader-visible cost of seqlock
    // retries and locked-path fallbacks under real write traffic.
    {
        use slabforge::store::sharded::ReadAttempt;
        use slabforge::store::store::ValueRef;
        let hot = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                32 << 20,
                true,
                1,
                Clock::System,
            )
            .unwrap(),
        );
        const HOT_KEYS: u64 = 256;
        for i in 0..HOT_KEYS {
            hot.set(format!("hot{i:03}").as_bytes(), &vec![b'h'; 400], 0, 0)
                .unwrap();
        }
        let per_reader = if smoke() { 20_000usize } else { 200_000 };
        let counts: &[usize] = if smoke() { &[1, 4] } else { &[1, 2, 4, 8, 16, 32] };
        for &n_readers in counts {
            for with_writer in [false, true] {
                let stop = Arc::new(AtomicBool::new(false));
                let writer = with_writer.then(|| {
                    let s = hot.clone();
                    let stop = stop.clone();
                    std::thread::spawn(move || {
                        let mut rng = Pcg64::new(97);
                        let v = vec![b'w'; 400];
                        while !stop.load(Ordering::Relaxed) {
                            let k = format!("hot{:03}", rng.gen_range(HOT_KEYS));
                            s.set(k.as_bytes(), &v, 0, 0).unwrap();
                        }
                    })
                });
                let t0 = Instant::now();
                let threads: Vec<_> = (0..n_readers)
                    .map(|r| {
                        let s = hot.clone();
                        std::thread::spawn(move || {
                            let mut rng = Pcg64::new(50 + r as u64);
                            let mut buf: Vec<u8> = Vec::with_capacity(512);
                            // reader 0 samples per-op latency for the p99
                            let mut lats: Vec<std::time::Duration> =
                                Vec::with_capacity(if r == 0 { per_reader } else { 0 });
                            for _ in 0..per_reader {
                                let k = format!("hot{:03}", rng.gen_range(HOT_KEYS));
                                let t = (r == 0).then(Instant::now);
                                buf.clear();
                                match s.get_optimistic(
                                    k.as_bytes(),
                                    &mut buf,
                                    |c| c.clear(),
                                    |c, v: ValueRef<'_>| c.extend_from_slice(v.data),
                                ) {
                                    ReadAttempt::Hit(()) => debug_assert_eq!(buf.len(), 400),
                                    ReadAttempt::Miss => {}
                                    ReadAttempt::Fallback => {
                                        s.get_with(k.as_bytes(), |_: ValueRef<'_>| ());
                                    }
                                }
                                if let Some(t) = t {
                                    lats.push(t.elapsed());
                                }
                            }
                            lats
                        })
                    })
                    .collect();
                let mut lats: Vec<std::time::Duration> = threads
                    .into_iter()
                    .flat_map(|t| t.join().unwrap())
                    .collect();
                let elapsed = t0.elapsed();
                stop.store(true, Ordering::Relaxed);
                if let Some(w) = writer {
                    w.join().unwrap();
                }
                let total_ops = n_readers * per_reader;
                let mops = total_ops as f64 / elapsed.as_secs_f64() / 1e6;
                lats.sort_unstable();
                let p99 = lats[lats.len() * 99 / 100];
                let tag = if with_writer { "+writer" } else { "no writer" };
                println!(
                    "hot shard {n_readers:2} readers {tag}: {mops:.2} Mops/s, reader-0 p99 {}",
                    human_duration(p99)
                );
                let row = Summary::from_samples(
                    &format!("hot shard get {n_readers} readers {tag}"),
                    vec![elapsed],
                    total_ops as f64,
                )
                .with_dim("readers", n_readers as f64);
                rows.push(if with_writer {
                    row.with_dim("get_p99_contended_us", p99.as_micros() as f64)
                } else {
                    row.with_dim("hot_shard_get_mops", mops)
                });
            }
        }
        let st = hot.stats();
        println!(
            "hot shard totals: {} retries, {} fallbacks, {} bumps queued / {} dropped",
            st.seqlock_retries, st.seqlock_fallbacks, st.lru_bump_queued, st.lru_bump_dropped
        );
    }

    // ---- accept burst (kernel-distributed SO_REUSEPORT listeners) ----------
    // Connection churn: every op is a fresh connect + one roundtrip +
    // close. With per-reactor reuseport listeners the kernel spreads the
    // accept load; the old layout funneled every accept through one
    // thread and an eventfd hop.
    {
        let n_conns = if smoke() { 128usize } else { 1024 };
        let burst_threads = 4usize;
        let per = n_conns / burst_threads;
        let t0 = Instant::now();
        let threads: Vec<_> = (0..burst_threads)
            .map(|_| {
                std::thread::spawn(move || {
                    for _ in 0..per {
                        let mut c = Client::connect(addr).unwrap();
                        c.version().unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let elapsed = t0.elapsed();
        let total = (burst_threads * per) as f64;
        let rate = total / elapsed.as_secs_f64();
        let accepts = handle.accept_counts();
        println!(
            "accept burst: {total:.0} connect+version roundtrips at {rate:.0} conns/s \
             (reuseport={}, per-reactor accepts {accepts:?})",
            handle.reuseport()
        );
        rows.push(
            Summary::from_samples("accept burst connect+version", vec![elapsed], total)
                .with_dim("accept_rate_conns_s", rate),
        );
    }

    // ---- udp get throughput (datagram front-end, same Request IR) ----------
    #[cfg(target_os = "linux")]
    {
        use slabforge::server::udp::{encode_header, parse_header, HEADER_LEN};
        let udp_store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                64 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let udp_handle = Server::new(udp_store.clone())
            .udp(true)
            .start("127.0.0.1:0")
            .unwrap();
        let ua = udp_handle.addr();
        let n_keys = 1024u64;
        {
            let mut seed = Client::connect(ua).unwrap();
            for i in 0..n_keys {
                seed.set_noreply(&format!("u{i:04}"), &vec![b'u'; 100], 0, 0)
                    .unwrap();
            }
            seed.version().unwrap(); // drain
        }
        let sock = std::net::UdpSocket::bind("127.0.0.1:0").unwrap();
        sock.connect(ua).unwrap();
        sock.set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        let n_udp = if smoke() { 2_000usize } else { 20_000 };
        let mut rng = Pcg64::new(61);
        let mut id = 1u16;
        let mut req = Vec::with_capacity(64);
        let mut buf = [0u8; 2048];
        let t0 = Instant::now();
        for _ in 0..n_udp {
            id = id.wrapping_add(1);
            req.clear();
            req.resize(HEADER_LEN, 0);
            encode_header(&mut req, id, 0, 1);
            req.extend_from_slice(
                format!("get u{:04}\r\n", rng.gen_range(n_keys)).as_bytes(),
            );
            sock.send(&req).unwrap();
            loop {
                let n = sock.recv(&mut buf).unwrap();
                let h = parse_header(&buf[..n]).unwrap();
                if h.request_id == id {
                    assert!(buf[HEADER_LEN..n].starts_with(b"VALUE "));
                    break;
                }
            }
        }
        let elapsed = t0.elapsed();
        let kops = n_udp as f64 / elapsed.as_secs_f64() / 1e3;
        println!(
            "udp get roundtrip: {n_udp} single-datagram gets at {kops:.1} kops/s \
             (rx {} / tx {} datagrams)",
            udp_handle.metrics.udp_datagrams_rx.load(Ordering::Relaxed),
            udp_handle.metrics.udp_datagrams_tx.load(Ordering::Relaxed),
        );
        rows.push(
            Summary::from_samples("udp get roundtrip", vec![elapsed], n_udp as f64)
                .with_dim("udp_get_kops", kops),
        );
        udp_handle.shutdown();
    }

    // ---- multi-tenant isolation: global vs per-tenant learner --------------
    // Two tenants with sharply diverged size distributions share one
    // memory-constrained server: tenant `a:` rewrites a small hot set of
    // ~200 B items, tenant `b:` churns ~4 KiB items with mostly-recent
    // reads. The phases run the identical end-to-end workload (full
    // protocol path, so attribution happens in the connection layer);
    // the only difference is whether tenants are defined — defined
    // tenants get per-tenant histograms, the divergence-gated merged
    // geometry, and need-based arbitration through the maintainer.
    // `tenant_agg_hit_rate` / `tenant_hole_bytes` vs the baseline
    // `global_*` dims are the headline comparison.
    {
        fn tenant_phase(n_rounds: usize, per_tenant: bool) -> (f64, u64, std::time::Duration, usize) {
            let store = Arc::new(
                ShardedStore::with(
                    ChunkSizePolicy::default(),
                    64 << 10, // small pages: every engaged class has some
                    4 << 20,  // 4 MiB: tenant B's churn oversubscribes it
                    true,
                    2,
                    Clock::System,
                )
                .unwrap(),
            );
            let collector = Arc::new(SizeCollector::default());
            store.set_observer(collector.clone());
            if per_tenant {
                let reg = store.tenants();
                reg.define("small", b"a:", None).unwrap();
                reg.define("large", b"b:", None).unwrap();
            }
            let tuner = AutoTuner::new(
                store.clone(),
                collector,
                OptimizerSettings {
                    enabled: true,
                    min_samples: 500,
                    min_improvement: 0.0,
                    algorithm: Algorithm::SteepestDescent,
                    backend: Backend::Rust,
                    ..Default::default()
                },
                64 << 10,
            )
            .unwrap();
            let stop = Arc::new(AtomicBool::new(false));
            let tuner_thread = tuner.spawn(stop.clone());
            let maint_thread = spawn_maintainer(
                store.clone(),
                MaintainerConfig {
                    // the tuner thread is the designated migration driver
                    pump_migration: false,
                    ..MaintainerConfig::default()
                },
                stop.clone(),
            );
            let handle = Server::with_control(store.clone(), tuner.clone())
                .start("127.0.0.1:0")
                .unwrap();
            let mut c = Client::connect(handle.addr()).unwrap();

            let mut rng = Pcg64::new(71);
            let mut churn = 0u64;
            let (mut gets, mut hits) = (0usize, 0usize);
            let t0 = Instant::now();
            for i in 0..n_rounds {
                if i == n_rounds / 2 {
                    // both phases learn mid-stream; the per-tenant phase's
                    // pass sees diverged tenant histograms and may adopt
                    // the merged geometry
                    let msg = c.slabs_optimize().unwrap();
                    assert!(msg.starts_with("OPTIMIZING"), "{msg}");
                }
                let measuring = i >= n_rounds / 2;
                // tenant A: small hot set, continuously rewritten
                let t = (rng.lognormal(210.0, 0.08).round() as usize).clamp(120, 400);
                let ka = format!("a:h{:03}", rng.gen_range(256));
                let _ = c.set(&ka, &vec![b'a'; value_len_for_total(t, true).unwrap()], 0, 0);
                // tenant B: large churning values
                let t = (rng.lognormal(4200.0, 0.12).round() as usize).clamp(2000, 8000);
                churn += 1;
                let kb = format!("b:c{churn:07}");
                let _ = c.set(&kb, &vec![b'b'; value_len_for_total(t, true).unwrap()], 0, 0);
                // reads: A hammers its hot set, B reads recent keys
                for _ in 0..3 {
                    let k = format!("a:h{:03}", rng.gen_range(256));
                    let hit = c.get(&k).unwrap().is_some();
                    if measuring {
                        gets += 1;
                        hits += usize::from(hit);
                    }
                }
                let back = rng.gen_range(64).min(churn - 1);
                let k = format!("b:c{:07}", churn - back);
                let hit = c.get(&k).unwrap().is_some();
                if measuring {
                    gets += 1;
                    hits += usize::from(hit);
                }
            }
            let elapsed = t0.elapsed();
            // settle: the pass must run and its drain must finish
            // before holes reflect the learned geometry
            let deadline = Instant::now() + std::time::Duration::from_secs(30);
            loop {
                let st = c.stats(Some("slabs")).unwrap();
                if st["optimize_pending"] == "0"
                    && st["optimize_runs"] != "0"
                    && st["migration_active"] == "0"
                {
                    break;
                }
                assert!(Instant::now() < deadline, "tenant-phase optimize never settled");
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            let holes = store.slab_stats().hole_bytes;
            stop.store(true, Ordering::SeqCst);
            tuner_thread.join().unwrap();
            maint_thread.join().unwrap();
            handle.shutdown();
            (hits as f64 / gets.max(1) as f64, holes, elapsed, gets)
        }

        let n_rounds = if smoke() { 1_500 } else { 6_000 };
        let (g_rate, g_holes, g_elapsed, g_ops) = tenant_phase(n_rounds, false);
        let (t_rate, t_holes, t_elapsed, t_ops) = tenant_phase(n_rounds, true);
        println!(
            "tenant isolation: global learner hit rate {:.3} / {} hole bytes, \
             per-tenant hit rate {:.3} / {} hole bytes",
            g_rate, g_holes, t_rate, t_holes
        );
        rows.push(
            Summary::from_samples("tenant mix global learner", vec![g_elapsed], g_ops as f64)
                .with_dim("global_agg_hit_rate", g_rate)
                .with_dim("global_hole_bytes", g_holes as f64),
        );
        rows.push(
            Summary::from_samples("tenant mix per-tenant learner", vec![t_elapsed], t_ops as f64)
                .with_dim("tenant_agg_hit_rate", t_rate)
                .with_dim("tenant_hole_bytes", t_holes as f64),
        );
    }

    // ---- warm restart (mmap memory file + manifest recovery) ---------------
    // Fill a persistence-enabled store, write the shutdown manifest, drop
    // it, and time the next boot's metadata-only recovery. `restart_warm_ms`
    // is the full open_or_cold wall time (manifest parse, integrity walk,
    // page adoption, item re-link) — zero value bytes are copied.
    #[cfg(unix)]
    {
        use slabforge::config::settings::Settings;
        let n_items = if smoke() { 5_000usize } else { 50_000 };
        let path = std::env::temp_dir().join(format!(
            "slabforge-bench-restart-{}.mem",
            std::process::id()
        ));
        let cleanup = |p: &std::path::Path| {
            for suffix in ["", ".meta", ".dirty"] {
                let mut f = p.as_os_str().to_os_string();
                f.push(suffix);
                let _ = std::fs::remove_file(std::path::PathBuf::from(f));
            }
        };
        cleanup(&path);
        let settings = Settings {
            memory_file: Some(path.display().to_string()),
            mem_limit: if smoke() { 32 << 20 } else { 256 << 20 },
            shards: 4,
            ..Settings::default()
        };
        let (cold_store, report) = slabforge::store::open_or_cold(&settings).unwrap();
        assert_eq!(report.state, "cold", "fresh memory file boots cold");
        let mut rng = Pcg64::new(81);
        for i in 0..n_items {
            let t = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16_000);
            let v = vec![b'r'; value_len_for_total(t, true).unwrap()];
            cold_store
                .set(format!("r{i:07}").as_bytes(), &v, 0, 0)
                .unwrap();
        }
        slabforge::store::write_manifest(&cold_store, &settings).unwrap();
        drop(cold_store);
        let t0 = Instant::now();
        let (warm_store, report) = slabforge::store::open_or_cold(&settings).unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(report.state, "warm", "{}", report.reason);
        assert_eq!(report.items_recovered, n_items as u64);
        assert!(warm_store.get(b"r0000000").is_some(), "recovered data must serve");
        println!(
            "warm restart: {} items recovered in {} ({} discarded)",
            report.items_recovered,
            human_duration(elapsed),
            report.items_discarded
        );
        rows.push(
            Summary::from_samples("warm restart recovery", vec![elapsed], n_items as f64)
                .with_dim("restart_warm_ms", elapsed.as_secs_f64() * 1e3)
                .with_dim("restart_items_recovered", report.items_recovered as f64),
        );
        drop(warm_store);
        cleanup(&path);
    }

    println!(
        "server saw {} commands total, {} items resident",
        handle.metrics.snapshot().commands,
        store.len()
    );
    println!("{}", table("TCP serving (loopback)", &rows));
    match write_json("BENCH_server.json", "TCP serving (loopback)", &rows) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_server.json: {e}"),
    }
    handle.shutdown();
}
