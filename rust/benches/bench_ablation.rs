//! Ablations D2–D6 (DESIGN.md §4):
//!
//! * D2/D3 — §6.1 best/worst cases (also `examples/worst_case.rs`)
//! * D4 — §6.3 convergence: restarts of Algorithm 1 land on the same
//!   minimum (the paper ran 100 restarts; default here 100, `--restarts N`)
//! * D5 — §6.4 σ influence: lower σ ⇒ larger savings
//! * D6 — §3 alternative: growth-factor tuning vs learned classes
//! * algorithm face-off — paper Algorithm 1 vs steepest vs DP optimum
//!
//! ```bash
//! cargo bench --bench bench_ablation
//! cargo bench --bench bench_ablation -- --restarts 100 --items 200000
//! ```

use slabforge::benchkit::paper::experiment_histogram;
use slabforge::benchkit::CsvWriter;
use slabforge::config::cli::Args;
use slabforge::config::settings::Algorithm;
use slabforge::optimizer::engine::{optimize, OptimizerParams, RustBackend};
use slabforge::optimizer::waste::WasteMap;
use slabforge::slab::geometry::default_slab_sizes;
use slabforge::slab::PAGE_SIZE;
use slabforge::util::histogram::SizeHistogram;
use slabforge::util::rng::Pcg64;
use slabforge::workload::spec::SizeDistribution;
use slabforge::workload::PAPER_EXPERIMENTS;

fn lognormal_hist(median: f64, sigma: f64, items: usize, seed: u64) -> SizeHistogram {
    let d = SizeDistribution::LogNormal {
        median,
        sigma_ln: sigma,
    };
    let mut rng = Pcg64::new(seed);
    let mut h = SizeHistogram::new(16384);
    for _ in 0..items {
        h.record(d.sample(&mut rng, 70, 16384));
    }
    h
}

fn run(hist: &SizeHistogram, current: &[usize], alg: Algorithm, seed: u64) -> (u64, u64, u64) {
    let backend = RustBackend::new(WasteMap::from_histogram(hist));
    let r = optimize(
        backend_ref(&backend),
        hist,
        current,
        &OptimizerParams {
            algorithm: alg,
            seed,
            ..Default::default()
        },
    );
    (r.old_waste, r.new_waste, r.evaluations)
}

// helper to keep the generic call readable
fn backend_ref(b: &RustBackend) -> &RustBackend {
    b
}

/// Fixed-memory pressure run for D7: T1 traffic with a 50 % get mix
/// into a deliberately undersized store (64 KiB pages so every class
/// can claim at least one page); returns (holes, hole fraction,
/// evictions, get hit rate).
fn pressure_run(learned_span: &[usize], ops: usize) -> (u64, f64, u64, f64) {
    use slabforge::slab::policy::ChunkSizePolicy;
    use slabforge::store::sharded::ShardedStore;
    use slabforge::store::store::{Clock, StoreError};
    use slabforge::workload::gen::value_len_for_total;

    // full table: learned span + page class appended by the policy
    let store = ShardedStore::with(
        ChunkSizePolicy::Explicit(learned_span.to_vec()),
        64 << 10,
        8 << 20, // 8 MiB: ~16k items of ~518 B -> heavy eviction
        true,
        1,
        Clock::System,
    )
    .unwrap();
    let mut rng = Pcg64::new(7);
    let d = PAPER_EXPERIMENTS[0].distribution();
    let mut next_key = 0usize;
    for _ in 0..ops {
        if next_key > 0 && rng.chance(0.5) {
            let k = rng.gen_range(next_key as u64);
            let _ = store.get(format!("k{k:07}").as_bytes());
        } else {
            let total = d.sample(&mut rng, 70, 16384);
            let vlen = value_len_for_total(total, true).unwrap();
            match store.set(format!("k{next_key:07}").as_bytes(), &vec![b'x'; vlen], 0, 0) {
                Ok(()) | Err(StoreError::OutOfMemory) => {}
                Err(e) => panic!("{e}"),
            }
            next_key += 1;
        }
    }
    let slabs = store.slab_stats();
    let ops_stats = store.stats();
    let hits = ops_stats.get_hits as f64;
    let gets = (ops_stats.get_hits + ops_stats.get_misses) as f64;
    (
        slabs.hole_bytes,
        slabs.hole_fraction(),
        ops_stats.evictions,
        if gets > 0.0 { hits / gets } else { 0.0 },
    )
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["bench"]).unwrap();
    let items: usize = args.flag_or("items", 100_000).unwrap();
    let restarts: usize = args.flag_or("restarts", 100).unwrap();
    let defaults = slabforge::slab::geometry::memcached_default_sizes();

    // ---------------------------------------------------------------- D4
    println!("## D4 — §6.3 convergence across {restarts} restarts (T1, Algorithm 1)");
    let hist = experiment_histogram(&PAPER_EXPERIMENTS[0], items, 77);
    let mut finals = std::collections::BTreeMap::<u64, usize>::new();
    for r in 0..restarts {
        let (_, new_waste, _) = run(&hist, &defaults, Algorithm::PaperHillClimb, 1000 + r as u64);
        *finals.entry(new_waste).or_insert(0) += 1;
    }
    let best = *finals.keys().next().unwrap();
    let worst = *finals.keys().last().unwrap();
    let spread = (worst - best) as f64 / best as f64;
    println!(
        "distinct final wastes: {} (best {best}, worst {worst}, spread {:.2}%)",
        finals.len(),
        spread * 100.0
    );
    println!(
        "paper claims convergence to one minimum; we observe spread {:.2}% — {}\n",
        spread * 100.0,
        if spread < 0.05 {
            "effectively one basin (supports the claim at ±5%)"
        } else {
            "MULTIPLE basins (refutes the global-minimum claim; see EXPERIMENTS.md)"
        }
    );

    // ---------------------------------------------------------------- D5
    println!("## D5 — §6.4 σ influence (μ=1210, varying σ_ln)");
    println!("| σ_ln | old waste | new waste | recovery |");
    println!("|---|---|---|---|");
    let mut csv = CsvWriter::new("results/sigma_sweep.csv", "sigma_ln,old_waste,new_waste,recovery");
    let mut last_recovery = f64::MAX;
    let mut monotone = true;
    for &sigma in &[0.02, 0.04, 0.08, 0.16, 0.32] {
        let h = lognormal_hist(1210.0, sigma, items, 88);
        let (old, new, _) = run(&h, &defaults, Algorithm::SteepestDescent, 5);
        let rec = 1.0 - new as f64 / old as f64;
        println!("| {sigma} | {old} | {new} | {:.2}% |", rec * 100.0);
        csv.row(&[
            sigma.to_string(),
            old.to_string(),
            new.to_string(),
            format!("{rec:.4}"),
        ]);
        if rec > last_recovery {
            monotone = false;
        }
        last_recovery = rec;
    }
    csv.finish().unwrap();
    println!(
        "paper: lower σ ⇒ larger savings — {}\n",
        if monotone { "CONFIRMED (monotone)" } else { "mostly holds (see rows)" }
    );

    // ---------------------------------------------------------------- D6
    println!("## D6 — §3 alternative: growth-factor tuning vs learned classes (T1)");
    println!("| configuration | classes in span | waste | vs default |");
    println!("|---|---|---|---|");
    let t1 = experiment_histogram(&PAPER_EXPERIMENTS[0], items, 99);
    let map = WasteMap::from_histogram(&t1);
    let default_cfg: Vec<u32> = defaults.iter().map(|&c| c as u32).collect();
    let base = map.waste_of(&default_cfg);
    for &factor in &[1.25, 1.15, 1.10, 1.05] {
        let sizes = default_slab_sizes(96, factor, PAGE_SIZE);
        let cfg: Vec<u32> = sizes.iter().map(|&c| c as u32).collect();
        let w = map.waste_of(&cfg);
        let span = sizes.iter().filter(|&&c| (300..=1000).contains(&c)).count();
        println!(
            "| factor {factor} | {span} | {w} | {:+.1}% |",
            (w as f64 / base as f64 - 1.0) * 100.0
        );
    }
    let (_, learned, _) = run(&t1, &defaults, Algorithm::SteepestDescent, 6);
    println!(
        "| LEARNED (same class count as default) | 6 | {learned} | {:+.1}% |",
        (learned as f64 / base as f64 - 1.0) * 100.0
    );
    println!("note: lower factors spend MORE classes for their savings; the learned\n\
              config wins at equal class count (the paper's §3 argument).\n");

    // ---------------------------------------------------------------- D7
    // The paper's §7 future work: "investigate the effect of increasing
    // the number of slab classes … weigh the increase in memory storage
    // efficacy against the deterioration of … eviction rates". We run a
    // fixed-memory store under pressure with DP-optimal configs of
    // K = 1..16 classes and measure both sides of the trade-off.
    println!("## D7 — §7 future work: class count vs waste vs eviction rate");
    println!("| K | waste (bytes) | hole frac | evictions | hit rate |");
    println!("|---|---|---|---|---|");
    let t1 = experiment_histogram(&PAPER_EXPERIMENTS[0], items, 123);
    let map7 = WasteMap::from_histogram(&t1);
    let mut csv7 = CsvWriter::new(
        "results/class_sweep.csv",
        "k,waste,hole_fraction,evictions,hit_rate",
    );
    for k in [1usize, 2, 4, 6, 8, 12, 16] {
        let dp = slabforge::optimizer::dp::dp_optimal(&map7, k);
        let sizes: Vec<usize> = dp.config.iter().map(|&c| c as usize).collect();
        let (holes, frac, evictions, hit_rate) = pressure_run(&sizes, items.min(60_000));
        println!(
            "| {k} | {holes} | {:.2}% | {evictions} | {:.2}% |",
            frac * 100.0,
            hit_rate * 100.0
        );
        csv7.row(&[
            k.to_string(),
            holes.to_string(),
            format!("{frac:.4}"),
            evictions.to_string(),
            format!("{hit_rate:.4}"),
        ]);
    }
    csv7.finish().unwrap();
    println!(
        "finding: waste falls steeply with K while eviction/hit-rate costs are\n\
         mild at this page:memory ratio (64 KiB pages / 8 MiB) — strongly\n\
         diminishing returns past K≈8. The §7 deterioration appears when pages\n\
         are large relative to memory (each extra class strands a page); rerun\n\
         with PAGE_SIZE pages to see it.\n"
    );

    // ---------------------------------------------------------- face-off
    println!("## Algorithm face-off (T1..T5, {items} items)");
    println!("| table | paper-alg1 waste (evals) | steepest waste (evals) | DP optimal waste |");
    println!("|---|---|---|---|");
    for e in &PAPER_EXPERIMENTS {
        let h = experiment_histogram(e, items, 300 + e.table as u64);
        let (_, w_p, e_p) = run(&h, &defaults, Algorithm::PaperHillClimb, 7);
        let (_, w_s, e_s) = run(&h, &defaults, Algorithm::SteepestDescent, 7);
        let (_, w_d, _) = run(&h, &defaults, Algorithm::DpOptimal, 7);
        println!("| T{} | {w_p} ({e_p}) | {w_s} ({e_s}) | {w_d} |", e.table);
        assert!(w_d <= w_p && w_d <= w_s, "DP must lower-bound greedy");
    }
    println!("\n(evals = objective evaluations; steepest needs far fewer, DP is the bound)");
}
