//! Optimizer micro/meso benchmarks (P1/P2 in DESIGN.md §4):
//! * waste-evaluation throughput, rust exact vs XLA artifact (batch 256)
//! * fused `hill_step` artifact vs unfused batched eval
//! * end-to-end convergence cost per algorithm (paper / steepest / DP)
//!
//! ```bash
//! cargo bench --bench bench_optimizer
//! ```

use slabforge::benchkit::paper::experiment_histogram;
use slabforge::benchkit::{bench, table, BenchOpts};
use slabforge::config::settings::Algorithm;
use slabforge::optimizer::engine::{optimize, OptimizerParams, RustBackend, WasteBackend};
use slabforge::optimizer::waste::{WasteMap, SENTINEL};
use slabforge::runtime::{XlaService, XlaWasteBackend};
use slabforge::util::rng::Pcg64;
use slabforge::workload::PAPER_EXPERIMENTS;
use std::path::Path;

fn main() {
    let e = &PAPER_EXPERIMENTS[0]; // T1 is the reference workload
    let hist = experiment_histogram(e, 200_000, 1);
    let rust = RustBackend::new(WasteMap::from_histogram(&hist));

    let mut rng = Pcg64::new(5);
    let batch: Vec<Vec<u32>> = (0..256)
        .map(|_| {
            let mut cfg: Vec<u32> = (0..6).map(|_| 300 + rng.gen_range(700) as u32).collect();
            cfg.sort_unstable();
            cfg
        })
        .collect();

    let mut rows = Vec::new();

    // ---- waste evaluation throughput -----------------------------------
    rows.push(bench(
        "waste eval rust x256",
        &BenchOpts {
            warmup: 3,
            iters: 30,
            units_per_iter: 256.0,
        },
        || {
            let w = rust.eval_batch(&batch);
            assert_eq!(w.len(), 256);
        },
    ));

    let svc = if Path::new("artifacts/manifest.json").exists() {
        Some(XlaService::start(Path::new("artifacts")).expect("artifacts"))
    } else {
        eprintln!("artifacts/ missing: skipping XLA rows");
        None
    };
    if let Some(svc) = &svc {
        let xla = XlaWasteBackend::new(svc, &hist);
        rows.push(bench(
            "waste eval xla  x256",
            &BenchOpts {
                warmup: 3,
                iters: 30,
                units_per_iter: 256.0,
            },
            || {
                let w = xla.eval_batch(&batch);
                assert_eq!(w.len(), 256);
            },
        ));

        // fused hill_step: expand+eval+argmin in ONE artifact call
        let man = svc.manifest().clone();
        let k = man.k_classes;
        let config: Vec<u32> = vec![304, 384, 480, 600, 752, 944];
        let mut deltas = vec![0.0f64; man.b_candidates * k];
        for c in 0..config.len() {
            deltas[(2 * c) * k + c] = 8.0;
            deltas[(2 * c + 1) * k + c] = -8.0;
        }
        rows.push(bench(
            "hill_step fused (1 call)",
            &BenchOpts {
                warmup: 3,
                iters: 30,
                units_per_iter: 256.0,
            },
            || {
                let (_, w, _) = xla.fused_hill_step(&config, &deltas).unwrap();
                assert!(w < SENTINEL * 1_000_000);
            },
        ));
    }

    // ---- single waste sweep cost (the inner loop primitive) ------------
    let map = WasteMap::from_histogram(&hist);
    let cfg = [304u32, 384, 480, 600, 752, 944];
    rows.push(bench(
        "waste sweep rust x1",
        &BenchOpts {
            warmup: 10,
            iters: 100,
            units_per_iter: 1.0,
        },
        || {
            std::hint::black_box(map.waste_of_sorted(&cfg));
        },
    ));

    // ---- full algorithm convergence -------------------------------------
    let current = slabforge::slab::geometry::memcached_default_sizes();
    for (name, alg) in [
        ("optimize paper-alg1", Algorithm::PaperHillClimb),
        ("optimize steepest", Algorithm::SteepestDescent),
        ("optimize dp-optimal", Algorithm::DpOptimal),
    ] {
        let mut evals = 0u64;
        rows.push(bench(
            name,
            &BenchOpts {
                warmup: 1,
                iters: 5,
                units_per_iter: 1.0,
            },
            || {
                let r = optimize(
                    &rust,
                    &hist,
                    &current,
                    &OptimizerParams {
                        algorithm: alg,
                        ..Default::default()
                    },
                );
                evals = r.evaluations;
                assert!(r.new_waste <= r.old_waste);
            },
        ));
        println!("{name}: {evals} evaluations/run");
    }

    println!("{}", table("optimizer benchmarks (T1, 200k items)", &rows));
}
