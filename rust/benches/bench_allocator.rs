//! Allocator + store hot-path benchmarks (P3 in DESIGN.md §4):
//! slab alloc/free, store set/get/delete, histogram collection
//! overhead, and live reconfiguration (migration) throughput.
//!
//! ```bash
//! cargo bench --bench bench_allocator
//! ```

use slabforge::benchkit::{bench, table, BenchOpts};
use slabforge::optimizer::collector::SizeCollector;
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::{SlabAllocator, PAGE_SIZE};
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::{Clock, KvStore};
use slabforge::util::rng::Pcg64;
use slabforge::workload::gen::value_len_for_total;
use std::sync::Arc;

const N: usize = 100_000;

fn keys() -> Vec<String> {
    (0..N).map(|i| format!("k{i:08}")).collect()
}

fn sizes(seed: u64) -> Vec<usize> {
    let mut rng = Pcg64::new(seed);
    (0..N)
        .map(|_| (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16_000))
        .collect()
}

fn main() {
    let keys = keys();
    let sizes = sizes(1);
    let values: Vec<Vec<u8>> = sizes
        .iter()
        .map(|&t| vec![b'x'; value_len_for_total(t, true).unwrap()])
        .collect();
    let mut rows = Vec::new();

    // ---- raw slab allocator ---------------------------------------------
    rows.push(bench(
        "slab alloc+free pairs",
        &BenchOpts {
            warmup: 2,
            iters: 10,
            units_per_iter: N as f64,
        },
        || {
            let mut a =
                SlabAllocator::new(&ChunkSizePolicy::default(), PAGE_SIZE, 256 << 20).unwrap();
            let mut handles = Vec::with_capacity(N);
            for &s in &sizes {
                handles.push((a.alloc(s).unwrap(), s));
            }
            for (h, s) in handles {
                a.free(h, s);
            }
        },
    ));

    // ---- single-shard store ---------------------------------------------
    rows.push(bench(
        "store set (fresh)",
        &BenchOpts {
            warmup: 1,
            iters: 8,
            units_per_iter: N as f64,
        },
        || {
            let mut s = KvStore::new(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                256 << 20,
                true,
                Clock::System,
            )
            .unwrap();
            for i in 0..N {
                s.set(keys[i].as_bytes(), &values[i], 0, 0).unwrap();
            }
        },
    ));

    let mut warm = KvStore::new(
        ChunkSizePolicy::default(),
        PAGE_SIZE,
        256 << 20,
        true,
        Clock::System,
    )
    .unwrap();
    for i in 0..N {
        warm.set(keys[i].as_bytes(), &values[i], 0, 0).unwrap();
    }
    let mut rng = Pcg64::new(2);
    rows.push(bench(
        "store get (warm, random)",
        &BenchOpts {
            warmup: 2,
            iters: 10,
            units_per_iter: N as f64,
        },
        || {
            for _ in 0..N {
                let i = rng.gen_range(N as u64) as usize;
                assert!(warm.get(keys[i].as_bytes()).is_some());
            }
        },
    ));

    rows.push(bench(
        "store overwrite",
        &BenchOpts {
            warmup: 1,
            iters: 8,
            units_per_iter: N as f64,
        },
        || {
            for i in 0..N {
                warm.set(keys[i].as_bytes(), &values[i], 0, 0).unwrap();
            }
        },
    ));

    // ---- sharded store (the serving configuration) ----------------------
    let sharded = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            256 << 20,
            true,
            4,
            Clock::System,
        )
        .unwrap(),
    );
    rows.push(bench(
        "sharded set 4 threads",
        &BenchOpts {
            warmup: 1,
            iters: 8,
            units_per_iter: N as f64,
        },
        || {
            let threads: Vec<_> = (0..4)
                .map(|t| {
                    let store = sharded.clone();
                    let keys: Vec<String> =
                        (0..N / 4).map(|i| format!("t{t}-{i:07}")).collect();
                    let vals: Vec<usize> = sizes[t * (N / 4)..(t + 1) * (N / 4)].to_vec();
                    std::thread::spawn(move || {
                        for (k, &total) in keys.iter().zip(vals.iter()) {
                            let v = vec![b'x'; value_len_for_total(total, true).unwrap()];
                            store.set(k.as_bytes(), &v, 0, 0).unwrap();
                        }
                    })
                })
                .collect();
            for t in threads {
                t.join().unwrap();
            }
        },
    ));

    // ---- collector overhead ---------------------------------------------
    let collector = Arc::new(SizeCollector::default());
    rows.push(bench(
        "collector record",
        &BenchOpts {
            warmup: 2,
            iters: 10,
            units_per_iter: N as f64,
        },
        || {
            for &s in &sizes {
                collector.record(s);
            }
        },
    ));

    // ---- live reconfiguration (migration) --------------------------------
    rows.push(bench(
        "reconfigure 100k items",
        &BenchOpts {
            warmup: 1,
            iters: 5,
            units_per_iter: N as f64,
        },
        || {
            let r = warm
                .reconfigure(ChunkSizePolicy::Explicit(vec![
                    464, 505, 543, 584, 636, 728, 944,
                ]))
                .unwrap();
            assert_eq!(r.items_dropped, 0);
            // flip back so each iteration does the same work
            warm.reconfigure(ChunkSizePolicy::default()).unwrap();
        },
    ));

    println!("{}", table("allocator / store hot paths (N=100k)", &rows));
}
