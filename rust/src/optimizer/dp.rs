//! Exact optimal slab classes by dynamic programming — the lower bound
//! the paper's greedy algorithm is judged against (ablation D4/D6).
//!
//! Observation: an optimal chunk value always coincides with some
//! observed item size (lowering a chunk to the largest covered size
//! never increases waste). So the problem reduces to choosing K
//! boundaries over the m distinct sizes — a classic 1-D partition
//! problem whose cost matrix satisfies the quadrangle inequality, which
//! makes the per-layer argmin monotone. We exploit that with
//! divide-and-conquer DP: O(K · m log m) instead of O(K · m²).

use super::waste::WasteMap;

/// Result of an exact optimization.
#[derive(Clone, Debug)]
pub struct DpResult {
    /// Optimal chunk sizes (ascending, ≤ K values — fewer when the
    /// histogram has fewer distinct sizes, the §6.1 best case).
    pub config: Vec<u32>,
    /// Total waste of `config` (0 when K ≥ distinct sizes).
    pub waste: u64,
    /// cost() invocations (the DP's work measure).
    pub evaluations: u64,
    /// DP layers solved.
    pub iterations: u64,
}

/// Solve for the optimal ≤K-class configuration covering every size in
/// `map` (the top class equals the maximum observed size).
pub fn dp_optimal(map: &WasteMap, k: usize) -> DpResult {
    dp_optimal_with_overflow(map, k, None)
}

/// Like [`dp_optimal`], but sizes above the last learned boundary are
/// charged to a fixed `overflow` chunk (the first suffix class of the
/// surrounding slab table) instead of being forced under the learned
/// top class. This is the true lower bound for the engine's
/// learn-a-span-within-a-table setting: greedy searches can shed their
/// largest items into the suffix class, and so may the optimum.
pub fn dp_optimal_with_overflow(map: &WasteMap, k: usize, overflow: Option<u32>) -> DpResult {
    let sizes = map.sizes();
    let counts = map.counts();
    let m = sizes.len();
    if m == 0 || k == 0 {
        return DpResult {
            config: Vec::new(),
            waste: 0,
            evaluations: 0,
            iterations: 0,
        };
    }
    if k >= m {
        // one exact-fit class per distinct size: zero waste (§6.1 best case)
        return DpResult {
            config: sizes.to_vec(),
            waste: 0,
            evaluations: 0,
            iterations: 0,
        };
    }

    // prefix sums over distinct sizes
    let mut pc = vec![0u64; m + 1]; // counts
    let mut pb = vec![0u64; m + 1]; // bytes
    for i in 0..m {
        pc[i + 1] = pc[i] + counts[i];
        pb[i + 1] = pb[i] + sizes[i] as u64 * counts[i];
    }
    let mut evals = 0u64;
    // cost of one class with chunk sizes[j] covering sizes[i..=j]
    let mut cost = |i: usize, j: usize| -> u64 {
        evals += 1;
        sizes[j] as u64 * (pc[j + 1] - pc[i]) - (pb[j + 1] - pb[i])
    };

    const INF: u64 = u64::MAX / 4;
    // dp[j] = best waste covering 0..=j with the current layer count,
    // where the last class's chunk is sizes[j].
    let mut prev = vec![INF; m];
    let mut cur = vec![INF; m];
    // parents[layer][j] = index of the previous layer's last boundary
    let mut parents: Vec<Vec<u32>> = Vec::with_capacity(k);

    for (j, slot) in prev.iter_mut().enumerate() {
        *slot = cost(0, j);
    }
    parents.push(vec![u32::MAX; m]); // layer 1 has no parent

    for _layer in 2..=k {
        let mut parent = vec![u32::MAX; m];
        // D&C over j with monotone argmin.
        // solve(j_lo..=j_hi) knowing opt(j) ∈ [i_lo, i_hi]
        let mut stack = vec![(0usize, m - 1, 0usize, m - 1)];
        while let Some((j_lo, j_hi, i_lo, i_hi)) = stack.pop() {
            if j_lo > j_hi {
                continue;
            }
            let j = j_lo + (j_hi - j_lo) / 2;
            // last class covers (i..=j] with chunk sizes[j]; previous
            // layer ends at i (so i < j).
            let hi = i_hi.min(j.saturating_sub(1));
            let mut best = INF;
            let mut best_i = usize::MAX;
            for i in i_lo..=hi {
                if prev[i] >= INF {
                    continue;
                }
                let c = prev[i] + cost(i + 1, j);
                if c < best {
                    best = c;
                    best_i = i;
                }
            }
            cur[j] = best;
            parent[j] = best_i as u32;
            if best_i != usize::MAX {
                if j > j_lo {
                    stack.push((j_lo, j - 1, i_lo, best_i));
                }
                if j < j_hi {
                    stack.push((j + 1, j_hi, best_i, i_hi));
                }
            } else {
                // no feasible split (j too small for this layer count)
                if j > j_lo {
                    stack.push((j_lo, j - 1, i_lo, i_hi));
                }
                if j < j_hi {
                    stack.push((j + 1, j_hi, i_lo, i_hi));
                }
            }
        }
        parents.push(parent);
        std::mem::swap(&mut prev, &mut cur);
        cur.fill(INF);
    }

    // pick the last learned boundary: forced to m-1 without an
    // overflow class; otherwise the tail above it is charged `overflow`
    let (mut j, waste) = match overflow {
        None => (m - 1, prev[m - 1]),
        Some(ov) => {
            assert!(
                ov as u64 >= sizes[m - 1] as u64,
                "overflow chunk {ov} cannot cover max size {}",
                sizes[m - 1]
            );
            let mut best = (m - 1, prev[m - 1]);
            for j in 0..m {
                if prev[j] >= INF {
                    continue;
                }
                let tail = ov as u64 * (pc[m] - pc[j + 1]) - (pb[m] - pb[j + 1]);
                let total = prev[j] + tail;
                if total < best.1 {
                    best = (j, total);
                }
            }
            best
        }
    };

    // reconstruct boundaries from the chosen end
    let mut config = Vec::with_capacity(k);
    for layer in (0..k).rev() {
        config.push(sizes[j]);
        let p = parents[layer][j];
        if p == u32::MAX {
            break;
        }
        j = p as usize;
    }
    config.reverse();

    DpResult {
        config,
        waste,
        evaluations: evals,
        iterations: k as u64,
    }
}

/// Brute-force optimum (exponential; ≤ ~15 distinct sizes): the oracle
/// the DP is validated against in unit, property, and ablation tests.
pub fn brute_force_optimal(map: &WasteMap, k: usize) -> (Vec<u32>, u64) {
    let sizes = map.sizes();
    let m = sizes.len();
    if m == 0 || k == 0 {
        return (Vec::new(), 0);
    }
    if k >= m {
        return (sizes.to_vec(), 0);
    }
    // choose k-1 boundaries from 0..m-1 (last boundary fixed at m-1)
    let mut best = (Vec::new(), u64::MAX);
    let mut choose = vec![0usize; k - 1];
    fn rec(
        map: &WasteMap,
        sizes: &[u32],
        choose: &mut Vec<usize>,
        pos: usize,
        start: usize,
        best: &mut (Vec<u32>, u64),
    ) {
        let m = sizes.len();
        if pos == choose.len() {
            let mut cfg: Vec<u32> = choose.iter().map(|&i| sizes[i]).collect();
            cfg.push(sizes[m - 1]);
            let w = map.waste_of_sorted(&cfg);
            if w < best.1 {
                *best = (cfg, w);
            }
            return;
        }
        for i in start..m - 1 {
            choose[pos] = i;
            rec(map, sizes, choose, pos + 1, i + 1, best);
        }
    }
    rec(map, sizes, &mut choose, 0, 0, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn map(pairs: &[(u32, u64)]) -> WasteMap {
        WasteMap::from_pairs(pairs.iter().copied())
    }

    #[test]
    fn trivial_cases() {
        let m = map(&[(100, 5)]);
        let r = dp_optimal(&m, 1);
        assert_eq!(r.config, vec![100]);
        assert_eq!(r.waste, 0);
        let r = dp_optimal(&m, 3);
        assert_eq!(r.waste, 0, "k >= m: exact fit");
        let empty = WasteMap::from_pairs(std::iter::empty());
        assert_eq!(dp_optimal(&empty, 4).config, Vec::<u32>::new());
    }

    #[test]
    fn two_clusters_two_classes() {
        // two tight clusters: optimal 2 classes sit on cluster maxima
        let m = map(&[(100, 10), (101, 10), (500, 10), (501, 10)]);
        let r = dp_optimal(&m, 2);
        assert_eq!(r.config, vec![101, 501]);
        assert_eq!(r.waste, 20); // one byte for each of the 10+10 lower items
    }

    #[test]
    fn matches_brute_force_on_random_inputs() {
        let mut rng = Pcg64::new(11);
        for trial in 0..30 {
            let m_sizes = 3 + rng.gen_range(9) as usize;
            let mut pairs: Vec<(u32, u64)> = Vec::new();
            let mut s = 10u32;
            for _ in 0..m_sizes {
                s += 1 + rng.gen_range(400) as u32;
                pairs.push((s, 1 + rng.gen_range(50)));
            }
            let wm = WasteMap::from_pairs(pairs.iter().copied());
            for k in 1..=m_sizes.min(5) {
                let dp = dp_optimal(&wm, k);
                let (_, bf_waste) = brute_force_optimal(&wm, k);
                assert_eq!(
                    dp.waste, bf_waste,
                    "trial {trial} k={k} pairs={pairs:?} dp={:?}",
                    dp.config
                );
                // reported waste is consistent with the evaluator
                assert_eq!(wm.waste_of_sorted(&dp.config), dp.waste);
            }
        }
    }

    #[test]
    fn waste_monotone_in_k() {
        let mut rng = Pcg64::new(12);
        let pairs: Vec<(u32, u64)> = {
            let mut m = std::collections::BTreeMap::new();
            for _ in 0..5000 {
                let s = rng.lognormal(518.0, 0.126).round().max(60.0) as u32;
                *m.entry(s).or_insert(0u64) += 1;
            }
            m.into_iter().collect()
        };
        let wm = WasteMap::from_pairs(pairs.iter().copied());
        let mut last = u64::MAX;
        for k in 1..=8 {
            let w = dp_optimal(&wm, k).waste;
            assert!(w <= last, "k={k}: {w} > {last}");
            last = w;
        }
    }

    #[test]
    fn top_class_covers_max() {
        let m = map(&[(100, 1), (900, 1), (5000, 1)]);
        for k in 1..=3 {
            let r = dp_optimal(&m, k);
            assert_eq!(*r.config.last().unwrap(), 5000, "k={k}");
        }
    }

    #[test]
    fn dc_efficiency() {
        // m distinct sizes, k classes: evals should be well under m²k
        let pairs: Vec<(u32, u64)> = (1..=2000u32).map(|s| (s * 3, 1 + (s % 7) as u64)).collect();
        let wm = WasteMap::from_pairs(pairs.iter().copied());
        let r = dp_optimal(&wm, 6);
        let m = 2000u64;
        assert!(
            r.evaluations < m * 20 * 6,
            "evals {} vs naive {}",
            r.evaluations,
            m * m * 6
        );
        assert!(r.waste > 0);
    }
}
