//! Batched steepest-descent hill climbing with shrinking step sizes.
//!
//! The paper's Algorithm 1 evaluates ONE random ±1-byte neighbor per
//! iteration — thousands of tiny evaluations. This variant evaluates
//! the complete ±δ neighbor set of the current configuration in one
//! batch (2·K + 1 candidates including "stay"), moves to the argmin,
//! and shrinks δ geometrically once no neighbor improves. With the XLA
//! backend the entire batch is a single fused PJRT `hill_step` call —
//! the L2 graph both expands and scores the neighbors, so one
//! optimization step costs one artifact execution.
//!
//! Same search space and invariants as Algorithm 1 (strictly ascending
//! spans, fixed prefix/suffix classes); converges to the same optima on
//! unimodal landscapes in far fewer evaluations (ablation
//! `bench_ablation --algorithms`).

use super::engine::WasteBackend;
use super::hillclimb::Outcome;
use std::ops::Range;

#[derive(Clone, Debug)]
pub struct SteepestParams {
    pub max_iters: u64,
    pub min_chunk: u32,
    pub max_chunk: u32,
    /// Starting δ; shrinks ÷4 until 1.
    pub initial_step: u32,
}

impl Default for SteepestParams {
    fn default() -> Self {
        SteepestParams {
            max_iters: 1_000_000,
            min_chunk: crate::slab::MIN_CHUNK as u32,
            max_chunk: crate::slab::PAGE_SIZE as u32,
            initial_step: 256,
        }
    }
}

/// Generate the valid ±δ neighbor set (plus the unchanged config).
fn neighbors(
    config: &[u32],
    span: &Range<usize>,
    step: u32,
    p: &SteepestParams,
) -> Vec<Vec<u32>> {
    let mut out = Vec::with_capacity(2 * span.len() + 1);
    out.push(config.to_vec());
    for idx in span.clone() {
        for up in [true, false] {
            let cur = config[idx];
            let cand = if up {
                cur.saturating_add(step)
            } else {
                cur.saturating_sub(step)
            };
            // clamp into the strictly-ascending corridor
            let lo = if idx > 0 { config[idx - 1] + 1 } else { p.min_chunk };
            let hi = if idx + 1 < config.len() {
                config[idx + 1] - 1
            } else {
                p.max_chunk
            };
            let cand = cand.clamp(lo.max(p.min_chunk), hi.min(p.max_chunk));
            if cand != cur {
                let mut c = config.to_vec();
                c[idx] = cand;
                out.push(c);
            }
        }
    }
    out
}

/// Run steepest descent over the learnable `span` of `full`.
pub fn steepest_descent<B: WasteBackend>(
    backend: &B,
    full: &[u32],
    span: Range<usize>,
    params: &SteepestParams,
) -> Outcome {
    let mut config = full.to_vec();
    let mut best_waste = backend.eval_one(&config);
    let mut evals = 1u64;
    let mut iters = 0u64;
    let mut step = params.initial_step.max(1);

    if span.is_empty() {
        return Outcome {
            config,
            iterations: 0,
            evaluations: evals,
        };
    }

    loop {
        if iters >= params.max_iters {
            break;
        }
        iters += 1;
        let cands = neighbors(&config, &span, step, params);
        let wastes = backend.eval_batch(&cands);
        evals += cands.len() as u64;
        let (best_idx, &w) = wastes
            .iter()
            .enumerate()
            .min_by_key(|&(_, w)| *w)
            .expect("candidates nonempty");
        if w < best_waste {
            best_waste = w;
            config = cands[best_idx].clone();
        } else if step > 1 {
            step = (step / 4).max(1);
        } else {
            break; // δ = 1 and no improving neighbor: local optimum
        }
    }

    Outcome {
        config,
        iterations: iters,
        evaluations: evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::engine::{RustBackend, WasteBackend};
    use crate::optimizer::hillclimb::{paper_hill_climb, HillClimbParams};
    use crate::optimizer::waste::WasteMap;
    use crate::util::rng::Pcg64;

    fn backend(pairs: &[(u32, u64)]) -> RustBackend {
        RustBackend::new(WasteMap::from_pairs(pairs.iter().copied()))
    }

    #[test]
    fn exact_fit_single_class() {
        let b = backend(&[(500, 1000)]);
        let full = vec![96u32, 600, 1024];
        let out = steepest_descent(&b, &full, 1..2, &SteepestParams::default());
        assert_eq!(out.config[1], 500);
        assert_eq!(b.eval_one(&out.config), 0);
    }

    #[test]
    fn far_fewer_evaluations_than_paper_algorithm() {
        let mut rng = Pcg64::new(5);
        let pairs: Vec<(u32, u64)> = {
            let mut m = std::collections::BTreeMap::new();
            for _ in 0..20_000 {
                let s = rng.lognormal(518.0, 0.126).round().max(60.0) as u32;
                *m.entry(s).or_insert(0u64) += 1;
            }
            m.into_iter().collect()
        };
        let b = RustBackend::new(WasteMap::from_pairs(pairs.iter().copied()));
        let full: Vec<u32> = crate::slab::geometry::memcached_default_sizes()
            .iter()
            .map(|&c| c as u32)
            .collect();
        let span = 5..11; // 304..944 region
        let st = steepest_descent(&b, &full, span.clone(), &SteepestParams::default());
        let hc = paper_hill_climb(&b, &full, span, &HillClimbParams::default());
        let w_st = b.eval_one(&st.config);
        let w_hc = b.eval_one(&hc.config);
        // similar quality (within 10 %), far fewer evaluations
        assert!(
            (w_st as f64) < (w_hc as f64) * 1.10,
            "steepest {w_st} vs paper {w_hc}"
        );
        assert!(
            st.evaluations * 5 < hc.evaluations,
            "steepest {} evals vs paper {}",
            st.evaluations,
            hc.evaluations
        );
    }

    #[test]
    fn maintains_ascending_invariant() {
        let b = backend(&[(100, 5), (105, 9), (110, 2)]);
        let full = vec![96u32, 104, 112, 200];
        let out = steepest_descent(&b, &full, 0..3, &SteepestParams::default());
        assert!(out.config.windows(2).all(|w| w[0] < w[1]), "{:?}", out.config);
    }

    #[test]
    fn never_regresses() {
        let b = backend(&[(77, 3), (900, 2), (5000, 1)]);
        let full: Vec<u32> = crate::slab::geometry::memcached_default_sizes()
            .iter()
            .map(|&c| c as u32)
            .collect();
        let start = b.eval_one(&full);
        let out = steepest_descent(&b, &full, 0..full.len(), &SteepestParams::default());
        assert!(b.eval_one(&out.config) <= start);
    }

    #[test]
    fn neighbor_generation_respects_corridor() {
        let p = SteepestParams::default();
        let cfg = vec![100u32, 110, 120];
        let n = neighbors(&cfg, &(1..2), 256, &p);
        // middle class can only move within (100, 120)
        for cand in &n {
            assert!(cand[1] > 100 && cand[1] < 121, "{cand:?}");
        }
        assert!(n.len() <= 3);
    }
}
