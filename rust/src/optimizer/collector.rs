//! Online item-size collector — "analyses the pattern of the sizes of
//! items previously entered into the memory" (paper §Abstract), without
//! slowing the set path: lock-free striped atomic counters for the
//! byte-granular head, a mutexed tail map for oversized items.

use crate::store::store::SizeObserver;
use crate::util::histogram::SizeHistogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default exact-head capacity: byte-granular up to 16 KiB (matches the
/// AOT artifact's S = 16384).
pub const DEFAULT_CAP: usize = 16384;

pub struct SizeCollector {
    /// counts[i] = items of total size i+1 (atomic, no lock).
    counts: Vec<AtomicU64>,
    /// Sizes above the head.
    overflow: Mutex<BTreeMap<usize, u64>>,
    /// Samples that landed above the byte-granular head. The mutexed
    /// tail keeps the exact sizes, but downstream `bucketize` clamps
    /// anything past its span into the last bucket — biasing the
    /// learned top class downward. This counter makes that loss of
    /// fidelity visible (`collector_overflow` in `stats slabs`).
    overflow_count: AtomicU64,
    total: AtomicU64,
    max_size: AtomicUsize,
}

impl SizeCollector {
    pub fn new(cap: usize) -> Self {
        SizeCollector {
            counts: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            overflow: Mutex::new(BTreeMap::new()),
            overflow_count: AtomicU64::new(0),
            total: AtomicU64::new(0),
            max_size: AtomicUsize::new(0),
        }
    }

    pub fn record(&self, size: usize) {
        if size == 0 {
            return;
        }
        if size <= self.counts.len() {
            self.counts[size - 1].fetch_add(1, Ordering::Relaxed);
        } else {
            *self.overflow.lock().unwrap().entry(size).or_insert(0) += 1;
            self.overflow_count.fetch_add(1, Ordering::Relaxed);
        }
        self.total.fetch_add(1, Ordering::Relaxed);
        self.max_size.fetch_max(size, Ordering::Relaxed);
    }

    /// Items observed since construction / last reset.
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn max_size(&self) -> usize {
        self.max_size.load(Ordering::Relaxed)
    }

    /// Samples recorded above the exact head cap since construction /
    /// last reset. Non-zero means the bucketized optimizer input is
    /// clamping real sizes into its last bucket.
    pub fn overflow_count(&self) -> u64 {
        self.overflow_count.load(Ordering::Relaxed)
    }

    /// Consistent-enough snapshot for optimization (counters may lag by
    /// in-flight sets; the optimizer tolerates that).
    pub fn snapshot(&self) -> SizeHistogram {
        let mut h = SizeHistogram::new(self.counts.len());
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n > 0 {
                h.record_n(i + 1, n);
            }
        }
        for (&size, &n) in self.overflow.lock().unwrap().iter() {
            h.record_n(size, n);
        }
        h
    }

    /// Zero all counters (e.g. after a reconfiguration epoch).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.overflow.lock().unwrap().clear();
        self.overflow_count.store(0, Ordering::Relaxed);
        self.total.store(0, Ordering::Relaxed);
        self.max_size.store(0, Ordering::Relaxed);
    }
}

impl SizeObserver for SizeCollector {
    fn observe(&self, total_size: usize) {
        self.record(total_size);
    }
}

impl Default for SizeCollector {
    fn default() -> Self {
        Self::new(DEFAULT_CAP)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn record_and_snapshot() {
        let c = SizeCollector::new(1024);
        c.record(100);
        c.record(100);
        c.record(1024);
        c.record(50_000); // overflow
        let h = c.snapshot();
        assert_eq!(h.count(100), 2);
        assert_eq!(h.count(1024), 1);
        assert_eq!(h.count(50_000), 1);
        assert_eq!(c.total(), 4);
        assert_eq!(c.max_size(), 50_000);
        assert_eq!(c.overflow_count(), 1);
    }

    #[test]
    fn overflow_counter_tracks_above_cap_only() {
        let c = SizeCollector::new(128);
        c.record(128); // at cap: exact head
        c.record(129);
        c.record(129);
        c.record(4096);
        assert_eq!(c.overflow_count(), 3);
        c.reset();
        assert_eq!(c.overflow_count(), 0);
    }

    #[test]
    fn reset_clears() {
        let c = SizeCollector::new(64);
        c.record(10);
        c.record(100_000);
        c.reset();
        assert_eq!(c.total(), 0);
        assert_eq!(c.snapshot().total_items(), 0);
        assert_eq!(c.max_size(), 0);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let c = Arc::new(SizeCollector::new(4096));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000usize {
                        c.record(1 + ((t * 10_000 + i) % 4096));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.total(), 80_000);
        assert_eq!(c.snapshot().total_items(), 80_000);
    }

    #[test]
    fn observer_trait_wires_in() {
        let c: Arc<SizeCollector> = Arc::new(SizeCollector::default());
        let obs: Arc<dyn crate::store::store::SizeObserver> = c.clone();
        obs.observe(518);
        assert_eq!(c.snapshot().count(518), 1);
    }
}
