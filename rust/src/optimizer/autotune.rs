//! The online coordinator: watch the collector, learn the traffic
//! pattern, optimize, and live-reconfigure the store — the paper's
//! offline workflow (measure → run algorithm → restart with
//! `-o slab_sizes`) turned into a background feature.

use super::collector::SizeCollector;
use super::engine::{optimize, OptimizeReport, OptimizerParams, RustBackend, WasteBackend};
use super::waste::WasteMap;
use crate::config::settings::{Backend, OptimizerSettings};
use crate::runtime::{XlaService, XlaWasteBackend};
use crate::server::conn::{Control, OptimizeGauges};
use crate::slab::policy::{validate_sizes, ChunkSizePolicy};
use crate::slab::MAX_CLASSES;
use crate::store::sharded::ShardedStore;
use crate::tenant::histogram_divergence;
use crate::util::histogram::SizeHistogram;
use crate::util::{failpoint, supervisor};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

/// The auto-tuner; also the server's [`Control`] implementation, so
/// `slabs optimize` / `slabs reconfigure` act through the same object.
pub struct AutoTuner {
    store: Arc<ShardedStore>,
    collector: Arc<SizeCollector>,
    settings: OptimizerSettings,
    engine: Option<Arc<XlaService>>,
    page_size: usize,
    history: Mutex<Vec<OptimizeReport>>,
    /// An async `slabs optimize` request is queued for the background
    /// loop (the control path returns `OPTIMIZING` without blocking).
    optimize_pending: AtomicBool,
    /// A dequeued pass is executing right now. `pending || running` is
    /// what the gauges report, so a client polling `optimize_pending`
    /// can never observe the gap between dequeue and gauge visibility —
    /// while a request arriving *during* a pass still re-queues via
    /// `optimize_pending` instead of being dropped.
    optimize_running: AtomicBool,
    /// Outcome gauges of async passes (`stats slabs` `optimize_*`).
    opt_gauges: Mutex<OptimizeGauges>,
}

impl AutoTuner {
    /// Build a tuner; with `Backend::Xla` this compiles the AOT
    /// artifacts up front (fails fast when `make artifacts` is stale).
    pub fn new(
        store: Arc<ShardedStore>,
        collector: Arc<SizeCollector>,
        settings: OptimizerSettings,
        page_size: usize,
    ) -> Result<Arc<Self>, String> {
        let engine = match settings.backend {
            Backend::Xla => Some(
                XlaService::start(Path::new(&settings.artifacts_dir))
                    .map_err(|e| format!("cannot load artifacts: {e}"))?,
            ),
            Backend::Rust => None,
        };
        Ok(Arc::new(AutoTuner {
            store,
            collector,
            settings,
            engine,
            page_size,
            history: Mutex::new(Vec::new()),
            optimize_pending: AtomicBool::new(false),
            optimize_running: AtomicBool::new(false),
            opt_gauges: Mutex::new(OptimizeGauges::default()),
        }))
    }

    /// Reports of every optimization run so far.
    ///
    /// Both tuner mutexes recover from poisoning via `into_inner`: the
    /// protected state (a report log, a gauge struct) is valid after
    /// any partial update, and a supervised pass that panicked must not
    /// take `stats slabs` down with it.
    pub fn history(&self) -> Vec<OptimizeReport> {
        self.history
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    fn params(&self) -> OptimizerParams {
        OptimizerParams {
            algorithm: self.settings.algorithm,
            seed: self.settings.seed,
            max_chunk: self.page_size as u32,
            ..Default::default()
        }
    }

    /// One **asynchronous** tuner pass — the unit the background loop
    /// runs for both the periodic retune and a queued `slabs optimize`:
    /// optimize against the live histogram and, when the predicted
    /// recovery clears the apply threshold, kick off the incremental
    /// drain (`begin_reconfigure`; the loop pumps the steps). The
    /// outcome lands in the `optimize_*` gauges of `stats slabs`
    /// instead of a blocking reply.
    fn run_async_pass(&self) {
        // failpoint: an optimizer pass dying mid-flight must be
        // survivable (supervised loop restarts; a kicked drain is
        // pumped by the next iteration)
        failpoint::maybe_panic("autotune.pass.panic");
        let seen = self.collector.total();
        if seen < self.settings.min_samples {
            return;
        }
        let hist = self.collector.snapshot();
        let current = self.store.chunk_sizes();
        let report = self.optimize_against(&hist, &current);
        let report = self.per_tenant_refine(&hist, &current, report);
        let recovery = report.recovery();
        self.history
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(report.clone());
        let mut applied = false;
        if recovery >= self.settings.min_improvement {
            let sizes: Vec<usize> = report.new_config.iter().map(|&c| c as usize).collect();
            match self.store.begin_reconfigure(ChunkSizePolicy::Explicit(sizes)) {
                Ok(()) => applied = true,
                // Busy (a drain already in flight) just skips the apply;
                // the next pass sees the post-drain geometry. Anything
                // else is a real fault — without a blocking reply to
                // carry it, say so loudly instead of masquerading as
                // below-threshold
                Err(crate::store::store::StoreError::Busy) => {}
                Err(e) => eprintln!("autotune: optimize apply failed: {e}"),
            }
        }
        let mut g = self.opt_gauges.lock().unwrap_or_else(PoisonError::into_inner);
        g.runs += 1;
        if applied {
            g.applied += 1;
        }
        g.last_recovery_bp = (recovery.max(0.0) * 10_000.0) as u64;
    }

    /// Per-tenant geometry: when tenants' observed size distributions
    /// have drifted apart (pairwise total-variation distance above the
    /// registry's threshold), a single global optimum splits the
    /// difference and serves nobody well. Optimize each diverged
    /// tenant's histogram separately, merge the per-tenant optima into
    /// one class table (union, near-duplicates pruned), and keep the
    /// merged table only if it scores **better than the global optimum
    /// on the global histogram** — the learner can only improve on the
    /// baseline, never regress it.
    fn per_tenant_refine(
        &self,
        global: &SizeHistogram,
        current: &[usize],
        report: OptimizeReport,
    ) -> OptimizeReport {
        let reg = self.store.tenants();
        if !reg.active() {
            return report;
        }
        // each tenant needs enough of its own samples to learn from;
        // half the global gate keeps a 50/50 split eligible
        let hists = reg.tenant_histograms((self.settings.min_samples / 2).max(1));
        if hists.len() < 2 {
            return report;
        }
        let mut max_div = 0.0f64;
        for i in 0..hists.len() {
            for j in i + 1..hists.len() {
                max_div = max_div.max(histogram_divergence(&hists[i].1, &hists[j].1));
            }
        }
        if max_div < reg.divergence_threshold() {
            return report;
        }
        let mut union: Vec<u32> = Vec::new();
        for (_, h) in &hists {
            union.extend(self.optimize_against(h, current).new_config);
        }
        union.sort_unstable();
        union.dedup();
        // prune near-equal sizes (an item that fits the smaller of two
        // classes 3% apart wastes almost nothing in the larger one),
        // widening the band until the table fits MAX_CLASSES
        let mut slack = 1.03f64;
        let mut merged = loop {
            let mut m: Vec<u32> = Vec::new();
            for &s in &union {
                if m.last().is_none_or(|&l| s as f64 > l as f64 * slack) {
                    m.push(s);
                }
            }
            if m.len() <= MAX_CLASSES {
                break m;
            }
            slack *= 1.05;
        };
        if merged.is_empty() {
            return report;
        }
        // the Explicit policy auto-appends a page-size top class when
        // it's missing; pin it here so that append can never push the
        // table past MAX_CLASSES
        let page = self.page_size as u32;
        if merged.last().is_some_and(|&l| l < page) {
            if merged.len() < MAX_CLASSES {
                merged.push(page);
            } else {
                *merged.last_mut().unwrap() = page;
            }
        }
        let merged_waste = self.eval_config(global, &merged);
        if merged_waste < report.new_waste {
            OptimizeReport {
                new_config: merged.clone(),
                new_span: merged,
                new_waste: merged_waste,
                ..report
            }
        } else {
            report
        }
    }

    /// Score one fixed configuration against a histogram (no search).
    fn eval_config(&self, hist: &SizeHistogram, config: &[u32]) -> u64 {
        match &self.engine {
            Some(engine) => XlaWasteBackend::new(engine, hist).eval_one(config),
            None => RustBackend::new(WasteMap::from_histogram(hist)).eval_one(config),
        }
    }

    fn optimize_against(&self, hist: &SizeHistogram, current: &[usize]) -> OptimizeReport {
        let params = self.params();
        match &self.engine {
            Some(engine) => {
                let backend = XlaWasteBackend::new(engine, hist);
                optimize(&backend, hist, current, &params)
            }
            None => {
                let backend = RustBackend::new(WasteMap::from_histogram(hist));
                optimize(&backend, hist, current, &params)
            }
        }
    }

    /// Background loop every `interval_secs`; stop via the flag.
    ///
    /// This thread is also the **migration driver**: whenever a drain
    /// is in flight (kicked off by `slabs reconfigure` or by a tuner
    /// pass), it pumps bounded [`ShardedStore::migration_step_all`]
    /// steps until the drain completes — each step holds a shard's
    /// write lock for at most `migrate_batch` items, so the reactor
    /// threads keep serving between steps and are never pinned for a
    /// whole migration.
    /// The loop body runs under [`supervisor::supervise`]: a panicking
    /// pass (or an injected `autotune.pass.panic`) is logged, counted
    /// in `thread_restarts`, and retried after a capped backoff. A
    /// panic while pumping a drain leaves the two-generation state
    /// parked inside the shards; the next iteration's
    /// `migration_active()` check picks it right back up.
    pub fn spawn(self: &Arc<Self>, shutdown: Arc<AtomicBool>) -> JoinHandle<()> {
        let tuner = self.clone();
        std::thread::Builder::new()
            .name("slabforge-autotune".into())
            .spawn(move || {
                let interval = Duration::from_secs(tuner.settings.interval_secs.max(1));
                let tick = Duration::from_millis(100);
                let mut waited = Duration::ZERO;
                supervisor::supervise("autotune", &shutdown, || {
                    if tuner.store.migration_active() {
                        while tuner.store.migration_step_all() {
                            if shutdown.load(Ordering::SeqCst) {
                                return;
                            }
                            // breathe between rounds: std's RwLock makes
                            // no fairness promise, so back-to-back write
                            // acquisitions could starve readers
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        return;
                    }
                    // a queued `slabs optimize` runs ahead of the
                    // periodic schedule; its drain is pumped above.
                    // `running` raises before `pending` clears (SeqCst),
                    // so `pending || running` — what the gauges report —
                    // is true for the whole request lifetime, while a
                    // request arriving mid-pass re-queues `pending` and
                    // gets its own pass on the next iteration
                    if tuner.optimize_pending.load(Ordering::SeqCst) {
                        tuner.optimize_running.store(true, Ordering::SeqCst);
                        tuner.optimize_pending.store(false, Ordering::SeqCst);
                        // `running` must clear even when the pass
                        // panics, or the gauges would report a stuck
                        // optimize forever; the panic still reaches the
                        // supervisor (logged + counted)
                        let pass = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || tuner.run_async_pass(),
                        ));
                        tuner.optimize_running.store(false, Ordering::SeqCst);
                        if let Err(p) = pass {
                            std::panic::resume_unwind(p);
                        }
                        return;
                    }
                    std::thread::sleep(tick);
                    waited += tick;
                    if waited < interval {
                        return;
                    }
                    waited = Duration::ZERO;
                    tuner.run_async_pass();
                });
            })
            .expect("spawn autotune thread")
    }
}

impl Control for AutoTuner {
    /// `slabs optimize` is **asynchronous**: the only synchronous work
    /// is the cheap sample-count gate, then the request is queued for
    /// the background loop and the connection gets `OPTIMIZING` back
    /// immediately — the issuing reactor is never parked for the
    /// optimization or its drain. Progress and the final recovery
    /// numbers are observable in `stats slabs` (`optimize_*` and
    /// `migration_*` gauges).
    fn optimize_now(&self) -> String {
        let seen = self.collector.total();
        if seen < self.settings.min_samples {
            return format!(
                "NOT_ENOUGH_DATA seen={seen} need={}",
                self.settings.min_samples
            );
        }
        self.optimize_pending.store(true, Ordering::SeqCst);
        format!("OPTIMIZING seen={seen}")
    }

    /// `slabs reconfigure` is asynchronous: validate, flip the geometry
    /// on every shard (O(shards), no item copied), and return
    /// immediately. The background loop ([`AutoTuner::spawn`]) drives
    /// the drain in bounded steps; progress is visible in `stats slabs`
    /// (`migration_*` gauges).
    fn reconfigure(&self, sizes: Vec<usize>) -> Result<String, String> {
        validate_sizes(&sizes, self.page_size).map_err(|e| e.to_string())?;
        self.store
            .begin_reconfigure(ChunkSizePolicy::Explicit(sizes))
            .map_err(|e| e.to_string())?;
        let g = self.store.migration_gauges();
        Ok(format!(
            "MIGRATING shards={} items={} batch={}",
            self.store.shard_count(),
            g.items_remaining,
            self.store.migrate_batch()
        ))
    }

    fn sizes_histogram(&self) -> Option<SizeHistogram> {
        Some(self.collector.snapshot())
    }

    fn optimize_gauges(&self) -> OptimizeGauges {
        let mut g = *self.opt_gauges.lock().unwrap_or_else(PoisonError::into_inner);
        g.pending = self.optimize_pending.load(Ordering::SeqCst)
            || self.optimize_running.load(Ordering::SeqCst);
        g.collector_overflow = self.collector.overflow_count();
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::Algorithm;
    use crate::slab::PAGE_SIZE;
    use crate::store::store::Clock;
    use crate::util::rng::Pcg64;
    use crate::workload::gen::value_len_for_total;

    fn setup(min_samples: u64) -> (Arc<ShardedStore>, Arc<SizeCollector>, Arc<AutoTuner>) {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                64 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let collector = Arc::new(SizeCollector::default());
        store.set_observer(collector.clone());
        let settings = OptimizerSettings {
            enabled: true,
            min_samples,
            min_improvement: 0.05,
            algorithm: Algorithm::SteepestDescent,
            backend: Backend::Rust,
            ..Default::default()
        };
        let tuner = AutoTuner::new(store.clone(), collector.clone(), settings, PAGE_SIZE).unwrap();
        (store, collector, tuner)
    }

    fn drive_lognormal(store: &ShardedStore, n: usize, seed: u64) {
        let mut rng = Pcg64::new(seed);
        for i in 0..n {
            let total = rng.lognormal(518.0, 0.126).round().max(70.0) as usize;
            let vlen = value_len_for_total(total.min(16000), true).unwrap();
            store
                .set(format!("k{i:08}").as_bytes(), &vec![b'x'; vlen], 0, 0)
                .unwrap();
        }
    }

    #[test]
    fn not_enough_data_short_circuits() {
        let (_, _, tuner) = setup(1000);
        // the gate answers synchronously and queues nothing
        let msg = tuner.optimize_now();
        assert!(msg.starts_with("NOT_ENOUGH_DATA seen=0 need=1000"), "{msg}");
        assert!(!tuner.optimize_gauges().pending);
        // a pass below the gate is a no-op: no run counted, no history
        tuner.run_async_pass();
        assert_eq!(tuner.optimize_gauges().runs, 0);
        assert!(tuner.history().is_empty());
    }

    #[test]
    fn full_cycle_reduces_live_waste() {
        let (store, _, tuner) = setup(1000);
        drive_lognormal(&store, 20_000, 3);
        let before = store.slab_stats().hole_bytes;
        tuner.run_async_pass();
        let g = tuner.optimize_gauges();
        assert_eq!((g.runs, g.applied), (1, 1), "{g:?}");
        assert!(g.last_recovery_bp > 2500, "recovery {} bp", g.last_recovery_bp);
        // drive the kicked drain to completion inline
        while store.migration_step_all() {}
        let after = store.slab_stats().hole_bytes;
        assert!(after < before, "live holes {after} !< {before}");
        assert_eq!(store.migration_gauges().dropped, 0);
        // store still serves every key
        assert!(store.get(b"k00000000").is_some());
        assert!(store.get(b"k00019999").is_some());
        assert_eq!(tuner.history().len(), 1);
    }

    #[test]
    fn control_trait_reconfigure_validates_and_kicks_off() {
        let (store, _, tuner) = setup(10);
        assert!(tuner.reconfigure(vec![500, 400]).is_err());
        let msg = tuner.reconfigure(vec![304, 600, 1024]).unwrap();
        assert!(msg.starts_with("MIGRATING"), "{msg}");
        // geometry flipped immediately; drain runs asynchronously
        assert_eq!(&store.chunk_sizes()[..3], &[304, 600, 1024]);
        while store.migration_step_all() {}
        assert!(!store.migration_active());
    }

    #[test]
    fn spawned_loop_drives_manual_migration() {
        let (store, _, tuner) = setup(u64::MAX); // never auto-tunes
        drive_lognormal(&store, 5000, 9);
        let stop = Arc::new(AtomicBool::new(false));
        let handle = tuner.spawn(stop.clone());
        let msg = tuner.reconfigure(vec![518, 1024, 8192]).unwrap();
        assert!(msg.starts_with("MIGRATING"), "{msg}");
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while store.migration_active() {
            assert!(
                std::time::Instant::now() < deadline,
                "background loop never finished the drain"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        // data survived the background drain
        assert!(store.get(b"k00000000").is_some());
        assert!(store.get(b"k00004999").is_some());
        assert!(store.migration_gauges().moved > 0);
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn control_optimize_now_is_async() {
        let (store, _, tuner) = setup(100);
        // below min_samples: the cheap gate answers synchronously
        let msg = tuner.optimize_now();
        assert!(msg.starts_with("NOT_ENOUGH_DATA"), "{msg}");
        drive_lognormal(&store, 5000, 4);
        let holes_before = store.slab_stats().hole_bytes;
        // enough data: the request queues and returns immediately
        let msg = tuner.optimize_now();
        assert!(msg.starts_with("OPTIMIZING"), "{msg}");
        assert!(tuner.optimize_gauges().pending);
        // the background loop consumes the request, kicks the drain,
        // and pumps it to completion; gauges report the outcome
        let stop = Arc::new(AtomicBool::new(false));
        let handle = tuner.spawn(stop.clone());
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let g = tuner.optimize_gauges();
            if !g.pending && g.runs >= 1 && !store.migration_active() {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "optimize never ran");
            std::thread::sleep(Duration::from_millis(10));
        }
        let g = tuner.optimize_gauges();
        assert_eq!(g.applied, 1, "{g:?}");
        assert!(g.last_recovery_bp > 2500, "{g:?}");
        assert!(store.slab_stats().hole_bytes < holes_before);
        assert!(store.get(b"k00000000").is_some(), "data survived");
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn async_pass_without_thread_is_drivable_inline() {
        let (store, _, tuner) = setup(100);
        drive_lognormal(&store, 5000, 13);
        assert!(tuner.optimize_now().starts_with("OPTIMIZING"));
        tuner.optimize_pending.store(false, Ordering::SeqCst);
        tuner.run_async_pass();
        assert!(store.migration_active(), "apply kicks an incremental drain");
        while store.migration_step_all() {}
        let g = tuner.optimize_gauges();
        assert_eq!((g.runs, g.applied), (1, 1));
        assert_eq!(tuner.history().len(), 1);
    }

    #[test]
    fn sizes_histogram_exposed() {
        let (store, _, tuner) = setup(10);
        drive_lognormal(&store, 100, 5);
        let h = tuner.sizes_histogram().unwrap();
        assert_eq!(h.total_items(), 100);
    }

    #[test]
    fn per_tenant_refine_never_regresses_and_covers_both_modes() {
        let (store, collector, tuner) = setup(100);
        let reg = store.tenants().clone();
        reg.define("small", b"a:", None).unwrap();
        reg.define("large", b"b:", None).unwrap();
        // two sharply divergent unimodal tenants (TV distance 1.0)
        for _ in 0..500 {
            reg.collector(1).record(200);
            reg.collector(2).record(5000);
            collector.record(200);
            collector.record(5000);
        }
        let current = store.chunk_sizes();
        let hist = collector.snapshot();
        let report = tuner.optimize_against(&hist, &current);
        let refined = tuner.per_tenant_refine(&hist, &current, report.clone());
        // adopt-only-if-better: the merged table can never score worse
        assert!(
            refined.new_waste <= report.new_waste,
            "merged {} > global {}",
            refined.new_waste,
            report.new_waste
        );
        // the refined table still admits both tenants' modes
        assert!(refined.new_config.iter().any(|&c| c >= 200));
        assert!(refined.new_config.iter().any(|&c| c >= 5000));
    }

    #[test]
    fn per_tenant_refine_is_inert_without_tenants() {
        let (store, collector, tuner) = setup(100);
        for _ in 0..500 {
            collector.record(300);
        }
        let current = store.chunk_sizes();
        let hist = collector.snapshot();
        let report = tuner.optimize_against(&hist, &current);
        let refined = tuner.per_tenant_refine(&hist, &current, report.clone());
        assert_eq!(refined.new_config, report.new_config);
        assert_eq!(refined.new_waste, report.new_waste);
        let _ = store;
    }

    #[test]
    fn spawned_loop_stops_on_shutdown() {
        let (_, _, tuner) = setup(u64::MAX);
        let stop = Arc::new(AtomicBool::new(false));
        let handle = tuner.spawn(stop.clone());
        std::thread::sleep(Duration::from_millis(150));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }
}
