//! The objective function: total memory holes for a candidate
//! configuration over an observed size histogram.
//!
//! Semantics are IDENTICAL to the L1 Pallas kernel (`waste.py`) and its
//! oracle (`ref.py`): each size is charged the smallest covering chunk;
//! sizes no chunk covers are charged the 2 MiB `SENTINEL` so
//! non-covering configurations always lose. All quantities are integers
//! (the kernel carries them in f64, exact below 2^53), so the two
//! backends agree bit-for-bit — asserted by integration tests.

use crate::util::histogram::SizeHistogram;

/// Must equal `kernels/waste.py::SENTINEL` (2 MiB).
pub const SENTINEL: u64 = 2 << 20;

/// A histogram compacted for repeated waste evaluation: ascending
/// `(size, count)` pairs with prefix sums for O(K log S) evaluation.
#[derive(Clone, Debug)]
pub struct WasteMap {
    sizes: Vec<u32>,
    counts: Vec<u64>,
    /// prefix_count[i] = Σ counts[..i]
    prefix_count: Vec<u64>,
    /// prefix_bytes[i] = Σ sizes[j]*counts[j] for j < i
    prefix_bytes: Vec<u64>,
}

impl WasteMap {
    pub fn from_histogram(hist: &SizeHistogram) -> Self {
        Self::from_pairs(hist.iter().map(|(s, c)| (s as u32, c)))
    }

    /// Build from ascending (size, count) pairs.
    pub fn from_pairs<I: IntoIterator<Item = (u32, u64)>>(pairs: I) -> Self {
        let mut sizes = Vec::new();
        let mut counts = Vec::new();
        for (s, c) in pairs {
            debug_assert!(sizes.last().is_none_or(|&last| last < s), "pairs ascending");
            if c == 0 {
                continue;
            }
            sizes.push(s);
            counts.push(c);
        }
        let mut prefix_count = Vec::with_capacity(sizes.len() + 1);
        let mut prefix_bytes = Vec::with_capacity(sizes.len() + 1);
        let (mut pc, mut pb) = (0u64, 0u64);
        prefix_count.push(0);
        prefix_bytes.push(0);
        for i in 0..sizes.len() {
            pc += counts[i];
            pb += sizes[i] as u64 * counts[i];
            prefix_count.push(pc);
            prefix_bytes.push(pb);
        }
        WasteMap {
            sizes,
            counts,
            prefix_count,
            prefix_bytes,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.sizes.is_empty()
    }

    /// Distinct sizes.
    pub fn len(&self) -> usize {
        self.sizes.len()
    }

    pub fn total_items(&self) -> u64 {
        *self.prefix_count.last().unwrap()
    }

    pub fn total_bytes(&self) -> u64 {
        *self.prefix_bytes.last().unwrap()
    }

    pub fn max_size(&self) -> Option<u32> {
        self.sizes.last().copied()
    }

    pub fn min_size(&self) -> Option<u32> {
        self.sizes.first().copied()
    }

    /// Ascending distinct sizes.
    pub fn sizes(&self) -> &[u32] {
        &self.sizes
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Index of the first size > `x` (prefix boundary).
    #[inline]
    fn upper_bound(&self, x: u32) -> usize {
        self.sizes.partition_point(|&s| s <= x)
    }

    /// Total waste for `config` (need not be sorted or deduplicated —
    /// we sort a scratch copy; for the hot path use
    /// [`WasteMap::waste_of_sorted`]).
    pub fn waste_of(&self, config: &[u32]) -> u64 {
        let mut sorted = config.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        self.waste_of_sorted(&sorted)
    }

    /// Total waste for an ascending, deduplicated `config`.
    ///
    /// For each consecutive chunk pair `(lo, hi]` the charged bytes are
    /// `hi * items_in_range - bytes_in_range`, O(1) via prefix sums —
    /// O(K log S) total.
    pub fn waste_of_sorted(&self, config: &[u32]) -> u64 {
        debug_assert!(config.windows(2).all(|w| w[0] < w[1]));
        let mut waste = 0u64;
        let mut lo_idx = 0usize; // first size index not yet covered
        for &chunk in config {
            let hi_idx = self.upper_bound(chunk);
            if hi_idx > lo_idx {
                let items = self.prefix_count[hi_idx] - self.prefix_count[lo_idx];
                let bytes = self.prefix_bytes[hi_idx] - self.prefix_bytes[lo_idx];
                waste += chunk as u64 * items - bytes;
                lo_idx = hi_idx;
            }
        }
        // sizes above every chunk: charged the sentinel
        let n = self.sizes.len();
        if lo_idx < n {
            let items = self.prefix_count[n] - self.prefix_count[lo_idx];
            let bytes = self.prefix_bytes[n] - self.prefix_bytes[lo_idx];
            waste += SENTINEL * items - bytes;
        }
        waste
    }

    /// Per-class breakdown `(chunk, items, waste)` for reporting.
    pub fn waste_breakdown(&self, config: &[u32]) -> Vec<(u32, u64, u64)> {
        let mut sorted = config.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        let mut rows = Vec::with_capacity(sorted.len());
        let mut lo_idx = 0usize;
        for &chunk in &sorted {
            let hi_idx = self.upper_bound(chunk);
            let items = self.prefix_count[hi_idx] - self.prefix_count[lo_idx];
            let bytes = self.prefix_bytes[hi_idx] - self.prefix_bytes[lo_idx];
            rows.push((chunk, items, chunk as u64 * items - bytes));
            lo_idx = hi_idx;
        }
        rows
    }

    /// Naive O(S·K) reference used by tests to validate the prefix-sum
    /// fast path.
    pub fn waste_of_naive(&self, config: &[u32]) -> u64 {
        let mut waste = 0u64;
        for (i, &s) in self.sizes.iter().enumerate() {
            let chunk = config
                .iter()
                .copied()
                .filter(|&c| c >= s)
                .min()
                .map(u64::from)
                .unwrap_or(SENTINEL);
            waste += (chunk - s as u64) * self.counts[i];
        }
        waste
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn map(pairs: &[(u32, u64)]) -> WasteMap {
        WasteMap::from_pairs(pairs.iter().copied())
    }

    #[test]
    fn single_bucket() {
        let m = map(&[(100, 1)]);
        assert_eq!(m.waste_of(&[128]), 28);
        assert_eq!(m.waste_of(&[100]), 0);
        assert_eq!(m.waste_of(&[64]), SENTINEL - 100);
    }

    #[test]
    fn smallest_covering_chunk() {
        let m = map(&[(200, 1)]);
        assert_eq!(m.waste_of(&[1024, 256, 512]), 56);
    }

    #[test]
    fn paper_table1_shape() {
        // uniform sizes 1..=1024, old config from Table 1
        let m = WasteMap::from_pairs((1..=1024u32).map(|s| (s, 1)));
        let cfg = [304u32, 384, 480, 600, 752, 944];
        let fast = m.waste_of(&cfg);
        assert_eq!(fast, m.waste_of_naive(&cfg));
        // sizes 945..=1024 are uncovered -> sentinel charges dominate
        assert!(fast > SENTINEL);
    }

    #[test]
    fn fast_matches_naive_random() {
        let mut rng = Pcg64::new(9);
        for _ in 0..50 {
            let n = 1 + rng.gen_range(200) as usize;
            let mut sizes: Vec<u32> = (0..n)
                .map(|_| 1 + rng.gen_range(10_000) as u32)
                .collect();
            sizes.sort_unstable();
            sizes.dedup();
            let pairs: Vec<(u32, u64)> = sizes
                .iter()
                .map(|&s| (s, 1 + rng.gen_range(1000)))
                .collect();
            let m = WasteMap::from_pairs(pairs.iter().copied());
            let k = 1 + rng.gen_range(8) as usize;
            let cfg: Vec<u32> = (0..k).map(|_| 1 + rng.gen_range(12_000) as u32).collect();
            assert_eq!(m.waste_of(&cfg), m.waste_of_naive(&cfg), "cfg {cfg:?}");
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = WasteMap::from_pairs((50..=500u32).step_by(7).map(|s| (s, (s % 13) as u64)));
        let cfg = [96u32, 200, 350, 512];
        let rows = m.waste_breakdown(&cfg);
        let total: u64 = rows.iter().map(|(_, _, w)| w).sum();
        assert_eq!(total, m.waste_of(&cfg));
        let items: u64 = rows.iter().map(|(_, n, _)| n).sum();
        assert_eq!(items, m.total_items());
    }

    #[test]
    fn empty_histogram_zero_waste() {
        let m = WasteMap::from_pairs(std::iter::empty());
        assert!(m.is_empty());
        assert_eq!(m.waste_of(&[100]), 0);
    }

    #[test]
    fn zero_counts_skipped() {
        let m = map(&[(10, 0), (20, 5)]);
        assert_eq!(m.len(), 1);
        assert_eq!(m.waste_of(&[30]), 50);
    }

    #[test]
    fn duplicate_and_unsorted_configs_ok() {
        let m = map(&[(100, 2), (300, 1)]);
        assert_eq!(m.waste_of(&[512, 128, 128, 512]), 2 * 28 + 212);
    }

    #[test]
    fn from_histogram_matches_pairs() {
        let mut h = SizeHistogram::new(1000);
        h.record_n(100, 3);
        h.record_n(999, 2);
        h.record_n(20_000, 1); // overflow side
        let m = WasteMap::from_histogram(&h);
        assert_eq!(m.total_items(), 6);
        assert_eq!(m.max_size(), Some(20_000));
    }
}
