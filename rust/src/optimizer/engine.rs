//! Backend-pluggable optimizer front door.
//!
//! The algorithms ([`hillclimb`](super::hillclimb),
//! [`steepest`](super::steepest), [`dp`](super::dp)) are generic over a
//! [`WasteBackend`]; two implementations exist:
//!
//! * [`RustBackend`] — the exact prefix-sum evaluator ([`WasteMap`]).
//! * `runtime::XlaWasteBackend` — the AOT Pallas kernel over PJRT
//!   (bit-identical results; one `waste_eval` call scores 256
//!   candidates).

use super::hillclimb::{paper_hill_climb, HillClimbParams};
use super::steepest::{steepest_descent, SteepestParams};
use super::waste::WasteMap;
use crate::config::settings::Algorithm;
use crate::util::histogram::SizeHistogram;
use std::time::Instant;

/// Scores candidate chunk configurations against a fixed histogram.
pub trait WasteBackend {
    /// Wasted bytes for each configuration (rows may be unsorted and
    /// contain duplicates; see `waste.rs` semantics).
    fn eval_batch(&self, configs: &[Vec<u32>]) -> Vec<u64>;

    fn eval_one(&self, config: &[u32]) -> u64 {
        self.eval_batch(std::slice::from_ref(&config.to_vec()))[0]
    }

    /// Preferred number of configurations per `eval_batch` call.
    fn preferred_batch(&self) -> usize {
        1
    }

    fn name(&self) -> &'static str;
}

/// Exact in-process evaluator.
pub struct RustBackend {
    map: WasteMap,
}

impl RustBackend {
    pub fn new(map: WasteMap) -> Self {
        RustBackend { map }
    }

    pub fn map(&self) -> &WasteMap {
        &self.map
    }
}

impl WasteBackend for RustBackend {
    fn eval_batch(&self, configs: &[Vec<u32>]) -> Vec<u64> {
        configs.iter().map(|c| self.map.waste_of(c)).collect()
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// What one optimization run produced.
#[derive(Clone, Debug)]
pub struct OptimizeReport {
    pub algorithm: Algorithm,
    pub backend: &'static str,
    /// Full chunk table before / after (prefix + learned span + suffix).
    pub old_config: Vec<u32>,
    pub new_config: Vec<u32>,
    /// The learned span only (what the paper's tables list).
    pub old_span: Vec<u32>,
    pub new_span: Vec<u32>,
    pub old_waste: u64,
    pub new_waste: u64,
    pub iterations: u64,
    pub evaluations: u64,
    pub elapsed: std::time::Duration,
}

impl OptimizeReport {
    /// The paper's headline: fraction of wasted memory recovered.
    pub fn recovery(&self) -> f64 {
        if self.old_waste == 0 {
            0.0
        } else {
            1.0 - self.new_waste as f64 / self.old_waste as f64
        }
    }
}

/// Optimizer configuration.
#[derive(Clone, Debug)]
pub struct OptimizerParams {
    pub algorithm: Algorithm,
    pub seed: u64,
    /// Algorithm 1's non-improving-tries budget.
    pub max_failures: u32,
    /// Safety cap on iterations.
    pub max_iters: u64,
    /// Chunk bounds (page size upper).
    pub min_chunk: u32,
    pub max_chunk: u32,
}

impl Default for OptimizerParams {
    fn default() -> Self {
        OptimizerParams {
            algorithm: Algorithm::SteepestDescent,
            seed: 0x51ab_f00d,
            max_failures: 1000,
            max_iters: 5_000_000,
            min_chunk: crate::slab::MIN_CHUNK as u32,
            max_chunk: crate::slab::PAGE_SIZE as u32,
        }
    }
}

/// Run one optimization against `current_config` (the store's full
/// chunk table) and the observed `hist`.
///
/// Only the **engaged span** — the contiguous run of classes that
/// actually received items — is learned (K stays constant, the paper's
/// constraint); prefix and suffix classes are preserved so
/// out-of-distribution items still have a home.
pub fn optimize<B: WasteBackend>(
    backend: &B,
    hist: &SizeHistogram,
    current_config: &[usize],
    params: &OptimizerParams,
) -> OptimizeReport {
    let started = Instant::now();
    let full: Vec<u32> = current_config.iter().map(|&c| c as u32).collect();
    let old_waste = backend.eval_one(&full);

    // engaged span: classes covering [min_seen, max_seen]
    let (span_lo, span_hi) = engaged_span(&full, hist);
    let old_span: Vec<u32> = full[span_lo..span_hi].to_vec();

    let assemble = |span: &[u32]| -> Vec<u32> {
        let mut cfg = Vec::with_capacity(full.len());
        cfg.extend_from_slice(&full[..span_lo]);
        cfg.extend_from_slice(span);
        cfg.extend_from_slice(&full[span_hi..]);
        cfg
    };

    let outcome = match params.algorithm {
        Algorithm::PaperHillClimb => paper_hill_climb(
            backend,
            &full,
            span_lo..span_hi,
            &HillClimbParams {
                seed: params.seed,
                max_failures: params.max_failures,
                max_iters: params.max_iters,
                min_chunk: params.min_chunk,
                max_chunk: params.max_chunk,
            },
        ),
        Algorithm::SteepestDescent => steepest_descent(
            backend,
            &full,
            span_lo..span_hi,
            &SteepestParams {
                max_iters: params.max_iters,
                min_chunk: params.min_chunk,
                max_chunk: params.max_chunk,
                initial_step: 256,
            },
        ),
        Algorithm::DpOptimal => {
            let map = WasteMap::from_histogram(hist);
            let k = span_hi - span_lo;
            // items above the learned span overflow into the first
            // suffix class (greedy searches may use it too — the bound
            // must share the search space)
            let overflow = full.get(span_hi).copied();
            let dp = super::dp::dp_optimal_with_overflow(&map, k, overflow);
            let mut cfg = assemble(&dp.config);
            cfg.sort_unstable();
            cfg.dedup();
            super::hillclimb::Outcome {
                config: cfg,
                evaluations: dp.evaluations,
                iterations: dp.iterations,
            }
        }
    };

    let new_waste = backend.eval_one(&outcome.config);
    // never regress: keep the old table when the search failed to improve
    let (new_config, new_waste) = if new_waste > old_waste {
        (full.clone(), old_waste)
    } else {
        (outcome.config, new_waste)
    };
    let new_span: Vec<u32> = new_config
        .iter()
        .copied()
        .filter(|c| !full[..span_lo].contains(c) && !full[span_hi..].contains(c))
        .collect();

    OptimizeReport {
        algorithm: params.algorithm,
        backend: backend.name(),
        old_config: full,
        old_span,
        new_span,
        new_config,
        old_waste,
        new_waste,
        iterations: outcome.iterations,
        evaluations: outcome.evaluations,
        elapsed: started.elapsed(),
    }
}

/// Index range (lo..hi) of classes that received items.
fn engaged_span(full: &[u32], hist: &SizeHistogram) -> (usize, usize) {
    if hist.total_items() == 0 {
        return (0, full.len());
    }
    let min_seen = hist.iter().next().map(|(s, _)| s as u32).unwrap_or(0);
    let max_seen = hist.max_size() as u32;
    let lo = full.partition_point(|&c| c < min_seen);
    let hi = full.partition_point(|&c| c < max_seen) + 1;
    (lo.min(full.len() - 1), hi.min(full.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::geometry::memcached_default_sizes;
    use crate::util::rng::Pcg64;

    fn lognormal_hist(median: f64, sigma: f64, n: usize, seed: u64) -> SizeHistogram {
        let mut h = SizeHistogram::new(16384);
        let mut rng = Pcg64::new(seed);
        for _ in 0..n {
            let s = rng.lognormal(median, sigma).round().max(50.0) as usize;
            h.record(s.min(16384));
        }
        h
    }

    #[test]
    fn engaged_span_covers_histogram() {
        let full: Vec<u32> = memcached_default_sizes().iter().map(|&c| c as u32).collect();
        let h = lognormal_hist(518.0, 0.126, 10_000, 1);
        let (lo, hi) = engaged_span(&full, &h);
        let min_seen = h.iter().next().unwrap().0 as u32;
        let max_seen = h.max_size() as u32;
        assert!(full[lo] >= min_seen);
        if lo > 0 {
            assert!(full[lo - 1] < min_seen);
        }
        assert!(full[hi - 1] >= max_seen, "top class covers max");
    }

    #[test]
    fn all_algorithms_reduce_waste_on_paper_t1() {
        let h = lognormal_hist(518.0, 0.126, 50_000, 2);
        let map = WasteMap::from_histogram(&h);
        let backend = RustBackend::new(map);
        let full = memcached_default_sizes();
        for alg in [
            Algorithm::PaperHillClimb,
            Algorithm::SteepestDescent,
            Algorithm::DpOptimal,
        ] {
            let params = OptimizerParams {
                algorithm: alg,
                max_failures: 300, // keep the paper algorithm fast in tests
                ..Default::default()
            };
            let report = optimize(&backend, &h, &full, &params);
            assert!(
                report.new_waste < report.old_waste,
                "{alg:?}: {} !< {}",
                report.new_waste,
                report.old_waste
            );
            assert!(
                report.recovery() > 0.25,
                "{alg:?}: recovery {}",
                report.recovery()
            );
            // config stays valid
            let mut sorted = report.new_config.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), report.new_config.len(), "{alg:?} emitted dup");
        }
    }

    #[test]
    fn dp_is_lower_bound() {
        let h = lognormal_hist(1210.0, 0.09, 30_000, 3);
        let backend = RustBackend::new(WasteMap::from_histogram(&h));
        let full = memcached_default_sizes();
        let mut wastes = std::collections::BTreeMap::new();
        for alg in [
            Algorithm::PaperHillClimb,
            Algorithm::SteepestDescent,
            Algorithm::DpOptimal,
        ] {
            let params = OptimizerParams {
                algorithm: alg,
                max_failures: 500,
                ..Default::default()
            };
            wastes.insert(format!("{alg:?}"), optimize(&backend, &h, &full, &params).new_waste);
        }
        let dp = wastes["DpOptimal"];
        assert!(dp <= wastes["PaperHillClimb"], "{wastes:?}");
        assert!(dp <= wastes["SteepestDescent"], "{wastes:?}");
    }

    #[test]
    fn never_regresses_on_degenerate_histograms() {
        let mut h = SizeHistogram::new(1024);
        h.record_n(600, 1000); // exactly a default class size
        let backend = RustBackend::new(WasteMap::from_histogram(&h));
        let full = memcached_default_sizes();
        let report = optimize(&backend, &h, &full, &OptimizerParams::default());
        assert_eq!(report.new_waste, 0, "exact fit is reachable");
        assert!(report.new_waste <= report.old_waste);
    }

    #[test]
    fn empty_histogram_keeps_config() {
        let h = SizeHistogram::new(64);
        let backend = RustBackend::new(WasteMap::from_histogram(&h));
        let full = memcached_default_sizes();
        let report = optimize(&backend, &h, &full, &OptimizerParams::default());
        assert_eq!(report.old_waste, 0);
        assert_eq!(report.new_waste, 0);
    }
}
