//! Algorithm 1 as published: greedy hill climbing by random ±1-byte
//! moves of a randomly selected slab class, stopping after `count`
//! consecutive non-improving tries.
//!
//! Two faithful-intent corrections to the paper's pseudocode (which
//! contains an obvious transcription slip — `newwaste = oldwaste` on
//! the accept branch — and resets the counter on *equal* waste, which
//! would random-walk plateaus forever):
//!
//! * accept when `newwaste <= oldwaste` (as written), but reset the
//!   failure counter only on **strict** improvement, so flat plateaus
//!   terminate;
//! * reject moves that break the strictly-ascending class invariant
//!   (memcached refuses such `slab_sizes` lists); a rejected move
//!   counts as a failed try.

use super::engine::WasteBackend;
use crate::util::rng::Pcg64;
use std::ops::Range;

/// Search outcome shared by the greedy algorithms.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub config: Vec<u32>,
    pub iterations: u64,
    pub evaluations: u64,
}

#[derive(Clone, Debug)]
pub struct HillClimbParams {
    pub seed: u64,
    /// The paper's `count <= 1000` budget of consecutive failures.
    pub max_failures: u32,
    pub max_iters: u64,
    pub min_chunk: u32,
    pub max_chunk: u32,
}

impl Default for HillClimbParams {
    fn default() -> Self {
        HillClimbParams {
            seed: 0x51ab_f00d,
            max_failures: 1000,
            max_iters: 5_000_000,
            min_chunk: crate::slab::MIN_CHUNK as u32,
            max_chunk: crate::slab::PAGE_SIZE as u32,
        }
    }
}

/// Run Algorithm 1 over the learnable `span` of `full` (other classes
/// stay fixed but participate in every waste evaluation).
pub fn paper_hill_climb<B: WasteBackend>(
    backend: &B,
    full: &[u32],
    span: Range<usize>,
    params: &HillClimbParams,
) -> Outcome {
    let mut rng = Pcg64::new(params.seed);
    let mut config = full.to_vec();
    let mut old_waste = backend.eval_one(&config);
    let mut evals = 1u64;
    let mut iters = 0u64;
    let mut failures = 0u32;

    let k = span.len();
    if k == 0 {
        return Outcome {
            config,
            iterations: 0,
            evaluations: evals,
        };
    }

    while failures <= params.max_failures && iters < params.max_iters {
        iters += 1;
        // "Temporarily move a randomly selected slab's chunk size up or
        // down 1 byte"
        let idx = span.start + rng.gen_range(k as u64) as usize;
        let up = rng.chance(0.5);
        let old_value = config[idx];
        let new_value = if up {
            old_value.saturating_add(1)
        } else {
            old_value.saturating_sub(1)
        };

        if !move_is_valid(&config, idx, new_value, params) {
            failures += 1;
            continue;
        }

        config[idx] = new_value;
        let new_waste = backend.eval_one(&config);
        evals += 1;
        if new_waste <= old_waste {
            let improved = new_waste < old_waste;
            old_waste = new_waste;
            if improved {
                failures = 0;
            } else {
                failures += 1; // plateau step: accepted but not progress
            }
        } else {
            config[idx] = old_value; // "Reset the Slab chunk sizes"
            failures += 1;
        }
    }

    Outcome {
        config,
        iterations: iters,
        evaluations: evals,
    }
}

/// A move is valid when bounds and strict ascending order hold.
fn move_is_valid(config: &[u32], idx: usize, new_value: u32, p: &HillClimbParams) -> bool {
    if new_value < p.min_chunk || new_value > p.max_chunk {
        return false;
    }
    if idx > 0 && config[idx - 1] >= new_value {
        return false;
    }
    if idx + 1 < config.len() && config[idx + 1] <= new_value {
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::engine::RustBackend;
    use crate::optimizer::waste::WasteMap;

    fn backend(pairs: &[(u32, u64)]) -> RustBackend {
        RustBackend::new(WasteMap::from_pairs(pairs.iter().copied()))
    }

    #[test]
    fn converges_to_exact_fit_single_class() {
        // all items are 500 bytes; one learnable class starting at 600
        let b = backend(&[(500, 1000)]);
        let full = vec![96u32, 600, 1024];
        let out = paper_hill_climb(&b, &full, 1..2, &HillClimbParams::default());
        assert_eq!(out.config[1], 500, "chunk should descend to the item size");
        assert_eq!(b.eval_one(&out.config), 0);
    }

    #[test]
    fn respects_span_fixed_classes() {
        let b = backend(&[(500, 10)]);
        let full = vec![96u32, 600, 1024];
        let out = paper_hill_climb(&b, &full, 1..2, &HillClimbParams::default());
        assert_eq!(out.config[0], 96);
        assert_eq!(out.config[2], 1024);
    }

    #[test]
    fn keeps_strict_order() {
        let b = backend(&[(100, 5), (120, 5), (140, 5)]);
        let full = vec![96u32, 110, 130, 150];
        let out = paper_hill_climb(&b, &full, 0..4, &HillClimbParams::default());
        assert!(out.config.windows(2).all(|w| w[0] < w[1]), "{:?}", out.config);
    }

    #[test]
    fn never_worse_than_start() {
        let b = backend(&[(300, 7), (400, 3), (777, 9)]);
        let full = vec![304u32, 480, 944];
        let start = b.eval_one(&full);
        let out = paper_hill_climb(&b, &full, 0..3, &HillClimbParams::default());
        assert!(b.eval_one(&out.config) <= start);
    }

    #[test]
    fn deterministic_per_seed() {
        let b = backend(&[(200, 5), (350, 5), (520, 5)]);
        let full = vec![96u32, 240, 480, 600];
        let p = HillClimbParams {
            max_failures: 200,
            ..Default::default()
        };
        let a = paper_hill_climb(&b, &full, 1..4, &p);
        let c = paper_hill_climb(&b, &full, 1..4, &p);
        assert_eq!(a.config, c.config);
        assert_eq!(a.iterations, c.iterations);
    }

    #[test]
    fn empty_span_is_noop() {
        let b = backend(&[(100, 1)]);
        let full = vec![128u32];
        let out = paper_hill_climb(&b, &full, 0..0, &HillClimbParams::default());
        assert_eq!(out.config, full);
    }
}
