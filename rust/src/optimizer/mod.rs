//! The paper's contribution: **learning slab classes** from the
//! observed item-size distribution to minimize memory holes.
//!
//! * [`collector`] — lock-striped online histogram of accounted item
//!   sizes (wired into every `set` via `store::SizeObserver`).
//! * [`waste`] — the objective function: exact wasted-bytes evaluation
//!   of a candidate chunk configuration against a histogram; the pure
//!   rust twin of the L1 Pallas kernel (bit-identical semantics).
//! * [`hillclimb`] — Algorithm 1 as published: random ±1-byte moves,
//!   stop after 1000 consecutive non-improving tries.
//! * [`steepest`] — batched steepest descent with shrinking steps; maps
//!   one optimization step onto one fused PJRT `hill_step` call.
//! * [`dp`] — exact optimum by divide-and-conquer DP over distinct
//!   sizes: the lower bound the greedy methods are judged against.
//! * [`engine`] — backend-pluggable front door (`Rust` exact evaluator
//!   or `Xla` AOT artifacts) operating on a store's live configuration.
//! * [`autotune`] — the online coordinator: watch the collector, learn,
//!   and live-reconfigure the store when predicted savings are large.

pub mod autotune;
pub mod collector;
pub mod dp;
pub mod engine;
pub mod hillclimb;
pub mod steepest;
pub mod waste;

pub use collector::SizeCollector;
pub use engine::{optimize, OptimizeReport, OptimizerParams, RustBackend, WasteBackend};
pub use waste::WasteMap;
