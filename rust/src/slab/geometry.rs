//! Memcached's default slab-class geometry.
//!
//! Chunk sizes start at `chunk_min` (default 96 B) and grow by `factor`
//! (default 1.25), each size rounded **up** to an 8-byte boundary, until
//! the half-page chunk cap; a final class of one full page closes the
//! chain. With the defaults this reproduces memcached's canonical chain
//!   96, 120, 152, 192, 240, 304, 384, 480, 600, 752, 944, 1184, 1480,
//!   1856, 2320, 2904, 3632, 4544, 5680, 7104, 8880, …
//! — exactly the class sizes quoted in the paper's Tables 1–5.

use super::{MAX_CLASSES, MIN_CHUNK, PAGE_SIZE};

/// Round up to the next multiple of 8 (memcached's CHUNK_ALIGN_BYTES).
#[inline]
pub fn align8(n: usize) -> usize {
    (n + 7) & !7
}

/// The default geometric chunk-size chain.
///
/// * `chunk_min` — first chunk size (memcached: 96).
/// * `factor` — growth factor (memcached: 1.25; the startup option the
///   paper §3 discusses tuning as the pre-existing mitigation).
/// * `page_size` — page/item-size cap; the final class is one full page.
///
/// Returns an ascending, deduplicated, 8-byte-aligned chain capped at
/// [`MAX_CLASSES`] entries.
pub fn default_slab_sizes(chunk_min: usize, factor: f64, page_size: usize) -> Vec<usize> {
    assert!(factor > 1.0, "growth factor must be > 1 (got {factor})");
    assert!(chunk_min >= MIN_CHUNK, "chunk_min {chunk_min} < {MIN_CHUNK}");
    assert!(page_size >= chunk_min * 2, "page too small");

    let chunk_cap = page_size / 2;
    let mut sizes = Vec::new();
    // memcached's slabs_init loop: align the size, emit it, then grow the
    // *aligned* size by the factor (alignment feeds back into the chain).
    let mut size = chunk_min;
    while sizes.len() < MAX_CLASSES - 1 {
        let aligned = align8(size);
        if aligned > chunk_cap {
            break;
        }
        if sizes.last() != Some(&aligned) {
            sizes.push(aligned);
        }
        // Guarantee forward progress even when the factor is too small to
        // clear the 8-byte alignment step (memcached relies on its fixed
        // 63-iteration loop; we dedup, so we must grow explicitly).
        size = ((aligned as f64 * factor) as usize).max(aligned + 1);
    }
    if sizes.last() != Some(&page_size) {
        sizes.push(page_size);
    }
    sizes
}

/// Convenience: the memcached defaults (96 B, 1.25×, 1 MiB page).
pub fn memcached_default_sizes() -> Vec<usize> {
    default_slab_sizes(96, 1.25, PAGE_SIZE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_chain_matches_memcached_and_paper() {
        let sizes = memcached_default_sizes();
        // The prefix quoted in the paper's tables:
        let expected_prefix = [
            96, 120, 152, 192, 240, 304, 384, 480, 600, 752, 944, 1184, 1480, 1856,
            2320, 2904, 3632, 4544, 5680, 7104, 8880,
        ];
        assert_eq!(&sizes[..expected_prefix.len()], &expected_prefix);
        // Final class is the full page.
        assert_eq!(*sizes.last().unwrap(), PAGE_SIZE);
        assert!(sizes.len() <= MAX_CLASSES);
    }

    #[test]
    fn ascending_unique_aligned() {
        for factor in [1.05, 1.1, 1.25, 1.5, 2.0] {
            let sizes = default_slab_sizes(96, factor, PAGE_SIZE);
            for w in sizes.windows(2) {
                assert!(w[0] < w[1], "not ascending at factor {factor}: {w:?}");
            }
            for &s in &sizes[..sizes.len() - 1] {
                assert_eq!(s % 8, 0, "unaligned chunk {s} at factor {factor}");
            }
        }
    }

    #[test]
    fn lower_factor_gives_more_classes() {
        let coarse = default_slab_sizes(96, 1.5, PAGE_SIZE).len();
        let fine = default_slab_sizes(96, 1.08, PAGE_SIZE).len();
        assert!(fine > coarse, "fine={fine} coarse={coarse}");
    }

    #[test]
    fn class_count_capped() {
        let sizes = default_slab_sizes(48, 1.01, PAGE_SIZE);
        assert!(sizes.len() <= MAX_CLASSES);
        assert_eq!(*sizes.last().unwrap(), PAGE_SIZE);
    }

    #[test]
    fn small_pages_work() {
        let sizes = default_slab_sizes(48, 1.25, 4096);
        assert_eq!(*sizes.last().unwrap(), 4096);
        assert!(sizes.iter().all(|&s| s <= 4096));
    }

    #[test]
    #[should_panic(expected = "growth factor")]
    fn rejects_non_growing_factor() {
        default_slab_sizes(96, 1.0, PAGE_SIZE);
    }
}
