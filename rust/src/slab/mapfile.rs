//! mmap-backed slab region — the durable page arena behind `--memory-file`.
//!
//! When warm restart is enabled every slab page lives inside one large
//! file-backed `MAP_SHARED` mapping instead of an anonymous heap
//! allocation. Pages are carved from the region in `page_size` extents;
//! a dropped extent returns to the region's in-process free list (the
//! bytes stay mapped for the life of the process, so optimistic readers
//! can never observe an unmapped page — the same guarantee the limbo
//! list gives heap pages). At clean shutdown the region is `msync`ed
//! and the metadata manifest (`store::restart`) records which extent
//! every class/page-slot occupies, so the next process can re-mmap the
//! file and adopt the pages in place — zero value-byte copies.
//!
//! Follows the repo's zero-crate FFI idiom (`server/sys.rs`): raw
//! `extern "C"` prototypes, `io::Error::last_os_error()` on failure,
//! and logged-never-panicking cleanup paths (a failed `munmap` during
//! drain must not abort the process mid-shutdown).

use crate::util::failpoint;
use std::io;
use std::ops::{Deref, DerefMut};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A page-sized buffer: either an anonymous heap allocation (the
/// default) or an extent of the mmap-backed region (warm restart).
/// Everything downstream (`Page`, `SlabClass`, the free-page pool)
/// works on `PageBuf` and never cares which variant it holds.
pub enum PageBuf {
    Heap(Box<[u8]>),
    Mapped(MappedPage),
}

impl PageBuf {
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            PageBuf::Heap(b) => b.len(),
            PageBuf::Mapped(m) => m.len,
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Byte offset of this buffer inside its region, `None` for heap
    /// buffers. The manifest's page map persists this.
    #[inline]
    pub fn region_offset(&self) -> Option<u64> {
        match self {
            PageBuf::Heap(_) => None,
            PageBuf::Mapped(m) => Some(m.offset),
        }
    }
}

impl Deref for PageBuf {
    type Target = [u8];

    #[inline]
    fn deref(&self) -> &[u8] {
        match self {
            PageBuf::Heap(b) => b,
            PageBuf::Mapped(m) => m.as_slice(),
        }
    }
}

impl DerefMut for PageBuf {
    #[inline]
    fn deref_mut(&mut self) -> &mut [u8] {
        match self {
            PageBuf::Heap(b) => b,
            PageBuf::Mapped(m) => m.as_mut_slice(),
        }
    }
}

impl From<Box<[u8]>> for PageBuf {
    fn from(b: Box<[u8]>) -> PageBuf {
        PageBuf::Heap(b)
    }
}

impl std::fmt::Debug for PageBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageBuf::Heap(b) => write!(f, "PageBuf::Heap({} B)", b.len()),
            PageBuf::Mapped(m) => write!(f, "PageBuf::Mapped({} B @ {})", m.len, m.offset),
        }
    }
}

/// One `page_size` extent of the mapped region. Dropping it returns the
/// extent to the region's free list; the mapping itself stays alive (and
/// readable) until the region is dropped at process exit.
pub struct MappedPage {
    ptr: *mut u8,
    len: usize,
    offset: u64,
    region: Arc<RegionInner>,
}

// The extent is exclusively owned by whoever holds the MappedPage, and
// the backing mapping outlives it (kept alive by the Arc).
unsafe impl Send for MappedPage {}
unsafe impl Sync for MappedPage {}

impl MappedPage {
    #[inline]
    fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    fn as_mut_slice(&mut self) -> &mut [u8] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl Drop for MappedPage {
    fn drop(&mut self) {
        // Return the extent for reuse; never unmaps (readers may still
        // be probing these bytes under the seqlock).
        if let Ok(mut free) = self.region.free.lock() {
            free.push(self.offset);
        }
    }
}

struct RegionInner {
    base: *mut u8,
    len: usize,
    page_size: usize,
    path: PathBuf,
    /// Free extent offsets, LIFO; initialised high→low so the lowest
    /// offsets are handed out first (mirrors the chunk free lists).
    free: Mutex<Vec<u64>>,
}

unsafe impl Send for RegionInner {}
unsafe impl Sync for RegionInner {}

impl Drop for RegionInner {
    fn drop(&mut self) {
        if let Err(e) = unmap(self.base, self.len) {
            // Shutdown path: log, never panic (a poisoned drain would
            // forfeit the manifest write).
            eprintln!(
                "slabforge: munmap of memory file {} failed: {e}",
                self.path.display()
            );
        }
    }
}

/// Handle to the mmap-backed slab arena; cheap to clone (all shards of
/// a store carve pages from the same region).
#[derive(Clone)]
pub struct SlabRegion {
    inner: Arc<RegionInner>,
}

impl SlabRegion {
    /// Create (or truncate) `path` sized for `pages` extents of
    /// `page_size` bytes and map it shared.
    pub fn create(path: &Path, page_size: usize, pages: usize) -> io::Result<SlabRegion> {
        SlabRegion::map(path, page_size, pages, true)
    }

    /// Map an existing memory file; its size must match exactly
    /// (geometry drift between runs invalidates the pair).
    pub fn open(path: &Path, page_size: usize, pages: usize) -> io::Result<SlabRegion> {
        SlabRegion::map(path, page_size, pages, false)
    }

    fn map(path: &Path, page_size: usize, pages: usize, create: bool) -> io::Result<SlabRegion> {
        assert!(page_size > 0 && pages > 0);
        if failpoint::fired("restart.mmap.fail") {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                "failpoint restart.mmap.fail",
            ));
        }
        let len = page_size * pages;
        let base = map_file(path, len, create)?;
        // High→low so `take()` pops offset 0 first.
        let free: Vec<u64> = (0..pages as u64).rev().map(|i| i * page_size as u64).collect();
        Ok(SlabRegion {
            inner: Arc::new(RegionInner {
                base,
                len,
                page_size,
                path: path.to_path_buf(),
                free: Mutex::new(free),
            }),
        })
    }

    #[inline]
    pub fn page_size(&self) -> usize {
        self.inner.page_size
    }

    #[inline]
    pub fn capacity_pages(&self) -> usize {
        self.inner.len / self.inner.page_size
    }

    pub fn path(&self) -> &Path {
        &self.inner.path
    }

    /// Carve the next free extent; `None` when the region is exhausted
    /// (the allocator treats it like heap OOM: evict or reject).
    pub fn take(&self) -> Option<PageBuf> {
        let offset = self.inner.free.lock().ok()?.pop()?;
        Some(PageBuf::Mapped(self.page_at(offset)))
    }

    /// Claim a specific extent (warm-restart recovery adopting the
    /// persisted page map). Errors on a misaligned, out-of-range, or
    /// already-claimed offset — all symptoms of a corrupt manifest.
    pub fn claim(&self, offset: u64) -> io::Result<PageBuf> {
        let ps = self.inner.page_size as u64;
        if offset % ps != 0 || offset + ps > self.inner.len as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("page offset {offset} invalid for region of {} B", self.inner.len),
            ));
        }
        let mut free = self
            .inner
            .free
            .lock()
            .map_err(|_| io::Error::new(io::ErrorKind::Other, "region free list poisoned"))?;
        match free.iter().position(|&o| o == offset) {
            Some(i) => {
                free.swap_remove(i);
                Ok(PageBuf::Mapped(self.page_at(offset)))
            }
            None => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("page offset {offset} claimed twice (corrupt page map)"),
            )),
        }
    }

    fn page_at(&self, offset: u64) -> MappedPage {
        MappedPage {
            ptr: unsafe { self.inner.base.add(offset as usize) },
            len: self.inner.page_size,
            offset,
            region: self.inner.clone(),
        }
    }

    /// Flush the whole region to its file (`msync(MS_SYNC)`) — called
    /// before the manifest is written so the file contents the manifest
    /// describes are durable first.
    pub fn sync(&self) -> io::Result<()> {
        sync_map(self.inner.base, self.inner.len)
    }
}

// ---------------------------------------------------------------------------
// raw mmap FFI (unix); non-unix builds degrade to an error so the
// `--memory-file` feature is simply unavailable there.
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod ffi {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const PROT_WRITE: c_int = 2;
    pub const MAP_SHARED: c_int = 1;
    pub const MS_SYNC: c_int = 4;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn msync(addr: *mut c_void, len: usize, flags: c_int) -> c_int;
    }
}

#[cfg(unix)]
fn map_file(path: &Path, len: usize, create: bool) -> io::Result<*mut u8> {
    use std::os::unix::io::AsRawFd;
    let file = if create {
        std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?
    } else {
        std::fs::OpenOptions::new().read(true).write(true).open(path)?
    };
    if create {
        file.set_len(len as u64)?;
    } else {
        let got = file.metadata()?.len();
        if got != len as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("memory file is {got} B, expected {len} B"),
            ));
        }
    }
    let ptr = unsafe {
        ffi::mmap(
            std::ptr::null_mut(),
            len,
            ffi::PROT_READ | ffi::PROT_WRITE,
            ffi::MAP_SHARED,
            file.as_raw_fd(),
            0,
        )
    };
    if ptr as isize == -1 {
        return Err(io::Error::last_os_error());
    }
    Ok(ptr as *mut u8)
    // `file` closes here; the mapping persists independently.
}

#[cfg(unix)]
fn unmap(base: *mut u8, len: usize) -> io::Result<()> {
    if unsafe { ffi::munmap(base as *mut _, len) } != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

#[cfg(unix)]
fn sync_map(base: *mut u8, len: usize) -> io::Result<()> {
    if unsafe { ffi::msync(base as *mut _, len, ffi::MS_SYNC) } != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

#[cfg(not(unix))]
fn map_file(_path: &Path, _len: usize, _create: bool) -> io::Result<*mut u8> {
    Err(io::Error::new(
        io::ErrorKind::Other,
        "--memory-file requires a unix platform",
    ))
}

#[cfg(not(unix))]
fn unmap(_base: *mut u8, _len: usize) -> io::Result<()> {
    Ok(())
}

#[cfg(not(unix))]
fn sync_map(_base: *mut u8, _len: usize) -> io::Result<()> {
    Ok(())
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("slabforge-mapfile-{}-{name}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn create_take_write_reopen_claim() {
        let path = tmp("roundtrip");
        {
            let r = SlabRegion::create(&path, 4096, 4).unwrap();
            assert_eq!(r.capacity_pages(), 4);
            let mut p0 = r.take().unwrap();
            assert_eq!(p0.region_offset(), Some(0), "lowest extent first");
            p0[..4].copy_from_slice(b"warm");
            let p1 = r.take().unwrap();
            assert_eq!(p1.region_offset(), Some(4096));
            r.sync().unwrap();
            std::mem::forget((p0, p1)); // keep extents out of the free list
        }
        {
            let r = SlabRegion::open(&path, 4096, 4).unwrap();
            let p0 = r.claim(0).unwrap();
            assert_eq!(&p0[..4], b"warm", "bytes survive the remap");
            assert!(r.claim(0).is_err(), "double claim rejected");
            assert!(r.claim(123).is_err(), "misaligned claim rejected");
            assert!(r.claim(1 << 40).is_err(), "out-of-range claim rejected");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn dropped_extent_returns_to_pool() {
        let path = tmp("pool");
        let r = SlabRegion::create(&path, 4096, 1).unwrap();
        let p = r.take().unwrap();
        assert!(r.take().is_none(), "region exhausted");
        drop(p);
        assert!(r.take().is_some(), "extent recycled after drop");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn size_mismatch_rejected_on_open() {
        let path = tmp("mismatch");
        drop(SlabRegion::create(&path, 4096, 2).unwrap());
        assert!(SlabRegion::open(&path, 4096, 3).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mmap_failpoint_degrades() {
        let path = tmp("failpoint");
        let _g = failpoint::armed("restart.mmap.fail", "once").unwrap();
        assert!(SlabRegion::create(&path, 4096, 1).is_err());
        // next attempt succeeds (failpoint consumed)
        assert!(SlabRegion::create(&path, 4096, 1).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}
