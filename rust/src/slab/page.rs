//! Pages: fixed-size memory arenas carved into equal chunks.
//!
//! Memory is allocated one page at a time (memcached: 1 MiB). A page is
//! assigned to one slab class and carved into `page_size / chunk_size`
//! chunks; the remainder at the page tail is *page tail waste*
//! (distinct from the per-item holes the paper targets, and tracked
//! separately in stats).
//!
//! Pages are no longer permanently welded to a class: a fully drained
//! page can be dissolved back into its raw buffer ([`Page::into_buf`])
//! and re-carved for a different chunk size ([`Page::from_buf`]) — the
//! mechanism the incremental slab migrator uses to hand memory from the
//! old chunk geometry to the new one without ever holding two full
//! copies of the cache.

use super::mapfile::PageBuf;

/// One page of cache memory, owned by a single slab class. The backing
/// buffer is a [`PageBuf`]: anonymous heap memory by default, or an
/// extent of the mmap-backed region when warm restart is enabled.
pub struct Page {
    data: PageBuf,
    chunk_size: usize,
}

impl Page {
    /// Allocate a zeroed heap page carved into `chunk_size` chunks.
    pub fn new(page_size: usize, chunk_size: usize) -> Self {
        Page::from_buf(vec![0u8; page_size].into_boxed_slice(), chunk_size)
    }

    /// Carve an existing buffer (a recycled page) into `chunk_size`
    /// chunks. The buffer is not zeroed: every chunk is fully
    /// overwritten up to the item length before any read.
    pub fn from_buf(data: impl Into<PageBuf>, chunk_size: usize) -> Self {
        let data = data.into();
        assert!(chunk_size > 0 && chunk_size <= data.len());
        Page { data, chunk_size }
    }

    /// Dissolve the page back into its raw buffer (for the free-page
    /// pool). Only legal once no live chunk references it.
    pub fn into_buf(self) -> PageBuf {
        self.data
    }

    /// Offset of the backing buffer inside the mapped region (`None`
    /// for heap pages) — what the warm-restart page map persists.
    #[inline]
    pub fn region_offset(&self) -> Option<u64> {
        self.data.region_offset()
    }

    /// Number of chunks this page holds.
    #[inline]
    pub fn chunk_count(&self) -> usize {
        self.data.len() / self.chunk_size
    }

    /// Bytes at the page tail not covered by any chunk.
    #[inline]
    pub fn tail_waste(&self) -> usize {
        self.data.len() % self.chunk_size
    }

    #[inline]
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Read-only view of chunk `idx`.
    #[inline]
    pub fn chunk(&self, idx: usize) -> &[u8] {
        let start = idx * self.chunk_size;
        &self.data[start..start + self.chunk_size]
    }

    /// Mutable view of chunk `idx`.
    #[inline]
    pub fn chunk_mut(&mut self, idx: usize) -> &mut [u8] {
        let start = idx * self.chunk_size;
        &mut self.data[start..start + self.chunk_size]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carving() {
        let p = Page::new(1024, 100);
        assert_eq!(p.chunk_count(), 10);
        assert_eq!(p.tail_waste(), 24);
        assert_eq!(p.chunk_size(), 100);
    }

    #[test]
    fn exact_fit_no_tail() {
        let p = Page::new(1024, 256);
        assert_eq!(p.chunk_count(), 4);
        assert_eq!(p.tail_waste(), 0);
    }

    #[test]
    fn chunk_views_are_disjoint() {
        let mut p = Page::new(256, 64);
        p.chunk_mut(0).fill(0xAA);
        p.chunk_mut(1).fill(0xBB);
        assert!(p.chunk(0).iter().all(|&b| b == 0xAA));
        assert!(p.chunk(1).iter().all(|&b| b == 0xBB));
        assert!(p.chunk(2).iter().all(|&b| b == 0));
    }

    #[test]
    fn single_chunk_page() {
        let p = Page::new(1 << 20, 1 << 20);
        assert_eq!(p.chunk_count(), 1);
        assert_eq!(p.tail_waste(), 0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_chunk_panics() {
        let p = Page::new(256, 64);
        let _ = p.chunk(4);
    }

    #[test]
    fn buf_roundtrip_recarves() {
        let mut p = Page::new(256, 64);
        p.chunk_mut(1).fill(0xCD);
        let buf = p.into_buf();
        assert_eq!(buf.len(), 256);
        // re-carve the same memory for a different chunk size
        let p2 = Page::from_buf(buf, 128);
        assert_eq!(p2.chunk_count(), 2);
        assert_eq!(p2.chunk_size(), 128);
    }
}
