//! A slab class: all pages carved to one chunk size, plus the free list
//! and the hole accounting the paper's metric is computed from.
//!
//! Pages occupy stable slots (`ChunkLoc::page` indexes never move), but
//! a slot can be vacated: when every chunk of a page is free the page
//! can be released back to the caller ([`SlabClass::release_drained_pages`])
//! and the slot reused later — the building block of incremental slab
//! migration, where old-geometry classes drain page by page.

use super::mapfile::PageBuf;
use super::page::Page;

/// Location of a chunk within its class: (page slot, chunk index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkLoc {
    pub page: u32,
    pub chunk: u32,
}

/// One slab class.
pub struct SlabClass {
    chunk_size: usize,
    /// Page slots; `None` marks a released page whose slot awaits reuse.
    pages: Vec<Option<Page>>,
    /// Live chunks per page slot — a page with 0 is fully drained.
    page_used: Vec<u32>,
    /// Head of the per-page intrusive item chain (arena item ids,
    /// threaded through `ItemMeta::{pg_prev,pg_next}`), parallel to
    /// `pages`. Owned by the store: the class only provides the stable
    /// per-page slot, so that a drain can enumerate a page's residents
    /// in O(chunks/page). `u32::MAX` = empty.
    item_head: Vec<u32>,
    /// Released slots available for the next added page.
    vacant: Vec<u32>,
    free: Vec<ChunkLoc>,
    used_chunks: usize,
    /// Σ of the *requested* sizes of live items — `used_chunks *
    /// chunk_size - requested_bytes` is this class's total memory hole.
    requested_bytes: u64,
}

/// Point-in-time statistics for one class (the `stats slabs` rows).
#[derive(Clone, Debug, PartialEq)]
pub struct ClassStats {
    pub chunk_size: usize,
    pub pages: usize,
    pub total_chunks: usize,
    pub used_chunks: usize,
    pub free_chunks: usize,
    /// Σ requested bytes of live items.
    pub requested_bytes: u64,
    /// Σ chunk bytes of live items (`used_chunks * chunk_size`).
    pub allocated_bytes: u64,
    /// allocated − requested: the paper's "memory wasted" for this class.
    pub hole_bytes: u64,
    /// Unusable page-tail bytes (page_size % chunk_size per page).
    pub tail_waste_bytes: u64,
}

impl SlabClass {
    pub fn new(chunk_size: usize) -> Self {
        SlabClass {
            chunk_size,
            pages: Vec::new(),
            page_used: Vec::new(),
            item_head: Vec::new(),
            vacant: Vec::new(),
            free: Vec::new(),
            used_chunks: 0,
            requested_bytes: 0,
        }
    }

    #[inline]
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    #[inline]
    pub fn has_free_chunk(&self) -> bool {
        !self.free.is_empty()
    }

    /// Live (non-released) pages.
    #[inline]
    pub fn pages(&self) -> usize {
        self.pages.iter().filter(|p| p.is_some()).count()
    }

    #[inline]
    pub fn used_chunks(&self) -> usize {
        self.used_chunks
    }

    /// Grow the class by one page carved from `buf`; its chunks join
    /// the free list. Released slots are reused before new ones.
    pub fn add_page(&mut self, buf: impl Into<PageBuf>) {
        let page = Page::from_buf(buf, self.chunk_size);
        let slot = match self.vacant.pop() {
            Some(s) => s,
            None => {
                self.pages.push(None);
                self.page_used.push(0);
                self.item_head.push(super::NIL_ITEM);
                (self.pages.len() - 1) as u32
            }
        };
        // Reverse order so the lowest offsets are handed out first.
        for chunk in (0..page.chunk_count() as u32).rev() {
            self.free.push(ChunkLoc { page: slot, chunk });
        }
        self.page_used[slot as usize] = 0;
        self.item_head[slot as usize] = super::NIL_ITEM;
        self.pages[slot as usize] = Some(page);
    }

    /// Take a free chunk, accounting `requested` bytes of real payload.
    /// Returns `None` when the class has no free chunk (caller decides
    /// whether to add a page or evict).
    pub fn alloc(&mut self, requested: usize) -> Option<ChunkLoc> {
        debug_assert!(requested <= self.chunk_size);
        let loc = self.free.pop()?;
        self.used_chunks += 1;
        self.page_used[loc.page as usize] += 1;
        self.requested_bytes += requested as u64;
        Some(loc)
    }

    /// Return a chunk to the free list, un-accounting its payload.
    pub fn free(&mut self, loc: ChunkLoc, requested: usize) {
        debug_assert!(self.used_chunks > 0);
        debug_assert!(self.requested_bytes >= requested as u64);
        debug_assert!(self.page_used[loc.page as usize] > 0);
        self.used_chunks -= 1;
        self.page_used[loc.page as usize] -= 1;
        self.requested_bytes -= requested as u64;
        self.free.push(loc);
    }

    /// Adjust accounting when an item is resized in place (append/
    /// prepend staying within the same chunk).
    pub fn reaccount(&mut self, old_requested: usize, new_requested: usize) {
        debug_assert!(new_requested <= self.chunk_size);
        self.requested_bytes = self.requested_bytes - old_requested as u64 + new_requested as u64;
    }

    /// Adopt a recovered page at an exact slot (warm-restart recovery).
    /// `used` lists the chunk indexes holding live items; every other
    /// chunk joins the free list. Slots between the current end and
    /// `slot` are created vacant so `ChunkLoc::page` indexes from the
    /// manifest stay valid verbatim. Requested-byte accounting arrives
    /// later, per item, via [`SlabClass::reaccount`] as the store
    /// re-links each resident.
    pub fn restore_page(&mut self, slot: u32, buf: PageBuf, used: &[u32]) -> Result<(), String> {
        let s = slot as usize;
        while self.pages.len() <= s {
            self.pages.push(None);
            self.page_used.push(0);
            self.item_head.push(super::NIL_ITEM);
            self.vacant.push((self.pages.len() - 1) as u32);
        }
        if self.pages[s].is_some() {
            return Err(format!("page slot {slot} restored twice"));
        }
        self.vacant.retain(|&v| v != slot);
        let page = Page::from_buf(buf, self.chunk_size);
        let count = page.chunk_count() as u32;
        let mut is_used = vec![false; count as usize];
        for &c in used {
            if c >= count {
                return Err(format!("chunk {c} out of range for page slot {slot}"));
            }
            if std::mem::replace(&mut is_used[c as usize], true) {
                return Err(format!("chunk {c} on page slot {slot} restored twice"));
            }
        }
        // Reverse order so the lowest offsets are handed out first.
        for chunk in (0..count).rev() {
            if !is_used[chunk as usize] {
                self.free.push(ChunkLoc { page: slot, chunk });
            }
        }
        self.pages[s] = Some(page);
        self.page_used[s] = used.len() as u32;
        self.item_head[s] = super::NIL_ITEM;
        self.used_chunks += used.len();
        Ok(())
    }

    /// `(slot, region_offset)` for every page still holding items — the
    /// warm-restart manifest's page map. Heap-backed pages yield no
    /// entry (persistence only makes sense with a mapped region).
    pub fn page_map(&self) -> Vec<(u32, u64)> {
        self.pages
            .iter()
            .enumerate()
            .filter(|(i, p)| p.is_some() && self.page_used[*i] > 0)
            .filter_map(|(i, p)| {
                p.as_ref()
                    .and_then(Page::region_offset)
                    .map(|off| (i as u32, off))
            })
            .collect()
    }

    /// Release every fully drained page: their chunks leave the free
    /// list, their slots become reusable, and the raw buffers are
    /// handed back (for the allocator's free-page pool).
    pub fn release_drained_pages(&mut self) -> Vec<PageBuf> {
        let mut drained = vec![false; self.pages.len()];
        let mut any = false;
        for (i, p) in self.pages.iter().enumerate() {
            if p.is_some() && self.page_used[i] == 0 {
                drained[i] = true;
                any = true;
            }
        }
        if !any {
            return Vec::new();
        }
        self.free.retain(|loc| !drained[loc.page as usize]);
        let mut out = Vec::new();
        for (i, is_drained) in drained.iter().enumerate() {
            if *is_drained {
                let page = self.pages[i].take().expect("drained page present");
                debug_assert_eq!(
                    self.item_head[i],
                    super::NIL_ITEM,
                    "drained page with a non-empty item chain"
                );
                out.push(page.into_buf());
                self.vacant.push(i as u32);
            }
        }
        out
    }

    /// Head of the per-page item chain for `page` (`NIL_ITEM` = empty).
    #[inline]
    pub fn page_item_head(&self, page: u32) -> u32 {
        self.item_head[page as usize]
    }

    /// Set the per-page item-chain head (the store maintains the links).
    #[inline]
    pub fn set_page_item_head(&mut self, page: u32, id: u32) {
        self.item_head[page as usize] = id;
    }

    /// `(page_slot, live_chunks)` for every page still holding items —
    /// the force-drain path picks its victim page from this.
    pub fn occupied_pages(&self) -> Vec<(u32, u32)> {
        self.pages
            .iter()
            .enumerate()
            .filter(|(i, p)| p.is_some() && self.page_used[*i] > 0)
            .map(|(i, _)| (i as u32, self.page_used[i]))
            .collect()
    }

    #[inline]
    pub fn chunk(&self, loc: ChunkLoc) -> &[u8] {
        self.pages[loc.page as usize]
            .as_ref()
            .expect("chunk in released page")
            .chunk(loc.chunk as usize)
    }

    #[inline]
    pub fn chunk_mut(&mut self, loc: ChunkLoc) -> &mut [u8] {
        self.pages[loc.page as usize]
            .as_mut()
            .expect("chunk in released page")
            .chunk_mut(loc.chunk as usize)
    }

    pub fn stats(&self) -> ClassStats {
        let total_chunks = self
            .pages
            .iter()
            .flatten()
            .map(Page::chunk_count)
            .sum::<usize>();
        let allocated = self.used_chunks as u64 * self.chunk_size as u64;
        ClassStats {
            chunk_size: self.chunk_size,
            pages: self.pages(),
            total_chunks,
            used_chunks: self.used_chunks,
            free_chunks: self.free.len(),
            requested_bytes: self.requested_bytes,
            allocated_bytes: allocated,
            hole_bytes: allocated - self.requested_bytes,
            tail_waste_bytes: self.pages.iter().flatten().map(|p| p.tail_waste() as u64).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(n: usize) -> Box<[u8]> {
        vec![0u8; n].into_boxed_slice()
    }

    #[test]
    fn page_growth_and_alloc() {
        let mut c = SlabClass::new(100);
        assert!(c.alloc(80).is_none());
        c.add_page(buf(1000)); // 10 chunks
        let a = c.alloc(80).unwrap();
        let b = c.alloc(90).unwrap();
        assert_ne!(a, b);
        let s = c.stats();
        assert_eq!(s.used_chunks, 2);
        assert_eq!(s.free_chunks, 8);
        assert_eq!(s.requested_bytes, 170);
        assert_eq!(s.allocated_bytes, 200);
        assert_eq!(s.hole_bytes, 30);
    }

    #[test]
    fn free_returns_chunk_and_accounting() {
        let mut c = SlabClass::new(64);
        c.add_page(buf(256));
        let a = c.alloc(50).unwrap();
        c.free(a, 50);
        let s = c.stats();
        assert_eq!(s.used_chunks, 0);
        assert_eq!(s.requested_bytes, 0);
        assert_eq!(s.hole_bytes, 0);
        assert_eq!(s.free_chunks, 4);
        // freed chunk is reusable
        assert!(c.alloc(10).is_some());
    }

    #[test]
    fn exhaustion() {
        let mut c = SlabClass::new(128);
        c.add_page(buf(256)); // 2 chunks
        assert!(c.alloc(1).is_some());
        assert!(c.alloc(1).is_some());
        assert!(c.alloc(1).is_none());
    }

    #[test]
    fn chunks_hand_out_low_offsets_first() {
        let mut c = SlabClass::new(100);
        c.add_page(buf(1000));
        let a = c.alloc(1).unwrap();
        assert_eq!(a, ChunkLoc { page: 0, chunk: 0 });
    }

    #[test]
    fn data_roundtrip() {
        let mut c = SlabClass::new(32);
        c.add_page(buf(128));
        let loc = c.alloc(5).unwrap();
        c.chunk_mut(loc)[..5].copy_from_slice(b"hello");
        assert_eq!(&c.chunk(loc)[..5], b"hello");
    }

    #[test]
    fn reaccount_moves_hole() {
        let mut c = SlabClass::new(100);
        c.add_page(buf(1000));
        c.alloc(40).unwrap();
        assert_eq!(c.stats().hole_bytes, 60);
        c.reaccount(40, 70);
        assert_eq!(c.stats().hole_bytes, 30);
        assert_eq!(c.stats().requested_bytes, 70);
    }

    #[test]
    fn tail_waste_reported() {
        let mut c = SlabClass::new(300);
        c.add_page(buf(1000)); // 3 chunks, 100 tail
        assert_eq!(c.stats().tail_waste_bytes, 100);
    }

    #[test]
    fn drained_page_released_and_slot_reused() {
        let mut c = SlabClass::new(100);
        c.add_page(buf(1000)); // slot 0
        c.add_page(buf(1000)); // slot 1
        assert_eq!(c.pages(), 2);
        // occupy one chunk on slot 1 (free list pops slot-1 chunks first)
        let held = c.alloc(60).unwrap();
        assert_eq!(held.page, 1);
        // slot 0 is fully free -> released; slot 1 is pinned by `held`
        let bufs = c.release_drained_pages();
        assert_eq!(bufs.len(), 1);
        assert_eq!(c.pages(), 1);
        assert_eq!(c.stats().free_chunks, 9, "slot-0 chunks left the free list");
        // the held chunk still reads/writes
        c.chunk_mut(held)[..2].copy_from_slice(b"ok");
        assert_eq!(&c.chunk(held)[..2], b"ok");
        // a new page reuses the vacated slot
        c.add_page(buf(1000));
        assert_eq!(c.pages(), 2);
        let a = c.alloc(1).unwrap();
        assert_eq!(a.page, 0, "released slot comes back first");
        // nothing is drained now: slot 0 and slot 1 both hold items
        assert!(c.release_drained_pages().is_empty());
    }

    #[test]
    fn restore_page_adopts_exact_slot_and_occupancy() {
        let mut c = SlabClass::new(100);
        // restore at slot 2: slots 0 and 1 materialise vacant so the
        // manifest's ChunkLoc::page indexes stay valid verbatim
        c.restore_page(2, PageBuf::from(buf(1000)), &[0, 3]).unwrap();
        assert_eq!(c.pages(), 1);
        assert_eq!(c.used_chunks(), 2);
        assert_eq!(c.stats().free_chunks, 8);
        // chunk 0 and 3 are live: a fresh alloc must not collide
        let a = c.alloc(10).unwrap();
        assert!(!(a.page == 2 && (a.chunk == 0 || a.chunk == 3)), "{a:?}");
        // duplicate slot, out-of-range chunk, duplicate chunk: rejected
        assert!(c.restore_page(2, PageBuf::from(buf(1000)), &[]).is_err());
        assert!(c.restore_page(3, PageBuf::from(buf(1000)), &[10]).is_err());
        assert!(c.restore_page(4, PageBuf::from(buf(1000)), &[1, 1]).is_err());
        // a later add_page reuses the vacant low slots
        c.add_page(buf(1000));
        let b = c.alloc(1).unwrap();
        assert!(b.page == 0 || b.page == 1, "{b:?}");
    }

    #[test]
    fn occupied_pages_tracks_live_chunks_per_slot() {
        let mut c = SlabClass::new(100);
        c.add_page(buf(1000)); // slot 0
        c.add_page(buf(1000)); // slot 1: handed out first
        for _ in 0..10 {
            c.alloc(1).unwrap(); // fills slot 1
        }
        let one_on_slot0 = c.alloc(1).unwrap();
        assert_eq!(one_on_slot0.page, 0);
        let mut occ = c.occupied_pages();
        occ.sort_unstable();
        assert_eq!(occ, vec![(0, 1), (1, 10)]);
        c.free(one_on_slot0, 1);
        // slot 0 drained: only slot 1 qualifies (used > 0)
        assert_eq!(c.occupied_pages(), vec![(1, 10)]);
    }
}
