//! A slab class: all pages carved to one chunk size, plus the free list
//! and the hole accounting the paper's metric is computed from.

use super::page::Page;

/// Location of a chunk within its class: (page index, chunk index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkLoc {
    pub page: u32,
    pub chunk: u32,
}

/// One slab class.
pub struct SlabClass {
    chunk_size: usize,
    pages: Vec<Page>,
    free: Vec<ChunkLoc>,
    used_chunks: usize,
    /// Σ of the *requested* sizes of live items — `used_chunks *
    /// chunk_size - requested_bytes` is this class's total memory hole.
    requested_bytes: u64,
}

/// Point-in-time statistics for one class (the `stats slabs` rows).
#[derive(Clone, Debug, PartialEq)]
pub struct ClassStats {
    pub chunk_size: usize,
    pub pages: usize,
    pub total_chunks: usize,
    pub used_chunks: usize,
    pub free_chunks: usize,
    /// Σ requested bytes of live items.
    pub requested_bytes: u64,
    /// Σ chunk bytes of live items (`used_chunks * chunk_size`).
    pub allocated_bytes: u64,
    /// allocated − requested: the paper's "memory wasted" for this class.
    pub hole_bytes: u64,
    /// Unusable page-tail bytes (page_size % chunk_size per page).
    pub tail_waste_bytes: u64,
}

impl SlabClass {
    pub fn new(chunk_size: usize) -> Self {
        SlabClass {
            chunk_size,
            pages: Vec::new(),
            free: Vec::new(),
            used_chunks: 0,
            requested_bytes: 0,
        }
    }

    #[inline]
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    #[inline]
    pub fn has_free_chunk(&self) -> bool {
        !self.free.is_empty()
    }

    #[inline]
    pub fn pages(&self) -> usize {
        self.pages.len()
    }

    #[inline]
    pub fn used_chunks(&self) -> usize {
        self.used_chunks
    }

    /// Grow the class by one page; its chunks join the free list.
    pub fn add_page(&mut self, page_size: usize) {
        let page = Page::new(page_size, self.chunk_size);
        let page_idx = self.pages.len() as u32;
        // Reverse order so the lowest offsets are handed out first.
        for chunk in (0..page.chunk_count() as u32).rev() {
            self.free.push(ChunkLoc {
                page: page_idx,
                chunk,
            });
        }
        self.pages.push(page);
    }

    /// Take a free chunk, accounting `requested` bytes of real payload.
    /// Returns `None` when the class has no free chunk (caller decides
    /// whether to add a page or evict).
    pub fn alloc(&mut self, requested: usize) -> Option<ChunkLoc> {
        debug_assert!(requested <= self.chunk_size);
        let loc = self.free.pop()?;
        self.used_chunks += 1;
        self.requested_bytes += requested as u64;
        Some(loc)
    }

    /// Return a chunk to the free list, un-accounting its payload.
    pub fn free(&mut self, loc: ChunkLoc, requested: usize) {
        debug_assert!(self.used_chunks > 0);
        debug_assert!(self.requested_bytes >= requested as u64);
        self.used_chunks -= 1;
        self.requested_bytes -= requested as u64;
        self.free.push(loc);
    }

    /// Adjust accounting when an item is resized in place (append/
    /// prepend staying within the same chunk).
    pub fn reaccount(&mut self, old_requested: usize, new_requested: usize) {
        debug_assert!(new_requested <= self.chunk_size);
        self.requested_bytes = self.requested_bytes - old_requested as u64 + new_requested as u64;
    }

    #[inline]
    pub fn chunk(&self, loc: ChunkLoc) -> &[u8] {
        self.pages[loc.page as usize].chunk(loc.chunk as usize)
    }

    #[inline]
    pub fn chunk_mut(&mut self, loc: ChunkLoc) -> &mut [u8] {
        self.pages[loc.page as usize].chunk_mut(loc.chunk as usize)
    }

    pub fn stats(&self) -> ClassStats {
        let total_chunks = self.pages.iter().map(Page::chunk_count).sum::<usize>();
        let allocated = self.used_chunks as u64 * self.chunk_size as u64;
        ClassStats {
            chunk_size: self.chunk_size,
            pages: self.pages.len(),
            total_chunks,
            used_chunks: self.used_chunks,
            free_chunks: self.free.len(),
            requested_bytes: self.requested_bytes,
            allocated_bytes: allocated,
            hole_bytes: allocated - self.requested_bytes,
            tail_waste_bytes: self.pages.iter().map(|p| p.tail_waste() as u64).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_growth_and_alloc() {
        let mut c = SlabClass::new(100);
        assert!(c.alloc(80).is_none());
        c.add_page(1000); // 10 chunks
        let a = c.alloc(80).unwrap();
        let b = c.alloc(90).unwrap();
        assert_ne!(a, b);
        let s = c.stats();
        assert_eq!(s.used_chunks, 2);
        assert_eq!(s.free_chunks, 8);
        assert_eq!(s.requested_bytes, 170);
        assert_eq!(s.allocated_bytes, 200);
        assert_eq!(s.hole_bytes, 30);
    }

    #[test]
    fn free_returns_chunk_and_accounting() {
        let mut c = SlabClass::new(64);
        c.add_page(256);
        let a = c.alloc(50).unwrap();
        c.free(a, 50);
        let s = c.stats();
        assert_eq!(s.used_chunks, 0);
        assert_eq!(s.requested_bytes, 0);
        assert_eq!(s.hole_bytes, 0);
        assert_eq!(s.free_chunks, 4);
        // freed chunk is reusable
        assert!(c.alloc(10).is_some());
    }

    #[test]
    fn exhaustion() {
        let mut c = SlabClass::new(128);
        c.add_page(256); // 2 chunks
        assert!(c.alloc(1).is_some());
        assert!(c.alloc(1).is_some());
        assert!(c.alloc(1).is_none());
    }

    #[test]
    fn chunks_hand_out_low_offsets_first() {
        let mut c = SlabClass::new(100);
        c.add_page(1000);
        let a = c.alloc(1).unwrap();
        assert_eq!(a, ChunkLoc { page: 0, chunk: 0 });
    }

    #[test]
    fn data_roundtrip() {
        let mut c = SlabClass::new(32);
        c.add_page(128);
        let loc = c.alloc(5).unwrap();
        c.chunk_mut(loc)[..5].copy_from_slice(b"hello");
        assert_eq!(&c.chunk(loc)[..5], b"hello");
    }

    #[test]
    fn reaccount_moves_hole() {
        let mut c = SlabClass::new(100);
        c.add_page(1000);
        c.alloc(40).unwrap();
        assert_eq!(c.stats().hole_bytes, 60);
        c.reaccount(40, 70);
        assert_eq!(c.stats().hole_bytes, 30);
        assert_eq!(c.stats().requested_bytes, 70);
    }

    #[test]
    fn tail_waste_reported() {
        let mut c = SlabClass::new(300);
        c.add_page(1000); // 3 chunks, 100 tail
        assert_eq!(c.stats().tail_waste_bytes, 100);
    }
}
