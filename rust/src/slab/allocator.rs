//! The slab allocator facade: class selection, the global page budget,
//! and whole-cache hole accounting (the paper's measured quantity).

use super::class::{ChunkLoc, ClassStats, SlabClass};
use super::policy::{ChunkSizePolicy, PolicyError};
use std::fmt;

/// Handle to an allocated chunk. `class` indexes the allocator's class
/// table; the location addresses the chunk within the class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkHandle {
    pub class: u16,
    pub loc: ChunkLoc,
}

/// Allocation failures.
#[derive(Debug, Clone, PartialEq)]
pub enum SlabError {
    /// Item exceeds the largest chunk (memcached: SERVER_ERROR object
    /// too large for cache).
    TooLarge { size: usize, max: usize },
    /// The class is full and the global page budget is exhausted; the
    /// caller should evict from `class` and retry (memcached behaviour
    /// with `-M` off is eviction; we surface the decision).
    NeedEviction { class: u16 },
    /// Invalid chunk-size configuration.
    Policy(PolicyError),
}

impl fmt::Display for SlabError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SlabError::TooLarge { size, max } => {
                write!(f, "object too large for cache ({size} > {max})")
            }
            SlabError::NeedEviction { class } => {
                write!(f, "class {class} full and memory limit reached")
            }
            SlabError::Policy(e) => write!(f, "bad slab policy: {e}"),
        }
    }
}

impl std::error::Error for SlabError {}

impl From<PolicyError> for SlabError {
    fn from(e: PolicyError) -> Self {
        SlabError::Policy(e)
    }
}

/// Whole-allocator statistics (aggregated `stats slabs`).
#[derive(Clone, Debug)]
pub struct SlabStats {
    pub per_class: Vec<ClassStats>,
    pub page_size: usize,
    pub pages_allocated: usize,
    pub page_budget: usize,
    pub requested_bytes: u64,
    pub allocated_bytes: u64,
    /// Σ per-class holes — the paper's "Memory wasted (bytes)".
    pub hole_bytes: u64,
    pub tail_waste_bytes: u64,
}

impl SlabStats {
    /// Fraction of allocated chunk memory lost to holes (paper §1: ~10 %).
    pub fn hole_fraction(&self) -> f64 {
        if self.allocated_bytes == 0 {
            0.0
        } else {
            self.hole_bytes as f64 / self.allocated_bytes as f64
        }
    }
}

/// The slab allocator: a class table sharing one page budget.
pub struct SlabAllocator {
    classes: Vec<SlabClass>,
    /// Ascending chunk sizes, parallel to `classes` (lookup table).
    chunk_sizes: Vec<usize>,
    page_size: usize,
    pages_allocated: usize,
    page_budget: usize,
}

impl SlabAllocator {
    /// Build an allocator from a policy, a page size, and a total
    /// memory limit (rounded down to whole pages, ≥ 1).
    pub fn new(
        policy: &ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
    ) -> Result<Self, SlabError> {
        let chunk_sizes = policy.materialize(page_size)?;
        let classes = chunk_sizes.iter().map(|&s| SlabClass::new(s)).collect();
        Ok(SlabAllocator {
            classes,
            chunk_sizes,
            page_size,
            pages_allocated: 0,
            page_budget: (mem_limit / page_size).max(1),
        })
    }

    /// The ascending chunk-size table.
    #[inline]
    pub fn chunk_sizes(&self) -> &[usize] {
        &self.chunk_sizes
    }

    #[inline]
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    #[inline]
    pub fn page_budget(&self) -> usize {
        self.page_budget
    }

    #[inline]
    pub fn pages_allocated(&self) -> usize {
        self.pages_allocated
    }

    /// Largest storable item.
    #[inline]
    pub fn max_item_size(&self) -> usize {
        *self.chunk_sizes.last().unwrap()
    }

    /// Smallest class whose chunk covers `size` (binary search).
    #[inline]
    pub fn class_for_size(&self, size: usize) -> Option<u16> {
        match self.chunk_sizes.binary_search(&size) {
            Ok(i) => Some(i as u16),
            Err(i) if i < self.chunk_sizes.len() => Some(i as u16),
            Err(_) => None,
        }
    }

    /// Chunk size of a class.
    #[inline]
    pub fn chunk_size_of(&self, class: u16) -> usize {
        self.chunk_sizes[class as usize]
    }

    /// Allocate a chunk for an item of `size` bytes.
    pub fn alloc(&mut self, size: usize) -> Result<ChunkHandle, SlabError> {
        let class = self.class_for_size(size).ok_or(SlabError::TooLarge {
            size,
            max: self.max_item_size(),
        })?;
        let ci = class as usize;
        if !self.classes[ci].has_free_chunk() {
            if self.pages_allocated < self.page_budget {
                self.classes[ci].add_page(self.page_size);
                self.pages_allocated += 1;
            } else {
                return Err(SlabError::NeedEviction { class });
            }
        }
        let loc = self.classes[ci]
            .alloc(size)
            .expect("free chunk present after page add");
        Ok(ChunkHandle { class, loc })
    }

    /// Free a chunk, un-accounting the item's requested `size`.
    pub fn free(&mut self, handle: ChunkHandle, size: usize) {
        self.classes[handle.class as usize].free(handle.loc, size);
    }

    /// Re-account an in-place item resize within the same chunk.
    pub fn reaccount(&mut self, handle: ChunkHandle, old_size: usize, new_size: usize) {
        self.classes[handle.class as usize].reaccount(old_size, new_size);
    }

    /// Read a stored chunk.
    #[inline]
    pub fn chunk(&self, handle: ChunkHandle) -> &[u8] {
        self.classes[handle.class as usize].chunk(handle.loc)
    }

    /// Write into a stored chunk.
    #[inline]
    pub fn chunk_mut(&mut self, handle: ChunkHandle) -> &mut [u8] {
        self.classes[handle.class as usize].chunk_mut(handle.loc)
    }

    /// Aggregate statistics (the paper's measurement instrument).
    pub fn stats(&self) -> SlabStats {
        let per_class: Vec<ClassStats> = self.classes.iter().map(SlabClass::stats).collect();
        SlabStats {
            requested_bytes: per_class.iter().map(|c| c.requested_bytes).sum(),
            allocated_bytes: per_class.iter().map(|c| c.allocated_bytes).sum(),
            hole_bytes: per_class.iter().map(|c| c.hole_bytes).sum(),
            tail_waste_bytes: per_class.iter().map(|c| c.tail_waste_bytes).sum(),
            pages_allocated: self.pages_allocated,
            page_budget: self.page_budget,
            page_size: self.page_size,
            per_class,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::PAGE_SIZE;

    fn small() -> SlabAllocator {
        // classes: 96,120,152,192,240,304,384,480,600,752,944,…,4096
        SlabAllocator::new(
            &ChunkSizePolicy::Geometric {
                chunk_min: 96,
                factor: 1.25,
            },
            4096,
            1 << 20,
        )
        .unwrap()
    }

    #[test]
    fn class_selection_smallest_covering() {
        let a = small();
        assert_eq!(a.chunk_size_of(a.class_for_size(1).unwrap()), 96);
        assert_eq!(a.chunk_size_of(a.class_for_size(96).unwrap()), 96);
        assert_eq!(a.chunk_size_of(a.class_for_size(97).unwrap()), 120);
        assert_eq!(a.chunk_size_of(a.class_for_size(500).unwrap()), 600);
        assert_eq!(a.class_for_size(5000), None);
    }

    #[test]
    fn alloc_tracks_holes_like_the_paper() {
        let mut a = small();
        // item of 518 bytes -> 600-byte chunk -> hole of 82
        a.alloc(518).unwrap();
        let s = a.stats();
        assert_eq!(s.requested_bytes, 518);
        assert_eq!(s.allocated_bytes, 600);
        assert_eq!(s.hole_bytes, 82);
        assert!((s.hole_fraction() - 82.0 / 600.0).abs() < 1e-12);
    }

    #[test]
    fn too_large_rejected() {
        let mut a = small();
        match a.alloc(4097) {
            Err(SlabError::TooLarge { size: 4097, max: 4096 }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn page_budget_enforced_then_eviction_requested() {
        // 1 page of 4096 total budget; 96-byte chunks -> 42 chunks
        let mut a = SlabAllocator::new(
            &ChunkSizePolicy::Geometric {
                chunk_min: 96,
                factor: 1.25,
            },
            4096,
            4096,
        )
        .unwrap();
        let per_page = 4096 / 96;
        for _ in 0..per_page {
            a.alloc(50).unwrap();
        }
        match a.alloc(50) {
            Err(SlabError::NeedEviction { class: 0 }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(a.pages_allocated(), 1);
    }

    #[test]
    fn free_then_realloc_reuses_chunk() {
        let mut a = small();
        let h = a.alloc(100).unwrap();
        a.free(h, 100);
        let h2 = a.alloc(110).unwrap();
        assert_eq!(h.class, h2.class);
        assert_eq!(a.stats().used_chunks_total(), 1);
    }

    impl SlabStats {
        fn used_chunks_total(&self) -> usize {
            self.per_class.iter().map(|c| c.used_chunks).sum()
        }
    }

    #[test]
    fn data_roundtrip_via_handle() {
        let mut a = small();
        let h = a.alloc(11).unwrap();
        a.chunk_mut(h)[..11].copy_from_slice(b"hello world");
        assert_eq!(&a.chunk(h)[..11], b"hello world");
    }

    #[test]
    fn explicit_policy_paper_table1() {
        let a = SlabAllocator::new(
            &ChunkSizePolicy::Explicit(vec![461, 510, 557, 614, 702, 943]),
            PAGE_SIZE,
            8 << 20,
        )
        .unwrap();
        // paper's learned T1 config + the implicit page class
        assert_eq!(
            a.chunk_sizes(),
            &[461, 510, 557, 614, 702, 943, PAGE_SIZE]
        );
        assert_eq!(a.chunk_size_of(a.class_for_size(518).unwrap()), 557);
    }

    #[test]
    fn distinct_classes_get_distinct_pages() {
        let mut a = small();
        a.alloc(50).unwrap(); // class 96
        a.alloc(500).unwrap(); // class 600
        assert_eq!(a.pages_allocated(), 2);
        let s = a.stats();
        assert_eq!(s.per_class[0].pages, 1);
        let c600 = s.per_class.iter().find(|c| c.chunk_size == 600).unwrap();
        assert_eq!(c600.pages, 1);
    }
}
