//! The slab allocator facade: class selection, the global page budget,
//! and whole-cache hole accounting (the paper's measured quantity).
//!
//! ## Two generations, one budget
//!
//! A live reconfiguration does not build a second allocator. Instead
//! the allocator itself holds up to two class tables: the **current**
//! generation (where every new allocation lands) and, while a migration
//! drains, the **old** generation (read/free only). Both draw pages
//! from one budget; a fully drained old page dissolves into the
//! free-page pool and is re-carved for the new geometry. The transient
//! overhead of a migration is therefore bounded by
//! [`MIGRATION_PAGE_SLACK`] pages — not the 2× of a shadow copy.

use super::class::{ChunkLoc, ClassStats, SlabClass};
use super::mapfile::{PageBuf, SlabRegion};
use super::policy::{ChunkSizePolicy, PolicyError};
use std::fmt;

/// Extra pages the budget tolerates while a migration is draining: the
/// new geometry needs somewhere to land items before the first old page
/// has fully drained. Constant — independent of cache size.
pub const MIGRATION_PAGE_SLACK: usize = 2;

/// Handle to an allocated chunk. `class` indexes the allocator's class
/// table; the location addresses the chunk within the class. Whether it
/// points into the current or the old generation is tracked by the
/// owner (the store tags each item with its generation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkHandle {
    pub class: u16,
    pub loc: ChunkLoc,
}

/// Allocation failures.
#[derive(Debug, Clone, PartialEq)]
pub enum SlabError {
    /// Item exceeds the largest chunk (memcached: SERVER_ERROR object
    /// too large for cache).
    TooLarge { size: usize, max: usize },
    /// The class is full and the global page budget is exhausted; the
    /// caller should evict from `class` and retry (memcached behaviour
    /// with `-M` off is eviction; we surface the decision).
    NeedEviction { class: u16 },
    /// Invalid chunk-size configuration.
    Policy(PolicyError),
}

impl fmt::Display for SlabError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SlabError::TooLarge { size, max } => {
                write!(f, "object too large for cache ({size} > {max})")
            }
            SlabError::NeedEviction { class } => {
                write!(f, "class {class} full and memory limit reached")
            }
            SlabError::Policy(e) => write!(f, "bad slab policy: {e}"),
        }
    }
}

impl std::error::Error for SlabError {}

impl From<PolicyError> for SlabError {
    fn from(e: PolicyError) -> Self {
        SlabError::Policy(e)
    }
}

/// Whole-allocator statistics (aggregated `stats slabs`). While a
/// migration drains, totals cover **both** generations and `per_class`
/// lists the current-generation classes followed by the old-generation
/// classes still holding pages.
#[derive(Clone, Debug)]
pub struct SlabStats {
    pub per_class: Vec<ClassStats>,
    pub page_size: usize,
    /// Carved pages, both generations.
    pub pages_allocated: usize,
    /// Recycled page buffers waiting in the free pool (still resident).
    pub pages_free: usize,
    pub page_budget: usize,
    pub requested_bytes: u64,
    pub allocated_bytes: u64,
    /// Σ per-class holes — the paper's "Memory wasted (bytes)".
    pub hole_bytes: u64,
    pub tail_waste_bytes: u64,
}

impl SlabStats {
    /// Fraction of allocated chunk memory lost to holes (paper §1: ~10 %).
    pub fn hole_fraction(&self) -> f64 {
        if self.allocated_bytes == 0 {
            0.0
        } else {
            self.hole_bytes as f64 / self.allocated_bytes as f64
        }
    }
}

/// The old (draining) generation of a mid-migration allocator.
struct OldGen {
    classes: Vec<SlabClass>,
    chunk_sizes: Vec<usize>,
}

/// The slab allocator: a class table sharing one page budget, plus —
/// while a migration drains — the previous generation's class table.
pub struct SlabAllocator {
    classes: Vec<SlabClass>,
    /// Ascending chunk sizes, parallel to `classes` (lookup table).
    chunk_sizes: Vec<usize>,
    old: Option<OldGen>,
    /// Recycled page buffers (from drained old pages) awaiting reuse.
    free_pages: Vec<PageBuf>,
    /// Durable page source (warm restart). When attached, fresh pages
    /// are extents of the mmap-backed file, never anonymous heap.
    region: Option<SlabRegion>,
    page_size: usize,
    /// Carved pages across both generations (excludes `free_pages`).
    pages_allocated: usize,
    page_budget: usize,
    /// Two-phase limbo for page buffers leaving the cache: an
    /// optimistic reader may still dereference a chunk address inside a
    /// buffer that was just released, so buffers are never returned to
    /// the OS immediately. They age here for at least one full
    /// maintainer pass ([`drain_limbo`]) first — long after any
    /// optimistic read window (which re-validates its seqlock stripe
    /// microseconds before touching the bytes) has closed.
    ///
    /// [`drain_limbo`]: SlabAllocator::drain_limbo
    ///
    /// Mapped buffers take the same route; dropping one returns its
    /// extent to the region's free list (the bytes stay mapped, so the
    /// optimistic-reader guarantee holds identically).
    limbo_fresh: Vec<PageBuf>,
    limbo_aged: Vec<PageBuf>,
}

impl SlabAllocator {
    /// Build an allocator from a policy, a page size, and a total
    /// memory limit (rounded down to whole pages, ≥ 1).
    pub fn new(
        policy: &ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
    ) -> Result<Self, SlabError> {
        SlabAllocator::with_region(policy, page_size, mem_limit, None)
    }

    /// Like [`SlabAllocator::new`], but carving pages from an
    /// mmap-backed region when one is attached (warm restart).
    pub fn with_region(
        policy: &ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
        region: Option<SlabRegion>,
    ) -> Result<Self, SlabError> {
        let chunk_sizes = policy.materialize(page_size)?;
        let classes = chunk_sizes.iter().map(|&s| SlabClass::new(s)).collect();
        Ok(SlabAllocator {
            classes,
            chunk_sizes,
            old: None,
            free_pages: Vec::new(),
            region,
            page_size,
            pages_allocated: 0,
            page_budget: (mem_limit / page_size).max(1),
            limbo_fresh: Vec::new(),
            limbo_aged: Vec::new(),
        })
    }

    /// Send a page buffer toward the OS via the two-phase limbo (see
    /// the field docs): it survives at least one [`drain_limbo`] call.
    ///
    /// [`drain_limbo`]: SlabAllocator::drain_limbo
    fn condemn(&mut self, buf: PageBuf) {
        self.limbo_fresh.push(buf);
    }

    /// Age the limbo one phase: buffers condemned before the *previous*
    /// drain are finally freed, freshly condemned ones move to aged.
    /// Called once per maintainer pass (and per migration pump round).
    pub fn drain_limbo(&mut self) {
        self.limbo_aged.clear();
        std::mem::swap(&mut self.limbo_aged, &mut self.limbo_fresh);
    }

    /// Buffers currently parked in limbo (test/introspection hook).
    pub fn limbo_pages(&self) -> usize {
        self.limbo_fresh.len() + self.limbo_aged.len()
    }

    /// The ascending chunk-size table (current generation).
    #[inline]
    pub fn chunk_sizes(&self) -> &[usize] {
        &self.chunk_sizes
    }

    #[inline]
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    #[inline]
    pub fn page_budget(&self) -> usize {
        self.page_budget
    }

    /// Carved pages across both generations.
    #[inline]
    pub fn pages_allocated(&self) -> usize {
        self.pages_allocated
    }

    /// Recycled page buffers held for reuse.
    #[inline]
    pub fn free_page_count(&self) -> usize {
        self.free_pages.len()
    }

    /// Resident pages: carved (both generations) + pooled buffers.
    #[inline]
    pub fn resident_pages(&self) -> usize {
        self.pages_allocated + self.free_pages.len()
    }

    /// Head of the per-page item chain for a page in either generation
    /// (the store maintains the links through `ItemMeta::{pg_prev,
    /// pg_next}`).
    #[inline]
    pub fn page_item_head(&self, old: bool, class: u16, page: u32) -> u32 {
        if old {
            self.old
                .as_ref()
                .expect("old-generation index without an active migration")
                .classes[class as usize]
                .page_item_head(page)
        } else {
            self.classes[class as usize].page_item_head(page)
        }
    }

    /// Set the per-page item-chain head in either generation.
    #[inline]
    pub fn set_page_item_head(&mut self, old: bool, class: u16, page: u32, id: u32) {
        if old {
            self.old
                .as_mut()
                .expect("old-generation index without an active migration")
                .classes[class as usize]
                .set_page_item_head(page, id);
        } else {
            self.classes[class as usize].set_page_item_head(page, id);
        }
    }

    /// Largest storable item.
    #[inline]
    pub fn max_item_size(&self) -> usize {
        *self.chunk_sizes.last().unwrap()
    }

    /// Smallest class whose chunk covers `size` (binary search).
    #[inline]
    pub fn class_for_size(&self, size: usize) -> Option<u16> {
        match self.chunk_sizes.binary_search(&size) {
            Ok(i) => Some(i as u16),
            Err(i) if i < self.chunk_sizes.len() => Some(i as u16),
            Err(_) => None,
        }
    }

    /// Chunk size of a class (current generation).
    #[inline]
    pub fn chunk_size_of(&self, class: u16) -> usize {
        self.chunk_sizes[class as usize]
    }

    /// Pages the budget admits right now (slack applies while a
    /// migration is draining).
    #[inline]
    fn effective_budget(&self) -> usize {
        self.page_budget + if self.old.is_some() { MIGRATION_PAGE_SLACK } else { 0 }
    }

    /// Obtain a page buffer: recycled first, fresh while under budget.
    /// Failpoint `slab.page_alloc` simulates exhaustion: the caller
    /// sees `NeedEviction` exactly as if the budget were spent.
    fn take_page(&mut self) -> Option<PageBuf> {
        if crate::util::failpoint::fired("slab.page_alloc") {
            return None;
        }
        if let Some(buf) = self.free_pages.pop() {
            return Some(buf);
        }
        if self.pages_allocated < self.effective_budget() {
            match &self.region {
                // Region-backed: every page is a durable extent; an
                // exhausted region reads as budget exhaustion (the
                // region is sized for budget + migration slack).
                Some(region) => region.take(),
                None => Some(PageBuf::from(vec![0u8; self.page_size].into_boxed_slice())),
            }
        } else {
            None
        }
    }

    /// Retain a released page buffer for reuse, unless total resident
    /// pages would exceed the current budget (then the memory is
    /// returned to the OS). During a migration the slack applies, so a
    /// full-budget drain recycles pages through the pool instead of
    /// paying a free + zeroed-realloc per page; `finish_migration`
    /// trims the pool back under the strict budget.
    fn retire_page(&mut self, buf: PageBuf) {
        if self.pages_allocated + self.free_pages.len() < self.effective_budget() {
            self.free_pages.push(buf);
        } else {
            self.condemn(buf);
        }
    }

    /// Allocate a chunk for an item of `size` bytes (current
    /// generation).
    pub fn alloc(&mut self, size: usize) -> Result<ChunkHandle, SlabError> {
        let class = self.class_for_size(size).ok_or(SlabError::TooLarge {
            size,
            max: self.max_item_size(),
        })?;
        let ci = class as usize;
        if !self.classes[ci].has_free_chunk() {
            match self.take_page() {
                Some(buf) => {
                    self.classes[ci].add_page(buf);
                    self.pages_allocated += 1;
                }
                None => return Err(SlabError::NeedEviction { class }),
            }
        }
        let loc = self.classes[ci]
            .alloc(size)
            .expect("free chunk present after page add");
        Ok(ChunkHandle { class, loc })
    }

    /// Free a current-generation chunk, un-accounting the item's
    /// requested `size`.
    pub fn free(&mut self, handle: ChunkHandle, size: usize) {
        self.classes[handle.class as usize].free(handle.loc, size);
    }

    /// Free an old-generation chunk (items still draining).
    pub fn free_old(&mut self, handle: ChunkHandle, size: usize) {
        self.old
            .as_mut()
            .expect("old-generation free without an active migration")
            .classes[handle.class as usize]
            .free(handle.loc, size);
    }

    /// Re-account an in-place item resize within the same chunk
    /// (current generation).
    pub fn reaccount(&mut self, handle: ChunkHandle, old_size: usize, new_size: usize) {
        self.classes[handle.class as usize].reaccount(old_size, new_size);
    }

    /// Read a stored current-generation chunk.
    #[inline]
    pub fn chunk(&self, handle: ChunkHandle) -> &[u8] {
        self.classes[handle.class as usize].chunk(handle.loc)
    }

    /// Read a stored chunk from either generation.
    #[inline]
    pub fn chunk_gen(&self, old: bool, handle: ChunkHandle) -> &[u8] {
        if old {
            self.old
                .as_ref()
                .expect("old-generation read without an active migration")
                .classes[handle.class as usize]
                .chunk(handle.loc)
        } else {
            self.classes[handle.class as usize].chunk(handle.loc)
        }
    }

    /// Write into a stored current-generation chunk.
    #[inline]
    pub fn chunk_mut(&mut self, handle: ChunkHandle) -> &mut [u8] {
        self.classes[handle.class as usize].chunk_mut(handle.loc)
    }

    // ---------------------------------------------------- warm restart

    /// The attached durable region, if any.
    #[inline]
    pub fn region(&self) -> Option<&SlabRegion> {
        self.region.as_ref()
    }

    /// Adopt a recovered page at an exact `(class, slot)` with the
    /// given live-chunk set (warm-restart recovery). Counts against the
    /// page budget like any carved page.
    pub fn restore_page(
        &mut self,
        class: u16,
        slot: u32,
        buf: PageBuf,
        used: &[u32],
    ) -> Result<(), String> {
        let ci = class as usize;
        if ci >= self.classes.len() {
            return Err(format!("class {class} out of range"));
        }
        if buf.len() != self.page_size {
            return Err(format!("page buffer is {} B, expected {}", buf.len(), self.page_size));
        }
        self.classes[ci].restore_page(slot, buf, used)?;
        self.pages_allocated += 1;
        Ok(())
    }

    /// `(class, page_slot, region_offset)` for every current-generation
    /// page still holding items — the warm-restart manifest's page map.
    /// Only meaningful once a migration has fully drained (the manifest
    /// writer forces that first).
    pub fn page_map(&self) -> Vec<(u16, u32, u64)> {
        self.classes
            .iter()
            .enumerate()
            .flat_map(|(ci, c)| {
                c.page_map()
                    .into_iter()
                    .map(move |(slot, off)| (ci as u16, slot, off))
            })
            .collect()
    }

    // ------------------------------------------------------- migration

    /// True while an old generation is still draining.
    #[inline]
    pub fn migration_active(&self) -> bool {
        self.old.is_some()
    }

    /// Chunk-size table of the draining generation, if any.
    pub fn old_chunk_sizes(&self) -> Option<&[usize]> {
        self.old.as_ref().map(|o| o.chunk_sizes.as_slice())
    }

    /// Start a migration: the current class table becomes the old
    /// (draining) generation and a fresh table for `policy` takes over.
    /// All future allocations land in the new geometry; old chunks stay
    /// readable via [`chunk_gen`] until individually freed.
    ///
    /// [`chunk_gen`]: SlabAllocator::chunk_gen
    pub fn begin_migration(&mut self, policy: &ChunkSizePolicy) -> Result<(), SlabError> {
        assert!(self.old.is_none(), "migration already active");
        let new_sizes = policy.materialize(self.page_size)?;
        let new_classes: Vec<SlabClass> = new_sizes.iter().map(|&s| SlabClass::new(s)).collect();
        let old_classes = std::mem::replace(&mut self.classes, new_classes);
        let old_sizes = std::mem::replace(&mut self.chunk_sizes, new_sizes);
        self.old = Some(OldGen {
            classes: old_classes,
            chunk_sizes: old_sizes,
        });
        Ok(())
    }

    /// Copy `len` bytes from an old-generation chunk into a
    /// current-generation chunk (the item move, no intermediate buffer).
    pub fn migrate_copy(&mut self, from: ChunkHandle, to: ChunkHandle, len: usize) {
        let old = self
            .old
            .as_ref()
            .expect("migrate_copy without an active migration");
        let src = old.classes[from.class as usize].chunk(from.loc);
        let dst = self.classes[to.class as usize].chunk_mut(to.loc);
        dst[..len].copy_from_slice(&src[..len]);
    }

    /// Release every fully drained old-generation page into the
    /// free-page pool. Returns the number of pages released.
    pub fn release_old_drained_pages(&mut self) -> usize {
        let Some(old) = self.old.as_mut() else { return 0 };
        let mut bufs = Vec::new();
        for class in &mut old.classes {
            bufs.append(&mut class.release_drained_pages());
        }
        let freed = bufs.len();
        for buf in bufs {
            self.pages_allocated -= 1;
            self.retire_page(buf);
        }
        freed
    }

    /// Occupancy of every old-generation page still holding live
    /// chunks: `(class, page_slot, live_chunks)`, unordered. The
    /// force-drain path sorts this ascending to pick the cheapest
    /// drainable page.
    pub fn old_page_occupancy(&self) -> Vec<(u16, u32, u32)> {
        let Some(old) = self.old.as_ref() else {
            return Vec::new();
        };
        old.classes
            .iter()
            .enumerate()
            .flat_map(|(ci, c)| {
                c.occupied_pages()
                    .into_iter()
                    .map(move |(p, n)| (ci as u16, p, n))
            })
            .collect()
    }

    /// Occupancy of every **current-generation** page still holding
    /// live chunks: `(class, page_slot, live_chunks)` — the maintainer's
    /// slack-shedding pass picks its victim page from this.
    pub fn page_occupancy(&self) -> Vec<(u16, u32, u32)> {
        self.classes
            .iter()
            .enumerate()
            .flat_map(|(ci, c)| {
                c.occupied_pages()
                    .into_iter()
                    .map(move |(p, n)| (ci as u16, p, n))
            })
            .collect()
    }

    /// Drop pooled page buffers until resident pages fit the strict
    /// budget. Returns the buffers returned to the OS.
    pub fn trim_free_pool(&mut self) -> usize {
        let mut shed = 0;
        while self.resident_pages() > self.page_budget {
            match self.free_pages.pop() {
                Some(buf) => {
                    self.condemn(buf);
                    shed += 1;
                }
                None => break,
            }
        }
        shed
    }

    /// Release fully drained **current-generation** pages — the
    /// maintainer's slack-shedding move, only meaningful while carved
    /// pages exceed the strict budget (a post-migration overshoot of up
    /// to [`MIGRATION_PAGE_SLACK`]). Released buffers go through the
    /// pool gate, which drops them outright when resident pages are at
    /// or over budget. Returns pages released from their class.
    pub fn release_current_drained_pages(&mut self) -> usize {
        let mut bufs = Vec::new();
        for class in &mut self.classes {
            bufs.append(&mut class.release_drained_pages());
        }
        let freed = bufs.len();
        for buf in bufs {
            self.pages_allocated -= 1;
            self.retire_page(buf);
        }
        freed
    }

    /// Live chunks remaining in the old generation.
    pub fn old_used_chunks(&self) -> usize {
        self.old
            .as_ref()
            .map_or(0, |o| o.classes.iter().map(SlabClass::used_chunks).sum())
    }

    /// Drop the (fully drained) old generation, releasing its remaining
    /// pages. Returns the number of pages released. Panics in debug
    /// builds if live old chunks remain.
    pub fn finish_migration(&mut self) -> usize {
        let freed = self.release_old_drained_pages();
        if let Some(old) = self.old.take() {
            debug_assert!(
                old.classes.iter().all(|c| c.used_chunks() == 0),
                "finish_migration with live old chunks"
            );
            // shed pooled buffers until resident pages fit the strict
            // budget again. Carved pages are never un-carved: when the
            // new geometry packs less densely, up to the slack can
            // remain live past the drain — a permanent overshoot capped
            // at MIGRATION_PAGE_SLACK (take_page never admits beyond
            // budget + slack, so repeated migrations cannot compound it)
            while self.pages_allocated + self.free_pages.len() > self.page_budget {
                match self.free_pages.pop() {
                    Some(buf) => self.condemn(buf),
                    None => break,
                }
            }
        }
        freed
    }

    /// Aggregate statistics (the paper's measurement instrument);
    /// covers both generations while a migration drains.
    pub fn stats(&self) -> SlabStats {
        let mut per_class: Vec<ClassStats> =
            self.classes.iter().map(SlabClass::stats).collect();
        if let Some(old) = &self.old {
            per_class.extend(
                old.classes
                    .iter()
                    .map(SlabClass::stats)
                    .filter(|c| c.pages > 0),
            );
        }
        SlabStats {
            requested_bytes: per_class.iter().map(|c| c.requested_bytes).sum(),
            allocated_bytes: per_class.iter().map(|c| c.allocated_bytes).sum(),
            hole_bytes: per_class.iter().map(|c| c.hole_bytes).sum(),
            tail_waste_bytes: per_class.iter().map(|c| c.tail_waste_bytes).sum(),
            pages_allocated: self.pages_allocated,
            pages_free: self.free_pages.len(),
            page_budget: self.page_budget,
            page_size: self.page_size,
            per_class,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::PAGE_SIZE;

    fn small() -> SlabAllocator {
        // classes: 96,120,152,192,240,304,384,480,600,752,944,…,4096
        SlabAllocator::new(
            &ChunkSizePolicy::Geometric {
                chunk_min: 96,
                factor: 1.25,
            },
            4096,
            1 << 20,
        )
        .unwrap()
    }

    #[test]
    fn class_selection_smallest_covering() {
        let a = small();
        assert_eq!(a.chunk_size_of(a.class_for_size(1).unwrap()), 96);
        assert_eq!(a.chunk_size_of(a.class_for_size(96).unwrap()), 96);
        assert_eq!(a.chunk_size_of(a.class_for_size(97).unwrap()), 120);
        assert_eq!(a.chunk_size_of(a.class_for_size(500).unwrap()), 600);
        assert_eq!(a.class_for_size(5000), None);
    }

    #[test]
    fn alloc_tracks_holes_like_the_paper() {
        let mut a = small();
        // item of 518 bytes -> 600-byte chunk -> hole of 82
        a.alloc(518).unwrap();
        let s = a.stats();
        assert_eq!(s.requested_bytes, 518);
        assert_eq!(s.allocated_bytes, 600);
        assert_eq!(s.hole_bytes, 82);
        assert!((s.hole_fraction() - 82.0 / 600.0).abs() < 1e-12);
    }

    #[test]
    fn too_large_rejected() {
        let mut a = small();
        match a.alloc(4097) {
            Err(SlabError::TooLarge { size: 4097, max: 4096 }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn page_budget_enforced_then_eviction_requested() {
        // 1 page of 4096 total budget; 96-byte chunks -> 42 chunks
        let mut a = SlabAllocator::new(
            &ChunkSizePolicy::Geometric {
                chunk_min: 96,
                factor: 1.25,
            },
            4096,
            4096,
        )
        .unwrap();
        let per_page = 4096 / 96;
        for _ in 0..per_page {
            a.alloc(50).unwrap();
        }
        match a.alloc(50) {
            Err(SlabError::NeedEviction { class: 0 }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(a.pages_allocated(), 1);
    }

    #[test]
    fn free_then_realloc_reuses_chunk() {
        let mut a = small();
        let h = a.alloc(100).unwrap();
        a.free(h, 100);
        let h2 = a.alloc(110).unwrap();
        assert_eq!(h.class, h2.class);
        assert_eq!(a.stats().used_chunks_total(), 1);
    }

    impl SlabStats {
        fn used_chunks_total(&self) -> usize {
            self.per_class.iter().map(|c| c.used_chunks).sum()
        }
    }

    #[test]
    fn data_roundtrip_via_handle() {
        let mut a = small();
        let h = a.alloc(11).unwrap();
        a.chunk_mut(h)[..11].copy_from_slice(b"hello world");
        assert_eq!(&a.chunk(h)[..11], b"hello world");
    }

    #[test]
    fn explicit_policy_paper_table1() {
        let a = SlabAllocator::new(
            &ChunkSizePolicy::Explicit(vec![461, 510, 557, 614, 702, 943]),
            PAGE_SIZE,
            8 << 20,
        )
        .unwrap();
        // paper's learned T1 config + the implicit page class
        assert_eq!(
            a.chunk_sizes(),
            &[461, 510, 557, 614, 702, 943, PAGE_SIZE]
        );
        assert_eq!(a.chunk_size_of(a.class_for_size(518).unwrap()), 557);
    }

    #[test]
    fn distinct_classes_get_distinct_pages() {
        let mut a = small();
        a.alloc(50).unwrap(); // class 96
        a.alloc(500).unwrap(); // class 600
        assert_eq!(a.pages_allocated(), 2);
        let s = a.stats();
        assert_eq!(s.per_class[0].pages, 1);
        let c600 = s.per_class.iter().find(|c| c.chunk_size == 600).unwrap();
        assert_eq!(c600.pages, 1);
    }

    // ------------------------------------------- generation migration

    #[test]
    fn begin_migration_switches_geometry_keeps_old_readable() {
        let mut a = small();
        let h = a.alloc(100).unwrap();
        a.chunk_mut(h)[..3].copy_from_slice(b"abc");
        a.begin_migration(&ChunkSizePolicy::Explicit(vec![256, 4096]))
            .unwrap();
        assert!(a.migration_active());
        assert_eq!(a.chunk_sizes(), &[256, 4096]);
        // old chunk still readable through the generation-aware path
        assert_eq!(&a.chunk_gen(true, h)[..3], b"abc");
        // new allocations land in the new geometry
        let h2 = a.alloc(100).unwrap();
        assert_eq!(a.chunk_size_of(h2.class), 256);
    }

    #[test]
    fn drained_old_pages_recycle_into_new_geometry() {
        // budget: exactly 2 pages of 4096
        let mut a = SlabAllocator::new(
            &ChunkSizePolicy::Explicit(vec![512, 4096]),
            4096,
            8192,
        )
        .unwrap();
        let handles: Vec<_> = (0..8).map(|_| a.alloc(500).unwrap()).collect();
        assert_eq!(a.pages_allocated(), 1);
        a.begin_migration(&ChunkSizePolicy::Explicit(vec![1024, 4096]))
            .unwrap();
        // drain the old page: move items one by one
        for h in handles {
            let to = a.alloc(500).unwrap();
            a.migrate_copy(h, to, 500);
            a.free_old(h, 500);
        }
        assert_eq!(a.old_used_chunks(), 0);
        let freed = a.release_old_drained_pages();
        assert_eq!(freed, 1);
        assert_eq!(a.finish_migration(), 0);
        assert!(!a.migration_active());
        // peak stayed within budget + slack
        assert!(a.pages_allocated() + a.free_page_count() <= 2 + MIGRATION_PAGE_SLACK);
    }

    #[test]
    fn migration_slack_admits_extra_pages_then_budget_restores() {
        // budget 1 page, full
        let mut a = SlabAllocator::new(
            &ChunkSizePolicy::Explicit(vec![512, 4096]),
            4096,
            4096,
        )
        .unwrap();
        let held: Vec<_> = (0..8).map(|_| a.alloc(400).unwrap()).collect();
        assert!(matches!(a.alloc(400), Err(SlabError::NeedEviction { .. })));
        a.begin_migration(&ChunkSizePolicy::Explicit(vec![600, 4096]))
            .unwrap();
        // slack lets the new generation start before any page drains
        let moved = a.alloc(400).unwrap();
        a.migrate_copy(held[0], moved, 400);
        a.free_old(held[0], 400);
        for &h in &held[1..] {
            let to = a.alloc(400).unwrap();
            a.migrate_copy(h, to, 400);
            a.free_old(h, 400);
        }
        assert!(a.pages_allocated() <= 1 + MIGRATION_PAGE_SLACK);
        a.finish_migration();
        // after the drain the budget is strict again
        assert!(a.pages_allocated() + a.free_page_count() <= 1 + MIGRATION_PAGE_SLACK);
    }

    #[test]
    fn freed_page_buffers_age_through_limbo() {
        // budget 1 page; migrating to a less dense geometry strands
        // over-budget buffers, which must age through limbo (stale
        // optimistic readers may still hold chunk addresses into them)
        // instead of returning to the OS immediately
        let mut a = SlabAllocator::new(
            &ChunkSizePolicy::Explicit(vec![512, 4096]),
            4096,
            4096,
        )
        .unwrap();
        let held: Vec<_> = (0..8).map(|_| a.alloc(400).unwrap()).collect();
        a.begin_migration(&ChunkSizePolicy::Explicit(vec![600, 4096]))
            .unwrap();
        for &h in &held {
            let to = a.alloc(400).unwrap();
            a.migrate_copy(h, to, 400);
            a.free_old(h, 400);
        }
        a.finish_migration();
        let parked = a.limbo_pages();
        assert!(parked > 0, "over-budget buffers parked in limbo");
        a.drain_limbo();
        assert_eq!(a.limbo_pages(), parked, "first drain only ages");
        a.drain_limbo();
        assert_eq!(a.limbo_pages(), 0, "second drain returns them to the OS");
    }

    #[test]
    fn stats_cover_both_generations() {
        let mut a = small();
        a.alloc(518).unwrap(); // old gen: 600-chunk, hole 82
        a.begin_migration(&ChunkSizePolicy::Explicit(vec![530, 4096]))
            .unwrap();
        a.alloc(520).unwrap(); // new gen: 530-chunk, hole 10
        let s = a.stats();
        assert_eq!(s.requested_bytes, 518 + 520);
        assert_eq!(s.hole_bytes, 82 + 10);
        assert_eq!(s.pages_allocated, 2);
        assert!(s.per_class.iter().any(|c| c.chunk_size == 600 && c.used_chunks == 1));
        assert!(s.per_class.iter().any(|c| c.chunk_size == 530 && c.used_chunks == 1));
    }

    #[test]
    fn old_page_occupancy_spans_classes() {
        let mut a = small();
        let _pin96 = a.alloc(50).unwrap();
        for _ in 0..5 {
            a.alloc(500).unwrap();
        }
        a.begin_migration(&ChunkSizePolicy::Explicit(vec![128, 700, 4096]))
            .unwrap();
        let mut occ = a.old_page_occupancy();
        occ.sort_unstable_by_key(|&(_, _, n)| n);
        assert_eq!(occ.len(), 2, "{occ:?}");
        // the 96-byte class holds a single item: cheapest drain
        let (class, _page, used) = occ[0];
        assert_eq!(a.old_chunk_sizes().unwrap()[class as usize], 96);
        assert_eq!(used, 1);
        assert_eq!(occ[1].2, 5);
    }
}
