//! Chunk-size policies: how a slab-class configuration is chosen.
//!
//! The paper compares memcached's **geometric default** against an
//! **explicit learned list** (applied via the `-o slab_sizes` startup
//! option); both are first-class here, and a running store can be
//! re-configured from one to the other (`store::sharded::reconfigure`).

use super::geometry::default_slab_sizes;
use super::{MAX_CLASSES, MIN_CHUNK};
use std::fmt;

/// How slab chunk sizes are derived.
#[derive(Clone, Debug, PartialEq)]
pub enum ChunkSizePolicy {
    /// Memcached's default: `chunk_min` growing by `factor` per class.
    Geometric { chunk_min: usize, factor: f64 },
    /// An explicit ascending list (the `-o slab_sizes` analog; what the
    /// optimizer emits). The final page-size class is appended
    /// automatically if missing, so every item ≤ page always fits.
    Explicit(Vec<usize>),
}

impl Default for ChunkSizePolicy {
    fn default() -> Self {
        ChunkSizePolicy::Geometric {
            chunk_min: 96,
            factor: 1.25,
        }
    }
}

/// Why a policy failed validation.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyError {
    Empty,
    TooManyClasses(usize),
    ChunkTooSmall(usize),
    ChunkTooLarge(usize),
    NotAscending(usize, usize),
    BadFactor(f64),
}

impl fmt::Display for PolicyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PolicyError::Empty => write!(f, "no chunk sizes"),
            PolicyError::TooManyClasses(n) => {
                write!(f, "{n} classes > max {MAX_CLASSES}")
            }
            PolicyError::ChunkTooSmall(s) => write!(f, "chunk {s} < min {MIN_CHUNK}"),
            PolicyError::ChunkTooLarge(s) => write!(f, "chunk {s} > page size"),
            PolicyError::NotAscending(a, b) => {
                write!(f, "chunk sizes not strictly ascending: {a} !< {b}")
            }
            PolicyError::BadFactor(x) => write!(f, "growth factor {x} must be > 1"),
        }
    }
}

impl std::error::Error for PolicyError {}

impl ChunkSizePolicy {
    /// Materialize the policy into a validated ascending chunk-size
    /// list for the given page size.
    pub fn materialize(&self, page_size: usize) -> Result<Vec<usize>, PolicyError> {
        let sizes = match self {
            ChunkSizePolicy::Geometric { chunk_min, factor } => {
                if *factor <= 1.0 {
                    return Err(PolicyError::BadFactor(*factor));
                }
                if *chunk_min < MIN_CHUNK {
                    return Err(PolicyError::ChunkTooSmall(*chunk_min));
                }
                default_slab_sizes(*chunk_min, *factor, page_size)
            }
            ChunkSizePolicy::Explicit(list) => {
                let mut sizes = list.clone();
                if sizes.last().is_some_and(|&last| last < page_size) {
                    sizes.push(page_size);
                }
                sizes
            }
        };
        validate_sizes(&sizes, page_size)?;
        Ok(sizes)
    }
}

/// Validate an ascending chunk-size list against the page size.
pub fn validate_sizes(sizes: &[usize], page_size: usize) -> Result<(), PolicyError> {
    if sizes.is_empty() {
        return Err(PolicyError::Empty);
    }
    if sizes.len() > MAX_CLASSES {
        return Err(PolicyError::TooManyClasses(sizes.len()));
    }
    for w in sizes.windows(2) {
        if w[0] >= w[1] {
            return Err(PolicyError::NotAscending(w[0], w[1]));
        }
    }
    if sizes[0] < MIN_CHUNK {
        return Err(PolicyError::ChunkTooSmall(sizes[0]));
    }
    if *sizes.last().unwrap() > page_size {
        return Err(PolicyError::ChunkTooLarge(*sizes.last().unwrap()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::PAGE_SIZE;

    #[test]
    fn default_policy_is_memcached() {
        let sizes = ChunkSizePolicy::default().materialize(PAGE_SIZE).unwrap();
        assert_eq!(&sizes[..4], &[96, 120, 152, 192]);
        assert_eq!(*sizes.last().unwrap(), PAGE_SIZE);
    }

    #[test]
    fn explicit_appends_page_class() {
        let p = ChunkSizePolicy::Explicit(vec![304, 384, 480, 600, 752, 944]);
        let sizes = p.materialize(PAGE_SIZE).unwrap();
        assert_eq!(sizes, vec![304, 384, 480, 600, 752, 944, PAGE_SIZE]);
    }

    #[test]
    fn explicit_with_page_class_not_duplicated() {
        let p = ChunkSizePolicy::Explicit(vec![304, PAGE_SIZE]);
        assert_eq!(p.materialize(PAGE_SIZE).unwrap(), vec![304, PAGE_SIZE]);
    }

    #[test]
    fn rejects_descending() {
        let p = ChunkSizePolicy::Explicit(vec![500, 400]);
        assert!(matches!(
            p.materialize(PAGE_SIZE),
            Err(PolicyError::NotAscending(500, 400))
        ));
    }

    #[test]
    fn rejects_tiny_and_huge() {
        assert!(matches!(
            ChunkSizePolicy::Explicit(vec![8]).materialize(PAGE_SIZE),
            Err(PolicyError::ChunkTooSmall(8))
        ));
        assert!(matches!(
            ChunkSizePolicy::Explicit(vec![PAGE_SIZE + 1]).materialize(PAGE_SIZE),
            Err(PolicyError::ChunkTooLarge(_))
        ));
    }

    #[test]
    fn rejects_too_many_classes() {
        let huge: Vec<usize> = (0..80).map(|i| 96 + 8 * i).collect();
        assert!(matches!(
            ChunkSizePolicy::Explicit(huge).materialize(PAGE_SIZE),
            Err(PolicyError::TooManyClasses(_))
        ));
    }

    #[test]
    fn rejects_bad_factor() {
        let p = ChunkSizePolicy::Geometric {
            chunk_min: 96,
            factor: 0.9,
        };
        assert!(matches!(p.materialize(PAGE_SIZE), Err(PolicyError::BadFactor(_))));
    }
}
