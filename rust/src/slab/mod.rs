//! The slab allocator — memcached's memory substrate, rebuilt.
//!
//! Memory is claimed from a global pool one **page** (default 1 MiB) at
//! a time; each page is assigned to a **slab class** and carved into
//! equal-size **chunks**; every stored item occupies exactly one chunk
//! of the smallest class whose chunk size covers it. The gap between an
//! item's true size and its chunk size is a **memory hole** — the
//! internal fragmentation this whole project exists to minimize.
//!
//! * [`geometry`] — memcached's default geometric chunk-size chain
//!   (96 B growing by 1.25×, 8-byte aligned): the paper's baseline.
//! * [`policy`] — how chunk sizes are chosen (geometric default,
//!   explicit `-o slab_sizes`-style lists, learned configurations).
//! * [`page`] / [`class`] — pages, chunk carving, per-class free lists.
//! * [`allocator`] — the allocator facade + hole accounting.
//! * [`mapfile`] — the mmap-backed page region behind `--memory-file`
//!   (warm restart): pages carved from a durable file instead of heap.

pub mod allocator;
pub mod class;
pub mod geometry;
pub mod mapfile;
pub mod page;
pub mod policy;

pub use allocator::{ChunkHandle, SlabAllocator, SlabError, SlabStats};
pub use mapfile::{PageBuf, SlabRegion};
pub use geometry::default_slab_sizes;
pub use policy::ChunkSizePolicy;

/// Default page size: 1 MiB, memcached's `settings.item_size_max`.
pub const PAGE_SIZE: usize = 1 << 20;

/// Smallest legal chunk: memcached's 48-byte base chunk + item header.
pub const MIN_CHUNK: usize = 48;

/// Memcached caps its class table at 63 usable classes.
pub const MAX_CLASSES: usize = 63;

/// Sentinel for "no item" in the per-page item chains the store threads
/// through the class table (mirrors `store::arena::NIL` without making
/// the slab layer depend on the store).
pub const NIL_ITEM: u32 = u32::MAX;
