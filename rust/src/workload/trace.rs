//! Operation traces: record, save, replay. CSV on disk so experiment
//! inputs can be archived and replayed byte-identically.

use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// One cache operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Op {
    Set { key: String, value_len: usize },
    Get { key: String },
    Delete { key: String },
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Op::Set { key, value_len } => write!(f, "set,{key},{value_len}"),
            Op::Get { key } => write!(f, "get,{key},"),
            Op::Delete { key } => write!(f, "del,{key},"),
        }
    }
}

impl Op {
    pub fn parse(line: &str) -> Option<Op> {
        let mut parts = line.splitn(3, ',');
        let verb = parts.next()?;
        let key = parts.next()?.to_string();
        let arg = parts.next().unwrap_or("");
        match verb {
            "set" => Some(Op::Set {
                key,
                value_len: arg.parse().ok()?,
            }),
            "get" => Some(Op::Get { key }),
            "del" => Some(Op::Delete { key }),
            _ => None,
        }
    }

    pub fn key(&self) -> &str {
        match self {
            Op::Set { key, .. } | Op::Get { key } | Op::Delete { key } => key,
        }
    }
}

/// An in-memory trace with CSV persistence.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub ops: Vec<Op>,
}

impl Trace {
    pub fn from_ops<I: IntoIterator<Item = Op>>(ops: I) -> Self {
        Trace {
            ops: ops.into_iter().collect(),
        }
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        writeln!(w, "op,key,arg")?;
        for op in &self.ops {
            writeln!(w, "{op}")?;
        }
        w.flush()
    }

    pub fn load(path: &Path) -> std::io::Result<Trace> {
        let r = BufReader::new(std::fs::File::open(path)?);
        let mut ops = Vec::new();
        for (i, line) in r.lines().enumerate() {
            let line = line?;
            if i == 0 && line.starts_with("op,") {
                continue; // header
            }
            if line.trim().is_empty() {
                continue;
            }
            let op = Op::parse(&line).ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad trace line {}: '{line}'", i + 1),
                )
            })?;
            ops.push(op);
        }
        Ok(Trace { ops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let ops = vec![
            Op::Set {
                key: "k1".into(),
                value_len: 100,
            },
            Op::Get { key: "k1".into() },
            Op::Delete { key: "k1".into() },
        ];
        for op in &ops {
            assert_eq!(Op::parse(&op.to_string()).unwrap(), *op);
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("slabforge-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let t = Trace::from_ops([
            Op::Set {
                key: "a".into(),
                value_len: 5,
            },
            Op::Get { key: "a".into() },
        ]);
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Op::parse("bogus,key,1").is_none());
        assert!(Op::parse("set,key,notanum").is_none());
        assert!(Op::parse("").is_none());
    }
}
