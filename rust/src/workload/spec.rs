//! Workload specifications, including the paper's five experiments with
//! the reconstructed log-space σ (DESIGN.md §3: the paper's "σ in bytes"
//! cannot be literal; we pin σ_ln from the tables' own evidence — the
//! default classes that received items, the old-config waste/item, and
//! the learned top chunk ≈ max observed size).

use crate::util::rng::Pcg64;

/// Item **total-size** distribution (header + key + value, see
/// `store::item::total_item_size`).
#[derive(Clone, Debug, PartialEq)]
pub enum SizeDistribution {
    /// Log-normal by median and log-space sigma (the paper's family).
    LogNormal { median: f64, sigma_ln: f64 },
    /// Truncated normal.
    Normal { mean: f64, sd: f64 },
    /// Uniform inclusive range.
    Uniform { min: usize, max: usize },
    /// Single fixed size (§6.1 best case).
    Fixed { size: usize },
    /// A small set of fixed sizes with weights (§6.1 best case,
    /// k-point distribution).
    Discrete { sizes: Vec<(usize, f64)> },
    /// §6.1 worst case: sizes exactly on the default chunk chain with
    /// frequency ∝ 1.25⁻ⁿ.
    GeomDecay { chunk_sizes: Vec<usize> },
    /// Facebook-ETC-like: log-normal body + a small heavy tail.
    EtcLike {
        median: f64,
        sigma_ln: f64,
        tail_fraction: f64,
        tail_max: usize,
    },
}

impl SizeDistribution {
    /// Draw one item size, clamped to `[min_size, max_size]`.
    pub fn sample(&self, rng: &mut Pcg64, min_size: usize, max_size: usize) -> usize {
        let raw = match self {
            SizeDistribution::LogNormal { median, sigma_ln } => {
                rng.lognormal(*median, *sigma_ln)
            }
            SizeDistribution::Normal { mean, sd } => rng.normal(*mean, *sd),
            SizeDistribution::Uniform { min, max } => {
                rng.gen_range_inclusive(*min as u64, *max as u64) as f64
            }
            SizeDistribution::Fixed { size } => *size as f64,
            SizeDistribution::Discrete { sizes } => {
                let total: f64 = sizes.iter().map(|(_, w)| w).sum();
                let mut pick = rng.next_f64() * total;
                let mut chosen = sizes.last().map(|(s, _)| *s).unwrap_or(min_size);
                for (s, w) in sizes {
                    if pick < *w {
                        chosen = *s;
                        break;
                    }
                    pick -= w;
                }
                chosen as f64
            }
            SizeDistribution::GeomDecay { chunk_sizes } => {
                // P(class n) ∝ 1.25^-n over the given chain
                let n = chunk_sizes.len();
                let weights: Vec<f64> = (0..n).map(|i| 1.25f64.powi(-(i as i32))).collect();
                let total: f64 = weights.iter().sum();
                let mut pick = rng.next_f64() * total;
                let mut idx = n - 1;
                for (i, w) in weights.iter().enumerate() {
                    if pick < *w {
                        idx = i;
                        break;
                    }
                    pick -= w;
                }
                chunk_sizes[idx] as f64
            }
            SizeDistribution::EtcLike {
                median,
                sigma_ln,
                tail_fraction,
                tail_max,
            } => {
                if rng.chance(*tail_fraction) {
                    rng.gen_range_inclusive(*median as u64, *tail_max as u64) as f64
                } else {
                    rng.lognormal(*median, *sigma_ln)
                }
            }
        };
        (raw.round() as i64).clamp(min_size as i64, max_size as i64) as usize
    }
}

/// A complete workload: sizes + op mix + key space.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub distribution: SizeDistribution,
    /// Items to insert (the paper: 1 M).
    pub items: usize,
    /// get:set ratio as the fraction of gets (0.0 = pure inserts, the
    /// paper's waste experiments; 0.9 ≈ Facebook ETC).
    pub get_fraction: f64,
    /// Distinct keys (cycled by the key generator).
    pub key_space: usize,
    /// Zipf exponent for get-key popularity (0 = uniform).
    pub zipf_s: f64,
    /// Clamp bounds for item total size.
    pub min_size: usize,
    pub max_size: usize,
    pub seed: u64,
}

impl WorkloadSpec {
    /// Pure-insert workload with the given size distribution (the
    /// paper's §5 setup).
    pub fn inserts(distribution: SizeDistribution, items: usize, seed: u64) -> Self {
        WorkloadSpec {
            distribution,
            items,
            get_fraction: 0.0,
            key_space: items,
            zipf_s: 0.0,
            min_size: 50,
            max_size: 1 << 20,
            seed,
        }
    }
}

/// One of the paper's five table experiments.
#[derive(Clone, Debug)]
pub struct PaperExperiment {
    /// Table number (1-5).
    pub table: u32,
    /// μ as quoted (we use it as the log-normal median).
    pub mu: f64,
    /// σ as quoted in the paper (bytes — not usable directly).
    pub paper_sigma: f64,
    /// Reconstructed log-space σ (DESIGN.md §3 calibration).
    pub sigma_ln: f64,
    /// The default classes the paper lists as "Old Configuration".
    pub old_config: &'static [usize],
    /// The learned classes the paper reports as "New Configuration".
    pub paper_new_config: &'static [usize],
    /// Paper's old/new wasted bytes over 1 M items.
    pub paper_old_waste: u64,
    pub paper_new_waste: u64,
}

impl PaperExperiment {
    pub fn distribution(&self) -> SizeDistribution {
        SizeDistribution::LogNormal {
            median: self.mu,
            sigma_ln: self.sigma_ln,
        }
    }

    /// Number of learnable classes (kept constant by the algorithm).
    pub fn k(&self) -> usize {
        self.old_config.len()
    }

    /// Paper's recovered-waste fraction for this table.
    pub fn paper_recovery(&self) -> f64 {
        1.0 - self.paper_new_waste as f64 / self.paper_old_waste as f64
    }
}

/// Tables 1–5. σ_ln values are the DESIGN.md §3 calibration, chosen so
/// (a) ≥99.9 % of items land within the old-config class span and
/// (b) old-config waste/item matches the paper's (62/147/230/410/748 B).
pub const PAPER_EXPERIMENTS: [PaperExperiment; 5] = [
    PaperExperiment {
        table: 1,
        mu: 518.0,
        paper_sigma: 10.5,
        sigma_ln: 0.126,
        old_config: &[304, 384, 480, 600, 752, 944],
        paper_new_config: &[461, 510, 557, 614, 702, 943],
        paper_old_waste: 62_013_552,
        paper_new_waste: 32_809_986,
    },
    PaperExperiment {
        table: 2,
        mu: 1210.0,
        paper_sigma: 15.8,
        sigma_ln: 0.090,
        old_config: &[944, 1184, 1480, 1856],
        paper_new_config: &[1173, 1280, 1414, 1735],
        paper_old_waste: 147_403_935,
        paper_new_waste: 74_979_930,
    },
    PaperExperiment {
        table: 3,
        mu: 2109.0,
        paper_sigma: 16.6,
        sigma_ln: 0.065,
        old_config: &[1856, 2320, 2904],
        paper_new_config: &[2120, 2287, 2643],
        paper_old_waste: 230_144_462,
        paper_new_waste: 111_980_981,
    },
    PaperExperiment {
        table: 4,
        mu: 4133.0,
        paper_sigma: 15.8,
        sigma_ln: 0.027,
        old_config: &[4544, 5680],
        paper_new_config: &[4246, 4644],
        paper_old_waste: 410_568_873,
        paper_new_waste: 181_599_689,
    },
    PaperExperiment {
        table: 5,
        mu: 8131.0,
        paper_sigma: 15.2,
        sigma_ln: 0.0124,
        old_config: &[8880],
        paper_new_config: &[8628],
        paper_old_waste: 748_193_597,
        paper_new_waste: 496_353_869,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lognormal_sampling_matches_median() {
        let d = SizeDistribution::LogNormal {
            median: 518.0,
            sigma_ln: 0.126,
        };
        let mut rng = Pcg64::new(1);
        let mut xs: Vec<usize> = (0..50_001).map(|_| d.sample(&mut rng, 1, 1 << 20)).collect();
        xs.sort_unstable();
        let med = xs[xs.len() / 2];
        assert!((med as f64 - 518.0).abs() < 15.0, "median {med}");
    }

    #[test]
    fn clamping_respected() {
        let d = SizeDistribution::Normal {
            mean: 100.0,
            sd: 500.0,
        };
        let mut rng = Pcg64::new(2);
        for _ in 0..1000 {
            let s = d.sample(&mut rng, 50, 200);
            assert!((50..=200).contains(&s));
        }
    }

    #[test]
    fn fixed_and_discrete() {
        let mut rng = Pcg64::new(3);
        let f = SizeDistribution::Fixed { size: 777 };
        assert_eq!(f.sample(&mut rng, 1, 1 << 20), 777);
        let d = SizeDistribution::Discrete {
            sizes: vec![(100, 1.0), (200, 1.0)],
        };
        let mut seen = [false; 2];
        for _ in 0..100 {
            match d.sample(&mut rng, 1, 1 << 20) {
                100 => seen[0] = true,
                200 => seen[1] = true,
                other => panic!("unexpected size {other}"),
            }
        }
        assert!(seen[0] && seen[1]);
    }

    #[test]
    fn geom_decay_prefers_small_classes() {
        let d = SizeDistribution::GeomDecay {
            chunk_sizes: vec![96, 120, 152, 192],
        };
        let mut rng = Pcg64::new(4);
        let mut counts = std::collections::BTreeMap::new();
        for _ in 0..10_000 {
            *counts.entry(d.sample(&mut rng, 1, 1 << 20)).or_insert(0u32) += 1;
        }
        assert!(counts[&96] > counts[&120]);
        assert!(counts[&120] > counts[&152]);
    }

    #[test]
    fn paper_experiments_consistent() {
        for e in &PAPER_EXPERIMENTS {
            assert_eq!(e.old_config.len(), e.paper_new_config.len(), "T{}", e.table);
            assert!(e.paper_new_waste < e.paper_old_waste, "T{}", e.table);
            let rec = e.paper_recovery();
            assert!((0.3..0.6).contains(&rec), "T{} recovery {rec}", e.table);
        }
        // quoted recoveries: 47.09, 49.13, 51.34, 55.76, 33.65 (%)
        let quoted = [0.4709, 0.4913, 0.5134, 0.5576, 0.3365];
        for (e, q) in PAPER_EXPERIMENTS.iter().zip(quoted) {
            assert!(
                (e.paper_recovery() - q).abs() < 0.0005,
                "T{}: {} vs {}",
                e.table,
                e.paper_recovery(),
                q
            );
        }
    }

    #[test]
    fn sigma_calibration_keeps_items_in_old_span() {
        // ≥99.5 % of samples must fall inside the class span the paper's
        // old-config tables imply (previous class of first .. last).
        let chain = crate::slab::geometry::memcached_default_sizes();
        for e in &PAPER_EXPERIMENTS {
            let first = e.old_config[0];
            let last = *e.old_config.last().unwrap();
            let prev = chain.iter().rev().find(|&&c| c < first).copied().unwrap_or(0);
            let mut rng = Pcg64::new(42 + e.table as u64);
            let d = e.distribution();
            let n = 100_000;
            let inside = (0..n)
                .filter(|_| {
                    let s = d.sample(&mut rng, 1, 1 << 20);
                    s > prev && s <= last
                })
                .count();
            assert!(
                inside as f64 / n as f64 > 0.995,
                "T{}: only {}/{} inside ({prev},{last}]",
                e.table,
                inside,
                n
            );
        }
    }
}
