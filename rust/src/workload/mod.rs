//! Deterministic workload generation — the traffic patterns of the
//! paper's evaluation (§5: five log-normal size distributions, 1 M items
//! each) plus the §6.1 best/worst-case adversarial patterns and a
//! Facebook-ETC-like mix for realism.

pub mod gen;
pub mod spec;
pub mod trace;

pub use gen::WorkloadGen;
pub use spec::{PaperExperiment, SizeDistribution, WorkloadSpec, PAPER_EXPERIMENTS};
pub use trace::{Op, Trace};
