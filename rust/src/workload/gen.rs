//! Operation-stream generator over a [`WorkloadSpec`].
//!
//! Keys are `k<NNNNNNNN>` (fixed 9-byte length so the key contributes a
//! constant to the item total); value lengths are derived from the
//! target item **total size** minus the fixed overheads, so the sizes
//! entering the slab allocator follow the spec's distribution exactly.

use super::spec::WorkloadSpec;
use super::trace::Op;
use crate::store::item::total_item_size;
use crate::util::rng::Pcg64;

/// Fixed generated-key length ("k" + 8 digits).
pub const KEY_LEN: usize = 9;

/// Render the i-th key.
pub fn key_for(i: usize) -> String {
    format!("k{:08}", i % 100_000_000)
}

/// Value length that makes an item's accounted total equal `total`.
/// Returns `None` when `total` is too small to fit the overheads.
pub fn value_len_for_total(total: usize, use_cas: bool) -> Option<usize> {
    let base = total_item_size(KEY_LEN, 0, use_cas);
    total.checked_sub(base)
}

/// Streaming generator: deterministic, no allocation of the whole trace.
pub struct WorkloadGen {
    spec: WorkloadSpec,
    rng: Pcg64,
    emitted: usize,
    next_key: usize,
    use_cas: bool,
}

impl WorkloadGen {
    pub fn new(spec: WorkloadSpec, use_cas: bool) -> Self {
        let rng = Pcg64::new(spec.seed);
        WorkloadGen {
            spec,
            rng,
            emitted: 0,
            next_key: 0,
            use_cas,
        }
    }

    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    /// Smallest total size this generator can emit (overhead floor).
    pub fn min_total(&self) -> usize {
        total_item_size(KEY_LEN, 0, self.use_cas)
    }
}

impl Iterator for WorkloadGen {
    type Item = Op;

    fn next(&mut self) -> Option<Op> {
        if self.emitted >= self.spec.items {
            return None;
        }
        self.emitted += 1;
        // get or set?
        if self.next_key > 0 && self.rng.chance(self.spec.get_fraction) {
            let keyspace = self.next_key.min(self.spec.key_space);
            let rank = if self.spec.zipf_s > 0.0 {
                self.rng.zipf(keyspace as u64, self.spec.zipf_s) as usize
            } else {
                self.rng.gen_range(keyspace as u64) as usize
            };
            // rank 0 = most recent key (popularity skews to recent)
            let idx = self.next_key - 1 - rank;
            return Some(Op::Get { key: key_for(idx) });
        }
        let floor = self.min_total().max(self.spec.min_size);
        let total = self
            .spec
            .distribution
            .sample(&mut self.rng, floor, self.spec.max_size);
        let vlen = value_len_for_total(total, self.use_cas)
            .expect("clamped total covers overheads");
        let idx = self.next_key % self.spec.key_space;
        self.next_key += 1;
        Some(Op::Set {
            key: key_for(idx),
            value_len: vlen,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::SizeDistribution;

    fn spec(items: usize, get_fraction: f64) -> WorkloadSpec {
        WorkloadSpec {
            distribution: SizeDistribution::LogNormal {
                median: 518.0,
                sigma_ln: 0.126,
            },
            items,
            get_fraction,
            key_space: 1_000_000,
            zipf_s: 0.99,
            min_size: 50,
            max_size: 1 << 20,
            seed: 7,
        }
    }

    #[test]
    fn deterministic() {
        let a: Vec<Op> = WorkloadGen::new(spec(100, 0.5), true).collect();
        let b: Vec<Op> = WorkloadGen::new(spec(100, 0.5), true).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn pure_insert_workload_has_no_gets() {
        let ops: Vec<Op> = WorkloadGen::new(spec(500, 0.0), true).collect();
        assert_eq!(ops.len(), 500);
        assert!(ops.iter().all(|o| matches!(o, Op::Set { .. })));
    }

    #[test]
    fn item_totals_follow_distribution() {
        let ops: Vec<Op> = WorkloadGen::new(spec(20_000, 0.0), true).collect();
        let mut totals: Vec<usize> = ops
            .iter()
            .map(|o| match o {
                Op::Set { key, value_len } => total_item_size(key.len(), *value_len, true),
                _ => unreachable!(),
            })
            .collect();
        totals.sort_unstable();
        let med = totals[totals.len() / 2];
        assert!((med as f64 - 518.0).abs() < 20.0, "median total {med}");
    }

    #[test]
    fn mixed_workload_get_fraction_respected() {
        let ops: Vec<Op> = WorkloadGen::new(spec(20_000, 0.7), true).collect();
        let gets = ops.iter().filter(|o| matches!(o, Op::Get { .. })).count();
        let frac = gets as f64 / ops.len() as f64;
        assert!((frac - 0.7).abs() < 0.02, "get fraction {frac}");
    }

    #[test]
    fn gets_reference_existing_keys() {
        let mut max_set_idx: i64 = -1;
        for op in WorkloadGen::new(spec(5000, 0.5), true) {
            match op {
                Op::Set { key, .. } => {
                    let idx: i64 = key[1..].parse().unwrap();
                    max_set_idx = max_set_idx.max(idx);
                }
                Op::Get { key } => {
                    let idx: i64 = key[1..].parse().unwrap();
                    assert!(idx <= max_set_idx, "get of unseen key {key}");
                }
                _ => {}
            }
        }
    }

    #[test]
    fn value_len_accounting_roundtrip() {
        // overhead floor: 48 + 8 (cas) + 9 (key) + 2 = 67
        for total in [67, 100, 518, 8192] {
            let vlen = value_len_for_total(total, true).unwrap();
            assert_eq!(total_item_size(KEY_LEN, vlen, true), total);
        }
        assert_eq!(value_len_for_total(10, true), None);
    }
}
