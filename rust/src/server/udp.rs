//! Memcached UDP frame protocol on the shared command IR.
//!
//! Every UDP datagram carries an 8-byte frame header followed by plain
//! text-protocol bytes:
//!
//! ```text
//! 0-1  request id   (opaque; echoed on every response datagram)
//! 2-3  sequence no  (0-based)
//! 4-5  datagram count for this message
//! 6-7  reserved     (0 on send, ignored on receive)
//! ```
//!
//! all three counters big-endian — the classic memcached framing.
//! **Requests** must fit one datagram (`seq == 0 && total == 1`;
//! anything else is dropped, memcached parity). **Responses** are
//! fragmented into up to [`MAX_RESPONSE_FRAGS`] datagrams of
//! [`DATAGRAM_MAX`] bytes, sequence numbers incrementing; a response
//! that would need more is replaced by a single `SERVER_ERROR`
//! datagram (parity with dropping oversized UDP responses, but
//! diagnosable by the client).
//!
//! The payload runs through the **same** [`Conn`] state machine as TCP
//! — one parser, one `Exec` core, one `ResponseWriter` — so the two
//! transports cannot diverge semantically (the integration suite diffs
//! them on an identical script). A datagram is a complete pipelined
//! batch: if a command spills past the frame (a torn datagram), the
//! completed prefix is answered, a `CLIENT_ERROR` is appended, and the
//! connection state resets so the next datagram starts clean.

use super::conn::Conn;

/// Frame header bytes prepended to every datagram.
pub const HEADER_LEN: usize = 8;

/// Max bytes per datagram on the wire (memcached's
/// `UDP_MAX_PAYLOAD_SIZE`), header included.
pub const DATAGRAM_MAX: usize = 1400;

/// Response payload bytes per datagram.
pub const PAYLOAD_MAX: usize = DATAGRAM_MAX - HEADER_LEN;

/// Ceiling on response datagrams per request. Beyond it the response
/// is replaced by [`OVERSIZED_RESPONSE`] — a reply spanning more
/// fragments than this has no business on a lossy transport (one
/// dropped fragment wastes the whole burst).
pub const MAX_RESPONSE_FRAGS: usize = 64;

/// The single-datagram reply sent in place of an oversized response.
pub const OVERSIZED_RESPONSE: &[u8] = b"SERVER_ERROR response too large for udp\r\n";

/// Decoded frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    pub request_id: u16,
    pub seq: u16,
    pub total: u16,
}

/// Parse the 8-byte frame header off a datagram. `None` = too short.
#[inline]
pub fn parse_header(dgram: &[u8]) -> Option<FrameHeader> {
    if dgram.len() < HEADER_LEN {
        return None;
    }
    Some(FrameHeader {
        request_id: u16::from_be_bytes([dgram[0], dgram[1]]),
        seq: u16::from_be_bytes([dgram[2], dgram[3]]),
        total: u16::from_be_bytes([dgram[4], dgram[5]]),
    })
}

/// Encode a frame header into the first 8 bytes of `out`.
#[inline]
pub fn encode_header(out: &mut [u8], request_id: u16, seq: u16, total: u16) {
    out[0..2].copy_from_slice(&request_id.to_be_bytes());
    out[2..4].copy_from_slice(&seq.to_be_bytes());
    out[4..6].copy_from_slice(&total.to_be_bytes());
    out[6] = 0;
    out[7] = 0;
}

/// Number of datagrams a response of `len` bytes needs.
#[inline]
pub fn frags_for(len: usize) -> usize {
    len.div_ceil(PAYLOAD_MAX)
}

/// Fragment `response` into framed datagrams, handing each to `emit`
/// (built in `scratch`, reused across fragments — no allocation once
/// `scratch` reached [`DATAGRAM_MAX`]). An empty response emits
/// nothing (an all-quiet pipeline sends no reply). An oversized
/// response emits one [`OVERSIZED_RESPONSE`] datagram instead and
/// returns `false`.
pub fn fragment(
    request_id: u16,
    response: &[u8],
    scratch: &mut Vec<u8>,
    mut emit: impl FnMut(&[u8]),
) -> bool {
    let total = frags_for(response.len());
    if total == 0 {
        return true;
    }
    if total > MAX_RESPONSE_FRAGS {
        scratch.clear();
        scratch.resize(HEADER_LEN, 0);
        encode_header(scratch, request_id, 0, 1);
        scratch.extend_from_slice(OVERSIZED_RESPONSE);
        emit(scratch);
        return false;
    }
    for (seq, chunk) in response.chunks(PAYLOAD_MAX).enumerate() {
        scratch.clear();
        scratch.resize(HEADER_LEN, 0);
        encode_header(scratch, request_id, seq as u16, total as u16);
        scratch.extend_from_slice(chunk);
        emit(scratch);
    }
    true
}

/// Run one request datagram through the shared connection state
/// machine, appending the raw (unframed) response bytes to `reply`.
/// Returns the request id to frame the reply under, or `None` when the
/// datagram is not a well-formed single-fragment request — such frames
/// are dropped without reply (there is no id worth answering to).
pub fn handle_datagram(conn: &mut Conn, dgram: &[u8], reply: &mut Vec<u8>) -> Option<u16> {
    let h = parse_header(dgram)?;
    if h.seq != 0 || h.total != 1 {
        return None; // multi-datagram requests are not a thing
    }
    conn.on_bytes(&dgram[HEADER_LEN..], reply);
    if !conn.finish_datagram() {
        // a command ran past the end of the frame: answer what
        // completed, flag the truncation, and start the next datagram
        // from a clean parser
        reply.extend_from_slice(b"CLIENT_ERROR truncated datagram\r\n");
    }
    Some(h.request_id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::NoControl;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::PAGE_SIZE;
    use crate::store::sharded::ShardedStore;
    use crate::store::store::Clock;
    use std::sync::Arc;

    fn conn() -> Conn {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                32 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        Conn::new(store, Arc::new(NoControl))
    }

    fn framed(id: u16, body: &[u8]) -> Vec<u8> {
        let mut d = vec![0u8; HEADER_LEN];
        encode_header(&mut d, id, 0, 1);
        d.extend_from_slice(body);
        d
    }

    /// Reassemble emitted fragments, asserting the frame invariants.
    fn reassemble(frames: &[Vec<u8>], want_id: u16) -> Vec<u8> {
        let total = frames.len();
        let mut body = Vec::new();
        for (i, f) in frames.iter().enumerate() {
            assert!(f.len() <= DATAGRAM_MAX);
            let h = parse_header(f).unwrap();
            assert_eq!(h.request_id, want_id);
            assert_eq!(h.seq as usize, i);
            assert_eq!(h.total as usize, total);
            assert_eq!(&f[6..8], &[0, 0], "reserved bytes are zero");
            body.extend_from_slice(&f[HEADER_LEN..]);
        }
        body
    }

    #[test]
    fn header_roundtrip() {
        let mut buf = [0u8; HEADER_LEN];
        encode_header(&mut buf, 0xBEEF, 3, 9);
        assert_eq!(
            parse_header(&buf),
            Some(FrameHeader {
                request_id: 0xBEEF,
                seq: 3,
                total: 9
            })
        );
        // big-endian on the wire
        assert_eq!(&buf[..2], &[0xBE, 0xEF]);
        assert_eq!(parse_header(&buf[..7]), None, "short datagram");
    }

    #[test]
    fn single_fragment_response() {
        let mut scratch = Vec::new();
        let mut frames: Vec<Vec<u8>> = Vec::new();
        assert!(fragment(7, b"END\r\n", &mut scratch, |f| frames.push(f.to_vec())));
        assert_eq!(frames.len(), 1);
        assert_eq!(reassemble(&frames, 7), b"END\r\n");
    }

    #[test]
    fn empty_response_emits_nothing() {
        let mut scratch = Vec::new();
        let mut n = 0;
        assert!(fragment(1, b"", &mut scratch, |_| n += 1));
        assert_eq!(n, 0);
    }

    #[test]
    fn multi_datagram_response_reassembles() {
        // a response spanning several fragments, with a non-aligned tail
        let body: Vec<u8> = (0..PAYLOAD_MAX * 3 + 123)
            .map(|i| (i % 251) as u8)
            .collect();
        assert_eq!(frags_for(body.len()), 4);
        let mut scratch = Vec::new();
        let mut frames: Vec<Vec<u8>> = Vec::new();
        assert!(fragment(42, &body, &mut scratch, |f| frames.push(f.to_vec())));
        assert_eq!(frames.len(), 4);
        // every fragment but the last is full
        for f in &frames[..3] {
            assert_eq!(f.len(), DATAGRAM_MAX);
        }
        assert_eq!(reassemble(&frames, 42), body);
    }

    #[test]
    fn exact_boundary_needs_no_extra_fragment() {
        let body = vec![b'x'; PAYLOAD_MAX * 2];
        let mut scratch = Vec::new();
        let mut frames: Vec<Vec<u8>> = Vec::new();
        assert!(fragment(5, &body, &mut scratch, |f| frames.push(f.to_vec())));
        assert_eq!(frames.len(), 2);
        assert_eq!(reassemble(&frames, 5), body);
    }

    #[test]
    fn oversized_response_drops_to_server_error() {
        let body = vec![b'x'; PAYLOAD_MAX * MAX_RESPONSE_FRAGS + 1];
        let mut scratch = Vec::new();
        let mut frames: Vec<Vec<u8>> = Vec::new();
        assert!(!fragment(9, &body, &mut scratch, |f| frames.push(f.to_vec())));
        assert_eq!(frames.len(), 1);
        assert_eq!(reassemble(&frames, 9), OVERSIZED_RESPONSE);
    }

    #[test]
    fn datagram_set_get_through_shared_conn() {
        let mut c = conn();
        let mut reply = Vec::new();
        let id = handle_datagram(&mut c, &framed(1, b"set k 0 0 5\r\nhello\r\n"), &mut reply);
        assert_eq!(id, Some(1));
        assert_eq!(reply, b"STORED\r\n");
        reply.clear();
        let id = handle_datagram(&mut c, &framed(2, b"get k\r\nmg k v s\r\n"), &mut reply);
        assert_eq!(id, Some(2));
        assert_eq!(
            String::from_utf8_lossy(&reply),
            "VALUE k 0 5\r\nhello\r\nEND\r\nVA 5 s5\r\nhello\r\n"
        );
    }

    #[test]
    fn bad_frames_are_dropped() {
        let mut c = conn();
        let mut reply = Vec::new();
        // too short for a header
        assert_eq!(handle_datagram(&mut c, b"abc", &mut reply), None);
        // multi-fragment request shapes
        let mut d = vec![0u8; HEADER_LEN];
        encode_header(&mut d, 1, 1, 2);
        d.extend_from_slice(b"get k\r\n");
        assert_eq!(handle_datagram(&mut c, &d, &mut reply), None);
        encode_header(&mut d, 1, 0, 2);
        assert_eq!(handle_datagram(&mut c, &d, &mut reply), None);
        assert!(reply.is_empty());
    }

    #[test]
    fn torn_datagram_answers_prefix_and_resets() {
        let mut c = conn();
        let mut reply = Vec::new();
        // one whole command + one command missing its data block
        let id = handle_datagram(
            &mut c,
            &framed(3, b"set a 0 0 1\r\nx\r\nset b 0 0 5\r\nhe"),
            &mut reply,
        );
        assert_eq!(id, Some(3));
        let t = String::from_utf8_lossy(&reply);
        assert!(t.starts_with("STORED\r\n"), "{t}");
        assert!(t.contains("CLIENT_ERROR truncated datagram"), "{t}");
        // the parser is clean again: the next datagram is unaffected by
        // the dangling data phase
        reply.clear();
        let id = handle_datagram(&mut c, &framed(4, b"get a\r\n"), &mut reply);
        assert_eq!(id, Some(4));
        assert_eq!(String::from_utf8_lossy(&reply), "VALUE a 0 1\r\nx\r\nEND\r\n");
    }

    #[test]
    fn quit_over_udp_does_not_poison_the_conn() {
        let mut c = conn();
        let mut reply = Vec::new();
        handle_datagram(&mut c, &framed(1, b"quit\r\n"), &mut reply);
        reply.clear();
        let id = handle_datagram(&mut c, &framed(2, b"version\r\n"), &mut reply);
        assert_eq!(id, Some(2));
        assert!(String::from_utf8_lossy(&reply).starts_with("VERSION"));
    }
}
