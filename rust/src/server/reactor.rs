//! Sharded epoll reactor: N event-loop threads, each owning one epoll
//! instance and a slab of [`DrivenConn`] connection state machines.
//!
//! ## Architecture
//!
//! ```text
//!                       accept thread (server::tcp)
//!                     round-robin  |  max_conns gate
//!             +-----------+-----------+-----------+
//!             v           v           v
//!        [inbox 0]    [inbox 1]   [inbox N-1]      (Mutex<Vec> + eventfd)
//!             |           |           |
//!        reactor 0    reactor 1   reactor N-1      (one epoll each)
//!          epoll_wait -> DrivenConn::drive(readable, writable)
//! ```
//!
//! Sockets are nonblocking and registered **edge-triggered**
//! (`EPOLLIN | EPOLLRDHUP | EPOLLET`); `DrivenConn` keeps its own
//! readiness memory so edges are never lost across yields. EPOLLOUT
//! interest is added only while a connection has output the socket
//! refused (`ConnState::Open { wants_write: true }`) and removed once
//! drained — the "interest re-registration" half of backpressure.
//! Connections that yield with buffered work (read budget, output
//! high-water) go on a redrive list served before the next sleep, so
//! the loop neither busy-spins nor strands an edge-triggered socket.
//!
//! The reactor also owns the idle sweep (close sockets quiet past
//! `idle_timeout`) and the graceful-shutdown drain (flush in-flight
//! responses, bounded by [`DRAIN_DEADLINE`], then close everything).

#![cfg(target_os = "linux")]

use super::conn::{Conn, ConnState, Control, DrivenConn};
use super::metrics::Metrics;
use super::sys::{
    Epoll, EpollEvent, WakeFd, EPOLLERR, EPOLLET, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};
use crate::store::sharded::ShardedStore;
use std::net::TcpStream;
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Event token reserved for the inbox eventfd.
const WAKE_TOKEN: u64 = u64::MAX;

/// Events drained per `epoll_wait`.
const EVENTS_PER_WAIT: usize = 256;

/// Wait timeout: bounds shutdown-observation and idle-sweep latency.
const TICK_MS: i32 = 200;

/// How often the idle sweep scans the connection slab.
const SWEEP_EVERY: Duration = Duration::from_secs(1);

/// Graceful shutdown: total time budget for flushing in-flight
/// responses before connections are closed regardless.
const DRAIN_DEADLINE: Duration = Duration::from_millis(500);

/// Idle-buffer shrink floor: a drained idle connection keeps at most
/// this much receive/output/staging capacity per buffer.
const IDLE_BUF_FLOOR: usize = 4096;

/// How long a connection must sit idle before the sweep reclaims its
/// oversized buffers (immediately under budget pressure).
const IDLE_SHRINK_AFTER: Duration = Duration::from_secs(5);

/// Hand-off queue from the accept thread into one reactor.
struct Inbox {
    queue: Mutex<Vec<TcpStream>>,
    wake: WakeFd,
    /// Cleared when the owning reactor exits (including by panic) so
    /// the accept thread stops routing sockets into a black hole.
    alive: AtomicBool,
    /// Connections the accept thread asks this reactor to reap (oldest
    /// idle first) — the fd-exhaustion relief valve.
    reap: AtomicUsize,
}

impl Inbox {
    /// Poison-proof lock: a reactor that panicked while holding the
    /// queue must not take the accept thread down with it.
    fn queue(&self) -> std::sync::MutexGuard<'_, Vec<TcpStream>> {
        match self.queue.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

/// The running reactor threads; shared between the `ServerHandle` and
/// the accept thread (hence the interior-mutable join list).
pub(crate) struct ReactorPool {
    inboxes: Vec<Arc<Inbox>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    metrics: Arc<Metrics>,
}

impl ReactorPool {
    pub(crate) fn threads(&self) -> usize {
        self.inboxes.len()
    }

    /// Queue an accepted socket onto reactor `i % N` (skipping dead
    /// reactors) and wake it. If every reactor has died the socket is
    /// dropped and its gauge claim released.
    pub(crate) fn dispatch(&self, i: usize, stream: TcpStream) {
        let n = self.inboxes.len();
        for offset in 0..n {
            let inbox = &self.inboxes[(i + offset) % n];
            if !inbox.alive.load(Ordering::SeqCst) {
                continue;
            }
            inbox.queue().push(stream);
            inbox.wake.wake();
            return;
        }
        // no live reactor: close the socket, undo the accept gate
        Metrics::bump(&self.metrics.connections_closed);
        Metrics::dec(&self.metrics.curr_connections);
    }

    /// Wake every reactor so it observes the shutdown flag promptly.
    pub(crate) fn wake_all(&self) {
        for inbox in &self.inboxes {
            inbox.wake.wake();
        }
    }

    /// Ask every reactor to close its oldest-idle connection (the
    /// accept thread's EMFILE relief valve — frees up to N fds).
    pub(crate) fn request_reap(&self) {
        for inbox in &self.inboxes {
            inbox.reap.fetch_add(1, Ordering::SeqCst);
            inbox.wake.wake();
        }
    }

    pub(crate) fn join_all(&self) {
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn `threads` reactor event loops.
pub(crate) fn start(
    threads: usize,
    idle_timeout: Option<Duration>,
    buffer_budget: usize,
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<Arc<ReactorPool>> {
    let threads = threads.max(1);
    let mut inboxes = Vec::with_capacity(threads);
    let mut handles = Vec::with_capacity(threads);
    for i in 0..threads {
        let inbox = Arc::new(Inbox {
            queue: Mutex::new(Vec::new()),
            wake: WakeFd::new()?,
            alive: AtomicBool::new(true),
            reap: AtomicUsize::new(0),
        });
        let ep = Epoll::new()?;
        ep.add(inbox.wake.raw(), WAKE_TOKEN, EPOLLIN)?;
        let ctx = ReactorCtx {
            ep,
            inbox: inbox.clone(),
            idle_timeout,
            buffer_budget,
            store: store.clone(),
            control: control.clone(),
            metrics: metrics.clone(),
            shutdown: shutdown.clone(),
        };
        let thread_inbox = inbox.clone();
        let h = std::thread::Builder::new()
            .name(format!("slabforge-reactor-{i}"))
            .spawn(move || {
                // contain panics: one reactor dying must not poison the
                // accept thread or silently black-hole its inbox — the
                // dispatcher fails over to the surviving reactors.
                // Connection gauges stay correct because Entry::drop
                // does the accounting even during unwinding.
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                    ctx.run()
                }));
                thread_inbox.alive.store(false, Ordering::SeqCst);
                if r.is_err() {
                    eprintln!(
                        "reactor-{i} panicked; its connections were closed and \
                         new sockets fail over to the remaining reactors"
                    );
                }
            })?;
        inboxes.push(inbox);
        handles.push(h);
    }
    Ok(Arc::new(ReactorPool {
        inboxes,
        handles: Mutex::new(handles),
        metrics,
    }))
}

/// One live connection slot. The connection gauges are settled in
/// `Drop`, not at explicit close sites, so the accounting stays correct
/// even when a reactor unwinds from a panic and its slab is dropped.
struct Entry {
    dc: DrivenConn<TcpStream>,
    fd: RawFd,
    /// EPOLLOUT currently registered.
    interest_write: bool,
    /// Pending-output bytes currently charged to the global
    /// `conn_buffer_bytes` gauge (settled after every drive; the gauge
    /// is the sum of these across all reactors).
    accounted: usize,
    metrics: Arc<Metrics>,
}

impl Entry {
    /// Reconcile the global buffer gauge with this connection's actual
    /// pending output.
    fn settle_account(&mut self) {
        let now = self.dc.pending_out_len();
        if now > self.accounted {
            self.metrics
                .conn_buffer_bytes
                .fetch_add((now - self.accounted) as u64, Ordering::Relaxed);
        } else if now < self.accounted {
            self.metrics
                .conn_buffer_bytes
                .fetch_sub((self.accounted - now) as u64, Ordering::Relaxed);
        }
        self.accounted = now;
    }
}

impl Drop for Entry {
    fn drop(&mut self) {
        // the TcpStream closes with the DrivenConn, which deregisters
        // the fd from epoll
        if self.accounted > 0 {
            self.metrics
                .conn_buffer_bytes
                .fetch_sub(self.accounted as u64, Ordering::Relaxed);
        }
        Metrics::bump(&self.metrics.connections_closed);
        Metrics::dec(&self.metrics.curr_connections);
    }
}

/// Slab-of-connections table: slot index doubles as the epoll token, so
/// event dispatch is a bounds-checked vector index, no hashing.
struct Slab {
    conns: Vec<Option<Entry>>,
    free: Vec<usize>,
}

impl Slab {
    fn alloc(&mut self) -> usize {
        match self.free.pop() {
            Some(slot) => slot,
            None => {
                self.conns.push(None);
                self.conns.len() - 1
            }
        }
    }

    fn close(&mut self, slot: usize) {
        if self.conns[slot].take().is_some() {
            self.free.push(slot);
        }
    }
}

struct ReactorCtx {
    ep: Epoll,
    inbox: Arc<Inbox>,
    idle_timeout: Option<Duration>,
    /// Global connection-buffer byte budget (0 = unlimited): when the
    /// `conn_buffer_bytes` gauge exceeds this, the reactor sheds its
    /// most-backlogged stalled connections and the accept thread
    /// pauses (`server::tcp`).
    buffer_budget: usize,
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
}

impl ReactorCtx {
    fn run(self) {
        let mut slab = Slab {
            conns: Vec::new(),
            free: Vec::new(),
        };
        let mut events = vec![EpollEvent::zeroed(); EVENTS_PER_WAIT];
        // redrive double-buffer, persistent across iterations so the
        // event loop itself allocates nothing in steady state
        let mut redrive: Vec<usize> = Vec::new();
        let mut next: Vec<usize> = Vec::new();
        let mut last_sweep = Instant::now();
        loop {
            let timeout = if redrive.is_empty() { TICK_MS } else { 0 };
            let n = match self.ep.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("reactor: epoll_wait failed: {e}");
                    break;
                }
            };
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let mut accept_new = false;
            for ev in events.iter().take(n) {
                // copy out of the (possibly packed) kernel struct
                let (bits, token) = {
                    let e = *ev;
                    (e.events, e.data)
                };
                if token == WAKE_TOKEN {
                    self.inbox.wake.drain();
                    accept_new = true;
                    continue;
                }
                let readable = bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0;
                let writable = bits & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0;
                self.drive_slot(&mut slab, token as usize, readable, writable, &mut next);
            }
            // fd-exhaustion relief requested by the accept thread:
            // close the oldest-idle connections to free descriptors
            let reap = self.inbox.reap.swap(0, Ordering::SeqCst);
            if reap > 0 {
                self.reap_oldest(&mut slab, reap);
            }
            // new sockets register after the event batch so a freed
            // slot can never be reused while its stale events are still
            // in `events`
            if accept_new {
                let fresh: Vec<TcpStream> =
                    std::mem::take(&mut *self.inbox.queue());
                for stream in fresh {
                    self.register(&mut slab, stream, &mut next);
                }
            }
            // re-drive yielded connections (buffered input or lifted
            // backpressure) before sleeping again
            for i in 0..redrive.len() {
                let slot = redrive[i];
                self.drive_slot(&mut slab, slot, false, false, &mut next);
            }
            redrive.clear();
            next.sort_unstable();
            next.dedup();
            std::mem::swap(&mut redrive, &mut next);

            if self.buffer_budget > 0 {
                self.shed_over_budget(&mut slab);
            }
            if last_sweep.elapsed() >= SWEEP_EVERY {
                self.sweep_idle(&mut slab);
                last_sweep = Instant::now();
            }
        }
        self.drain_and_close(&mut slab);
    }

    /// Overload shedding: while the global buffer gauge is over budget,
    /// close this reactor's most-backlogged *stalled* connection (has
    /// pending output and EPOLLOUT registered — i.e. the socket already
    /// refused it). Healthy connections are never shed; each close
    /// releases its accounted bytes, so the loop terminates.
    fn shed_over_budget(&self, slab: &mut Slab) {
        while self.metrics.conn_buffer_bytes.load(Ordering::Relaxed) > self.buffer_budget as u64
        {
            let mut victim: Option<(usize, usize)> = None;
            for slot in 0..slab.conns.len() {
                if let Some(e) = &slab.conns[slot] {
                    let pending = e.dc.pending_out_len();
                    if e.interest_write
                        && pending > 0
                        && victim.is_none_or(|(_, p)| pending > p)
                    {
                        victim = Some((slot, pending));
                    }
                }
            }
            // no stalled conn here: another reactor holds the backlog
            let Some((slot, _)) = victim else { return };
            Metrics::bump(&self.metrics.shed_connections);
            slab.close(slot);
        }
    }

    /// Close the `n` longest-idle connections (EMFILE relief). Under fd
    /// exhaustion even a mostly-active table must give something up, so
    /// this picks the oldest unconditionally.
    fn reap_oldest(&self, slab: &mut Slab, n: usize) {
        let now = Instant::now();
        for _ in 0..n {
            let mut oldest: Option<(usize, Duration)> = None;
            for slot in 0..slab.conns.len() {
                if let Some(e) = &slab.conns[slot] {
                    let idle = e.dc.idle_for(now);
                    if oldest.is_none_or(|(_, d)| idle > d) {
                        oldest = Some((slot, idle));
                    }
                }
            }
            let Some((slot, _)) = oldest else { return };
            Metrics::bump(&self.metrics.shed_connections);
            slab.close(slot);
        }
    }

    /// Register an accepted socket: nonblocking, edge-triggered
    /// read-interest, then an immediate drive so bytes that arrived
    /// before registration are not stranded.
    fn register(&self, slab: &mut Slab, stream: TcpStream, redrive: &mut Vec<usize>) {
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            Metrics::bump(&self.metrics.connections_closed);
            Metrics::dec(&self.metrics.curr_connections);
            return;
        }
        let fd = stream.as_raw_fd();
        let slot = slab.alloc();
        if self
            .ep
            .add(fd, slot as u64, EPOLLIN | EPOLLRDHUP | EPOLLET)
            .is_err()
        {
            slab.free.push(slot);
            Metrics::bump(&self.metrics.connections_closed);
            Metrics::dec(&self.metrics.curr_connections);
            return;
        }
        let conn = Conn::with_metrics(
            self.store.clone(),
            self.control.clone(),
            self.metrics.clone(),
        );
        let dc = DrivenConn::new(stream, conn).with_direct_fd(fd);
        slab.conns[slot] = Some(Entry {
            dc,
            fd,
            interest_write: false,
            accounted: 0,
            metrics: self.metrics.clone(),
        });
        self.drive_slot(slab, slot, true, true, redrive);
    }

    /// Drive one connection and apply the outcome: close, EPOLLOUT
    /// interest re-registration, or a redrive request.
    ///
    /// The drive runs under `catch_unwind`: a request that panics the
    /// execution core (lock-poisoning recovery gone wrong, a poisoned
    /// payload) closes **that connection** — never the reactor. State
    /// isolation is per-connection by construction (`Conn` owns its
    /// buffers; store mutations are transactional per call).
    fn drive_slot(
        &self,
        slab: &mut Slab,
        slot: usize,
        readable: bool,
        writable: bool,
        redrive: &mut Vec<usize>,
    ) {
        // (outcome computed first so the entry borrow ends before the
        // slab is mutated)
        let outcome = match slab.conns.get_mut(slot).and_then(Option::as_mut) {
            None => return, // stale event for an already-closed connection
            Some(entry) => {
                let state = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    entry.dc.drive(readable, writable, &self.metrics)
                }))
                .unwrap_or_else(|_| {
                    eprintln!(
                        "slabforge: connection panicked mid-request; closing only that \
                         connection"
                    );
                    ConnState::Closed
                });
                // keep the global buffer gauge in sync with whatever
                // this drive buffered or flushed
                entry.settle_account();
                match state {
                    ConnState::Closed => None,
                    ConnState::Open { wants_write } => Some((
                        wants_write,
                        entry.interest_write,
                        entry.fd,
                        entry.dc.wants_redrive(),
                    )),
                }
            }
        };
        match outcome {
            None => slab.close(slot),
            Some((wants_write, interest_write, fd, wants_redrive)) => {
                // re-arm whenever write interest is (or was) registered
                // even if unchanged: with edge-triggered registration a
                // spuriously-cleared `write_ready` (injected EAGAIN, a
                // raced short write) would otherwise wait forever for
                // an edge that already passed — EPOLL_CTL_MOD re-delivers
                // the event if the socket is in fact writable.
                if wants_write || interest_write {
                    let mut bits = EPOLLIN | EPOLLRDHUP | EPOLLET;
                    if wants_write {
                        bits |= EPOLLOUT;
                    }
                    if self.ep.modify(fd, slot as u64, bits).is_err() {
                        slab.close(slot);
                        return;
                    }
                    if let Some(entry) = slab.conns[slot].as_mut() {
                        entry.interest_write = wants_write;
                    }
                }
                if wants_redrive {
                    redrive.push(slot);
                }
            }
        }
    }

    /// Periodic housekeeping pass: close connections with no activity
    /// past the idle timeout (`quit`-less load generators cannot leak
    /// fds) and reclaim oversized buffers from idle survivors —
    /// immediately when the buffer gauge nears its budget, otherwise
    /// only after [`IDLE_SHRINK_AFTER`] so active connections keep
    /// their warm allocations.
    fn sweep_idle(&self, slab: &mut Slab) {
        let now = Instant::now();
        let pressure = self.buffer_budget > 0
            && self.metrics.conn_buffer_bytes.load(Ordering::Relaxed)
                > (self.buffer_budget as u64) / 2;
        for slot in 0..slab.conns.len() {
            let Some(entry) = slab.conns[slot].as_mut() else {
                continue;
            };
            let idle = entry.dc.idle_for(now);
            if self.idle_timeout.is_some_and(|t| idle > t) {
                slab.close(slot);
                continue;
            }
            if pressure || idle > IDLE_SHRINK_AFTER {
                entry.dc.shrink_idle(IDLE_BUF_FLOOR);
            }
        }
    }

    /// Graceful shutdown: flush whatever responses are already encoded
    /// (flush-only — no further reads or command execution; bounded by
    /// [`DRAIN_DEADLINE`]), then close every socket.
    fn drain_and_close(&self, slab: &mut Slab) {
        let deadline = Instant::now() + DRAIN_DEADLINE;
        loop {
            let mut pending = false;
            for entry in slab.conns.iter_mut().flatten() {
                if entry.dc.has_pending_out() {
                    entry.dc.flush_pending(&self.metrics);
                    pending |= entry.dc.has_pending_out();
                }
            }
            if !pending || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        for slot in 0..slab.conns.len() {
            slab.close(slot);
        }
    }
}
