//! Sharded epoll reactor: N event-loop threads, each owning one epoll
//! instance and a slab of [`DrivenConn`] connection state machines.
//!
//! ## Architecture
//!
//! ```text
//!   SO_REUSEPORT (default):              fallback (option unavailable):
//!
//!   kernel hashes SYNs / datagrams            accept thread (server::tcp)
//!    |            |           |             round-robin  |  max_conns gate
//!    v            v           v            +-----------+-----------+
//!  [lsn 0]     [lsn 1]    [lsn N-1]        v           v           v
//!  [udp 0]     [udp 1]    [udp N-1]   [inbox 0]   [inbox 1]  [inbox N-1]
//!    |            |           |            |           |           |
//!  reactor 0   reactor 1  reactor N-1  reactor 0   reactor 1  reactor N-1
//!          epoll_wait -> accept burst / recvmmsg batch /
//!                        DrivenConn::drive(readable, writable)
//! ```
//!
//! In reuseport mode every reactor owns its **own** listening socket
//! (and optionally its own UDP socket): the kernel distributes
//! accepts, so no lock, queue, or eventfd hop exists anywhere on the
//! accept path, and the `max_conns` gate plus the EMFILE reserve-fd
//! relief both run per-reactor. The inbox + eventfd machinery survives
//! only as the fallback when `SO_REUSEPORT` is unavailable (and for
//! shutdown wakeups). Reactor threads can be pinned to cores
//! (`pin_cores`), which also tags connections for the
//! `reactor_cross_shard` affinity stat.
//!
//! Connection sockets are nonblocking and registered **edge-triggered**
//! (`EPOLLIN | EPOLLRDHUP | EPOLLET`); `DrivenConn` keeps its own
//! readiness memory so edges are never lost across yields. EPOLLOUT
//! interest is added only while a connection has output the socket
//! refused (`ConnState::Open { wants_write: true }`) and removed once
//! drained — the "interest re-registration" half of backpressure.
//! Connections that yield with buffered work (read budget, output
//! high-water) go on a redrive list served before the next sleep, so
//! the loop neither busy-spins nor strands an edge-triggered socket.
//!
//! The reactor also owns the idle sweep (close sockets quiet past
//! `idle_timeout`) and the graceful-shutdown drain (flush in-flight
//! responses, bounded by [`DRAIN_DEADLINE`], then close everything).

#![cfg(target_os = "linux")]

use super::conn::{Conn, ConnState, Control, DrivenConn};
use super::metrics::Metrics;
use super::sys::{
    self, Epoll, EpollEvent, WakeFd, EPOLLERR, EPOLLET, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};
use super::udp;
use crate::store::sharded::ShardedStore;
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Event token reserved for the inbox eventfd.
const WAKE_TOKEN: u64 = u64::MAX;

/// Event token for this reactor's own listening socket (reuseport
/// mode). Registered level-triggered so a burst cut short (EMFILE,
/// accept budget) re-fires without bookkeeping.
const LISTEN_TOKEN: u64 = u64::MAX - 1;

/// Event token for this reactor's UDP socket (level-triggered, same
/// reasoning: an un-drained batch re-fires).
const UDP_TOKEN: u64 = u64::MAX - 2;

/// Accepts per listener wakeup before returning to serve connections
/// (level-triggered registration re-fires if more are pending).
const ACCEPT_BURST: usize = 64;

/// Receive buffer per UDP datagram slot. A request must fit one
/// datagram; anything longer arrives truncated and answers
/// `CLIENT_ERROR` via the torn-datagram path.
const UDP_RX_BUF: usize = 16 * 1024;

/// Events drained per `epoll_wait`.
const EVENTS_PER_WAIT: usize = 256;

/// Wait timeout: bounds shutdown-observation and idle-sweep latency.
const TICK_MS: i32 = 200;

/// How often the idle sweep scans the connection slab.
const SWEEP_EVERY: Duration = Duration::from_secs(1);

/// Graceful shutdown: total time budget for flushing in-flight
/// responses before connections are closed regardless.
const DRAIN_DEADLINE: Duration = Duration::from_millis(500);

/// Idle-buffer shrink floor: a drained idle connection keeps at most
/// this much receive/output/staging capacity per buffer.
const IDLE_BUF_FLOOR: usize = 4096;

/// How long a connection must sit idle before the sweep reclaims its
/// oversized buffers (immediately under budget pressure).
const IDLE_SHRINK_AFTER: Duration = Duration::from_secs(5);

/// Hand-off queue from the accept thread into one reactor.
struct Inbox {
    queue: Mutex<Vec<TcpStream>>,
    wake: WakeFd,
    /// Cleared when the owning reactor exits (including by panic) so
    /// the accept thread stops routing sockets into a black hole.
    alive: AtomicBool,
    /// Connections the accept thread asks this reactor to reap (oldest
    /// idle first) — the fd-exhaustion relief valve.
    reap: AtomicUsize,
    /// Connections accepted into this reactor (kernel-distributed in
    /// reuseport mode, dispatcher-assigned in fallback mode) — the
    /// distribution the reuseport integration test asserts on.
    accepted: AtomicU64,
}

impl Inbox {
    /// Poison-proof lock: a reactor that panicked while holding the
    /// queue must not take the accept thread down with it.
    fn queue(&self) -> std::sync::MutexGuard<'_, Vec<TcpStream>> {
        match self.queue.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

/// The running reactor threads; shared between the `ServerHandle` and
/// the accept thread (hence the interior-mutable join list).
pub(crate) struct ReactorPool {
    inboxes: Vec<Arc<Inbox>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    metrics: Arc<Metrics>,
}

impl ReactorPool {
    pub(crate) fn threads(&self) -> usize {
        self.inboxes.len()
    }

    /// Queue an accepted socket onto reactor `i % N` (skipping dead
    /// reactors) and wake it. If every reactor has died the socket is
    /// dropped and its gauge claim released.
    pub(crate) fn dispatch(&self, i: usize, stream: TcpStream) {
        let n = self.inboxes.len();
        for offset in 0..n {
            let inbox = &self.inboxes[(i + offset) % n];
            if !inbox.alive.load(Ordering::SeqCst) {
                continue;
            }
            inbox.accepted.fetch_add(1, Ordering::Relaxed);
            inbox.queue().push(stream);
            inbox.wake.wake();
            return;
        }
        // no live reactor: close the socket, undo the accept gate
        Metrics::bump(&self.metrics.connections_closed);
        Metrics::dec(&self.metrics.curr_connections);
    }

    /// Wake every reactor so it observes the shutdown flag promptly.
    pub(crate) fn wake_all(&self) {
        for inbox in &self.inboxes {
            inbox.wake.wake();
        }
    }

    /// Ask every reactor to close its oldest-idle connection (the
    /// accept thread's EMFILE relief valve — frees up to N fds).
    pub(crate) fn request_reap(&self) {
        for inbox in &self.inboxes {
            inbox.reap.fetch_add(1, Ordering::SeqCst);
            inbox.wake.wake();
        }
    }

    pub(crate) fn join_all(&self) {
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }

    /// Per-reactor accepted-connection counts.
    pub(crate) fn accept_counts(&self) -> Vec<u64> {
        self.inboxes
            .iter()
            .map(|i| i.accepted.load(Ordering::Relaxed))
            .collect()
    }
}

/// Front-end layout handed to [`start`] by `server::tcp`.
pub(crate) struct ReactorConfig {
    pub threads: usize,
    pub idle_timeout: Option<Duration>,
    pub buffer_budget: usize,
    /// Live-connection cap, enforced at accept time (per-reactor in
    /// reuseport mode, by the accept thread in fallback mode — the
    /// gauge it gates on is global either way).
    pub max_conns: usize,
    /// Pin reactor `i` to core `i % cores` and tag connections for the
    /// cross-shard affinity stat.
    pub pin_cores: bool,
    /// One `SO_REUSEPORT` listener per reactor; empty = fallback mode
    /// (the accept thread owns the single listener and dispatches).
    pub listeners: Vec<TcpListener>,
    /// Per-reactor UDP sockets. One per reactor in reuseport mode; a
    /// single socket (served by reactor 0) in fallback mode; empty =
    /// UDP disabled.
    pub udp: Vec<UdpSocket>,
}

/// Spawn `cfg.threads` reactor event loops.
pub(crate) fn start(
    cfg: ReactorConfig,
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<Arc<ReactorPool>> {
    let threads = cfg.threads.max(1);
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    let mut listeners: Vec<Option<TcpListener>> = cfg.listeners.into_iter().map(Some).collect();
    listeners.resize_with(threads, || None);
    let mut udp_socks: Vec<Option<UdpSocket>> = cfg.udp.into_iter().map(Some).collect();
    udp_socks.resize_with(threads, || None);
    let mut inboxes = Vec::with_capacity(threads);
    let mut handles = Vec::with_capacity(threads);
    for i in 0..threads {
        let inbox = Arc::new(Inbox {
            queue: Mutex::new(Vec::new()),
            wake: WakeFd::new()?,
            alive: AtomicBool::new(true),
            reap: AtomicUsize::new(0),
            accepted: AtomicU64::new(0),
        });
        let ep = Epoll::new()?;
        ep.add(inbox.wake.raw(), WAKE_TOKEN, EPOLLIN)?;
        let listener = listeners[i].take();
        let udp_sock = udp_socks[i].take();
        if let Some(l) = &listener {
            ep.add(l.as_raw_fd(), LISTEN_TOKEN, EPOLLIN)?;
        }
        if let Some(u) = &udp_sock {
            u.set_nonblocking(true)?;
            ep.add(u.as_raw_fd(), UDP_TOKEN, EPOLLIN)?;
        }
        let ctx = ReactorCtx {
            ep,
            inbox: inbox.clone(),
            id: i as u32,
            total: threads as u32,
            idle_timeout: cfg.idle_timeout,
            buffer_budget: cfg.buffer_budget,
            max_conns: cfg.max_conns,
            pin: cfg.pin_cores.then_some(i % cores),
            listener,
            udp_sock,
            store: store.clone(),
            control: control.clone(),
            metrics: metrics.clone(),
            shutdown: shutdown.clone(),
        };
        let thread_inbox = inbox.clone();
        let h = std::thread::Builder::new()
            .name(format!("slabforge-reactor-{i}"))
            .spawn(move || {
                // contain panics: one reactor dying must not poison the
                // accept thread or silently black-hole its inbox — the
                // dispatcher fails over to the surviving reactors.
                // Connection gauges stay correct because Entry::drop
                // does the accounting even during unwinding.
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                    ctx.run()
                }));
                thread_inbox.alive.store(false, Ordering::SeqCst);
                if r.is_err() {
                    eprintln!(
                        "reactor-{i} panicked; its connections were closed and \
                         new sockets fail over to the remaining reactors"
                    );
                }
            })?;
        inboxes.push(inbox);
        handles.push(h);
    }
    Ok(Arc::new(ReactorPool {
        inboxes,
        handles: Mutex::new(handles),
        metrics,
    }))
}

/// One live connection slot. The connection gauges are settled in
/// `Drop`, not at explicit close sites, so the accounting stays correct
/// even when a reactor unwinds from a panic and its slab is dropped.
struct Entry {
    dc: DrivenConn<TcpStream>,
    fd: RawFd,
    /// EPOLLOUT currently registered.
    interest_write: bool,
    /// Pending-output bytes currently charged to the global
    /// `conn_buffer_bytes` gauge (settled after every drive; the gauge
    /// is the sum of these across all reactors).
    accounted: usize,
    metrics: Arc<Metrics>,
}

impl Entry {
    /// Reconcile the global buffer gauge with this connection's actual
    /// pending output.
    fn settle_account(&mut self) {
        let now = self.dc.pending_out_len();
        if now > self.accounted {
            self.metrics
                .conn_buffer_bytes
                .fetch_add((now - self.accounted) as u64, Ordering::Relaxed);
        } else if now < self.accounted {
            self.metrics
                .conn_buffer_bytes
                .fetch_sub((self.accounted - now) as u64, Ordering::Relaxed);
        }
        self.accounted = now;
    }
}

impl Drop for Entry {
    fn drop(&mut self) {
        // the TcpStream closes with the DrivenConn, which deregisters
        // the fd from epoll
        if self.accounted > 0 {
            self.metrics
                .conn_buffer_bytes
                .fetch_sub(self.accounted as u64, Ordering::Relaxed);
        }
        Metrics::bump(&self.metrics.connections_closed);
        Metrics::dec(&self.metrics.curr_connections);
    }
}

/// Slab-of-connections table: slot index doubles as the epoll token, so
/// event dispatch is a bounds-checked vector index, no hashing.
struct Slab {
    conns: Vec<Option<Entry>>,
    free: Vec<usize>,
}

impl Slab {
    fn alloc(&mut self) -> usize {
        match self.free.pop() {
            Some(slot) => slot,
            None => {
                self.conns.push(None);
                self.conns.len() - 1
            }
        }
    }

    fn close(&mut self, slot: usize) {
        if self.conns[slot].take().is_some() {
            self.free.push(slot);
        }
    }
}

struct ReactorCtx {
    ep: Epoll,
    inbox: Arc<Inbox>,
    /// This reactor's index / the pool size (affinity tagging).
    id: u32,
    total: u32,
    idle_timeout: Option<Duration>,
    /// Global connection-buffer byte budget (0 = unlimited): when the
    /// `conn_buffer_bytes` gauge exceeds this, the reactor sheds its
    /// most-backlogged stalled connections and stops accepting (the
    /// fallback accept thread pauses, `server::tcp`).
    buffer_budget: usize,
    max_conns: usize,
    /// Core to pin this reactor thread to (`--pin-cores`).
    pin: Option<usize>,
    /// This reactor's own `SO_REUSEPORT` listener (reuseport mode).
    listener: Option<TcpListener>,
    /// This reactor's UDP socket.
    udp_sock: Option<UdpSocket>,
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
}

/// Per-reactor UDP serving state: fixed datagram slots for
/// `recvmmsg`, one reused [`Conn`] (datagrams are independent request
/// batches — the parser resets at every frame boundary), and staging
/// buffers so a full receive batch fragments and sends through one
/// `sendmmsg` with no steady-state allocation.
struct UdpState {
    sock: UdpSocket,
    conn: Conn,
    bufs: Vec<Box<[u8]>>,
    addrs: Vec<sys::SockAddrStorage>,
    lens: Vec<usize>,
    /// Raw (unframed) response bytes of the datagram being served.
    reply: Vec<u8>,
    /// Single-frame scratch for `udp::fragment`.
    frame: Vec<u8>,
    /// Staged outgoing frames (bytes + per-frame `(start, end,
    /// addr-slot)` spans) for the batched send.
    stage: Vec<u8>,
    spans: Vec<(usize, usize, usize)>,
}

impl ReactorCtx {
    fn run(mut self) {
        if let Some(core) = self.pin {
            // best-effort: a constrained cpuset must not kill serving
            let _ = sys::pin_to_core(core);
        }
        // EMFILE livelock breaker (reuseport mode — each reactor owns
        // its listener, so each parks its own fd to give back)
        let mut reserve: Option<std::fs::File> = self
            .listener
            .as_ref()
            .and_then(|l| sys::dup_fd(l.as_raw_fd()).ok());
        let mut udp_state = self.udp_sock.take().map(|s| {
            let mut conn = Conn::with_metrics(
                self.store.clone(),
                self.control.clone(),
                self.metrics.clone(),
            );
            if self.pin.is_some() {
                conn.set_affinity(self.id, self.total);
            }
            UdpState {
                sock: s,
                conn,
                bufs: (0..sys::MAX_BATCH)
                    .map(|_| vec![0u8; UDP_RX_BUF].into_boxed_slice())
                    .collect(),
                addrs: vec![sys::SockAddrStorage::zeroed(); sys::MAX_BATCH],
                lens: vec![0usize; sys::MAX_BATCH],
                reply: Vec::with_capacity(4096),
                frame: Vec::with_capacity(udp::DATAGRAM_MAX),
                stage: Vec::with_capacity(8192),
                spans: Vec::new(),
            }
        });
        let mut slab = Slab {
            conns: Vec::new(),
            free: Vec::new(),
        };
        let mut events = vec![EpollEvent::zeroed(); EVENTS_PER_WAIT];
        // redrive double-buffer, persistent across iterations so the
        // event loop itself allocates nothing in steady state
        let mut redrive: Vec<usize> = Vec::new();
        let mut next: Vec<usize> = Vec::new();
        let mut last_sweep = Instant::now();
        loop {
            let timeout = if redrive.is_empty() { TICK_MS } else { 0 };
            let n = match self.ep.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("reactor: epoll_wait failed: {e}");
                    break;
                }
            };
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let mut accept_new = false;
            let mut accept_own = false;
            let mut serve_udp = false;
            for ev in events.iter().take(n) {
                // copy out of the (possibly packed) kernel struct
                let (bits, token) = {
                    let e = *ev;
                    (e.events, e.data)
                };
                match token {
                    WAKE_TOKEN => {
                        self.inbox.wake.drain();
                        accept_new = true;
                    }
                    LISTEN_TOKEN => accept_own = true,
                    UDP_TOKEN => serve_udp = true,
                    _ => {
                        let readable =
                            bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0;
                        let writable = bits & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0;
                        self.drive_slot(&mut slab, token as usize, readable, writable, &mut next);
                    }
                }
            }
            // fd-exhaustion relief requested by the accept thread
            // (fallback mode): close oldest-idle connections to free
            // descriptors. In reuseport mode each reactor handles its
            // own EMFILE inside accept_burst.
            let reap = self.inbox.reap.swap(0, Ordering::SeqCst);
            if reap > 0 {
                self.reap_oldest(&mut slab, reap);
            }
            if accept_own {
                self.accept_burst(&mut slab, &mut next, &mut reserve);
            }
            if serve_udp {
                if let Some(st) = udp_state.as_mut() {
                    self.udp_service(st);
                }
            }
            // new sockets register after the event batch so a freed
            // slot can never be reused while its stale events are still
            // in `events`
            if accept_new {
                let fresh: Vec<TcpStream> =
                    std::mem::take(&mut *self.inbox.queue());
                for stream in fresh {
                    self.register(&mut slab, stream, &mut next);
                }
            }
            // re-drive yielded connections (buffered input or lifted
            // backpressure) before sleeping again
            for i in 0..redrive.len() {
                let slot = redrive[i];
                self.drive_slot(&mut slab, slot, false, false, &mut next);
            }
            redrive.clear();
            next.sort_unstable();
            next.dedup();
            std::mem::swap(&mut redrive, &mut next);

            if self.buffer_budget > 0 {
                self.shed_over_budget(&mut slab);
            }
            if last_sweep.elapsed() >= SWEEP_EVERY {
                self.sweep_idle(&mut slab);
                last_sweep = Instant::now();
            }
        }
        self.drain_and_close(&mut slab);
    }

    /// Overload shedding: while the global buffer gauge is over budget,
    /// close this reactor's most-backlogged *stalled* connection (has
    /// pending output and EPOLLOUT registered — i.e. the socket already
    /// refused it). Healthy connections are never shed; each close
    /// releases its accounted bytes, so the loop terminates.
    fn shed_over_budget(&self, slab: &mut Slab) {
        while self.metrics.conn_buffer_bytes.load(Ordering::Relaxed) > self.buffer_budget as u64
        {
            let mut victim: Option<(usize, usize)> = None;
            for slot in 0..slab.conns.len() {
                if let Some(e) = &slab.conns[slot] {
                    let pending = e.dc.pending_out_len();
                    if e.interest_write
                        && pending > 0
                        && victim.is_none_or(|(_, p)| pending > p)
                    {
                        victim = Some((slot, pending));
                    }
                }
            }
            // no stalled conn here: another reactor holds the backlog
            let Some((slot, _)) = victim else { return };
            Metrics::bump(&self.metrics.shed_connections);
            slab.close(slot);
        }
    }

    /// Close the `n` longest-idle connections (EMFILE relief). Under fd
    /// exhaustion even a mostly-active table must give something up, so
    /// this picks the oldest unconditionally.
    fn reap_oldest(&self, slab: &mut Slab, n: usize) {
        let now = Instant::now();
        for _ in 0..n {
            let mut oldest: Option<(usize, Duration)> = None;
            for slot in 0..slab.conns.len() {
                if let Some(e) = &slab.conns[slot] {
                    let idle = e.dc.idle_for(now);
                    if oldest.is_none_or(|(_, d)| idle > d) {
                        oldest = Some((slot, idle));
                    }
                }
            }
            let Some((slot, _)) = oldest else { return };
            Metrics::bump(&self.metrics.shed_connections);
            slab.close(slot);
        }
    }

    /// Reuseport accept path: drain this reactor's own listener — no
    /// lock, no queue, no eventfd hop; the kernel already picked us.
    /// Bounded per wakeup so an accept flood cannot starve established
    /// connections (the level-triggered listener re-fires).
    fn accept_burst(
        &self,
        slab: &mut Slab,
        redrive: &mut Vec<usize>,
        reserve: &mut Option<std::fs::File>,
    ) {
        let Some(listener) = &self.listener else { return };
        // over the buffer budget: stop admitting load; the backlog
        // queues in the kernel until shedding drains the gauge
        if self.buffer_budget > 0
            && self.metrics.conn_buffer_bytes.load(Ordering::Relaxed) > self.buffer_budget as u64
        {
            return;
        }
        for _ in 0..ACCEPT_BURST {
            let accepted = if crate::util::failpoint::fired("accept.emfile") {
                Err(std::io::Error::from_raw_os_error(24)) // EMFILE
            } else {
                listener.accept().map(|(s, _)| s)
            };
            match accepted {
                Ok(stream) => {
                    self.inbox.accepted.fetch_add(1, Ordering::Relaxed);
                    if !super::tcp::try_admit(&self.metrics, self.max_conns) {
                        continue; // drop: close immediately
                    }
                    self.register(slab, stream, redrive);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                // EMFILE(24)/ENFILE(23): fd exhaustion, handled wholly
                // within this reactor now that it owns the listener —
                // give back the parked reserve fd, accept-and-close one
                // pending socket so the backlog cannot livelock, re-park
                // the reserve, and reap our own oldest connections.
                Err(e) if matches!(e.raw_os_error(), Some(23) | Some(24)) => {
                    drop(reserve.take());
                    if let Ok((s, _)) = listener.accept() {
                        Metrics::bump(&self.metrics.connections_accepted);
                        Metrics::bump(&self.metrics.rejected_connections);
                        drop(s);
                    }
                    *reserve = sys::dup_fd(listener.as_raw_fd()).ok();
                    self.reap_oldest(slab, 2);
                    return;
                }
                Err(_) => continue, // ECONNABORTED and friends
            }
        }
    }

    /// Serve this reactor's UDP socket: `recvmmsg` a batch, run every
    /// datagram through the shared [`Conn`] (same parser/`Exec` core
    /// as TCP), fragment the replies per the frame spec, and push them
    /// back out through `sendmmsg`. Frames the socket refuses are
    /// dropped — UDP is lossy by contract.
    fn udp_service(&self, st: &mut UdpState) {
        let fd = st.sock.as_raw_fd();
        loop {
            let n = {
                let mut slices: Vec<&mut [u8]> = st.bufs.iter_mut().map(|b| &mut **b).collect();
                match sys::recv_batch(fd, &mut slices, &mut st.addrs, &mut st.lens) {
                    Ok(0) => return,
                    Ok(n) => n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                    Err(_) => return,
                }
            };
            Metrics::add(&self.metrics.udp_datagrams_rx, n as u64);
            let UdpState {
                conn,
                bufs,
                addrs,
                lens,
                reply,
                frame,
                stage,
                spans,
                ..
            } = st;
            stage.clear();
            spans.clear();
            for i in 0..n {
                let len = lens[i].min(bufs[i].len());
                Metrics::add(&self.metrics.bytes_read, len as u64);
                reply.clear();
                let Some(id) = udp::handle_datagram(conn, &bufs[i][..len], reply) else {
                    Metrics::bump(&self.metrics.udp_bad_frames);
                    continue;
                };
                Metrics::bump(&self.metrics.commands);
                if !udp::fragment(id, reply, frame, |f| {
                    let s = stage.len();
                    stage.extend_from_slice(f);
                    spans.push((s, stage.len(), i));
                }) {
                    Metrics::bump(&self.metrics.udp_oversized_drops);
                }
            }
            let mut off = 0;
            while off < spans.len() {
                let end = (off + sys::MAX_BATCH).min(spans.len());
                let msgs: Vec<(&[u8], &sys::SockAddrStorage)> = spans[off..end]
                    .iter()
                    .map(|&(s, e, a)| (&stage[s..e], &addrs[a]))
                    .collect();
                match sys::send_batch(fd, &msgs) {
                    Ok(0) => break,
                    Ok(sent) => {
                        Metrics::add(&self.metrics.udp_datagrams_tx, sent as u64);
                        let bytes: usize =
                            spans[off..off + sent].iter().map(|&(s, e, _)| e - s).sum();
                        Metrics::add(&self.metrics.bytes_written, bytes as u64);
                        off += sent;
                    }
                    // lossy transport: a refused frame is dropped, not
                    // parked — no per-peer backpressure state for UDP
                    Err(_) => break,
                }
            }
            if n < sys::MAX_BATCH {
                return;
            }
        }
    }

    /// Register an accepted socket: nonblocking, edge-triggered
    /// read-interest, then an immediate drive so bytes that arrived
    /// before registration are not stranded.
    fn register(&self, slab: &mut Slab, stream: TcpStream, redrive: &mut Vec<usize>) {
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            Metrics::bump(&self.metrics.connections_closed);
            Metrics::dec(&self.metrics.curr_connections);
            return;
        }
        let fd = stream.as_raw_fd();
        let slot = slab.alloc();
        if self
            .ep
            .add(fd, slot as u64, EPOLLIN | EPOLLRDHUP | EPOLLET)
            .is_err()
        {
            slab.free.push(slot);
            Metrics::bump(&self.metrics.connections_closed);
            Metrics::dec(&self.metrics.curr_connections);
            return;
        }
        let mut conn = Conn::with_metrics(
            self.store.clone(),
            self.control.clone(),
            self.metrics.clone(),
        );
        if self.pin.is_some() {
            conn.set_affinity(self.id, self.total);
        }
        let dc = DrivenConn::new(stream, conn).with_direct_fd(fd);
        slab.conns[slot] = Some(Entry {
            dc,
            fd,
            interest_write: false,
            accounted: 0,
            metrics: self.metrics.clone(),
        });
        self.drive_slot(slab, slot, true, true, redrive);
    }

    /// Drive one connection and apply the outcome: close, EPOLLOUT
    /// interest re-registration, or a redrive request.
    ///
    /// The drive runs under `catch_unwind`: a request that panics the
    /// execution core (lock-poisoning recovery gone wrong, a poisoned
    /// payload) closes **that connection** — never the reactor. State
    /// isolation is per-connection by construction (`Conn` owns its
    /// buffers; store mutations are transactional per call).
    fn drive_slot(
        &self,
        slab: &mut Slab,
        slot: usize,
        readable: bool,
        writable: bool,
        redrive: &mut Vec<usize>,
    ) {
        // (outcome computed first so the entry borrow ends before the
        // slab is mutated)
        let outcome = match slab.conns.get_mut(slot).and_then(Option::as_mut) {
            None => return, // stale event for an already-closed connection
            Some(entry) => {
                let state = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    entry.dc.drive(readable, writable, &self.metrics)
                }))
                .unwrap_or_else(|_| {
                    eprintln!(
                        "slabforge: connection panicked mid-request; closing only that \
                         connection"
                    );
                    ConnState::Closed
                });
                // keep the global buffer gauge in sync with whatever
                // this drive buffered or flushed
                entry.settle_account();
                match state {
                    ConnState::Closed => None,
                    ConnState::Open { wants_write } => Some((
                        wants_write,
                        entry.interest_write,
                        entry.fd,
                        entry.dc.wants_redrive(),
                    )),
                }
            }
        };
        match outcome {
            None => slab.close(slot),
            Some((wants_write, interest_write, fd, wants_redrive)) => {
                // re-arm whenever write interest is (or was) registered
                // even if unchanged: with edge-triggered registration a
                // spuriously-cleared `write_ready` (injected EAGAIN, a
                // raced short write) would otherwise wait forever for
                // an edge that already passed — EPOLL_CTL_MOD re-delivers
                // the event if the socket is in fact writable.
                if wants_write || interest_write {
                    let mut bits = EPOLLIN | EPOLLRDHUP | EPOLLET;
                    if wants_write {
                        bits |= EPOLLOUT;
                    }
                    if self.ep.modify(fd, slot as u64, bits).is_err() {
                        slab.close(slot);
                        return;
                    }
                    if let Some(entry) = slab.conns[slot].as_mut() {
                        entry.interest_write = wants_write;
                    }
                }
                if wants_redrive {
                    redrive.push(slot);
                }
            }
        }
    }

    /// Periodic housekeeping pass: close connections with no activity
    /// past the idle timeout (`quit`-less load generators cannot leak
    /// fds) and reclaim oversized buffers from idle survivors —
    /// immediately when the buffer gauge nears its budget, otherwise
    /// only after [`IDLE_SHRINK_AFTER`] so active connections keep
    /// their warm allocations.
    fn sweep_idle(&self, slab: &mut Slab) {
        let now = Instant::now();
        let pressure = self.buffer_budget > 0
            && self.metrics.conn_buffer_bytes.load(Ordering::Relaxed)
                > (self.buffer_budget as u64) / 2;
        for slot in 0..slab.conns.len() {
            let Some(entry) = slab.conns[slot].as_mut() else {
                continue;
            };
            let idle = entry.dc.idle_for(now);
            if self.idle_timeout.is_some_and(|t| idle > t) {
                slab.close(slot);
                continue;
            }
            if pressure || idle > IDLE_SHRINK_AFTER {
                entry.dc.shrink_idle(IDLE_BUF_FLOOR);
            }
        }
    }

    /// Graceful shutdown: flush whatever responses are already encoded
    /// (flush-only — no further reads or command execution; bounded by
    /// [`DRAIN_DEADLINE`]), then close every socket.
    fn drain_and_close(&self, slab: &mut Slab) {
        let deadline = Instant::now() + DRAIN_DEADLINE;
        loop {
            let mut pending = false;
            for entry in slab.conns.iter_mut().flatten() {
                if entry.dc.has_pending_out() {
                    entry.dc.flush_pending(&self.metrics);
                    pending |= entry.dc.has_pending_out();
                }
            }
            if !pending || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        for slot in 0..slab.conns.len() {
            slab.close(slot);
        }
    }
}
