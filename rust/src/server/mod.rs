//! TCP front end: a sharded **epoll reactor** (raw `libc` epoll via
//! `server::sys` — no async runtime, nothing vendored) drives every
//! connection's parse/respond state machine from readiness events;
//! the legacy thread-per-connection mode survives behind
//! [`ServeMode::Threaded`] for A/B benching and non-Linux builds.
//!
//! Layers: `sys` (raw epoll/eventfd/socket/mmsg FFI) → `reactor`
//! (event loops, connection slab, per-reactor accept + UDP service,
//! idle sweep, drain) → `conn` (protocol state machine + `DrivenConn`
//! readiness wrapper + bounded `OutBuf`) → `udp` (datagram frame
//! codec over the same `Conn`) → `tcp` (listener bootstrap + mode
//! dispatch) → `metrics` (gauges the `stats` command reports).

pub mod conn;
pub mod metrics;
#[cfg(target_os = "linux")]
pub(crate) mod reactor;
#[cfg(target_os = "linux")]
pub mod sys;
pub mod tcp;
pub mod udp;

pub use conn::{Conn, ConnState, DrivenConn, NoControl, OutBuf, RespSink};
pub use tcp::{Control, ServeMode, Server, ServerHandle};
