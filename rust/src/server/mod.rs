//! Threaded TCP front end (tokio is not vendored in this offline image;
//! memcached itself is thread-per-event-loop, and a worker-thread model
//! over `std::net` preserves the same serving semantics — DESIGN.md §3).

pub mod conn;
pub mod metrics;
pub mod tcp;

pub use conn::{Conn, NoControl};
pub use tcp::{Control, Server, ServerHandle};
