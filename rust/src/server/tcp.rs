//! TCP server: accept loop + thread-per-connection workers over the
//! [`Conn`](super::conn::Conn) state machine.

use super::conn::Conn;
use super::metrics::Metrics;
use crate::store::sharded::ShardedStore;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

pub use super::conn::{Control, NoControl};

/// A running server; dropping the handle does NOT stop it — call
/// [`ServerHandle::shutdown`].
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// The bound address (useful with `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, unblock the accept loop, join it. In-flight
    /// connection threads finish their current command and exit on the
    /// next read (connections are closed by peers or idle-out).
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Server configuration + launch.
pub struct Server {
    pub store: Arc<ShardedStore>,
    pub control: Arc<dyn Control>,
}

impl Server {
    pub fn new(store: Arc<ShardedStore>) -> Self {
        Server {
            store,
            control: Arc::new(NoControl),
        }
    }

    pub fn with_control(store: Arc<ShardedStore>, control: Arc<dyn Control>) -> Self {
        Server { store, control }
    }

    /// Bind and serve in background threads.
    pub fn start(self, listen: &str) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Metrics::new());

        let accept_shutdown = shutdown.clone();
        let accept_metrics = metrics.clone();
        let store = self.store;
        let control = self.control;
        let accept_thread = std::thread::Builder::new()
            .name("slabforge-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    Metrics::bump(&accept_metrics.connections_accepted);
                    let store = store.clone();
                    let control = control.clone();
                    let metrics = accept_metrics.clone();
                    let conn_shutdown = accept_shutdown.clone();
                    let _ = std::thread::Builder::new()
                        .name("slabforge-conn".into())
                        .spawn(move || {
                            serve_connection(stream, store, control, &metrics, &conn_shutdown);
                            Metrics::bump(&metrics.connections_closed);
                        });
                }
            })?;

        Ok(ServerHandle {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
            metrics,
        })
    }
}

/// Once the reused output buffer balloons past this (a huge multiget
/// response), shrink it back so an idle connection doesn't pin the
/// high-water mark forever.
const OUT_BUF_KEEP: usize = 256 * 1024;
const OUT_BUF_STEADY: usize = 16 * 1024;

fn serve_connection(
    mut stream: TcpStream,
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    metrics: &Metrics,
    shutdown: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    // periodic read timeouts let the thread observe shutdown
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(250)));
    let mut conn = Conn::new(store, control);
    let mut rbuf = [0u8; 16 * 1024];
    // reused across reads: steady-state traffic costs zero buffer
    // allocations per request (the Conn's receive cursor buffer and
    // staging buffers are likewise retained)
    let mut out: Vec<u8> = Vec::with_capacity(OUT_BUF_STEADY);
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match stream.read(&mut rbuf) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                Metrics::add(&metrics.bytes_read, n as u64);
                out.clear();
                let done = conn.on_bytes(&rbuf[..n], &mut out);
                Metrics::add(&metrics.commands, done as u64);
                if !out.is_empty() {
                    if stream.write_all(&out).is_err() {
                        return;
                    }
                    Metrics::add(&metrics.bytes_written, out.len() as u64);
                    if out.capacity() > OUT_BUF_KEEP {
                        out = Vec::with_capacity(OUT_BUF_STEADY);
                    }
                }
                if conn.closing {
                    return;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::PAGE_SIZE;
    use crate::store::store::Clock;

    fn start_server() -> ServerHandle {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        Server::new(store).start("127.0.0.1:0").unwrap()
    }

    #[test]
    fn end_to_end_set_get_over_tcp() {
        let handle = start_server();
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"set k 0 0 5\r\nhello\r\nget k\r\nquit\r\n").unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        let t = String::from_utf8_lossy(&buf);
        assert!(t.contains("STORED"));
        assert!(t.contains("VALUE k 0 5\r\nhello"));
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let handle = start_server();
        let addr = handle.addr();
        let threads: Vec<_> = (0..8)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    for i in 0..50 {
                        let key = format!("k-{t}-{i}");
                        let cmd = format!("set {key} 0 0 3\r\nv{i:02}\r\nget {key}\r\n");
                        s.write_all(cmd.as_bytes()).unwrap();
                        let mut buf = [0u8; 512];
                        let mut got = Vec::new();
                        while !String::from_utf8_lossy(&got).contains("END\r\n") {
                            let n = s.read(&mut buf).unwrap();
                            assert!(n > 0);
                            got.extend_from_slice(&buf[..n]);
                        }
                        let t = String::from_utf8_lossy(&got);
                        assert!(t.contains(&format!("VALUE {key} 0 3")), "{t}");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(handle.metrics.snapshot().commands >= 800);
        handle.shutdown();
    }

    #[test]
    fn shutdown_unblocks() {
        let handle = start_server();
        handle.shutdown(); // must not hang
    }
}
