//! TCP front end: listener bootstrap + accept loop. The accept thread
//! gates on `max_conns` and hands sockets to one of two serving
//! back ends:
//!
//! * [`ServeMode::Event`] (default on Linux) — the sharded epoll
//!   reactor (`server::reactor`): `reactor_threads` event-loop threads
//!   drive every connection's [`Conn`](super::conn::Conn) state machine
//!   from readiness events. Scales to thousands of sockets on a handful
//!   of OS threads.
//! * [`ServeMode::Threaded`] — the legacy thread-per-connection model,
//!   kept behind a config flag for A/B benching and as the non-Linux
//!   fallback.

use super::conn::Conn;
use super::metrics::Metrics;
#[cfg(target_os = "linux")]
use super::reactor::{self, ReactorPool};
#[cfg(target_os = "linux")]
use super::sys;
use crate::store::sharded::ShardedStore;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
#[cfg(target_os = "linux")]
use std::net::{ToSocketAddrs, UdpSocket};
#[cfg(target_os = "linux")]
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

pub use super::conn::{Control, NoControl};

/// Which serving back end `Server::start` launches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// Epoll reactor (default; falls back to `Threaded` off Linux).
    Event,
    /// Legacy thread-per-connection.
    Threaded,
}

/// Default cap on live connections (memcached's `-c` default).
pub const DEFAULT_MAX_CONNS: usize = 1024;

fn default_reactor_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(2)
}

/// Accept-gate bookkeeping shared by every accept site (the fallback
/// accept thread, the per-reactor reuseport bursts, threaded mode):
/// count the accept, enforce `max_conns`, and on admission claim a
/// `curr_connections` slot (the serving back end releases it on close).
pub(crate) fn try_admit(metrics: &Metrics, max_conns: usize) -> bool {
    Metrics::bump(&metrics.connections_accepted);
    if metrics.curr_connections.load(Ordering::Relaxed) >= max_conns as u64 {
        Metrics::bump(&metrics.rejected_connections);
        return false;
    }
    Metrics::bump(&metrics.curr_connections);
    true
}

/// A running server; dropping the handle does NOT stop it — call
/// [`ServerHandle::shutdown`].
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    #[cfg(target_os = "linux")]
    pool: Option<Arc<ReactorPool>>,
    /// Reactor threads serving connections (0 in threaded mode).
    reactors: usize,
    /// Kernel-distributed accept is live (per-reactor `SO_REUSEPORT`
    /// listeners; no accept thread exists).
    reuseport: bool,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// The bound address (useful with `:0` ephemeral ports). The UDP
    /// front-end, when enabled, serves the same port.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Event-loop threads serving connections; 0 means legacy threaded
    /// mode.
    pub fn reactors(&self) -> usize {
        self.reactors
    }

    /// True when each reactor owns its own `SO_REUSEPORT` listener
    /// (false = single-listener fallback or threaded mode).
    pub fn reuseport(&self) -> bool {
        self.reuseport
    }

    /// Per-reactor accepted-connection distribution (empty in
    /// threaded mode).
    pub fn accept_counts(&self) -> Vec<u64> {
        #[cfg(target_os = "linux")]
        if let Some(pool) = &self.pool {
            return pool.accept_counts();
        }
        Vec::new()
    }

    /// Stop accepting, drain the reactors (in-flight responses are
    /// flushed, bounded), close every connection, join all threads. In
    /// threaded mode, connection threads observe the flag on their next
    /// read-timeout tick.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        #[cfg(target_os = "linux")]
        if let Some(pool) = &self.pool {
            pool.wake_all();
        }
        if let Some(t) = self.accept_thread.take() {
            // poke the listener so a blocking accept() returns;
            // reuseport reactors need no poke — the eventfd wake above
            // already reached every event loop
            let _ = TcpStream::connect(self.addr);
            if t.join().is_err() {
                // a panicked accept thread must not be silent: the warm
                // shutdown path that follows relies on a quiesced server
                eprintln!("slabforge: accept thread panicked during shutdown");
            }
        }
        #[cfg(target_os = "linux")]
        if let Some(pool) = self.pool.take() {
            pool.join_all();
        }
    }
}

/// Server configuration + launch (builder-style knobs, then `start`).
pub struct Server {
    pub store: Arc<ShardedStore>,
    pub control: Arc<dyn Control>,
    pub mode: ServeMode,
    pub reactor_threads: usize,
    pub max_conns: usize,
    pub idle_timeout: Option<Duration>,
    /// Global connection-buffer byte budget (0 = unlimited). Over
    /// budget, the reactors shed their most-backlogged stalled
    /// connections and accepting pauses until the gauge falls back
    /// under.
    pub conn_buffer_budget: usize,
    /// Per-reactor `SO_REUSEPORT` listeners (default). Falls back to
    /// the single-listener accept thread when the option is
    /// unavailable; irrelevant in threaded mode.
    pub reuseport: bool,
    /// Serve the memcached UDP frame protocol on the same port.
    pub udp: bool,
    /// Pin reactor threads to cores and tag connections for the
    /// `reactor_cross_shard` affinity stat.
    pub pin_cores: bool,
}

impl Server {
    pub fn new(store: Arc<ShardedStore>) -> Self {
        Server::with_control(store, Arc::new(NoControl))
    }

    pub fn with_control(store: Arc<ShardedStore>, control: Arc<dyn Control>) -> Self {
        Server {
            store,
            control,
            mode: ServeMode::Event,
            reactor_threads: default_reactor_threads(),
            max_conns: DEFAULT_MAX_CONNS,
            idle_timeout: None,
            conn_buffer_budget: 0,
            reuseport: true,
            udp: false,
            pin_cores: false,
        }
    }

    pub fn mode(mut self, mode: ServeMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn reactor_threads(mut self, n: usize) -> Self {
        self.reactor_threads = n.max(1);
        self
    }

    pub fn max_conns(mut self, n: usize) -> Self {
        self.max_conns = n.max(1);
        self
    }

    /// Close connections with no read activity for this long
    /// (`None` = never).
    pub fn idle_timeout(mut self, t: Option<Duration>) -> Self {
        self.idle_timeout = t;
        self
    }

    /// Cap total pending-output bytes across all connections
    /// (0 = unlimited); see [`Server::conn_buffer_budget`].
    pub fn conn_buffer_budget(mut self, bytes: usize) -> Self {
        self.conn_buffer_budget = bytes;
        self
    }

    /// Per-reactor `SO_REUSEPORT` listeners (on by default); off
    /// forces the single-listener accept thread.
    pub fn reuseport(mut self, on: bool) -> Self {
        self.reuseport = on;
        self
    }

    /// Serve the memcached UDP frame protocol on the same port.
    pub fn udp(mut self, on: bool) -> Self {
        self.udp = on;
        self
    }

    /// Pin reactor threads to cores (`sched_setaffinity`).
    pub fn pin_cores(mut self, on: bool) -> Self {
        self.pin_cores = on;
        self
    }

    /// Bind and serve in background threads.
    pub fn start(self, listen: &str) -> std::io::Result<ServerHandle> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Metrics::new());

        #[cfg(target_os = "linux")]
        if self.mode == ServeMode::Event {
            return self.start_event(listen, shutdown, metrics);
        }
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        self.start_threaded(listener, addr, shutdown, metrics)
    }

    /// Reactor mode. Preferred layout: one `SO_REUSEPORT` listener
    /// (and UDP socket) per reactor, kernel-distributed accept, no
    /// accept thread at all. When the socket option is unavailable the
    /// old layout survives: a single listener plus a thin accept
    /// thread that gates on `max_conns` and round-robins sockets into
    /// the reactor inboxes.
    #[cfg(target_os = "linux")]
    fn start_event(
        self,
        listen: &str,
        shutdown: Arc<AtomicBool>,
        metrics: Arc<Metrics>,
    ) -> std::io::Result<ServerHandle> {
        let threads = self.reactor_threads.max(1);
        // reactor 0's listener resolves the address (`:0` ephemeral
        // ports included); the rest bind the resolved one. Any failure
        // — old kernel, no SO_REUSEPORT — falls back whole-hog.
        let mut reuse_listeners: Vec<TcpListener> = Vec::new();
        if self.reuseport {
            let requested = listen.to_socket_addrs().ok().and_then(|mut a| a.next());
            if let Some(req) = requested {
                if let Ok(first) = sys::listen_reuseport(req) {
                    if let Ok(resolved) = first.local_addr() {
                        reuse_listeners.push(first);
                        for _ in 1..threads {
                            match sys::listen_reuseport(resolved) {
                                Ok(l) => reuse_listeners.push(l),
                                Err(_) => {
                                    reuse_listeners.clear();
                                    break;
                                }
                            }
                        }
                    }
                }
            }
        }
        let reuse = !reuse_listeners.is_empty();
        let (fallback_listener, addr) = if reuse {
            (None, reuse_listeners[0].local_addr()?)
        } else {
            let l = TcpListener::bind(listen)?;
            let a = l.local_addr()?;
            (Some(l), a)
        };
        // UDP front-end: per-reactor reuseport sockets when possible,
        // else one socket served by reactor 0 (TCP and UDP port spaces
        // are distinct, so the single bind always works).
        let mut udp_socks: Vec<UdpSocket> = Vec::new();
        if self.udp {
            if reuse {
                for _ in 0..threads {
                    match sys::udp_reuseport(addr) {
                        Ok(s) => udp_socks.push(s),
                        Err(_) => {
                            udp_socks.clear();
                            break;
                        }
                    }
                }
            }
            if udp_socks.is_empty() {
                let s = UdpSocket::bind(addr)?;
                s.set_nonblocking(true)?;
                udp_socks.push(s);
            }
        }
        let pool = reactor::start(
            reactor::ReactorConfig {
                threads,
                idle_timeout: self.idle_timeout,
                buffer_budget: self.conn_buffer_budget,
                max_conns: self.max_conns,
                pin_cores: self.pin_cores,
                listeners: reuse_listeners,
                udp: udp_socks,
            },
            self.store,
            self.control,
            metrics.clone(),
            shutdown.clone(),
        )?;
        let reactors = pool.threads();
        let Some(listener) = fallback_listener else {
            return Ok(ServerHandle {
                addr,
                shutdown,
                accept_thread: None,
                pool: Some(pool),
                reactors,
                reuseport: true,
                metrics,
            });
        };
        let accept_shutdown = shutdown.clone();
        let accept_metrics = metrics.clone();
        let max_conns = self.max_conns;
        let buffer_budget = self.conn_buffer_budget;
        let accept_pool = pool.clone();
        // EMFILE livelock breaker: park one fd now so there is always
        // one to give back when the table fills up
        let mut reserve = sys::dup_fd(listener.as_raw_fd()).ok();
        let accept_thread = std::thread::Builder::new()
            .name("slabforge-accept".into())
            .spawn(move || {
                let mut next = 0usize;
                loop {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    // shed-on-pressure: over the buffer budget, stop
                    // admitting load (the backlog queues in the kernel)
                    // until the reactors shed/drain back under it
                    if buffer_budget > 0
                        && accept_metrics.conn_buffer_bytes.load(Ordering::Relaxed)
                            > buffer_budget as u64
                    {
                        std::thread::sleep(Duration::from_millis(5));
                        continue;
                    }
                    let accepted = if crate::util::failpoint::fired("accept.emfile") {
                        Err(std::io::Error::from_raw_os_error(24)) // EMFILE
                    } else {
                        listener.accept().map(|(s, _)| s)
                    };
                    match accepted {
                        Ok(stream) => {
                            if !try_admit(&accept_metrics, max_conns) {
                                continue; // drop: close immediately
                            }
                            accept_pool.dispatch(next, stream);
                            next = next.wrapping_add(1);
                        }
                        // EMFILE(24)/ENFILE(23): fd exhaustion. Give
                        // back the reserve fd, accept-and-close one
                        // pending socket so the backlog cannot livelock
                        // us, re-park the reserve, and ask the reactors
                        // to reap their oldest connections.
                        Err(e) if matches!(e.raw_os_error(), Some(23) | Some(24)) => {
                            drop(reserve.take());
                            let _ = listener.set_nonblocking(true);
                            if let Ok((s, _)) = listener.accept() {
                                Metrics::bump(&accept_metrics.connections_accepted);
                                Metrics::bump(&accept_metrics.rejected_connections);
                                drop(s);
                            }
                            let _ = listener.set_nonblocking(false);
                            reserve = sys::dup_fd(listener.as_raw_fd()).ok();
                            accept_pool.request_reap();
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => continue,
                    }
                }
            })?;

        Ok(ServerHandle {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
            pool: Some(pool),
            reactors,
            reuseport: false,
            metrics,
        })
    }

    /// Legacy mode: one OS thread per connection.
    fn start_threaded(
        self,
        listener: TcpListener,
        addr: SocketAddr,
        shutdown: Arc<AtomicBool>,
        metrics: Arc<Metrics>,
    ) -> std::io::Result<ServerHandle> {
        let accept_shutdown = shutdown.clone();
        let accept_metrics = metrics.clone();
        let store = self.store;
        let control = self.control;
        let max_conns = self.max_conns;
        let idle_timeout = self.idle_timeout;
        let accept_thread = std::thread::Builder::new()
            .name("slabforge-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    if !try_admit(&accept_metrics, max_conns) {
                        continue; // drop: close immediately
                    }
                    let store = store.clone();
                    let control = control.clone();
                    let metrics = accept_metrics.clone();
                    let conn_shutdown = accept_shutdown.clone();
                    let spawned = std::thread::Builder::new()
                        .name("slabforge-conn".into())
                        .spawn(move || {
                            // a poisoned request kills its own
                            // connection, never the process: the stream
                            // closes with the unwound stack and the
                            // gauges below still settle
                            let r = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    serve_connection(
                                        stream,
                                        store,
                                        control,
                                        metrics.clone(),
                                        &conn_shutdown,
                                        idle_timeout,
                                    )
                                }),
                            );
                            if r.is_err() {
                                eprintln!(
                                    "slabforge: connection thread panicked; closing only \
                                     that connection"
                                );
                            }
                            Metrics::bump(&metrics.connections_closed);
                            Metrics::dec(&metrics.curr_connections);
                        });
                    if spawned.is_err() {
                        // thread exhaustion: the socket was dropped with
                        // the closure — undo the gauge or it drifts up
                        // to max_conns and rejects forever
                        Metrics::bump(&accept_metrics.connections_closed);
                        Metrics::dec(&accept_metrics.curr_connections);
                    }
                }
            })?;

        Ok(ServerHandle {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
            #[cfg(target_os = "linux")]
            pool: None,
            reactors: 0,
            reuseport: false,
            metrics,
        })
    }
}

// Output-buffer shrink thresholds are shared with the reactor path
// (`conn::OUT_KEEP`/`conn::OUT_STEADY`) so the two modes cannot
// silently diverge when retuned.
use super::conn::{OUT_KEEP as OUT_BUF_KEEP, OUT_STEADY as OUT_BUF_STEADY};

/// Legacy thread-per-connection serving loop (blocking reads with a
/// periodic timeout to observe shutdown and the idle deadline).
fn serve_connection(
    mut stream: TcpStream,
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    metrics: Arc<Metrics>,
    shutdown: &AtomicBool,
    idle_timeout: Option<Duration>,
) {
    let _ = stream.set_nodelay(true);
    // periodic read timeouts let the thread observe shutdown + idleness
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(250)));
    let mut conn = Conn::with_metrics(store, control, metrics.clone());
    let mut rbuf = [0u8; 16 * 1024];
    // reused across reads: steady-state traffic costs zero buffer
    // allocations per request (the Conn's receive cursor buffer and
    // staging buffers are likewise retained)
    let mut out: Vec<u8> = Vec::with_capacity(OUT_BUF_STEADY);
    let mut last_activity = std::time::Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        if let Some(limit) = idle_timeout {
            if last_activity.elapsed() > limit {
                return; // reap: same contract as the reactor's idle sweep
            }
        }
        match stream.read(&mut rbuf) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                last_activity = std::time::Instant::now();
                Metrics::add(&metrics.bytes_read, n as u64);
                out.clear();
                let done = conn.on_bytes(&rbuf[..n], &mut out);
                Metrics::add(&metrics.commands, done as u64);
                if !out.is_empty() {
                    if stream.write_all(&out).is_err() {
                        return;
                    }
                    Metrics::add(&metrics.bytes_written, out.len() as u64);
                    if out.capacity() > OUT_BUF_KEEP {
                        out = Vec::with_capacity(OUT_BUF_STEADY);
                    }
                }
                if conn.closing {
                    return;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::PAGE_SIZE;
    use crate::store::store::Clock;

    fn store() -> Arc<ShardedStore> {
        Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        )
    }

    fn start_server() -> ServerHandle {
        Server::new(store()).start("127.0.0.1:0").unwrap()
    }

    fn start_threaded_server() -> ServerHandle {
        Server::new(store())
            .mode(ServeMode::Threaded)
            .start("127.0.0.1:0")
            .unwrap()
    }

    fn exchange(handle: &ServerHandle) {
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"set k 0 0 5\r\nhello\r\nget k\r\nquit\r\n")
            .unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        let t = String::from_utf8_lossy(&buf);
        assert!(t.contains("STORED"), "{t}");
        assert!(t.contains("VALUE k 0 5\r\nhello"), "{t}");
    }

    #[test]
    fn end_to_end_set_get_over_tcp() {
        let handle = start_server();
        #[cfg(target_os = "linux")]
        assert!(handle.reactors() >= 1, "event mode must be the default");
        exchange(&handle);
        handle.shutdown();
    }

    #[test]
    fn legacy_threaded_mode_still_serves() {
        let handle = start_threaded_server();
        assert_eq!(handle.reactors(), 0);
        exchange(&handle);
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let handle = start_server();
        let addr = handle.addr();
        let threads: Vec<_> = (0..8)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    for i in 0..50 {
                        let key = format!("k-{t}-{i}");
                        let cmd = format!("set {key} 0 0 3\r\nv{i:02}\r\nget {key}\r\n");
                        s.write_all(cmd.as_bytes()).unwrap();
                        let mut buf = [0u8; 512];
                        let mut got = Vec::new();
                        while !String::from_utf8_lossy(&got).contains("END\r\n") {
                            let n = s.read(&mut buf).unwrap();
                            assert!(n > 0);
                            got.extend_from_slice(&buf[..n]);
                        }
                        let t = String::from_utf8_lossy(&got);
                        assert!(t.contains(&format!("VALUE {key} 0 3")), "{t}");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(handle.metrics.snapshot().commands >= 800);
        handle.shutdown();
    }

    #[test]
    fn shutdown_unblocks() {
        let handle = start_server();
        handle.shutdown(); // must not hang
    }

    #[test]
    fn shutdown_unblocks_threaded() {
        let handle = start_threaded_server();
        handle.shutdown(); // must not hang
    }

    #[test]
    fn max_conns_rejects_excess_accepts() {
        let handle = Server::new(store())
            .max_conns(2)
            .start("127.0.0.1:0")
            .unwrap();
        let _a = TcpStream::connect(handle.addr()).unwrap();
        let _b = TcpStream::connect(handle.addr()).unwrap();
        // give the accept thread time to register both
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while handle.metrics.snapshot().curr_connections < 2 {
            assert!(std::time::Instant::now() < deadline, "conns not registered");
            std::thread::sleep(Duration::from_millis(10));
        }
        // the third connection is accepted then dropped by the gate
        let mut c = TcpStream::connect(handle.addr()).unwrap();
        let mut buf = [0u8; 16];
        let _ = c.write_all(b"version\r\n");
        let n = c.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "rejected connection must be closed");
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while handle.metrics.snapshot().rejected_connections < 1 {
            assert!(std::time::Instant::now() < deadline, "rejection not counted");
            std::thread::sleep(Duration::from_millis(10));
        }
        handle.shutdown();
    }
}
