//! Raw Linux syscall bindings for the epoll reactor (`server::reactor`).
//!
//! The offline build image vendors no crates (not even `libc`), so the
//! handful of syscalls the reactor needs — `epoll_*`, `eventfd`,
//! `writev`, `signal`, `socket`/`setsockopt`/`bind`/`listen` (the
//! SO_REUSEPORT listener group), `recvmmsg`/`sendmmsg` (UDP batch I/O)
//! and `sched_setaffinity` (core pinning) — are declared here as
//! `extern "C"` against the system libc that `std` already links.
//! Everything is wrapped in safe RAII types;
//! `std::io::Error::last_os_error()` reads `errno` for us.
//!
//! **unwrap() audit (warm-restart PR).** Every `unwrap()`/`expect()` in
//! this module lives under `#[cfg(test)]` — the production wrappers all
//! return `io::Result` and let the caller decide (the reactor logs and
//! degrades; startup fails loudly). The two non-Result paths are
//! deliberate: `WakeFd::wake`/`drain` ignore errors because they run on
//! the async wakeup path where the only recovery is "try again on the
//! next wakeup", and `Epoll::drop` logs a failed `close(2)` instead of
//! panicking — a double-close during shutdown teardown must never turn
//! a clean drain into an abort.

#![cfg(target_os = "linux")]

use std::fs::File;
use std::io::{self, Read, Write};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};

// ---------------------------------------------------------------- epoll

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLLET: u32 = 1 << 31;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
#[allow(dead_code)]
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;

const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

/// Kernel `struct epoll_event`. Packed on x86_64 only (the kernel UAPI
/// declares it `__attribute__((packed))` there and natural elsewhere).
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

#[repr(C)]
struct IoVec {
    base: *const c_void,
    len: usize,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn writev(fd: c_int, iov: *const IoVec, iovcnt: c_int) -> isize;
    fn signal(signum: c_int, handler: usize) -> usize;
    fn dup(fd: c_int) -> c_int;
    fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
    fn setsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *const c_void,
        optlen: u32,
    ) -> c_int;
    fn bind(fd: c_int, addr: *const c_void, addrlen: u32) -> c_int;
    fn listen(fd: c_int, backlog: c_int) -> c_int;
    fn recvmmsg(
        fd: c_int,
        msgvec: *mut MMsgHdr,
        vlen: u32,
        flags: c_int,
        timeout: *mut c_void,
    ) -> c_int;
    fn sendmmsg(fd: c_int, msgvec: *mut MMsgHdr, vlen: u32, flags: c_int) -> c_int;
    fn sched_setaffinity(pid: c_int, cpusetsize: usize, mask: *const u64) -> c_int;
}

/// An epoll instance. Registered fds deregister themselves when their
/// owner closes them, so only `add`/`modify`/`wait` are needed.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn add(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, token, events)
    }

    pub fn modify(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, token, events)
    }

    /// Wait for events; `timeout_ms < 0` blocks forever. `EINTR` is
    /// reported as zero events so callers just loop.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        let n = unsafe {
            epoll_wait(
                self.fd,
                events.as_mut_ptr(),
                events.len() as c_int,
                timeout_ms,
            )
        };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(n as usize)
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        let rc = unsafe { close(self.fd) };
        if rc < 0 {
            eprintln!(
                "slabforge: close(epoll fd {}) failed during teardown: {}",
                self.fd,
                io::Error::last_os_error()
            );
        }
    }
}

// -------------------------------------------------------------- eventfd

/// Cross-thread reactor wakeup: an `eventfd` wrapped in a `File` (which
/// gives us read/write/close without further FFI). Nonblocking, so
/// `drain` can slurp until empty.
pub struct WakeFd {
    file: File,
}

impl WakeFd {
    pub fn new() -> io::Result<WakeFd> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(WakeFd {
            file: unsafe { File::from_raw_fd(fd) },
        })
    }

    pub fn raw(&self) -> RawFd {
        self.file.as_raw_fd()
    }

    /// Wake the owning reactor (async-safe, callable from any thread).
    pub fn wake(&self) {
        let _ = (&self.file).write(&1u64.to_ne_bytes());
    }

    /// Consume pending wakeups so a level-triggered registration quiesces.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        while matches!((&self.file).read(&mut buf), Ok(n) if n > 0) {}
    }
}

// --------------------------------------------------------------- writev

/// Scatter-gather write of up to four slices (pending buffer, response
/// header, value chunk, trailing CRLF). Returns bytes written.
///
/// Failpoints (disarmed: one relaxed load each):
/// * `sys.writev.eagain` — report `WouldBlock` without writing, as if
///   the socket buffer were full (the conn must buffer and re-arm
///   EPOLLOUT);
/// * `sys.writev.short` — truncate the request to a 1-byte write (the
///   byte IS written, so short-write bookkeeping must resume exactly
///   after it — dropping it would corrupt the stream, which is the
///   bug class this point exists to catch).
pub fn writev_slices(fd: RawFd, bufs: &[&[u8]]) -> io::Result<usize> {
    debug_assert!(bufs.len() <= 4);
    if crate::util::failpoint::fired("sys.writev.eagain") {
        return Err(io::Error::from(io::ErrorKind::WouldBlock));
    }
    if crate::util::failpoint::fired("sys.writev.short") {
        if let Some(first) = bufs.iter().find(|b| !b.is_empty()) {
            let iov = IoVec {
                base: first.as_ptr() as *const c_void,
                len: 1,
            };
            let rc = unsafe { writev(fd, &iov, 1) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            return Ok(rc as usize);
        }
        return Ok(0);
    }
    let mut iov = [IoVec {
        base: std::ptr::null(),
        len: 0,
    }, IoVec {
        base: std::ptr::null(),
        len: 0,
    }, IoVec {
        base: std::ptr::null(),
        len: 0,
    }, IoVec {
        base: std::ptr::null(),
        len: 0,
    }];
    let mut n = 0;
    for b in bufs {
        if b.is_empty() {
            continue;
        }
        iov[n] = IoVec {
            base: b.as_ptr() as *const c_void,
            len: b.len(),
        };
        n += 1;
    }
    if n == 0 {
        return Ok(0);
    }
    let rc = unsafe { writev(fd, iov.as_ptr(), n as c_int) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(rc as usize)
}

// ------------------------------------------------------------------ dup

/// `dup(2)` an fd into an owned `File` — used by the accept loop to
/// park a **reserve fd** at startup: on `EMFILE` the reserve is
/// dropped, the table briefly has one free slot to accept-and-close
/// with, and the reserve is re-duplicated afterwards (the classic
/// fd-exhaustion livelock breaker).
pub fn dup_fd(fd: RawFd) -> io::Result<File> {
    let rc = unsafe { dup(fd) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(unsafe { File::from_raw_fd(rc) })
}

// -------------------------------------------------- reuseport sockets

const AF_INET: c_int = 2;
const AF_INET6: c_int = 10;
const SOCK_STREAM: c_int = 1;
const SOCK_DGRAM: c_int = 2;
const SOCK_NONBLOCK: c_int = 0o4000;
const SOCK_CLOEXEC: c_int = 0o2000000;
const SOL_SOCKET: c_int = 1;
const SO_REUSEADDR: c_int = 2;
const SO_REUSEPORT: c_int = 15;
const LISTEN_BACKLOG: c_int = 1024;

/// `struct sockaddr_in` (x86_64 Linux layout; ports/addr in network
/// byte order).
#[repr(C)]
struct SockAddrIn {
    family: u16,
    port: u16,
    addr: u32,
    zero: [u8; 8],
}

/// `struct sockaddr_in6`.
#[repr(C)]
struct SockAddrIn6 {
    family: u16,
    port: u16,
    flowinfo: u32,
    addr: [u8; 16],
    scope_id: u32,
}

/// A `struct sockaddr_storage`-sized blob plus its valid length —
/// written by `recv_batch`, passed back verbatim to `send_batch` so
/// the reactor never has to parse peer addresses on the datagram path.
#[repr(C, align(8))]
#[derive(Clone, Copy)]
pub struct SockAddrStorage {
    pub data: [u8; 128],
    pub len: u32,
}

impl SockAddrStorage {
    pub fn zeroed() -> SockAddrStorage {
        SockAddrStorage {
            data: [0; 128],
            len: 0,
        }
    }
}

impl Default for SockAddrStorage {
    fn default() -> Self {
        SockAddrStorage::zeroed()
    }
}

/// Encode a `SocketAddr` into storage form (for tests and one-off
/// sends through [`send_batch`]).
pub fn encode_addr(addr: &std::net::SocketAddr) -> SockAddrStorage {
    let mut ss = SockAddrStorage::zeroed();
    match addr {
        std::net::SocketAddr::V4(a) => {
            let sa = SockAddrIn {
                family: AF_INET as u16,
                port: a.port().to_be(),
                addr: u32::from_ne_bytes(a.ip().octets()),
                zero: [0; 8],
            };
            let n = std::mem::size_of::<SockAddrIn>();
            unsafe {
                std::ptr::copy_nonoverlapping(
                    &sa as *const SockAddrIn as *const u8,
                    ss.data.as_mut_ptr(),
                    n,
                );
            }
            ss.len = n as u32;
        }
        std::net::SocketAddr::V6(a) => {
            let sa = SockAddrIn6 {
                family: AF_INET6 as u16,
                port: a.port().to_be(),
                flowinfo: a.flowinfo(),
                addr: a.ip().octets(),
                scope_id: a.scope_id(),
            };
            let n = std::mem::size_of::<SockAddrIn6>();
            unsafe {
                std::ptr::copy_nonoverlapping(
                    &sa as *const SockAddrIn6 as *const u8,
                    ss.data.as_mut_ptr(),
                    n,
                );
            }
            ss.len = n as u32;
        }
    }
    ss
}

/// Decode a storage blob back into a `SocketAddr` (tests, logging).
pub fn decode_addr(ss: &SockAddrStorage) -> Option<std::net::SocketAddr> {
    let family = u16::from_ne_bytes([ss.data[0], ss.data[1]]) as c_int;
    if family == AF_INET && ss.len as usize >= std::mem::size_of::<SockAddrIn>() {
        let port = u16::from_be_bytes([ss.data[2], ss.data[3]]);
        let ip = std::net::Ipv4Addr::new(ss.data[4], ss.data[5], ss.data[6], ss.data[7]);
        Some(std::net::SocketAddr::from((ip, port)))
    } else if family == AF_INET6 && ss.len as usize >= std::mem::size_of::<SockAddrIn6>() {
        let port = u16::from_be_bytes([ss.data[2], ss.data[3]]);
        let mut oct = [0u8; 16];
        oct.copy_from_slice(&ss.data[8..24]);
        Some(std::net::SocketAddr::from((std::net::Ipv6Addr::from(oct), port)))
    } else {
        None
    }
}

/// Open + bind a nonblocking SO_REUSEPORT socket on `addr`. Every
/// reactor calls this against the *same* address, so the kernel hashes
/// incoming connections/datagrams across the group — zero shared state
/// on the accept path. Fails cleanly (socket closed) when the kernel
/// rejects the option, which is the caller's signal to fall back to
/// the single-listener mode.
fn open_reuseport(addr: std::net::SocketAddr, stream: bool) -> io::Result<RawFd> {
    let domain = if addr.is_ipv4() { AF_INET } else { AF_INET6 };
    let ty = if stream { SOCK_STREAM } else { SOCK_DGRAM };
    let fd = unsafe { socket(domain, ty | SOCK_NONBLOCK | SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let fail = |fd: c_int| -> io::Error {
        let e = io::Error::last_os_error();
        unsafe { close(fd) };
        e
    };
    let one: c_int = 1;
    let optlen = std::mem::size_of::<c_int>() as u32;
    let optval = &one as *const c_int as *const c_void;
    // REUSEADDR keeps restarts from tripping over TIME_WAIT; REUSEPORT
    // is the load-bearing one — its absence aborts the whole mode.
    unsafe { setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, optval, optlen) };
    if unsafe { setsockopt(fd, SOL_SOCKET, SO_REUSEPORT, optval, optlen) } < 0 {
        return Err(fail(fd));
    }
    let ss = encode_addr(&addr);
    if unsafe { bind(fd, ss.data.as_ptr() as *const c_void, ss.len) } < 0 {
        return Err(fail(fd));
    }
    if stream && unsafe { listen(fd, LISTEN_BACKLOG) } < 0 {
        return Err(fail(fd));
    }
    Ok(fd)
}

/// A nonblocking SO_REUSEPORT TCP listener (one per reactor thread).
pub fn listen_reuseport(addr: std::net::SocketAddr) -> io::Result<std::net::TcpListener> {
    let fd = open_reuseport(addr, true)?;
    Ok(unsafe { std::net::TcpListener::from_raw_fd(fd) })
}

/// A nonblocking SO_REUSEPORT UDP socket (one per reactor thread).
pub fn udp_reuseport(addr: std::net::SocketAddr) -> io::Result<std::net::UdpSocket> {
    let fd = open_reuseport(addr, false)?;
    Ok(unsafe { std::net::UdpSocket::from_raw_fd(fd) })
}

// ----------------------------------------------- datagram batch I/O

/// `struct msghdr` (x86_64 Linux; `repr(C)` reproduces the padding
/// after `namelen` and `flags`).
#[repr(C)]
struct MsgHdr {
    name: *mut c_void,
    namelen: u32,
    iov: *mut IoVec,
    iovlen: usize,
    control: *mut c_void,
    controllen: usize,
    flags: c_int,
}

/// `struct mmsghdr`.
#[repr(C)]
struct MMsgHdr {
    hdr: MsgHdr,
    len: u32,
}

/// Max datagrams moved per `recvmmsg`/`sendmmsg` call (stack-built
/// header arrays — no allocation on the datagram path).
pub const MAX_BATCH: usize = 32;

fn empty_mmsghdr() -> MMsgHdr {
    MMsgHdr {
        hdr: MsgHdr {
            name: std::ptr::null_mut(),
            namelen: 0,
            iov: std::ptr::null_mut(),
            iovlen: 0,
            control: std::ptr::null_mut(),
            controllen: 0,
            flags: 0,
        },
        len: 0,
    }
}

/// Receive up to `min(bufs, addrs, lens, MAX_BATCH)` datagrams in one
/// syscall. For each received message `i`, `lens[i]` gets the payload
/// length and `addrs[i]` the source address. Returns the count;
/// `WouldBlock` when the socket is drained.
pub fn recv_batch(
    fd: RawFd,
    bufs: &mut [&mut [u8]],
    addrs: &mut [SockAddrStorage],
    lens: &mut [usize],
) -> io::Result<usize> {
    let n = bufs.len().min(addrs.len()).min(lens.len()).min(MAX_BATCH);
    if n == 0 {
        return Ok(0);
    }
    let mut iovs: [IoVec; MAX_BATCH] = std::array::from_fn(|_| IoVec {
        base: std::ptr::null(),
        len: 0,
    });
    let mut hdrs: [MMsgHdr; MAX_BATCH] = std::array::from_fn(|_| empty_mmsghdr());
    for i in 0..n {
        iovs[i] = IoVec {
            base: bufs[i].as_mut_ptr() as *const c_void,
            len: bufs[i].len(),
        };
        hdrs[i].hdr.name = addrs[i].data.as_mut_ptr() as *mut c_void;
        hdrs[i].hdr.namelen = addrs[i].data.len() as u32;
        hdrs[i].hdr.iov = &mut iovs[i];
        hdrs[i].hdr.iovlen = 1;
    }
    let rc = unsafe { recvmmsg(fd, hdrs.as_mut_ptr(), n as u32, 0, std::ptr::null_mut()) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    let got = rc as usize;
    for i in 0..got {
        lens[i] = hdrs[i].len as usize;
        addrs[i].len = hdrs[i].hdr.namelen;
    }
    Ok(got)
}

/// Send up to `MAX_BATCH` datagrams in one syscall. Returns how many
/// the kernel took (a partial count is normal under send-buffer
/// pressure; the caller resumes from there or drops — UDP is lossy).
pub fn send_batch(fd: RawFd, msgs: &[(&[u8], &SockAddrStorage)]) -> io::Result<usize> {
    let n = msgs.len().min(MAX_BATCH);
    if n == 0 {
        return Ok(0);
    }
    let mut iovs: [IoVec; MAX_BATCH] = std::array::from_fn(|_| IoVec {
        base: std::ptr::null(),
        len: 0,
    });
    let mut hdrs: [MMsgHdr; MAX_BATCH] = std::array::from_fn(|_| empty_mmsghdr());
    for (i, (payload, addr)) in msgs.iter().take(n).enumerate() {
        iovs[i] = IoVec {
            base: payload.as_ptr() as *const c_void,
            len: payload.len(),
        };
        hdrs[i].hdr.name = addr.data.as_ptr() as *mut c_void;
        hdrs[i].hdr.namelen = addr.len;
        hdrs[i].hdr.iov = &mut iovs[i];
        hdrs[i].hdr.iovlen = 1;
    }
    let rc = unsafe { sendmmsg(fd, hdrs.as_mut_ptr(), n as u32, 0) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(rc as usize)
}

// ------------------------------------------------------- cpu affinity

/// Pin the calling thread to one CPU (`sched_setaffinity(0, ...)`).
/// Used by `--pin-cores`: reactor `i` pins to core `i % ncores`, so a
/// connection's reactor — and with kernel reuseport hashing, its whole
/// 4-tuple — stays on one core end-to-end.
pub fn pin_to_core(core: usize) -> io::Result<()> {
    let mut mask = [0u64; 16]; // 1024 CPUs
    if core >= mask.len() * 64 {
        return Err(io::Error::from(io::ErrorKind::InvalidInput));
    }
    mask[core / 64] = 1u64 << (core % 64);
    let rc = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

// -------------------------------------------------------------- signals

const SIGINT: c_int = 2;
const SIGTERM: c_int = 15;

static TERM_FLAG: AtomicBool = AtomicBool::new(false);

extern "C" fn on_term(_sig: c_int) {
    TERM_FLAG.store(true, Ordering::SeqCst);
}

/// Install SIGTERM/SIGINT handlers that set a flag (the only
/// async-signal-safe thing we do); returns the flag for the caller to
/// poll. Used by `main` for graceful serve shutdown.
pub fn install_term_flag() -> &'static AtomicBool {
    let handler = on_term as extern "C" fn(c_int) as usize;
    unsafe {
        signal(SIGTERM, handler);
        signal(SIGINT, handler);
    }
    &TERM_FLAG
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_wait_times_out_empty() {
        let ep = Epoll::new().unwrap();
        let mut evs = vec![EpollEvent::zeroed(); 8];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn wakefd_roundtrip() {
        let ep = Epoll::new().unwrap();
        let wake = WakeFd::new().unwrap();
        ep.add(wake.raw(), 7, EPOLLIN).unwrap();
        let mut evs = vec![EpollEvent::zeroed(); 8];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0, "quiet before wake");
        wake.wake();
        wake.wake();
        let n = ep.wait(&mut evs, 100).unwrap();
        assert_eq!(n, 1);
        let token = evs[0].data;
        assert_eq!(token, 7);
        wake.drain();
        // drained: level-triggered registration goes quiet again
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn reuseport_listeners_share_one_port() {
        use std::io::Write as _;
        let a = listen_reuseport("127.0.0.1:0".parse().unwrap()).unwrap();
        let port = a.local_addr().unwrap().port();
        let b = listen_reuseport(format!("127.0.0.1:{port}").parse().unwrap())
            .expect("second SO_REUSEPORT bind to the same port");
        // a client lands on exactly one of the two listeners
        let mut c = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        c.write_all(b"x").unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        let mut accepted = 0;
        while std::time::Instant::now() < deadline {
            for l in [&a, &b] {
                match l.accept() {
                    Ok(_) => accepted += 1,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                    Err(e) => panic!("accept: {e}"),
                }
            }
            if accepted > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(accepted, 1);
    }

    #[test]
    fn addr_encode_decode_roundtrip() {
        for addr in ["127.0.0.1:11211", "[::1]:0"] {
            let a: std::net::SocketAddr = addr.parse().unwrap();
            assert_eq!(decode_addr(&encode_addr(&a)), Some(a));
        }
        assert_eq!(decode_addr(&SockAddrStorage::zeroed()), None);
    }

    #[test]
    fn mmsg_batch_roundtrip() {
        let rx = udp_reuseport("127.0.0.1:0".parse().unwrap()).unwrap();
        let rx_addr = rx.local_addr().unwrap();
        let tx = std::net::UdpSocket::bind("127.0.0.1:0").unwrap();
        let dst = encode_addr(&rx_addr);
        let msgs: Vec<(&[u8], &SockAddrStorage)> =
            vec![(b"one", &dst), (b"two2", &dst), (b"three33", &dst)];
        assert_eq!(send_batch(tx.as_raw_fd(), &msgs).unwrap(), 3);

        let mut b0 = [0u8; 64];
        let mut b1 = [0u8; 64];
        let mut b2 = [0u8; 64];
        let mut got: Vec<Vec<u8>> = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while got.len() < 3 && std::time::Instant::now() < deadline {
            let mut bufs: [&mut [u8]; 3] = [&mut b0, &mut b1, &mut b2];
            let mut addrs = [SockAddrStorage::zeroed(); 3];
            let mut lens = [0usize; 3];
            match recv_batch(rx.as_raw_fd(), &mut bufs, &mut addrs, &mut lens) {
                Ok(n) => {
                    for i in 0..n {
                        got.push(bufs[i][..lens[i]].to_vec());
                        // the source address round-trips to the sender
                        assert_eq!(
                            decode_addr(&addrs[i]),
                            Some(tx.local_addr().unwrap())
                        );
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => panic!("recv_batch: {e}"),
            }
        }
        got.sort();
        assert_eq!(got, vec![b"one".to_vec(), b"three33".to_vec(), b"two2".to_vec()]);
    }

    #[test]
    fn pin_to_core_zero() {
        // every Linux environment lets a thread restrict itself to CPU 0
        pin_to_core(0).unwrap();
        assert!(pin_to_core(100_000).is_err(), "out-of-range core rejected");
    }

    #[test]
    fn writev_scatter_order() {
        use std::io::Read;
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let tx = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        let n = writev_slices(tx.as_raw_fd(), &[b"ab", b"", b"cde", b"f"]).unwrap();
        assert_eq!(n, 6);
        let mut got = [0u8; 6];
        rx.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"abcdef");
    }
}
