//! Raw Linux syscall bindings for the epoll reactor (`server::reactor`).
//!
//! The offline build image vendors no crates (not even `libc`), so the
//! handful of syscalls the reactor needs — `epoll_*`, `eventfd`,
//! `writev`, `signal` — are declared here as `extern "C"` against the
//! system libc that `std` already links. Everything is wrapped in safe
//! RAII types; `std::io::Error::last_os_error()` reads `errno` for us.

#![cfg(target_os = "linux")]

use std::fs::File;
use std::io::{self, Read, Write};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};

// ---------------------------------------------------------------- epoll

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLLET: u32 = 1 << 31;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
#[allow(dead_code)]
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;

const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

/// Kernel `struct epoll_event`. Packed on x86_64 only (the kernel UAPI
/// declares it `__attribute__((packed))` there and natural elsewhere).
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

#[repr(C)]
struct IoVec {
    base: *const c_void,
    len: usize,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn writev(fd: c_int, iov: *const IoVec, iovcnt: c_int) -> isize;
    fn signal(signum: c_int, handler: usize) -> usize;
    fn dup(fd: c_int) -> c_int;
}

/// An epoll instance. Registered fds deregister themselves when their
/// owner closes them, so only `add`/`modify`/`wait` are needed.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn add(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, token, events)
    }

    pub fn modify(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, token, events)
    }

    /// Wait for events; `timeout_ms < 0` blocks forever. `EINTR` is
    /// reported as zero events so callers just loop.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        let n = unsafe {
            epoll_wait(
                self.fd,
                events.as_mut_ptr(),
                events.len() as c_int,
                timeout_ms,
            )
        };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(n as usize)
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

// -------------------------------------------------------------- eventfd

/// Cross-thread reactor wakeup: an `eventfd` wrapped in a `File` (which
/// gives us read/write/close without further FFI). Nonblocking, so
/// `drain` can slurp until empty.
pub struct WakeFd {
    file: File,
}

impl WakeFd {
    pub fn new() -> io::Result<WakeFd> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(WakeFd {
            file: unsafe { File::from_raw_fd(fd) },
        })
    }

    pub fn raw(&self) -> RawFd {
        self.file.as_raw_fd()
    }

    /// Wake the owning reactor (async-safe, callable from any thread).
    pub fn wake(&self) {
        let _ = (&self.file).write(&1u64.to_ne_bytes());
    }

    /// Consume pending wakeups so a level-triggered registration quiesces.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        while matches!((&self.file).read(&mut buf), Ok(n) if n > 0) {}
    }
}

// --------------------------------------------------------------- writev

/// Scatter-gather write of up to four slices (pending buffer, response
/// header, value chunk, trailing CRLF). Returns bytes written.
///
/// Failpoints (disarmed: one relaxed load each):
/// * `sys.writev.eagain` — report `WouldBlock` without writing, as if
///   the socket buffer were full (the conn must buffer and re-arm
///   EPOLLOUT);
/// * `sys.writev.short` — truncate the request to a 1-byte write (the
///   byte IS written, so short-write bookkeeping must resume exactly
///   after it — dropping it would corrupt the stream, which is the
///   bug class this point exists to catch).
pub fn writev_slices(fd: RawFd, bufs: &[&[u8]]) -> io::Result<usize> {
    debug_assert!(bufs.len() <= 4);
    if crate::util::failpoint::fired("sys.writev.eagain") {
        return Err(io::Error::from(io::ErrorKind::WouldBlock));
    }
    if crate::util::failpoint::fired("sys.writev.short") {
        if let Some(first) = bufs.iter().find(|b| !b.is_empty()) {
            let iov = IoVec {
                base: first.as_ptr() as *const c_void,
                len: 1,
            };
            let rc = unsafe { writev(fd, &iov, 1) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            return Ok(rc as usize);
        }
        return Ok(0);
    }
    let mut iov = [IoVec {
        base: std::ptr::null(),
        len: 0,
    }, IoVec {
        base: std::ptr::null(),
        len: 0,
    }, IoVec {
        base: std::ptr::null(),
        len: 0,
    }, IoVec {
        base: std::ptr::null(),
        len: 0,
    }];
    let mut n = 0;
    for b in bufs {
        if b.is_empty() {
            continue;
        }
        iov[n] = IoVec {
            base: b.as_ptr() as *const c_void,
            len: b.len(),
        };
        n += 1;
    }
    if n == 0 {
        return Ok(0);
    }
    let rc = unsafe { writev(fd, iov.as_ptr(), n as c_int) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(rc as usize)
}

// ------------------------------------------------------------------ dup

/// `dup(2)` an fd into an owned `File` — used by the accept loop to
/// park a **reserve fd** at startup: on `EMFILE` the reserve is
/// dropped, the table briefly has one free slot to accept-and-close
/// with, and the reserve is re-duplicated afterwards (the classic
/// fd-exhaustion livelock breaker).
pub fn dup_fd(fd: RawFd) -> io::Result<File> {
    let rc = unsafe { dup(fd) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(unsafe { File::from_raw_fd(rc) })
}

// -------------------------------------------------------------- signals

const SIGINT: c_int = 2;
const SIGTERM: c_int = 15;

static TERM_FLAG: AtomicBool = AtomicBool::new(false);

extern "C" fn on_term(_sig: c_int) {
    TERM_FLAG.store(true, Ordering::SeqCst);
}

/// Install SIGTERM/SIGINT handlers that set a flag (the only
/// async-signal-safe thing we do); returns the flag for the caller to
/// poll. Used by `main` for graceful serve shutdown.
pub fn install_term_flag() -> &'static AtomicBool {
    let handler = on_term as extern "C" fn(c_int) as usize;
    unsafe {
        signal(SIGTERM, handler);
        signal(SIGINT, handler);
    }
    &TERM_FLAG
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_wait_times_out_empty() {
        let ep = Epoll::new().unwrap();
        let mut evs = vec![EpollEvent::zeroed(); 8];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn wakefd_roundtrip() {
        let ep = Epoll::new().unwrap();
        let wake = WakeFd::new().unwrap();
        ep.add(wake.raw(), 7, EPOLLIN).unwrap();
        let mut evs = vec![EpollEvent::zeroed(); 8];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0, "quiet before wake");
        wake.wake();
        wake.wake();
        let n = ep.wait(&mut evs, 100).unwrap();
        assert_eq!(n, 1);
        let token = evs[0].data;
        assert_eq!(token, 7);
        wake.drain();
        // drained: level-triggered registration goes quiet again
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn writev_scatter_order() {
        use std::io::Read;
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let tx = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        let n = writev_slices(tx.as_raw_fd(), &[b"ab", b"", b"cde", b"f"]).unwrap();
        assert_eq!(n, 6);
        let mut got = [0u8; 6];
        rx.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"abcdef");
    }
}
