//! Server-level counters (lock-free; sampled by `stats` and benches).

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Metrics {
    pub connections_accepted: AtomicU64,
    pub connections_closed: AtomicU64,
    pub commands: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub protocol_errors: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            connections_accepted: self.connections_accepted.load(Ordering::Relaxed),
            connections_closed: self.connections_closed.load(Ordering::Relaxed),
            commands: self.commands.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub connections_accepted: u64,
    pub connections_closed: u64,
    pub commands: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub protocol_errors: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        Metrics::bump(&m.commands);
        Metrics::bump(&m.commands);
        Metrics::add(&m.bytes_read, 100);
        let s = m.snapshot();
        assert_eq!(s.commands, 2);
        assert_eq!(s.bytes_read, 100);
        assert_eq!(s.protocol_errors, 0);
    }
}
