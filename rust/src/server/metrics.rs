//! Server-level counters (lock-free; sampled by `stats` and benches).

use crate::util::supervisor;
use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Metrics {
    /// Lifetime accepted connections (memcached `total_connections`).
    pub connections_accepted: AtomicU64,
    pub connections_closed: AtomicU64,
    /// Live connections right now (gauge: inc on accept, dec on close).
    pub curr_connections: AtomicU64,
    /// Accepts refused because `max_conns` live connections existed.
    pub rejected_connections: AtomicU64,
    /// Times a connection yielded the reactor mid-stream — output
    /// backpressure (bounded write buffer full) or read-budget
    /// exhaustion under a firehose client (memcached `conn_yields`).
    pub conn_yields: AtomicU64,
    pub commands: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub protocol_errors: AtomicU64,
    /// Connections closed by overload shedding (conn-buffer budget
    /// exhausted; most-backlogged stalled connection evicted first).
    pub shed_connections: AtomicU64,
    /// Gauge: bytes currently buffered in connection output buffers
    /// across all reactors (what the conn-buffer budget is charged
    /// against).
    pub conn_buffer_bytes: AtomicU64,
    /// Requests whose key's home shard is not affine to the serving
    /// reactor (`shard % reactors != reactor`). Only counted when core
    /// pinning is on; measures how much traffic crosses cores.
    pub reactor_cross_shard: AtomicU64,
    /// UDP datagrams received / response fragments sent.
    pub udp_datagrams_rx: AtomicU64,
    pub udp_datagrams_tx: AtomicU64,
    /// UDP responses dropped because they exceeded the fragment cap
    /// (`SERVER_ERROR` frame sent instead, memcached parity).
    pub udp_oversized_drops: AtomicU64,
    /// Datagrams dropped at the frame layer (short header or a
    /// multi-fragment request, which the protocol forbids).
    pub udp_bad_frames: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    /// `stats reset`: zero the cumulative counters. The
    /// `curr_connections` gauge is live state, not a counter, and
    /// survives (memcached parity: `stats_reset` clears `struct stats`
    /// but not `stats_state`).
    pub fn reset(&self) {
        for c in [
            &self.connections_accepted,
            &self.connections_closed,
            &self.rejected_connections,
            &self.conn_yields,
            &self.commands,
            &self.bytes_read,
            &self.bytes_written,
            &self.protocol_errors,
            &self.shed_connections,
            &self.reactor_cross_shard,
            &self.udp_datagrams_rx,
            &self.udp_datagrams_tx,
            &self.udp_oversized_drops,
            &self.udp_bad_frames,
        ] {
            c.store(0, Ordering::Relaxed);
        }
    }

    /// The connection-level gauges `stats` reports (memcached parity).
    pub fn conn_counters(&self) -> ConnCounters {
        ConnCounters {
            curr: self.curr_connections.load(Ordering::Relaxed),
            total: self.connections_accepted.load(Ordering::Relaxed),
            rejected: self.rejected_connections.load(Ordering::Relaxed),
            yields: self.conn_yields.load(Ordering::Relaxed),
            shed: self.shed_connections.load(Ordering::Relaxed),
            buffer_bytes: self.conn_buffer_bytes.load(Ordering::Relaxed),
            thread_restarts: supervisor::thread_restarts(),
            cross_shard: self.reactor_cross_shard.load(Ordering::Relaxed),
            udp_rx: self.udp_datagrams_rx.load(Ordering::Relaxed),
            udp_tx: self.udp_datagrams_tx.load(Ordering::Relaxed),
            udp_oversized: self.udp_oversized_drops.load(Ordering::Relaxed),
            udp_bad: self.udp_bad_frames.load(Ordering::Relaxed),
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            connections_accepted: self.connections_accepted.load(Ordering::Relaxed),
            connections_closed: self.connections_closed.load(Ordering::Relaxed),
            curr_connections: self.curr_connections.load(Ordering::Relaxed),
            rejected_connections: self.rejected_connections.load(Ordering::Relaxed),
            conn_yields: self.conn_yields.load(Ordering::Relaxed),
            commands: self.commands.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            shed_connections: self.shed_connections.load(Ordering::Relaxed),
            conn_buffer_bytes: self.conn_buffer_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of the connection gauges, consumed by `stats` rendering.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ConnCounters {
    pub curr: u64,
    pub total: u64,
    pub rejected: u64,
    pub yields: u64,
    pub shed: u64,
    pub buffer_bytes: u64,
    pub thread_restarts: u64,
    pub cross_shard: u64,
    pub udp_rx: u64,
    pub udp_tx: u64,
    pub udp_oversized: u64,
    pub udp_bad: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub connections_accepted: u64,
    pub connections_closed: u64,
    pub curr_connections: u64,
    pub rejected_connections: u64,
    pub conn_yields: u64,
    pub commands: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub protocol_errors: u64,
    pub shed_connections: u64,
    pub conn_buffer_bytes: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        Metrics::bump(&m.commands);
        Metrics::bump(&m.commands);
        Metrics::add(&m.bytes_read, 100);
        let s = m.snapshot();
        assert_eq!(s.commands, 2);
        assert_eq!(s.bytes_read, 100);
        assert_eq!(s.protocol_errors, 0);
    }

    #[test]
    fn gauge_inc_dec_and_conn_counters() {
        let m = Metrics::new();
        Metrics::bump(&m.connections_accepted);
        Metrics::bump(&m.connections_accepted);
        Metrics::bump(&m.curr_connections);
        Metrics::bump(&m.curr_connections);
        Metrics::dec(&m.curr_connections);
        Metrics::bump(&m.rejected_connections);
        Metrics::bump(&m.conn_yields);
        let c = m.conn_counters();
        assert_eq!(c.curr, 1);
        assert_eq!(c.total, 2);
        assert_eq!(c.rejected, 1);
        assert_eq!(c.yields, 1);
    }

    #[test]
    fn reset_clears_counters_keeps_curr_gauge() {
        let m = Metrics::new();
        Metrics::bump(&m.connections_accepted);
        Metrics::bump(&m.curr_connections);
        Metrics::add(&m.bytes_read, 512);
        Metrics::bump(&m.commands);
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.connections_accepted, 0);
        assert_eq!(s.bytes_read, 0);
        assert_eq!(s.commands, 0);
        assert_eq!(s.curr_connections, 1, "live gauge survives");
    }
}
