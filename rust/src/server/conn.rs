//! Per-connection protocol state machine, transport-agnostic: bytes in,
//! bytes out. The same machine drives real sockets (`server::tcp`) and
//! in-memory tests.

use crate::protocol::parse::{parse_command, Command, ParseError, StoreOp};
use crate::protocol::{response, stats};
use crate::store::sharded::ShardedStore;
use crate::store::store::{CasResult, StoreError};
use crate::util::histogram::SizeHistogram;
use std::sync::Arc;

/// Hard cap on one command line (memcached: 2048 for key lines).
const MAX_LINE: usize = 8192;

/// Hard cap on a data block (1 MiB value + slack).
const MAX_DATA: usize = (1 << 20) + 1024;

/// Hook for the admin extensions; implemented by the optimizer
/// coordinator and injected by the launcher.
pub trait Control: Send + Sync {
    /// `slabs optimize` — returns a status line (without CRLF).
    fn optimize_now(&self) -> String;
    /// `slabs reconfigure` — apply explicit sizes; status line.
    fn reconfigure(&self, sizes: Vec<usize>) -> Result<String, String>;
    /// `stats sizes` source (the learned histogram), if any.
    fn sizes_histogram(&self) -> Option<SizeHistogram>;
}

/// No-op control for servers launched without the optimizer.
pub struct NoControl;

impl Control for NoControl {
    fn optimize_now(&self) -> String {
        "SERVER_ERROR optimizer not enabled".into()
    }

    fn reconfigure(&self, sizes: Vec<usize>) -> Result<String, String> {
        let _ = sizes;
        Err("optimizer not enabled".into())
    }

    fn sizes_histogram(&self) -> Option<SizeHistogram> {
        None
    }
}

enum Phase {
    /// Waiting for a full command line.
    Line,
    /// Waiting for `len` data bytes + CRLF of a storage command.
    Data { cmd: Command, len: usize },
}

/// Connection state machine.
pub struct Conn {
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    buf: Vec<u8>,
    phase: Phase,
    start: std::time::Instant,
    pub closing: bool,
}

impl Conn {
    pub fn new(store: Arc<ShardedStore>, control: Arc<dyn Control>) -> Self {
        Conn {
            store,
            control,
            buf: Vec::with_capacity(4096),
            phase: Phase::Line,
            start: std::time::Instant::now(),
            closing: false,
        }
    }

    /// Feed received bytes; protocol responses accumulate in `out`.
    /// Returns the number of commands completed.
    pub fn on_bytes(&mut self, data: &[u8], out: &mut Vec<u8>) -> usize {
        self.buf.extend_from_slice(data);
        let mut completed = 0;
        loop {
            match &self.phase {
                Phase::Line => {
                    let Some(eol) = find_crlf(&self.buf) else {
                        if self.buf.len() > MAX_LINE {
                            response::client_error(out, "line too long");
                            self.closing = true;
                        }
                        return completed;
                    };
                    if eol > MAX_LINE {
                        // a complete-but-oversized line is equally abusive
                        response::client_error(out, "line too long");
                        self.closing = true;
                        return completed;
                    }
                    let line: Vec<u8> = self.buf[..eol].to_vec();
                    self.buf.drain(..eol + 2);
                    match parse_command(&line) {
                        Ok(cmd) => match cmd.data_len() {
                            Some(len) if len > MAX_DATA => {
                                // swallow the oversized block to stay in sync
                                response::server_error(out, "object too large for cache");
                                self.phase = Phase::Data {
                                    cmd: Command::Quit, // placeholder; data dropped
                                    len,
                                };
                            }
                            Some(len) => {
                                self.phase = Phase::Data { cmd, len };
                            }
                            None => {
                                self.execute(cmd, None, out);
                                completed += 1;
                            }
                        },
                        Err(ParseError::UnknownCommand) => {
                            response::error(out);
                        }
                        Err(ParseError::Client(msg)) => {
                            response::client_error(out, msg);
                        }
                    }
                }
                Phase::Data { len, .. } => {
                    let need = *len + 2;
                    if self.buf.len() < need {
                        return completed;
                    }
                    let Phase::Data { cmd, len } =
                        std::mem::replace(&mut self.phase, Phase::Line)
                    else {
                        unreachable!()
                    };
                    let ok_tail = &self.buf[len..len + 2] == b"\r\n";
                    let data: Vec<u8> = self.buf[..len].to_vec();
                    self.buf.drain(..need);
                    if matches!(cmd, Command::Quit) {
                        // oversized block swallowed above; error already sent
                        continue;
                    }
                    if !ok_tail {
                        response::client_error(out, "bad data chunk");
                        continue;
                    }
                    self.execute(cmd, Some(data), out);
                    completed += 1;
                }
            }
            if self.closing {
                return completed;
            }
        }
    }

    fn execute(&mut self, cmd: Command, data: Option<Vec<u8>>, out: &mut Vec<u8>) {
        let quiet = cmd.noreply();
        // `noreply` suppresses normal responses; errors still flow in
        // memcached, so we buffer into a scratch and drop on success.
        let mut scratch = Vec::new();
        let sink: &mut Vec<u8> = if quiet { &mut scratch } else { out };
        match cmd {
            Command::Get { keys, with_cas } => {
                for key in keys {
                    if let Some(v) = self.store.get(&key) {
                        response::value(sink, &key, &v, with_cas);
                    }
                }
                response::end(sink);
            }
            Command::Store {
                op,
                key,
                flags,
                exptime,
                cas,
                ..
            } => {
                let value = data.expect("storage command carries data");
                let outcome = match op {
                    StoreOp::Set => self.store.set(&key, &value, flags, exptime).map(|_| true),
                    StoreOp::Add => self.store.add(&key, &value, flags, exptime),
                    StoreOp::Replace => self.store.replace(&key, &value, flags, exptime),
                    StoreOp::Append => self.store.concat(&key, &value, true),
                    StoreOp::Prepend => self.store.concat(&key, &value, false),
                    StoreOp::Cas => match self.store.cas(&key, &value, flags, exptime, cas) {
                        Ok(CasResult::Stored) => Ok(true),
                        Ok(CasResult::Exists) => {
                            response::exists(sink);
                            return;
                        }
                        Ok(CasResult::NotFound) => {
                            response::not_found(sink);
                            return;
                        }
                        Err(e) => Err(e),
                    },
                };
                match outcome {
                    Ok(true) => response::stored(sink),
                    Ok(false) => response::not_stored(sink),
                    Err(e) => store_error(sink, &e),
                }
            }
            Command::Delete { key, .. } => {
                if self.store.delete(&key) {
                    response::deleted(sink);
                } else {
                    response::not_found(sink);
                }
            }
            Command::IncrDecr {
                key, delta, incr, ..
            } => match self.store.incr_decr(&key, delta, incr) {
                Ok(Some(n)) => response::number(sink, n),
                Ok(None) => response::not_found(sink),
                Err(e) => store_error(sink, &e),
            },
            Command::Touch { key, exptime, .. } => {
                if self.store.touch(&key, exptime) {
                    response::touched(sink);
                } else {
                    response::not_found(sink);
                }
            }
            Command::Stats { arg } => {
                match arg.as_deref() {
                    Some(b"slabs") => {
                        stats::render_slabs(sink, &self.store.slab_stats());
                    }
                    Some(b"sizes") => match self.control.sizes_histogram() {
                        Some(h) => stats::render_sizes(sink, &h),
                        None => {
                            let h = SizeHistogram::new(1);
                            stats::render_sizes(sink, &h);
                        }
                    },
                    _ => {
                        let ops = self.store.stats();
                        let slabs = self.store.slab_stats();
                        let uptime = self.start.elapsed().as_secs();
                        stats::render_general(sink, &ops, &slabs, self.store.len(), uptime);
                    }
                };
            }
            Command::FlushAll { .. } => {
                self.store.flush_all();
                response::ok(sink);
            }
            Command::Version => response::version(sink, env!("CARGO_PKG_VERSION")),
            Command::Verbosity { .. } => response::ok(sink),
            Command::Quit => {
                self.closing = true;
            }
            Command::SlabsReconfigure { sizes, .. } => match self.control.reconfigure(sizes) {
                Ok(msg) => {
                    sink.extend_from_slice(msg.as_bytes());
                    sink.extend_from_slice(b"\r\n");
                }
                Err(msg) => response::server_error(sink, &msg),
            },
            Command::SlabsOptimize => {
                let msg = self.control.optimize_now();
                sink.extend_from_slice(msg.as_bytes());
                sink.extend_from_slice(b"\r\n");
            }
        }
    }
}

fn store_error(out: &mut Vec<u8>, e: &StoreError) {
    match e {
        StoreError::BadKey => response::client_error(out, "bad key"),
        StoreError::NonNumeric => {
            response::client_error(out, "cannot increment or decrement non-numeric value")
        }
        StoreError::TooLarge { .. } => response::server_error(out, "object too large for cache"),
        StoreError::OutOfMemory => response::server_error(out, "out of memory storing object"),
    }
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::PAGE_SIZE;
    use crate::store::store::Clock;

    fn conn() -> Conn {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        Conn::new(store, Arc::new(NoControl))
    }

    fn run(c: &mut Conn, input: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        c.on_bytes(input, &mut out);
        out
    }

    #[test]
    fn set_get_exact() {
        let mut c = conn();
        let out = run(&mut c, b"set foo 7 0 5\r\nhello\r\nget foo\r\n");
        assert_eq!(
            String::from_utf8_lossy(&out),
            "STORED\r\nVALUE foo 7 5\r\nhello\r\nEND\r\n"
        );
    }

    #[test]
    fn fragmented_input_reassembles() {
        let mut c = conn();
        let mut out = Vec::new();
        for chunk in [
            &b"set fr"[..],
            &b"ag 0 0 "[..],
            &b"4\r\nda"[..],
            &b"ta\r"[..],
            &b"\nget frag\r\n"[..],
        ] {
            c.on_bytes(chunk, &mut out);
        }
        assert_eq!(
            String::from_utf8_lossy(&out),
            "STORED\r\nVALUE frag 0 4\r\ndata\r\nEND\r\n"
        );
    }

    #[test]
    fn pipelined_commands() {
        let mut c = conn();
        let out = run(
            &mut c,
            b"set a 0 0 1\r\nx\r\nset b 0 0 1\r\ny\r\nget a b\r\n",
        );
        let t = String::from_utf8_lossy(&out);
        assert_eq!(t.matches("STORED").count(), 2);
        assert!(t.contains("VALUE a 0 1"));
        assert!(t.contains("VALUE b 0 1"));
    }

    #[test]
    fn noreply_suppresses_response() {
        let mut c = conn();
        let out = run(&mut c, b"set q 0 0 1 noreply\r\nz\r\nget q\r\n");
        assert_eq!(
            String::from_utf8_lossy(&out),
            "VALUE q 0 1\r\nz\r\nEND\r\n"
        );
    }

    #[test]
    fn unknown_command_then_recovers() {
        let mut c = conn();
        let out = run(&mut c, b"bogus\r\nversion\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("ERROR\r\nVERSION"));
    }

    #[test]
    fn bad_data_tail_flagged() {
        let mut c = conn();
        let out = run(&mut c, b"set k 0 0 2\r\nabXXget k\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("CLIENT_ERROR bad data chunk"), "{t}");
    }

    #[test]
    fn delete_incr_touch_flow() {
        let mut c = conn();
        let out = run(
            &mut c,
            b"set n 0 0 2\r\n10\r\nincr n 5\r\ndecr n 100\r\ntouch n 60\r\ndelete n\r\ndelete n\r\n",
        );
        assert_eq!(
            String::from_utf8_lossy(&out),
            "STORED\r\n15\r\n0\r\nTOUCHED\r\nDELETED\r\nNOT_FOUND\r\n"
        );
    }

    #[test]
    fn cas_mismatch_reports_exists() {
        let mut c = conn();
        let out = run(&mut c, b"set k 0 0 1\r\nv\r\ngets k\r\n");
        let t = String::from_utf8_lossy(&out);
        let cas: u64 = t
            .split_whitespace()
            .nth(5) // VALUE k 0 1 <cas>
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        let bad = run(&mut c, format!("cas k 0 0 1 {}\r\nw\r\n", cas + 1).as_bytes());
        assert_eq!(String::from_utf8_lossy(&bad), "EXISTS\r\n");
        let good = run(&mut c, format!("cas k 0 0 1 {cas}\r\nw\r\n").as_bytes());
        assert_eq!(String::from_utf8_lossy(&good), "STORED\r\n");
    }

    #[test]
    fn stats_render() {
        let mut c = conn();
        let out = run(&mut c, b"set s 0 0 3\r\nabc\r\nstats\r\nstats slabs\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("STAT curr_items 1"));
        assert!(t.contains("chunk_size"));
    }

    #[test]
    fn quit_closes() {
        let mut c = conn();
        run(&mut c, b"quit\r\n");
        assert!(c.closing);
    }

    #[test]
    fn multi_get_missing_keys_skipped() {
        let mut c = conn();
        let out = run(&mut c, b"set a 0 0 1\r\nx\r\nget a missing b\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("VALUE a"));
        assert!(!t.contains("missing"));
    }

    #[test]
    fn binary_value_with_embedded_crlf() {
        let mut c = conn();
        let out = run(&mut c, b"set bin 0 0 6\r\nab\r\ncd\r\nget bin\r\n");
        let t = out.clone();
        assert!(String::from_utf8_lossy(&t).contains("VALUE bin 0 6"));
        assert!(t.windows(6).any(|w| w == b"ab\r\ncd"));
    }
}
