//! Per-connection protocol state machine, transport-agnostic: bytes in,
//! bytes out. The same machine drives real sockets (`server::tcp`) and
//! in-memory tests.
//!
//! ## Two front-ends, one execution core
//!
//! Both wire dialects — classic text and meta (`mg`/`ms`/`md`/`ma`/
//! `mn`) — parse into the same command IR (`protocol::Request`) and
//! execute through one core ([`Exec`]); responses render back through
//! `protocol::ResponseWriter`, which owns the dialect differences
//! (word responses vs code+flag echo, `noreply` vs `q` quiet
//! semantics). Meta data blocks (`ms`) reuse the classic `Phase::Data`
//! machinery; meta quiet mode composes with the bounded-sink
//! backpressure below because suppressed responses simply never enter
//! the output buffer, and `mn` emits its `MN` barrier unconditionally.
//!
//! ## Hot-path design
//!
//! The receive side is a cursor buffer ([`RecvBuf`]): completed
//! commands advance a cursor (O(1)) instead of `Vec::drain`-shifting
//! the buffer per command, and the unread tail is compacted at most
//! once per socket read. Command lines are parsed **in place** — the
//! `get`/`gets` fast path never copies the line or its keys, and
//! storage-command data blocks flow straight from the receive buffer
//! into the slab chunk (one copy). Responses are encoded directly into
//! the connection's output buffer under the shard lock
//! (`ShardedStore::get_with` / `get_batch`), so a get hit performs no
//! heap allocation at all: socket → hash probe → chunk-to-buffer copy.

use super::metrics::Metrics;
use crate::protocol::parse::{get_keys, parse_command, split_get, ParseError};
use crate::protocol::request::{DataRequest, Dialect, Opcode, Request};
use crate::protocol::writer::ResponseWriter;
use crate::protocol::{response, stats};
use crate::store::arena::Tier;
use crate::store::sharded::{ReadAttempt, ShardedStore};
use crate::store::store::{
    ArithOpts, ArithOutcome, DeleteOutcome, MetaGetOpts, MetaSetOpts, SetOutcome, ValueRef,
};
use crate::util::b64;
use crate::util::histogram::SizeHistogram;
use std::io::{ErrorKind, Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use crate::protocol::writer::{BufSink, RespSink};

/// Hard cap on one command line (memcached: 2048 for key lines).
const MAX_LINE: usize = 8192;

/// Hard cap on a data block (1 MiB value + slack).
const MAX_DATA: usize = (1 << 20) + 1024;

/// Multiget keys routed from the stack; longer batches pay one
/// transient allocation for the key-slice table.
const INLINE_KEYS: usize = 32;

/// Once the reused multiget staging buffer balloons past this, shrink
/// it back after the request (mirrors `tcp::OUT_BUF_KEEP` so one huge
/// multiget doesn't pin its high-water memory for the connection's
/// lifetime).
const SCRATCH_KEEP: usize = 256 * 1024;
const SCRATCH_STEADY: usize = 16 * 1024;

/// Progress/outcome gauges of the asynchronous `slabs optimize` path,
/// rendered into `stats slabs` (the final recovery numbers land here
/// instead of in a blocking reply).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptimizeGauges {
    /// An optimize request is queued or its drain is still running.
    pub pending: bool,
    /// Optimization passes completed (applied or not).
    pub runs: u64,
    /// Passes whose result was applied (a migration was kicked off).
    pub applied: u64,
    /// Predicted waste recovery of the most recent pass, in basis
    /// points (10000 = all waste recovered).
    pub last_recovery_bp: u64,
    /// Item sizes recorded above the collector's tracking cap
    /// ([`SizeCollector::overflow_count`]
    /// (crate::optimizer::collector::SizeCollector::overflow_count)) —
    /// when non-zero, the learned geometry's top class is biased low
    /// because `bucketize` clamps these into its last bucket.
    pub collector_overflow: u64,
}

/// Hook for the admin extensions; implemented by the optimizer
/// coordinator and injected by the launcher.
pub trait Control: Send + Sync {
    /// `slabs optimize` — returns a status line (without CRLF). The
    /// optimizer coordinator answers `OPTIMIZING` immediately and runs
    /// the pass (and its drain) on its background thread; completion
    /// is observable through [`Control::optimize_gauges`].
    fn optimize_now(&self) -> String;
    /// `slabs reconfigure` — apply explicit sizes; status line.
    fn reconfigure(&self, sizes: Vec<usize>) -> Result<String, String>;
    /// `stats sizes` source (the learned histogram), if any.
    fn sizes_histogram(&self) -> Option<SizeHistogram>;
    /// Async-optimize progress for `stats slabs` (zeros when the
    /// optimizer is not enabled).
    fn optimize_gauges(&self) -> OptimizeGauges {
        OptimizeGauges::default()
    }
}

/// No-op control for servers launched without the optimizer.
pub struct NoControl;

impl Control for NoControl {
    fn optimize_now(&self) -> String {
        "SERVER_ERROR optimizer not enabled".into()
    }

    fn reconfigure(&self, sizes: Vec<usize>) -> Result<String, String> {
        let _ = sizes;
        Err("optimizer not enabled".into())
    }

    fn sizes_histogram(&self) -> Option<SizeHistogram> {
        None
    }
}

/// Receive buffer with a consume cursor. Completed commands advance
/// `pos`; the unread tail moves to the front only when fresh bytes
/// arrive with a non-zero cursor, so an entire pipelined batch is
/// parsed and served without a single `memmove` (the old
/// `Vec::drain(..n)` paid an O(buffered) shift per command).
struct RecvBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl RecvBuf {
    fn new() -> Self {
        RecvBuf {
            buf: Vec::with_capacity(4096),
            pos: 0,
        }
    }

    /// Unconsumed bytes.
    #[inline]
    fn filled(&self) -> &[u8] {
        &self.buf[self.pos..]
    }

    #[inline]
    fn len(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Mark `n` unconsumed bytes as processed.
    #[inline]
    fn consume(&mut self, n: usize) {
        self.pos += n;
        debug_assert!(self.pos <= self.buf.len());
        if self.pos == self.buf.len() {
            // cheap steady-state reset: the whole buffer was consumed
            self.buf.clear();
            self.pos = 0;
        }
    }

    /// Append freshly received bytes, compacting the consumed prefix
    /// first so offsets stay small and memory stays bounded.
    fn extend(&mut self, data: &[u8]) {
        if self.pos > 0 {
            let live = self.buf.len() - self.pos;
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(live);
            self.pos = 0;
        }
        self.buf.extend_from_slice(data);
    }

    /// Release the high-water allocation of a fully-drained buffer
    /// down to `floor` capacity (idle-connection memory reclamation —
    /// a past 1 MiB upload must not pin 1 MiB per idle conn forever).
    fn shrink_idle(&mut self, floor: usize) {
        if self.len() == 0 && self.buf.capacity() > floor {
            self.buf.shrink_to(floor);
        }
    }
}

enum Phase {
    /// Waiting for a full command line.
    Line,
    /// Waiting for `len` data bytes + CRLF of a storage command
    /// (either dialect — the parked request is already owned).
    Data { req: DataRequest, len: usize },
    /// Swallowing the data block of a rejected storage command (the
    /// error line is already on the wire); keeps the stream in sync
    /// without buffering the oversized block.
    Discard { remaining: usize },
}

/// Connection state machine.
pub struct Conn {
    store: Arc<ShardedStore>,
    control: Arc<dyn Control>,
    rb: RecvBuf,
    phase: Phase,
    /// Reused staging buffer: out-of-order multiget hits before they
    /// are stitched into request order.
    scratch: Vec<u8>,
    /// Multiget spans: (request key index, scratch start, scratch end).
    spans: Vec<(u32, usize, usize)>,
    start: std::time::Instant,
    /// Server metrics for the `stats` connection gauges (`None` for
    /// embedded/test connections; gauges render as zero).
    metrics: Option<Arc<Metrics>>,
    /// `(reactor_id, reactor_count)` when core pinning is on: requests
    /// whose key's home shard is not `reactor_id`-affine bump the
    /// `reactor_cross_shard` stat, making cross-core traffic visible.
    affinity: Option<(u32, u32)>,
    pub closing: bool,
    /// Set when the last `on_bytes_sink` call stopped early because the
    /// sink saturated — complete commands may still be buffered, and
    /// the driver must re-feed (an empty slice suffices) once drained.
    yielded: bool,
}

impl Conn {
    pub fn new(store: Arc<ShardedStore>, control: Arc<dyn Control>) -> Self {
        Conn {
            store,
            control,
            rb: RecvBuf::new(),
            phase: Phase::Line,
            scratch: Vec::new(),
            spans: Vec::new(),
            start: std::time::Instant::now(),
            metrics: None,
            affinity: None,
            closing: false,
            yielded: false,
        }
    }

    /// Tag this connection with its serving reactor for the
    /// cross-shard affinity stat (only wired when `--pin-cores` makes
    /// the reactor↔core mapping meaningful).
    pub fn set_affinity(&mut self, reactor_id: u32, reactors: u32) {
        if reactors > 0 {
            self.affinity = Some((reactor_id, reactors));
        }
    }

    #[inline]
    fn note_shard_affinity(&self, key: &[u8]) {
        if let (Some((id, n)), Some(m)) = (self.affinity, self.metrics.as_deref()) {
            if self.store.shard_index(key) as u32 % n != id {
                Metrics::bump(&m.reactor_cross_shard);
            }
        }
    }

    /// Close out one UDP datagram: a well-formed datagram ends on a
    /// command boundary (no partial line or data block buffered).
    /// Returns `false` if the datagram was torn mid-command. Either
    /// way the parser is reset so the connection can serve the next
    /// datagram — UDP has no cross-datagram stream to preserve, and a
    /// `quit` (which only sets `closing`) must not poison the reused
    /// per-reactor connection.
    pub fn finish_datagram(&mut self) -> bool {
        let clean = self.rb.len() == 0 && matches!(self.phase, Phase::Line);
        self.rb.buf.clear();
        self.rb.pos = 0;
        self.phase = Phase::Line;
        self.closing = false;
        self.yielded = false;
        clean
    }

    /// Like [`Conn::new`], wiring the server [`Metrics`] in so `stats`
    /// reports the live connection gauges.
    pub fn with_metrics(
        store: Arc<ShardedStore>,
        control: Arc<dyn Control>,
        metrics: Arc<Metrics>,
    ) -> Self {
        let mut c = Conn::new(store, control);
        c.metrics = Some(metrics);
        c
    }

    /// Feed received bytes; protocol responses accumulate in `out`.
    /// Returns the number of commands completed.
    pub fn on_bytes(&mut self, data: &[u8], out: &mut Vec<u8>) -> usize {
        self.on_bytes_sink(data, &mut BufSink(out))
    }

    /// Idle-sweep memory reclamation: shed oversized receive/staging
    /// allocations left behind by a large upload or multiget.
    pub fn shrink_idle(&mut self, floor: usize) {
        self.rb.shrink_idle(floor);
        if self.scratch.is_empty() && self.scratch.capacity() > floor {
            self.scratch.shrink_to(floor);
        }
    }

    /// Sink-generic core of [`Conn::on_bytes`]: the reactor path feeds
    /// a bounded, socket-aware sink; tests and the threaded path feed a
    /// plain [`BufSink`].
    pub fn on_bytes_sink<S: RespSink>(&mut self, data: &[u8], sink: &mut S) -> usize {
        self.rb.extend(data);
        self.yielded = false;
        let mut completed = 0;
        loop {
            if self.closing {
                return completed;
            }
            if sink.saturated() {
                self.yielded = true;
                return completed;
            }
            match &self.phase {
                Phase::Line => {
                    let Some(eol) = find_crlf(self.rb.filled()) else {
                        if self.rb.len() > MAX_LINE {
                            response::client_error(sink.buf(), "line too long");
                            self.closing = true;
                        }
                        return completed;
                    };
                    if eol > MAX_LINE {
                        // a complete-but-oversized line is equally abusive
                        response::client_error(sink.buf(), "line too long");
                        self.closing = true;
                        return completed;
                    }
                    let line_total = eol + 2;
                    let line = &self.rb.buf[self.rb.pos..self.rb.pos + eol];
                    // Classic retrieval fast path: keys stay borrowed
                    // from the receive buffer; hits stream chunk -> out.
                    if let Some((with_cas, tail)) = split_get(line) {
                        if self.affinity.is_some() {
                            if let Some(first) = get_keys(tail).next() {
                                self.note_shard_affinity(first);
                            }
                        }
                        do_get(
                            &self.store,
                            &mut self.scratch,
                            &mut self.spans,
                            tail,
                            with_cas,
                            sink,
                        );
                        self.rb.consume(line_total);
                        completed += 1;
                        continue;
                    }
                    match parse_command(line) {
                        Ok(req) => {
                            // resolve base64 keys (`b`) in place; the
                            // decoded key lives on the stack so the mg
                            // hit path stays allocation-free
                            let mut kbuf = [0u8; 250];
                            let req = if req.b64_key {
                                match b64::decode(req.key, &mut kbuf) {
                                    Ok(n) if n > 0 => {
                                        let mut r = req;
                                        r.key = &kbuf[..n];
                                        r
                                    }
                                    _ => {
                                        // a storage line (`ms ... b`) still
                                        // announced a data block — swallow it
                                        // so its payload cannot execute as
                                        // commands
                                        let discard = req.data_len();
                                        self.rb.consume(line_total);
                                        response::client_error(sink.buf(), "bad base64 key");
                                        if let Some(len) = discard {
                                            self.phase = Phase::Discard {
                                                remaining: len.saturating_add(2),
                                            };
                                        }
                                        continue;
                                    }
                                }
                            } else {
                                req
                            };
                            match req.data_len() {
                                Some(len) if len > MAX_DATA => {
                                    self.rb.consume(line_total);
                                    response::server_error(
                                        sink.buf(),
                                        "object too large for cache",
                                    );
                                    // saturate: a client claiming ~usize::MAX
                                    // bytes must not wrap into a tiny discard
                                    // and smuggle its payload as commands
                                    self.phase = Phase::Discard {
                                        remaining: len.saturating_add(2),
                                    };
                                }
                                Some(len) => {
                                    let parked = req.to_data();
                                    self.rb.consume(line_total);
                                    self.phase = Phase::Data { req: parked, len };
                                }
                                None => {
                                    if req.op == Opcode::Get {
                                        self.note_shard_affinity(req.key);
                                    }
                                    Exec {
                                        store: &*self.store,
                                        control: &*self.control,
                                        scratch: &mut self.scratch,
                                        spans: &mut self.spans,
                                        metrics: self.metrics.as_deref(),
                                        start: self.start,
                                        closing: &mut self.closing,
                                    }
                                    .run(&req, sink);
                                    self.rb.consume(line_total);
                                    completed += 1;
                                }
                            }
                        }
                        Err(ParseError::UnknownCommand) => {
                            self.rb.consume(line_total);
                            response::error(sink.buf());
                        }
                        Err(ParseError::Client(msg)) => {
                            self.rb.consume(line_total);
                            response::client_error(sink.buf(), msg);
                        }
                    }
                }
                Phase::Data { len, .. } => {
                    let need = *len + 2;
                    if self.rb.len() < need {
                        return completed;
                    }
                    let Phase::Data { req, len } =
                        std::mem::replace(&mut self.phase, Phase::Line)
                    else {
                        unreachable!()
                    };
                    let avail = self.rb.filled();
                    if &avail[len..len + 2] != b"\r\n" {
                        self.rb.consume(need);
                        response::client_error(sink.buf(), "bad data chunk");
                        continue;
                    }
                    // execute with the data block borrowed straight out
                    // of the receive buffer: socket -> slab chunk, one copy
                    {
                        let data = &self.rb.buf[self.rb.pos..self.rb.pos + len];
                        execute_data(&self.store, &req, data, sink);
                    }
                    self.rb.consume(need);
                    completed += 1;
                }
                Phase::Discard { remaining } => {
                    let rem = *remaining;
                    let take = rem.min(self.rb.len());
                    self.rb.consume(take);
                    if take < rem {
                        self.phase = Phase::Discard {
                            remaining: rem - take,
                        };
                        return completed;
                    }
                    self.phase = Phase::Line;
                }
            }
        }
    }

}

/// The dialect-blind execution core: one [`Request`] in, responses out
/// through a [`ResponseWriter`] that renders whichever wire format the
/// request arrived in. Borrows the connection's state field-by-field so
/// the request may keep borrowing the receive buffer.
struct Exec<'e> {
    store: &'e ShardedStore,
    control: &'e dyn Control,
    scratch: &'e mut Vec<u8>,
    spans: &'e mut Vec<(u32, usize, usize)>,
    metrics: Option<&'e Metrics>,
    start: Instant,
    closing: &'e mut bool,
}

impl Exec<'_> {
    /// Execute a line-only (no data block) request. Storage requests go
    /// through [`execute_data`]; classic `get`/`gets` normally take the
    /// [`do_get`] fast path and only land here via [`parse_command`]
    /// (`gat`/`gats`, odd spacing, or direct test drives).
    fn run<S: RespSink>(&mut self, req: &Request<'_>, sink: &mut S) {
        match req.op {
            Opcode::Get => match req.dialect {
                Dialect::Classic => match req.touch_ttl {
                    Some(exp) => do_gat(self.store, req.key, exp, req.with_cas, sink),
                    None => do_get(
                        self.store,
                        self.scratch,
                        self.spans,
                        req.key,
                        req.with_cas,
                        sink,
                    ),
                },
                Dialect::Meta => do_meta_get(self.store, req, sink),
            },
            Opcode::Store => unreachable!("storage requests carry a data block"),
            Opcode::Delete => {
                let mut w = ResponseWriter::for_request(sink, req);
                match self.store.delete_cas(req.key, req.cas_compare, req.invalidate) {
                    DeleteOutcome::Deleted => w.deleted(),
                    DeleteOutcome::NotFound => w.not_found(),
                    DeleteOutcome::Exists => w.exists(),
                }
            }
            Opcode::Arith => {
                let mut w = ResponseWriter::for_request(sink, req);
                let reg = self.store.tenants();
                let tenant = reg.attribute(req.key, req.opaque);
                let opts = ArithOpts {
                    delta: req.delta,
                    incr: req.incr,
                    cas_compare: req.cas_compare,
                    vivify: req.vivify.map(|ttl| (ttl, req.arith_init)),
                    new_ttl: req.touch_ttl,
                    cas_set: req.cas_set,
                    binary_key: req.b64_key,
                    tenant,
                };
                match self.store.arith(req.key, &opts) {
                    Ok(ArithOutcome::Value { value, ttl, cas }) => {
                        if reg.active() {
                            reg.record_set(tenant);
                        }
                        w.number(value, ttl, cas)
                    }
                    Ok(ArithOutcome::NotFound) => w.not_found(),
                    Ok(ArithOutcome::Exists) => w.exists(),
                    Err(e) => w.store_error(&e),
                }
            }
            Opcode::Touch => {
                let mut w = ResponseWriter::for_request(sink, req);
                if self.store.touch(req.key, req.exptime) {
                    w.touched();
                } else {
                    w.not_found();
                }
            }
            Opcode::Noop => ResponseWriter::for_request(sink, req).noop(),
            Opcode::MetaDebug => do_me(self.store, req, sink),
            Opcode::Stats => self.run_stats(req.stats_arg, sink),
            Opcode::FlushAll => {
                self.store.flush_all();
                ResponseWriter::for_request(sink, req).ok();
            }
            Opcode::Version => ResponseWriter::for_request(sink, req)
                .line(concat!("VERSION ", env!("CARGO_PKG_VERSION"))),
            Opcode::Verbosity => ResponseWriter::for_request(sink, req).ok(),
            Opcode::Quit => *self.closing = true,
            Opcode::SlabsReconfigure => {
                let mut w = ResponseWriter::for_request(sink, req);
                match self.control.reconfigure(req.sizes.clone()) {
                    Ok(msg) => w.line(&msg),
                    Err(msg) => w.server_error(&msg),
                }
            }
            Opcode::SlabsOptimize => {
                let msg = self.control.optimize_now();
                ResponseWriter::for_request(sink, req).line(&msg);
            }
            Opcode::Failpoints => self.run_failpoints(req, sink),
            Opcode::Tenants => self.run_tenants(req, sink),
        }
    }

    /// `failpoints [list]` / `failpoints set <name=spec[,..]>` /
    /// `failpoints clear [name]` — runtime control of the
    /// fault-injection registry ([`crate::util::failpoint`]). `list`
    /// renders one `FAILPOINT <name> <spec> <fires>` line per armed
    /// point, then `END`.
    fn run_failpoints<S: RespSink>(&mut self, req: &Request<'_>, sink: &mut S) {
        use crate::util::failpoint;
        let mut w = ResponseWriter::for_request(sink, req);
        let arg = req.key;
        let (sub, rest) = match arg.iter().position(|&b| b == b' ') {
            Some(i) => (&arg[..i], &arg[i + 1..]),
            None => (arg, &b""[..]),
        };
        match sub {
            b"" | b"list" => {
                for (name, spec, fires) in failpoint::list() {
                    w.line(&format!("FAILPOINT {name} {spec} {fires}"));
                }
                w.line("END");
            }
            b"set" => {
                let spec = String::from_utf8_lossy(rest);
                match failpoint::arm_list(&spec) {
                    Ok(()) => w.ok(),
                    Err(e) => w.client_error(&e),
                }
            }
            b"clear" => {
                if rest.is_empty() {
                    failpoint::disarm_all();
                } else {
                    failpoint::disarm(&String::from_utf8_lossy(rest));
                }
                w.ok();
            }
            _ => w.client_error("usage: failpoints [list|set name=spec[,..]|clear [name]]"),
        }
    }

    /// `tenants list` / `tenants define <name> <prefix> [quota_pages]` /
    /// `tenants token <name> <token>` / `tenants quota <name> <pages>` —
    /// runtime control of the multi-tenant registry. `list` renders one
    /// `TENANT <id> <name> prefixes=<p,..> tokens=<n> quota=<pages>`
    /// line per defined tenant, then `END`. Rules added at runtime only
    /// affect attribution of new traffic; resident items keep their
    /// stamped owner until rewritten.
    fn run_tenants<S: RespSink>(&mut self, req: &Request<'_>, sink: &mut S) {
        const USAGE: &str =
            "usage: tenants [list|define name prefix [quota]|token name tok|quota name pages]";
        let mut w = ResponseWriter::for_request(sink, req);
        let reg = self.store.tenants();
        let mut toks = req.key.split(|&b| b == b' ').filter(|t| !t.is_empty());
        let sub = toks.next().unwrap_or(&b"list"[..]);
        match sub {
            b"list" => {
                for r in reg.rules_snapshot() {
                    let prefixes = r
                        .prefixes
                        .iter()
                        .map(|p| String::from_utf8_lossy(p).into_owned())
                        .collect::<Vec<_>>()
                        .join(",");
                    w.line(&format!(
                        "TENANT {} {} prefixes={} tokens={} quota={}",
                        r.id,
                        r.name,
                        if prefixes.is_empty() { "-" } else { prefixes.as_str() },
                        r.tokens.len(),
                        r.quota_pages,
                    ));
                }
                w.line("END");
            }
            b"define" => {
                let (Some(name), Some(prefix)) = (toks.next(), toks.next()) else {
                    w.client_error(USAGE);
                    return;
                };
                let quota = match toks.next() {
                    None => None,
                    Some(q) => match std::str::from_utf8(q).ok().and_then(|s| s.parse().ok()) {
                        Some(q) => Some(q),
                        None => {
                            w.client_error("quota must be a page count");
                            return;
                        }
                    },
                };
                match reg.define(&String::from_utf8_lossy(name), prefix, quota) {
                    Ok(id) => w.line(&format!("OK {id}")),
                    Err(e) => w.client_error(&e),
                }
            }
            b"token" => {
                let (Some(name), Some(token)) = (toks.next(), toks.next()) else {
                    w.client_error(USAGE);
                    return;
                };
                match reg.set_token(&String::from_utf8_lossy(name), token) {
                    Ok(id) => w.line(&format!("OK {id}")),
                    Err(e) => w.client_error(&e),
                }
            }
            b"quota" => {
                let (Some(name), Some(pages)) = (toks.next(), toks.next()) else {
                    w.client_error(USAGE);
                    return;
                };
                let Some(pages) = std::str::from_utf8(pages).ok().and_then(|s| s.parse().ok())
                else {
                    w.client_error("quota must be a page count");
                    return;
                };
                match reg.set_quota(&String::from_utf8_lossy(name), pages) {
                    Ok(id) => w.line(&format!("OK {id}")),
                    Err(e) => w.client_error(&e),
                }
            }
            _ => w.client_error(USAGE),
        }
    }

    fn run_stats<S: RespSink>(&mut self, arg: Option<&[u8]>, sink: &mut S) {
        let out = sink.buf();
        match arg {
            Some(b"slabs") => stats::render_slabs(
                out,
                &self.store.slab_stats(),
                &self.store.migration_gauges(),
                &self.control.optimize_gauges(),
            ),
            Some(b"sizes") => match self.control.sizes_histogram() {
                Some(h) => stats::render_sizes(out, &h),
                None => stats::render_sizes(out, &SizeHistogram::new(1)),
            },
            Some(b"tenants") => {
                stats::render_tenants(out, &self.store.tenants().stats_snapshot())
            }
            Some(b"reset") => {
                self.store.reset_stats();
                if let Some(m) = self.metrics {
                    m.reset();
                }
                response::reset(out);
            }
            _ => {
                let ops = self.store.stats();
                let slabs = self.store.slab_stats();
                let uptime = self.start.elapsed().as_secs();
                let conns = self
                    .metrics
                    .map(|m| m.conn_counters())
                    .unwrap_or_default();
                stats::render_general(
                    out,
                    &ops,
                    &slabs,
                    self.store.len(),
                    uptime,
                    &conns,
                    &self.store.restart_snapshot(),
                );
            }
        }
    }
}

/// Serve a `get`/`gets` line straight from the shard chunks into the
/// sink.
///
/// The single-key case — the dominant request shape — streams under
/// one shard lock with no staging and no allocation, through
/// [`RespSink::value`] so a socket-aware sink can scatter large values
/// with `writev`. A multiget routes all keys per shard
/// (`ShardedStore::get_batch`, each shard's lock taken once for the
/// batch) and restores request order by staging out-of-order hits in
/// `scratch` and stitching spans; both buffers are owned by the
/// connection and reused across requests.
fn do_get<S: RespSink>(
    store: &ShardedStore,
    scratch: &mut Vec<u8>,
    spans: &mut Vec<(u32, usize, usize)>,
    tail: &[u8],
    with_cas: bool,
    sink: &mut S,
) {
    let mut iter = get_keys(tail);
    let Some(first) = iter.next() else {
        // split_get guarantees at least one key
        response::end(sink.buf());
        return;
    };
    let Some(second) = iter.next() else {
        // lock-free first: the optimistic probe encodes straight into
        // the sink buffer (values < OPTIMISTIC_VALUE_MAX never take the
        // writev scatter path, so a torn encode is undone by truncating
        // back to the mark). Only expired/oversized items and exhausted
        // seqlock retries pay a lock.
        let mark = sink.buf().len();
        let hit = match store.get_optimistic(
            first,
            sink,
            |s: &mut S| s.buf().truncate(mark),
            |s, v| {
                s.value(first, v, with_cas);
            },
        ) {
            ReadAttempt::Hit(()) => true,
            ReadAttempt::Miss => false,
            ReadAttempt::Fallback => store
                .get_with(first, |v| sink.value(first, v, with_cas))
                .is_some(),
        };
        let reg = store.tenants();
        if reg.active() {
            reg.record_get(reg.attribute(first, b""), hit);
        }
        response::end(sink.buf());
        return;
    };

    // multiget: gather the key slices (stack table for short batches)
    let empty: &[u8] = b"";
    let mut stack = [empty; INLINE_KEYS];
    stack[0] = first;
    stack[1] = second;
    let mut n = 2usize;
    let mut heap: Vec<&[u8]> = Vec::new();
    for k in iter {
        if n < INLINE_KEYS {
            stack[n] = k;
        } else {
            if heap.is_empty() {
                heap.reserve(n * 2);
                heap.extend_from_slice(&stack[..n]);
            }
            heap.push(k);
        }
        n += 1;
    }
    let keys: &[&[u8]] = if heap.is_empty() { &stack[..n] } else { &heap };

    scratch.clear();
    spans.clear();
    let mut ctx = (&mut *scratch, &mut *spans);
    store.get_batch(
        keys,
        &mut ctx,
        |c, idx, v| {
            let s = c.0.len();
            response::value_ref(c.0, keys[idx], v, with_cas);
            c.1.push((idx as u32, s, c.0.len()));
        },
        // a torn optimistic encode is undone by dropping the span the
        // probe just staged (always the most recent one for this key)
        |c, idx| {
            if let Some(&(i, s, _)) = c.1.last() {
                if i == idx as u32 {
                    c.1.pop();
                    c.0.truncate(s);
                }
            }
        },
    );
    // single-shard batches (and lucky layouts) already arrive in
    // request order — skip the sort, splice directly
    if !spans.windows(2).all(|w| w[0].0 <= w[1].0) {
        spans.sort_unstable_by_key(|s| s.0);
    }
    // per-tenant counting: after the sort each key's hit is a span with
    // its index, so one merge-walk attributes the whole batch (skipped
    // entirely — one relaxed load — on a single-tenant server)
    let reg = store.tenants();
    if reg.active() {
        let mut si = 0usize;
        for (idx, k) in keys.iter().enumerate() {
            let hit = spans.get(si).is_some_and(|&(i, _, _)| i as usize == idx);
            if hit {
                si += 1;
            }
            reg.record_get(reg.attribute(k, b""), hit);
        }
    }
    let out = sink.buf();
    out.reserve(scratch.len() + 5);
    for &(_, s, e) in spans.iter() {
        out.extend_from_slice(&scratch[s..e]);
    }
    response::end(out);
    if scratch.capacity() > SCRATCH_KEEP {
        scratch.shrink_to(SCRATCH_STEADY);
    }
    if spans.capacity() > 4096 {
        spans.shrink_to(256);
    }
}

/// Classic `gat`/`gats`: serve each key like a get while refreshing
/// its TTL (touch-on-read, through the same store primitive the meta
/// `T` flag uses). Touch mutates, so every key takes its shard's write
/// lock — no batching, which matches memcached's per-key gat.
fn do_gat<S: RespSink>(
    store: &ShardedStore,
    tail: &[u8],
    exptime: u32,
    with_cas: bool,
    sink: &mut S,
) {
    let opts = MetaGetOpts {
        touch: Some(exptime),
        ..MetaGetOpts::default()
    };
    let reg = store.tenants();
    for key in get_keys(tail) {
        // the touch path never inserts, so no error can surface here
        let r = store.meta_get(key, &opts, |v, _| sink.value(key, v, with_cas));
        if reg.active() {
            reg.record_get(reg.attribute(key, b""), matches!(r, Ok(Some(_))));
        }
    }
    response::end(sink.buf());
}

/// Meta `mg`: single-key retrieval with flag-driven extras. Plain
/// lookups go **lock-free** first ([`ShardedStore::meta_get_optimistic`]
/// — seqlock probe, metadata echoes built from the validated record
/// copy, LRU bump deferred to the maintainer) and encode straight into
/// the sink. Requests the optimistic path cannot answer exactly
/// (touch-on-read, bumping `h`, base64 keys, vivify misses, oversized
/// values, recache-`R` win races, stale items) fall back to the locked
/// [`ShardedStore::meta_get`].
fn do_meta_get<S: RespSink>(store: &ShardedStore, req: &Request<'_>, sink: &mut S) {
    let mut w = ResponseWriter::for_request(sink, req);
    let reg = store.tenants();
    let tenant = reg.attribute(req.key, req.opaque);
    let opts = MetaGetOpts {
        touch: req.touch_ttl,
        vivify: req.vivify,
        vivify_cas: req.cas_set,
        binary_key: req.b64_key,
        no_bump: req.no_bump,
        wants_hit_before: req.want & crate::protocol::request::want::HIT != 0,
        recache: req.recache,
        tenant,
    };
    let key = req.key;
    let mark = w.buf().len();
    match store.meta_get_optimistic(
        key,
        &opts,
        &mut w,
        |w| w.buf().truncate(mark),
        |w, v, hit| {
            w.value(key, v, hit);
        },
    ) {
        ReadAttempt::Hit(()) => {
            if reg.active() {
                reg.record_get(tenant, true);
            }
            return;
        }
        ReadAttempt::Miss => {
            if reg.active() {
                reg.record_get(tenant, false);
            }
            w.miss();
            return;
        }
        ReadAttempt::Fallback => {}
    }
    let r = store.meta_get(key, &opts, |v, hit| w.value(key, v, hit));
    if reg.active() {
        reg.record_get(tenant, matches!(r, Ok(Some(_))));
    }
    match r {
        Ok(Some(_)) => {}
        Ok(None) => w.miss(),
        Err(e) => w.store_error(&e),
    }
}

/// Meta `me`: dump one item's bookkeeping (`ME <key> exp=.. la=..
/// cas=.. fetch=.. cls=.. tier=.. size=..`) for debugging slab/LRU
/// placement. Read-locked and side-effect free — it neither bumps the
/// LRU nor flips the fetched bit. Miss answers `EN`.
fn do_me<S: RespSink>(store: &ShardedStore, req: &Request<'_>, sink: &mut S) {
    let mut w = ResponseWriter::for_request(sink, req);
    match store.debug_item(req.key) {
        Some(d) => {
            let tier = match d.tier {
                Tier::Hot => "hot",
                Tier::Warm => "warm",
                Tier::Cold => "cold",
            };
            let key = String::from_utf8_lossy(req.key_echo);
            w.line(&format!(
                "ME {key} exp={} la={} cas={} fetch={} cls={} tier={tier} size={}",
                d.ttl,
                d.la,
                d.cas,
                u8::from(d.fetched),
                d.class,
                d.vlen,
            ));
        }
        None => w.miss(),
    }
}

/// Execute a storage request whose data block just completed, with the
/// block borrowed from the receive buffer (copied once, into the slab
/// chunk under the shard's write lock). Both dialects land on
/// [`ShardedStore::meta_set`]; the writer renders the outcome.
fn execute_data<S: RespSink>(store: &ShardedStore, req: &DataRequest, data: &[u8], sink: &mut S) {
    let mut w = ResponseWriter::for_data(sink, req);
    let reg = store.tenants();
    let tenant = reg.attribute(&req.key, &req.opaque);
    if reg.active() {
        reg.record_set(tenant);
    }
    let opts = MetaSetOpts {
        mode: req.mode,
        flags: req.set_flags,
        exptime: req.exptime,
        cas_compare: req.cas_compare,
        cas_set: req.cas_set,
        binary_key: req.b64_key,
        invalidate: req.invalidate,
        tenant,
    };
    match store.meta_set(&req.key, data, &opts) {
        Ok(SetOutcome::Stored { cas }) => w.stored(cas),
        Ok(SetOutcome::NotStored) => w.not_stored(),
        Ok(SetOutcome::Exists) => w.exists(),
        Ok(SetOutcome::NotFound) => w.not_found(),
        Err(e) => w.store_error(&e),
    }
}

/// Find the first CRLF; scans for `\n` (a single-byte search the
/// compiler vectorizes) and verifies the preceding `\r`, skipping bare
/// newlines like the old `windows(2)` scan did.
fn find_crlf(buf: &[u8]) -> Option<usize> {
    let mut from = 0;
    while let Some(nl) = buf[from..].iter().position(|&b| b == b'\n') {
        let i = from + nl;
        if i > 0 && buf[i - 1] == b'\r' {
            return Some(i - 1);
        }
        from = i + 1;
    }
    None
}

// ====================================================================
// Event-driven connection: bounded output buffer + readiness-driven
// state machine (the reactor's unit of work)
// ====================================================================

/// Output backpressure high-water mark: once this many unflushed bytes
/// are buffered, the connection stops executing commands (the receive
/// buffer keeps the unread tail) until the socket drains. Worst-case
/// overshoot is one response (≤ one max-size value + header).
pub const OUT_HIGH_WATER: usize = 512 * 1024;

/// Values at least this large take the `writev` scatter path (header
/// from the output buffer, chunk straight from the slab) instead of the
/// chunk→buffer copy.
pub const DIRECT_VALUE_MIN: usize = 4096;

/// Socket reads one `drive` call may perform before yielding back to
/// the reactor so one firehose client cannot starve its siblings
/// (memcached's `conn_yields`). 32 reads × 16 KiB = 512 KiB per turn.
const DRIVE_READ_BUDGET: usize = 32;

/// Shrink thresholds for the reused output buffer (shared with the
/// legacy threaded path in `server::tcp`): drop the high-water
/// allocation of a huge response once it has fully drained.
pub(crate) const OUT_KEEP: usize = 256 * 1024;
pub(crate) const OUT_STEADY: usize = 16 * 1024;

/// Write buffer with a flush cursor: responses append at the tail,
/// flushed bytes advance `pos`, and a fully drained buffer resets (and
/// sheds an oversized allocation).
pub struct OutBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl OutBuf {
    pub fn new() -> OutBuf {
        OutBuf {
            buf: Vec::with_capacity(OUT_STEADY),
            pos: 0,
        }
    }

    /// Bytes encoded but not yet written to the socket.
    #[inline]
    pub fn pending(&self) -> &[u8] {
        &self.buf[self.pos..]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len() - self.pos
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append target for response encoding.
    #[inline]
    pub fn buf_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }

    /// Mark `n` pending bytes as flushed.
    pub fn consume(&mut self, n: usize) {
        self.pos += n;
        debug_assert!(self.pos <= self.buf.len());
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
            if self.buf.capacity() > OUT_KEEP {
                self.buf.shrink_to(OUT_STEADY);
            }
        }
    }

    /// Release a drained-but-oversized allocation down to `floor`
    /// (idle sweep under connection-buffer budget pressure).
    pub fn shrink_idle(&mut self, floor: usize) {
        if self.is_empty() && self.buf.capacity() > floor {
            self.buf.shrink_to(floor);
        }
    }
}

impl Default for OutBuf {
    fn default() -> Self {
        OutBuf::new()
    }
}

/// What the reactor should do with the connection after a `drive`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnState {
    /// Keep serving. `wants_write` asks for writable-interest
    /// (EPOLLOUT) registration: pending output exists and the socket
    /// returned `WouldBlock`.
    Open { wants_write: bool },
    /// Tear down: protocol `quit`, peer close, or I/O error. Any
    /// pending output has already been flushed (or is unflushable).
    Closed,
}

/// A connection driven by readiness events: nonblocking transport +
/// [`Conn`] protocol state machine + bounded [`OutBuf`], with
/// edge-triggered readiness memory (`read_ready`/`write_ready`) so a
/// yield never loses an edge.
pub struct DrivenConn<T> {
    io: T,
    conn: Conn,
    out: OutBuf,
    /// ET memory: the socket reported readable and we have not yet
    /// drained it to `WouldBlock`.
    read_ready: bool,
    /// ET memory: the socket accepted the last write (no `WouldBlock`
    /// since); cleared on short/refused writes.
    write_ready: bool,
    peer_closed: bool,
    dead: bool,
    /// Raw fd for the `writev` scatter path (`None` disables it — test
    /// transports and non-Linux builds).
    direct_fd: Option<i32>,
    last_activity: Instant,
}

impl<T: Read + Write> DrivenConn<T> {
    pub fn new(io: T, conn: Conn) -> DrivenConn<T> {
        DrivenConn {
            io,
            conn,
            out: OutBuf::new(),
            read_ready: false,
            // fresh sockets are writable until proven otherwise
            write_ready: true,
            peer_closed: false,
            dead: false,
            direct_fd: None,
            last_activity: Instant::now(),
        }
    }

    /// Enable the `writev` scatter path on this transport's fd.
    pub fn with_direct_fd(mut self, fd: i32) -> DrivenConn<T> {
        self.direct_fd = Some(fd);
        self
    }

    /// Unflushed response bytes exist (graceful-shutdown drain check).
    pub fn has_pending_out(&self) -> bool {
        !self.out.is_empty()
    }

    /// Unflushed response bytes — the quantity the reactor charges
    /// against the global connection-buffer budget (a stalled reader
    /// accumulates up to `OUT_HIGH_WATER` + one response here).
    pub fn pending_out_len(&self) -> usize {
        self.out.len()
    }

    /// Idle-sweep memory reclamation: shed drained-but-oversized
    /// receive, staging, and output allocations down to `floor`.
    pub fn shrink_idle(&mut self, floor: usize) {
        self.out.shrink_idle(floor);
        self.conn.shrink_idle(floor);
    }

    /// The connection yielded with work still buffered (kernel bytes
    /// unread or parsed-but-unexecuted commands) and can make progress
    /// without a new readiness event. The reactor re-drives these
    /// before sleeping.
    pub fn wants_redrive(&self) -> bool {
        !self.dead
            && !self.conn.closing
            && !self.peer_closed
            && (self.read_ready || self.conn.yielded)
            && self.out.len() < OUT_HIGH_WATER
    }

    pub fn idle_for(&self, now: Instant) -> Duration {
        now.saturating_duration_since(self.last_activity)
    }

    /// Advance the connection as far as readiness allows: flush pending
    /// output, resume backpressured command execution, read and execute
    /// new commands — in that order, looping until the socket would
    /// block, the read budget is spent, or output hits the high-water
    /// mark. Pass the readiness edges observed since the last call.
    pub fn drive(&mut self, readable: bool, writable: bool, metrics: &Metrics) -> ConnState {
        if readable {
            self.read_ready = true;
            self.last_activity = Instant::now();
        }
        if writable {
            self.write_ready = true;
        }
        let mut rbuf = [0u8; 16 * 1024];
        let mut budget = DRIVE_READ_BUDGET;
        loop {
            self.flush(metrics);
            if self.dead {
                return ConnState::Closed;
            }
            if self.conn.closing || self.peer_closed {
                if self.out.is_empty() {
                    return ConnState::Closed;
                }
                break; // drain-only: flush remaining output, then close
            }
            // flush invariant: out is empty or the socket is full, so
            // crossing the high-water mark always means "wait for
            // EPOLLOUT", never a busy loop
            if self.out.len() >= OUT_HIGH_WATER {
                Metrics::bump(&metrics.conn_yields);
                break;
            }
            if self.conn.yielded {
                // backpressure lifted: resume executing commands that
                // are already buffered before reading more
                let done = self.feed(&[], metrics);
                Metrics::add(&metrics.commands, done as u64);
                continue;
            }
            if !self.read_ready {
                break;
            }
            if budget == 0 {
                Metrics::bump(&metrics.conn_yields);
                break;
            }
            budget -= 1;
            // `conn.read.eintr`: exercise the signal-interrupt retry
            // path without a real signal (arm with `1inN`, never
            // `always` — like real EINTR storms, that would spin)
            if crate::util::failpoint::fired("conn.read.eintr") {
                budget += 1;
                continue;
            }
            match self.io.read(&mut rbuf) {
                Ok(0) => {
                    self.peer_closed = true;
                    self.read_ready = false;
                }
                Ok(n) => {
                    self.last_activity = Instant::now();
                    Metrics::add(&metrics.bytes_read, n as u64);
                    let done = self.feed(&rbuf[..n], metrics);
                    Metrics::add(&metrics.commands, done as u64);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    self.read_ready = false;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {
                    budget += 1;
                }
                Err(_) => return ConnState::Closed,
            }
        }
        ConnState::Open {
            wants_write: !self.out.is_empty(),
        }
    }

    /// Run the protocol machine over `data` with the socket-aware sink
    /// (bounded buffer + `writev` scatter for large values).
    fn feed(&mut self, data: &[u8], metrics: &Metrics) -> usize {
        let Self {
            conn,
            out,
            write_ready,
            dead,
            direct_fd,
            ..
        } = self;
        let mut sink = NetSink {
            out,
            write_ready,
            dead,
            fd: *direct_fd,
            metrics,
        };
        conn.on_bytes_sink(data, &mut sink)
    }

    /// Shutdown drain: write pending output only — never read or
    /// execute commands (the graceful-shutdown contract is "flush
    /// in-flight responses", not "keep serving"). Forces a write
    /// attempt even if the last write would-blocked, since the caller
    /// polls instead of waiting for EPOLLOUT.
    pub fn flush_pending(&mut self, metrics: &Metrics) {
        self.write_ready = true;
        self.flush(metrics);
    }

    /// Write pending output until drained or the socket refuses.
    fn flush(&mut self, metrics: &Metrics) {
        while self.write_ready && !self.out.is_empty() {
            match self.io.write(self.out.pending()) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => {
                    Metrics::add(&metrics.bytes_written, n as u64);
                    self.out.consume(n);
                    // write progress is liveness too: a client slowly
                    // draining a large response must not be reaped by
                    // the idle sweep mid-stream
                    self.last_activity = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    self.write_ready = false;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }
}

/// The reactor-path sink: responses land in the bounded [`OutBuf`];
/// large values scatter straight from the slab chunk to the socket via
/// `writev` while the shard lock pins the chunk, copying only whatever
/// tail the kernel did not accept.
struct NetSink<'a> {
    out: &'a mut OutBuf,
    write_ready: &'a mut bool,
    dead: &'a mut bool,
    #[cfg_attr(not(target_os = "linux"), allow(dead_code))]
    fd: Option<i32>,
    #[cfg_attr(not(target_os = "linux"), allow(dead_code))]
    metrics: &'a Metrics,
}

impl RespSink for NetSink<'_> {
    fn buf(&mut self) -> &mut Vec<u8> {
        self.out.buf_mut()
    }

    fn saturated(&self) -> bool {
        self.out.len() >= OUT_HIGH_WATER
    }

    fn value(&mut self, key: &[u8], v: ValueRef<'_>, with_cas: bool) {
        #[cfg(target_os = "linux")]
        if let Some(fd) = self.fd {
            if *self.write_ready && !*self.dead && v.data.len() >= DIRECT_VALUE_MIN {
                // encode the VALUE header into the output buffer, then
                // scatter [pending, chunk, CRLF] to the kernel
                response::value_header(
                    self.out.buf_mut(),
                    key,
                    v.data.len(),
                    v.flags,
                    with_cas.then_some(v.cas),
                );
                self.scatter(fd, v.data);
                return;
            }
        }
        response::value_ref(self.out.buf_mut(), key, v, with_cas);
    }

    /// Meta `VA` data blocks ride the same scatter machinery as classic
    /// `VALUE`s: the writer already encoded the header line into the
    /// buffer, so large chunks go `[pending, chunk, CRLF]` straight to
    /// the kernel.
    fn append_data(&mut self, data: &[u8]) {
        #[cfg(target_os = "linux")]
        if let Some(fd) = self.fd {
            if *self.write_ready && !*self.dead && data.len() >= DIRECT_VALUE_MIN {
                self.scatter(fd, data);
                return;
            }
        }
        let out = self.out.buf_mut();
        out.extend_from_slice(data);
        out.extend_from_slice(b"\r\n");
    }
}

#[cfg(target_os = "linux")]
impl NetSink<'_> {
    /// Hand `[pending output, data, CRLF]` to the kernel in one
    /// `writev` (the header line is already in the buffer). On a full
    /// send nothing of `data` is ever copied; on a short send only the
    /// unaccepted tail lands in the buffer.
    fn scatter(&mut self, fd: i32, data: &[u8]) {
        use super::sys::writev_slices;
        let total = self.out.len() + data.len() + 2;
        match writev_slices(fd, &[self.out.pending(), data, b"\r\n"]) {
            Ok(mut n) => {
                Metrics::add(&self.metrics.bytes_written, n as u64);
                if n < total {
                    *self.write_ready = false;
                }
                let take = n.min(self.out.len());
                self.out.consume(take);
                n -= take;
                if n < data.len() {
                    self.out.buf_mut().extend_from_slice(&data[n..]);
                    n = 0;
                } else {
                    n -= data.len();
                }
                if n < 2 {
                    self.out.buf_mut().extend_from_slice(&b"\r\n"[n..]);
                }
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted =>
            {
                *self.write_ready = false;
                self.out.buf_mut().extend_from_slice(data);
                self.out.buf_mut().extend_from_slice(b"\r\n");
            }
            Err(_) => {
                *self.dead = true;
                // keep the buffer protocol-consistent even though the
                // connection is about to close
                self.out.buf_mut().extend_from_slice(data);
                self.out.buf_mut().extend_from_slice(b"\r\n");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::PAGE_SIZE;
    use crate::store::store::Clock;

    fn conn_sharded(shards: usize) -> Conn {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                shards,
                Clock::System,
            )
            .unwrap(),
        );
        Conn::new(store, Arc::new(NoControl))
    }

    fn conn() -> Conn {
        conn_sharded(2)
    }

    fn run(c: &mut Conn, input: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        c.on_bytes(input, &mut out);
        out
    }

    #[test]
    fn set_get_exact() {
        let mut c = conn();
        let out = run(&mut c, b"set foo 7 0 5\r\nhello\r\nget foo\r\n");
        assert_eq!(
            String::from_utf8_lossy(&out),
            "STORED\r\nVALUE foo 7 5\r\nhello\r\nEND\r\n"
        );
    }

    #[test]
    fn failpoints_command_sets_lists_and_clears() {
        // names are unique to this test: the registry is
        // process-global and lib tests run in parallel
        let mut c = conn();
        let out = run(&mut c, b"failpoints set fp.conn.a=1in5,fp.conn.b=once\r\n");
        assert_eq!(out, b"OK\r\n");
        let out = String::from_utf8(run(&mut c, b"failpoints list\r\n")).unwrap();
        assert!(out.contains("FAILPOINT fp.conn.a 1in5 0"), "{out}");
        assert!(out.contains("FAILPOINT fp.conn.b once 0"), "{out}");
        assert!(out.ends_with("END\r\n"), "{out}");
        let out = run(&mut c, b"failpoints clear fp.conn.a\r\n");
        assert_eq!(out, b"OK\r\n");
        // cleared points stay listed (with their fire history) as `off`
        let out = String::from_utf8(run(&mut c, b"failpoints\r\n")).unwrap();
        assert!(out.contains("FAILPOINT fp.conn.a off"), "{out}");
        assert_eq!(run(&mut c, b"failpoints clear fp.conn.b\r\n"), b"OK\r\n");
        let out = run(&mut c, b"failpoints set fp.conn.a=bogus\r\n");
        assert!(out.starts_with(b"CLIENT_ERROR"), "{:?}", out);
        let out = run(&mut c, b"failpoints frob\r\n");
        assert!(out.starts_with(b"CLIENT_ERROR usage"), "{:?}", out);
    }

    #[test]
    fn fragmented_input_reassembles() {
        let mut c = conn();
        let mut out = Vec::new();
        for chunk in [
            &b"set fr"[..],
            &b"ag 0 0 "[..],
            &b"4\r\nda"[..],
            &b"ta\r"[..],
            &b"\nget frag\r\n"[..],
        ] {
            c.on_bytes(chunk, &mut out);
        }
        assert_eq!(
            String::from_utf8_lossy(&out),
            "STORED\r\nVALUE frag 0 4\r\ndata\r\nEND\r\n"
        );
    }

    #[test]
    fn pipelined_commands() {
        let mut c = conn();
        let out = run(
            &mut c,
            b"set a 0 0 1\r\nx\r\nset b 0 0 1\r\ny\r\nget a b\r\n",
        );
        let t = String::from_utf8_lossy(&out);
        assert_eq!(t.matches("STORED").count(), 2);
        assert!(t.contains("VALUE a 0 1"));
        assert!(t.contains("VALUE b 0 1"));
    }

    #[test]
    fn noreply_suppresses_response() {
        let mut c = conn();
        let out = run(&mut c, b"set q 0 0 1 noreply\r\nz\r\nget q\r\n");
        assert_eq!(
            String::from_utf8_lossy(&out),
            "VALUE q 0 1\r\nz\r\nEND\r\n"
        );
    }

    #[test]
    fn unknown_command_then_recovers() {
        let mut c = conn();
        let out = run(&mut c, b"bogus\r\nversion\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("ERROR\r\nVERSION"));
    }

    #[test]
    fn bad_data_tail_flagged() {
        let mut c = conn();
        let out = run(&mut c, b"set k 0 0 2\r\nabXXget k\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("CLIENT_ERROR bad data chunk"), "{t}");
    }

    #[test]
    fn delete_incr_touch_flow() {
        let mut c = conn();
        let out = run(
            &mut c,
            b"set n 0 0 2\r\n10\r\nincr n 5\r\ndecr n 100\r\ntouch n 60\r\ndelete n\r\ndelete n\r\n",
        );
        assert_eq!(
            String::from_utf8_lossy(&out),
            "STORED\r\n15\r\n0\r\nTOUCHED\r\nDELETED\r\nNOT_FOUND\r\n"
        );
    }

    #[test]
    fn cas_mismatch_reports_exists() {
        let mut c = conn();
        let out = run(&mut c, b"set k 0 0 1\r\nv\r\ngets k\r\n");
        let t = String::from_utf8_lossy(&out);
        let cas: u64 = t
            .split_whitespace()
            .nth(5) // VALUE k 0 1 <cas>
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        let bad = run(&mut c, format!("cas k 0 0 1 {}\r\nw\r\n", cas + 1).as_bytes());
        assert_eq!(String::from_utf8_lossy(&bad), "EXISTS\r\n");
        let good = run(&mut c, format!("cas k 0 0 1 {cas}\r\nw\r\n").as_bytes());
        assert_eq!(String::from_utf8_lossy(&good), "STORED\r\n");
    }

    #[test]
    fn stats_render() {
        let mut c = conn();
        let out = run(&mut c, b"set s 0 0 3\r\nabc\r\nstats\r\nstats slabs\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("STAT curr_items 1"));
        assert!(t.contains("chunk_size"));
    }

    #[test]
    fn quit_closes() {
        let mut c = conn();
        run(&mut c, b"quit\r\n");
        assert!(c.closing);
    }

    #[test]
    fn multi_get_missing_keys_skipped() {
        let mut c = conn();
        let out = run(&mut c, b"set a 0 0 1\r\nx\r\nget a missing b\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("VALUE a"));
        assert!(!t.contains("missing"));
    }

    #[test]
    fn binary_value_with_embedded_crlf() {
        let mut c = conn();
        let out = run(&mut c, b"set bin 0 0 6\r\nab\r\ncd\r\nget bin\r\n");
        let t = out.clone();
        assert!(String::from_utf8_lossy(&t).contains("VALUE bin 0 6"));
        assert!(t.windows(6).any(|w| w == b"ab\r\ncd"));
    }

    // ------------------------------------------------ meta dialect

    #[test]
    fn negative_exptime_is_dead_on_arrival() {
        // the parsed sentinel must be an absolute past time: the item
        // stores but can never be read back (memcached semantics)
        let mut c = conn();
        let out = run(
            &mut c,
            b"set k 0 0 1\r\nv\r\nset dead 0 -1 1\r\nw\r\nget dead\r\nget k\r\n",
        );
        assert_eq!(
            String::from_utf8_lossy(&out),
            "STORED\r\nSTORED\r\nEND\r\nVALUE k 0 1\r\nv\r\nEND\r\n"
        );
    }

    #[test]
    fn meta_set_get_roundtrip_with_flag_echo() {
        let mut c = conn();
        let out = run(&mut c, b"ms foo 5 F7 c k Oab\r\nhello\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("HD c"), "{t}");
        assert!(t.contains(" kfoo "), "{t}");
        assert!(t.trim_end().ends_with("Oab"), "{t}");
        let out = run(&mut c, b"mg foo v f c t s k Oxyz\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("VA 5 f7 c"), "{t}");
        assert!(t.contains(" t-1 "), "{t}");
        assert!(t.contains(" s5 "), "{t}");
        assert!(t.contains(" kfoo "), "{t}");
        assert!(t.contains(" Oxyz\r\nhello\r\n"), "{t}");
    }

    #[test]
    fn meta_flag_parse_echo_is_byte_exact() {
        let mut c = conn();
        run(&mut c, b"ms k 2 E42 T0\r\nhi\r\n");
        let out = run(&mut c, b"mg k v f c t s k\r\n");
        assert_eq!(
            String::from_utf8_lossy(&out),
            "VA 2 f0 c42 t-1 s2 kk\r\nhi\r\n"
        );
    }

    #[test]
    fn meta_la_hit_and_nobump_echoes() {
        let mut c = conn();
        run(&mut c, b"ms k 1\r\nx\r\n");
        // never fetched: h0; fresh: tiny l. `u` must not mark it fetched
        let out = run(&mut c, b"mg k v l h u\r\n");
        let t = String::from_utf8_lossy(&out).to_string();
        assert!(t.starts_with("VA 1 l"), "{t}");
        assert!(t.contains(" h0\r\n"), "{t}");
        let la: u64 = t.split(" l").nth(1).unwrap().split(' ').next().unwrap().parse().unwrap();
        assert!(la <= 2, "fresh item, la {la}");
        let out = run(&mut c, b"mg k v h u\r\n");
        assert!(
            String::from_utf8_lossy(&out).contains(" h0"),
            "u reads never mark fetched"
        );
        // a bumping h read reports the pre-state, then marks the item
        let out = run(&mut c, b"mg k v h\r\n");
        assert!(String::from_utf8_lossy(&out).contains(" h0"));
        let out = run(&mut c, b"mg k v h\r\n");
        assert!(String::from_utf8_lossy(&out).contains(" h1"));
        // canonical echo order: t, then l, then h, then s
        let out = run(&mut c, b"mg k t l h s\r\n");
        let t = String::from_utf8_lossy(&out).to_string();
        let pos = |needle: &str| t.find(needle).unwrap_or_else(|| panic!("{needle} in {t}"));
        assert!(pos(" t") < pos(" l") && pos(" l") < pos(" h1") && pos(" h1") < pos(" s1"), "{t}");
        // the mg-only flags are rejected on other verbs
        let out = run(&mut c, b"md k l\r\n");
        assert!(String::from_utf8_lossy(&out).starts_with("CLIENT_ERROR"));
    }

    #[test]
    fn meta_get_miss_and_quiet() {
        let mut c = conn();
        let out = run(&mut c, b"mg nope v\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "EN\r\n");
        // q suppresses the miss; mn flushes the barrier
        let out = run(&mut c, b"mg nope v q\r\nmg also v q\r\nmn\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "MN\r\n");
        // q does not suppress hits
        run(&mut c, b"ms hit 1\r\nx\r\n");
        let out = run(&mut c, b"mg hit v q\r\nmn\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "VA 1\r\nx\r\nMN\r\n");
    }

    #[test]
    fn meta_set_modes_and_quiet() {
        let mut c = conn();
        // quiet success suppressed
        let out = run(&mut c, b"ms q1 1 q\r\nx\r\n");
        assert!(out.is_empty(), "{:?}", String::from_utf8_lossy(&out));
        // add-on-present fails loudly even with q
        let out = run(&mut c, b"ms q1 1 ME q\r\ny\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "NS\r\n");
        // append via meta mode
        let out = run(&mut c, b"ms q1 2 MA\r\n-z\r\nmg q1 v\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "HD\r\nVA 3\r\nx-z\r\n");
        // replace-on-absent
        let out = run(&mut c, b"ms none 1 MR\r\nw\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "NS\r\n");
    }

    #[test]
    fn meta_cas_guards() {
        let mut c = conn();
        let out = run(&mut c, b"ms k 1 c\r\nv\r\n");
        let t = String::from_utf8_lossy(&out);
        let cas: u64 = t.trim().strip_prefix("HD c").unwrap().parse().unwrap();
        // ms with wrong CAS -> EX, right CAS -> HD
        let out = run(&mut c, format!("ms k 1 C{}\r\nw\r\n", cas + 1).as_bytes());
        assert_eq!(String::from_utf8_lossy(&out), "EX\r\n");
        let out = run(&mut c, format!("ms k 1 C{cas}\r\nw\r\n").as_bytes());
        assert_eq!(String::from_utf8_lossy(&out), "HD\r\n");
        // md with wrong CAS -> EX (item survives), then right CAS deletes
        let out = run(&mut c, b"mg k c\r\n");
        let t = String::from_utf8_lossy(&out);
        let cas: u64 = t.trim().strip_prefix("HD c").unwrap().parse().unwrap();
        let out = run(&mut c, format!("md k C{}\r\n", cas + 1).as_bytes());
        assert_eq!(String::from_utf8_lossy(&out), "EX\r\n");
        let out = run(&mut c, format!("md k C{cas}\r\nmd k\r\n").as_bytes());
        assert_eq!(String::from_utf8_lossy(&out), "HD\r\nNF\r\n");
    }

    #[test]
    fn meta_arith_flows() {
        let mut c = conn();
        run(&mut c, b"ms n 2\r\n10\r\n");
        let out = run(&mut c, b"ma n\r\nma n D5 v\r\nma n MD D3 v\r\nma missing\r\n");
        assert_eq!(
            String::from_utf8_lossy(&out),
            "HD\r\nVA 2\r\n16\r\nVA 2\r\n13\r\nNF\r\n"
        );
        // vivify with initial value
        let out = run(&mut c, b"ma fresh N60 J9 v t\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("VA 1 t"), "{t}");
        assert!(t.ends_with("\r\n9\r\n"), "{t}");
        // non-numeric -> CLIENT_ERROR
        run(&mut c, b"ms txt 3\r\nabc\r\n");
        let out = run(&mut c, b"ma txt\r\n");
        assert!(String::from_utf8_lossy(&out).starts_with("CLIENT_ERROR"));
    }

    #[test]
    fn meta_vivify_on_get() {
        let mut c = conn();
        let out = run(&mut c, b"mg viv N60 v t\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("VA 0 t"), "{t}");
        assert!(t.trim_end().ends_with(" W"), "winner flag: {t}");
        // classic dialect sees the vivified (empty) item
        let out = run(&mut c, b"get viv\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "VALUE viv 0 0\r\n\r\nEND\r\n");
        // second mg is a plain hit, no W
        let out = run(&mut c, b"mg viv v\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "VA 0\r\n\r\n");
    }

    #[test]
    fn meta_base64_keys_interoperate_with_classic() {
        let mut c = conn();
        // b64("foo") = "Zm9v": store via meta with b, read via classic
        let out = run(&mut c, b"ms Zm9v 3 b k\r\nabc\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("HD kZm9v"), "k echo stays encoded: {t}");
        let out = run(&mut c, b"get foo\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "VALUE foo 0 3\r\nabc\r\nEND\r\n");
        // and the reverse: classic store, meta b64 read
        run(&mut c, b"set bar 0 0 2\r\nhi\r\n");
        let out = run(&mut c, b"mg YmFy v b k\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "VA 2 kYmFy\r\nhi\r\n");
        // invalid base64 is a client error, stream stays in sync
        let out = run(&mut c, b"mg !!! b\r\nversion\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("CLIENT_ERROR bad base64 key\r\nVERSION"), "{t}");
    }

    #[test]
    fn meta_bad_b64_storage_discards_data_block() {
        // a rejected base64 key on ms must still swallow the announced
        // data block — its payload must not execute as commands
        let mut c = conn();
        run(&mut c, b"set keep 0 0 1\r\nv\r\n");
        let out = run(&mut c, b"ms !bad! 11 b\r\nflush_all\r\n\r\nversion\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("CLIENT_ERROR bad base64 key\r\nVERSION"), "{t}");
        assert!(!t.contains("OK"), "smuggled flush_all must not run: {t}");
        let out = run(&mut c, b"get keep\r\n");
        assert!(
            String::from_utf8_lossy(&out).contains("VALUE keep"),
            "store must be untouched"
        );
    }

    #[test]
    fn meta_binary_keys_via_b64() {
        // base64 keys may decode to bytes illegal in the text protocol
        // (here: an embedded space); they are first-class items
        let mut c = conn();
        // b64("a b") = "YSBi"
        let out = run(&mut c, b"ms YSBi 3 b c\r\nbin\r\n");
        assert!(String::from_utf8_lossy(&out).starts_with("HD c"));
        let out = run(&mut c, b"mg YSBi v k b\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "VA 3 kYSBi\r\nbin\r\n");
        // vivify works for binary keys too (b64("x\ty") = "eAl5")
        let out = run(&mut c, b"mg eAl5 v b N60\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("VA 0") && t.contains(" W"), "{t}");
        // and delete addresses the same binary key
        let out = run(&mut c, b"md YSBi b\r\nmg YSBi v b\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "HD\r\nEN\r\n");
    }

    #[test]
    fn meta_debug_dumps_item_bookkeeping() {
        let mut c = conn();
        run(&mut c, b"set foo 7 0 5\r\nhello\r\n");
        let out = run(&mut c, b"me foo\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("ME foo "), "{t}");
        assert!(t.contains("exp=-1"), "{t}");
        assert!(t.contains("la=0"), "{t}");
        assert!(t.contains("cas="), "{t}");
        assert!(t.contains("fetch=0"), "{t}");
        assert!(t.contains("cls="), "{t}");
        assert!(t.contains("tier=hot"), "{t}");
        assert!(t.contains("size=5"), "{t}");
        // a write-path fetch flips the bit the dump reports; the dump
        // itself is side-effect free (fetch stays as the get left it)
        run(&mut c, b"mg foo v h\r\n");
        let out = run(&mut c, b"me foo\r\nme foo\r\n");
        let t = String::from_utf8_lossy(&out);
        assert_eq!(t.matches("fetch=1").count(), 2, "{t}");
        // miss answers EN; b addresses base64 keys (b64("foo")="Zm9v")
        let out = run(&mut c, b"me nope\r\nme Zm9v b\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("EN\r\nME Zm9v "), "{t}");
        // echo flags are rejected loudly
        let out = run(&mut c, b"me foo v\r\n");
        assert!(String::from_utf8_lossy(&out).starts_with("CLIENT_ERROR"), "{out:?}");
    }

    #[test]
    fn meta_touch_on_read_updates_ttl() {
        use std::sync::atomic::Ordering;
        let (clock, cell) = Clock::manual(2_000_000);
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                2,
                clock,
            )
            .unwrap(),
        );
        let mut c = Conn::new(store, Arc::new(NoControl));
        run(&mut c, b"ms k 1 T50\r\nv\r\n");
        let out = run(&mut c, b"mg k t T500\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "HD t500\r\n");
        // past the original expiry the touched item still serves
        cell.store(2_000_100, Ordering::Relaxed);
        let out = run(&mut c, b"mg k t\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "HD t400\r\n");
    }

    #[test]
    fn meta_data_block_phase_and_errors() {
        let mut c = conn();
        // fragmented ms data block reassembles
        let mut out = Vec::new();
        for chunk in [&b"ms fr"[..], b"ag 4 c", b"\r\nda", b"ta\r", b"\nmg frag v\r\n"] {
            c.on_bytes(chunk, &mut out);
        }
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("HD c"), "{t}");
        assert!(t.ends_with("VA 4\r\ndata\r\n"), "{t}");
        // bad data tail flagged like classic
        let out = run(&mut c, b"ms k 2\r\nabXXmn\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("CLIENT_ERROR bad data chunk"), "{t}");
        assert!(t.ends_with("MN\r\n"), "stream resyncs: {t}");
        // oversized ms rejected and discarded
        let len = MAX_DATA + 1;
        let mut out = Vec::new();
        c.on_bytes(format!("ms huge {len}\r\n").as_bytes(), &mut out);
        assert!(String::from_utf8_lossy(&out).contains("SERVER_ERROR object too large"));
    }

    #[test]
    fn meta_parse_errors_recover() {
        let mut c = conn();
        let out = run(&mut c, b"mg\r\nms k\r\nmg k Z\r\nmz k\r\nmn\r\n");
        let t = String::from_utf8_lossy(&out);
        assert_eq!(t.matches("CLIENT_ERROR").count(), 3, "{t}");
        assert!(t.contains("ERROR\r\n"), "unknown meta verb: {t}");
        assert!(t.ends_with("MN\r\n"), "{t}");
    }

    #[test]
    fn classic_gat_touches_and_serves() {
        use std::sync::atomic::Ordering;
        let (clock, cell) = Clock::manual(3_000_000);
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                2,
                clock,
            )
            .unwrap(),
        );
        let mut c = Conn::new(store, Arc::new(NoControl));
        run(&mut c, b"set a 1 50 1\r\nx\r\nset b 2 50 1\r\ny\r\n");
        let out = run(&mut c, b"gat 500 a b missing\r\n");
        assert_eq!(
            String::from_utf8_lossy(&out),
            "VALUE a 1 1\r\nx\r\nVALUE b 2 1\r\ny\r\nEND\r\n"
        );
        // both TTLs were refreshed: alive past the original expiry
        cell.store(3_000_100, Ordering::Relaxed);
        let out = run(&mut c, b"get a b\r\n");
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("VALUE a") && t.contains("VALUE b"), "{t}");
        // gats returns the cas like gets
        let out = run(&mut c, b"gats 500 a\r\n");
        let t = String::from_utf8_lossy(&out);
        let ncols = t.lines().next().unwrap().split_whitespace().count();
        assert_eq!(ncols, 5, "VALUE key flags len cas: {t}");
    }

    #[test]
    fn stats_reset_zeroes_counters() {
        let mut c = conn();
        run(&mut c, b"set k 0 0 1\r\nv\r\nget k\r\nget missing\r\n");
        let before = String::from_utf8_lossy(&run(&mut c, b"stats\r\n")).to_string();
        assert!(before.contains("STAT cmd_get 2"), "{before}");
        let out = run(&mut c, b"stats reset\r\n");
        assert_eq!(String::from_utf8_lossy(&out), "RESET\r\n");
        let after = String::from_utf8_lossy(&run(&mut c, b"stats\r\n")).to_string();
        assert!(after.contains("STAT cmd_get 0"), "{after}");
        assert!(after.contains("STAT cmd_set 0"), "{after}");
        assert!(after.contains("STAT curr_items 1"), "gauge survives: {after}");
    }

    // ------------------------------------------------ hot-path refits

    /// Extract the keys of VALUE lines in on-the-wire order.
    fn value_keys(out: &[u8]) -> Vec<String> {
        String::from_utf8_lossy(out)
            .lines()
            .filter_map(|l| l.strip_prefix("VALUE ").map(|r| {
                r.split(' ').next().unwrap().to_string()
            }))
            .collect()
    }

    #[test]
    fn multiget_preserves_request_order_across_shards() {
        let mut c = conn_sharded(8);
        let mut setup = Vec::new();
        for i in 0..12 {
            setup.extend_from_slice(format!("set mk{i:02} 0 0 1\r\nx\r\n").as_bytes());
        }
        run(&mut c, &setup);
        let out = run(
            &mut c,
            b"get mk11 mk03 mk07 mk00 mk09 mk05 mk01 mk10 mk02 mk08 mk04 mk06\r\n",
        );
        assert_eq!(
            value_keys(&out),
            vec![
                "mk11", "mk03", "mk07", "mk00", "mk09", "mk05", "mk01", "mk10", "mk02",
                "mk08", "mk04", "mk06"
            ]
        );
        assert!(String::from_utf8_lossy(&out).ends_with("END\r\n"));
    }

    #[test]
    fn multiget_beyond_inline_key_table() {
        let mut c = conn_sharded(4);
        let n = INLINE_KEYS + 9; // force the heap fallback
        let mut setup = Vec::new();
        for i in 0..n {
            setup.extend_from_slice(format!("set big{i:02} 0 0 2\r\nvv\r\n").as_bytes());
        }
        run(&mut c, &setup);
        let keys: Vec<String> = (0..n).map(|i| format!("big{i:02}")).collect();
        let line = format!("get {}\r\n", keys.join(" "));
        let out = run(&mut c, line.as_bytes());
        assert_eq!(value_keys(&out), keys);
    }

    #[test]
    fn oversized_data_block_discarded_and_stream_resyncs() {
        let mut c = conn();
        let len = MAX_DATA + 1;
        let mut out = Vec::new();
        c.on_bytes(format!("set huge 0 0 {len}\r\n").as_bytes(), &mut out);
        assert!(
            String::from_utf8_lossy(&out).contains("SERVER_ERROR object too large"),
            "{}",
            String::from_utf8_lossy(&out)
        );
        assert!(!c.closing, "connection must stay up");
        // stream the oversized block in chunks; no extra output, no
        // buffering of the block (the discard consumes as bytes land)
        let chunk = vec![b'x'; 64 * 1024];
        let mut sent = 0;
        while sent + chunk.len() <= len {
            let done = c.on_bytes(&chunk, &mut out);
            assert_eq!(done, 0);
            sent += chunk.len();
        }
        let mut tail = vec![b'x'; len - sent];
        tail.extend_from_slice(b"\r\n");
        c.on_bytes(&tail, &mut out);
        // back in sync: the next command parses and executes
        let done = c.on_bytes(b"set ok 0 0 2\r\nhi\r\nget ok\r\n", &mut out);
        assert_eq!(done, 2);
        let t = String::from_utf8_lossy(&out);
        assert!(t.ends_with("STORED\r\nVALUE ok 0 2\r\nhi\r\nEND\r\n"), "{t}");
        assert_eq!(t.matches("SERVER_ERROR").count(), 1);
    }

    #[test]
    fn oversized_discard_interleaved_with_next_command_in_one_read() {
        let mut c = conn();
        let len = MAX_DATA + 100;
        let mut payload = format!("set huge 0 0 {len}\r\n").into_bytes();
        payload.extend(std::iter::repeat(b'y').take(len));
        payload.extend_from_slice(b"\r\nversion\r\n");
        let out = run(&mut c, &payload);
        let t = String::from_utf8_lossy(&out);
        assert!(t.starts_with("SERVER_ERROR"), "{t}");
        assert!(t.contains("VERSION"), "discard must resync mid-read: {t}");
    }

    #[test]
    fn absurd_nbytes_cannot_smuggle_commands() {
        // nbytes near usize::MAX must not wrap the discard length and
        // let the "data" bytes execute as protocol commands
        let mut c = conn();
        let mut out = Vec::new();
        c.on_bytes(format!("set k 0 0 {}\r\n", usize::MAX).as_bytes(), &mut out);
        assert!(String::from_utf8_lossy(&out).contains("SERVER_ERROR"));
        let done = c.on_bytes(b"get k\r\nversion\r\nquit\r\n", &mut out);
        assert_eq!(done, 0, "payload bytes must be swallowed, not parsed");
        assert!(!c.closing, "smuggled quit must not execute");
    }

    #[test]
    fn multiget_order_preserved_with_stale_items() {
        use std::sync::atomic::Ordering;
        let (clock, cell) = Clock::manual(9_000_000);
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                4,
                clock,
            )
            .unwrap(),
        );
        let mut c = Conn::new(store, Arc::new(NoControl));
        let mut setup = Vec::new();
        for i in 0..8 {
            setup.extend_from_slice(format!("set sk{i} 0 0 1\r\nx\r\n").as_bytes());
        }
        run(&mut c, &setup);
        // age every item past TOUCH_INTERVAL: the whole batch takes the
        // write-retry path, whose hits arrive after read-path hits —
        // the span sort must still restore request order on the wire
        cell.store(9_000_000 + 120, Ordering::Relaxed);
        let out = run(&mut c, b"get sk7 sk2 sk5 sk0 sk6 sk1 sk4 sk3\r\n");
        assert_eq!(
            value_keys(&out),
            vec!["sk7", "sk2", "sk5", "sk0", "sk6", "sk1", "sk4", "sk3"]
        );
    }

    #[test]
    fn byte_at_a_time_equals_single_read() {
        let script: &[u8] =
            b"set a 0 0 3\r\nfoo\r\nget a\r\nincr a 1\r\nset n 0 0 1\r\n7\r\nincr n 3\r\nget a n\r\ndelete a\r\nbogus\r\nget a\r\nversion\r\n";

        let mut whole = conn();
        let mut out_whole = Vec::new();
        let done_whole = whole.on_bytes(script, &mut out_whole);

        let mut bytewise = conn();
        let mut out_bytes = Vec::new();
        let mut done_bytes = 0;
        for &b in script {
            done_bytes += bytewise.on_bytes(&[b], &mut out_bytes);
        }

        assert_eq!(done_whole, done_bytes);
        // VERSION carries the crate version in both, so full equality
        // is well-defined
        assert_eq!(
            String::from_utf8_lossy(&out_whole),
            String::from_utf8_lossy(&out_bytes)
        );
        assert!(String::from_utf8_lossy(&out_whole).contains("CLIENT_ERROR"));
    }

    #[test]
    fn pipelined_burst_counts_every_command() {
        let mut c = conn();
        let mut batch = Vec::new();
        let n = 200;
        for i in 0..n {
            batch.extend_from_slice(format!("set p{i:03} 0 0 4\r\nabcd\r\n").as_bytes());
        }
        for i in 0..n {
            batch.extend_from_slice(format!("get p{i:03}\r\n").as_bytes());
        }
        let mut out = Vec::new();
        let done = c.on_bytes(&batch, &mut out);
        assert_eq!(done, 2 * n);
        let t = String::from_utf8_lossy(&out);
        assert_eq!(t.matches("STORED").count(), n);
        assert_eq!(t.matches("VALUE ").count(), n);
    }

    #[test]
    fn recv_buf_cursor_and_compaction() {
        let mut rb = RecvBuf::new();
        rb.extend(b"hello world");
        assert_eq!(rb.filled(), b"hello world");
        rb.consume(6);
        assert_eq!(rb.filled(), b"world");
        // extend compacts: the consumed prefix is dropped
        rb.extend(b"!");
        assert_eq!(rb.filled(), b"world!");
        assert_eq!(rb.pos, 0);
        // consuming everything resets cheaply
        rb.consume(6);
        assert_eq!(rb.len(), 0);
        assert_eq!(rb.buf.len(), 0);
    }

    #[test]
    fn find_crlf_skips_bare_newlines() {
        assert_eq!(find_crlf(b"abc\r\ndef"), Some(3));
        assert_eq!(find_crlf(b"ab\ncd\r\n"), Some(5));
        assert_eq!(find_crlf(b"\r\n"), Some(0));
        assert_eq!(find_crlf(b"no newline"), None);
        assert_eq!(find_crlf(b"\n\n\n"), None);
    }

    #[test]
    fn out_buf_cursor_flush() {
        let mut ob = OutBuf::new();
        ob.buf_mut().extend_from_slice(b"hello world");
        assert_eq!(ob.pending(), b"hello world");
        ob.consume(6);
        assert_eq!(ob.pending(), b"world");
        assert_eq!(ob.len(), 5);
        ob.consume(5);
        assert!(ob.is_empty());
        assert_eq!(ob.pending(), b"");
    }

    // ------------------------------------------------ driven connection

    /// Scripted nonblocking transport: queued input chunks, a per-call
    /// write cap (0 = `WouldBlock`), and syscall counters so tests can
    /// assert the drive loop never busy-spins.
    struct ScriptIo {
        input: std::collections::VecDeque<Vec<u8>>,
        eof: bool,
        write_cap: usize,
        written: Vec<u8>,
        reads: usize,
        writes: usize,
    }

    impl ScriptIo {
        fn new(write_cap: usize) -> ScriptIo {
            ScriptIo {
                input: Default::default(),
                eof: false,
                write_cap,
                written: Vec::new(),
                reads: 0,
                writes: 0,
            }
        }

        fn push(&mut self, chunk: &[u8]) {
            self.input.push_back(chunk.to_vec());
        }
    }

    impl std::io::Read for ScriptIo {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.reads += 1;
            match self.input.pop_front() {
                Some(chunk) => {
                    assert!(chunk.len() <= buf.len(), "script chunk exceeds read buffer");
                    buf[..chunk.len()].copy_from_slice(&chunk);
                    Ok(chunk.len())
                }
                None if self.eof => Ok(0),
                None => Err(std::io::ErrorKind::WouldBlock.into()),
            }
        }
    }

    impl std::io::Write for ScriptIo {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.writes += 1;
            if self.write_cap == 0 {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            let n = buf.len().min(self.write_cap);
            self.written.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn driven(write_cap: usize) -> (DrivenConn<ScriptIo>, Arc<Metrics>) {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let metrics = Arc::new(Metrics::new());
        let conn = Conn::with_metrics(store, Arc::new(NoControl), metrics.clone());
        (DrivenConn::new(ScriptIo::new(write_cap), conn), metrics)
    }

    /// Reference output: the same script through the plain buffer path.
    fn reference_output(script: &[u8]) -> Vec<u8> {
        let mut c = conn();
        let mut out = Vec::new();
        c.on_bytes(script, &mut out);
        out
    }

    #[test]
    fn drive_completes_simple_exchange() {
        let (mut dc, m) = driven(usize::MAX);
        dc.io.push(b"set a 0 0 5\r\nhello\r\nget a\r\n");
        let st = dc.drive(true, true, &m);
        assert_eq!(st, ConnState::Open { wants_write: false });
        assert!(!dc.has_pending_out());
        assert!(!dc.wants_redrive());
        assert_eq!(
            String::from_utf8_lossy(&dc.io.written),
            "STORED\r\nVALUE a 0 5\r\nhello\r\nEND\r\n"
        );
    }

    #[test]
    fn drive_blocked_write_requests_epollout_then_drains() {
        let (mut dc, m) = driven(0); // socket accepts nothing
        dc.io.push(b"set a 0 0 5\r\nhello\r\nget a\r\n");
        let st = dc.drive(true, true, &m);
        assert_eq!(st, ConnState::Open { wants_write: true });
        assert!(dc.has_pending_out());
        // EPOLLOUT arrives, socket opens up
        dc.io.write_cap = 7; // dribble the flush: several short writes
        let st = dc.drive(false, true, &m);
        assert_eq!(st, ConnState::Open { wants_write: false });
        assert_eq!(
            dc.io.written,
            reference_output(b"set a 0 0 5\r\nhello\r\nget a\r\n")
        );
    }

    #[test]
    fn drive_quit_flushes_then_closes() {
        let (mut dc, m) = driven(usize::MAX);
        dc.io.push(b"version\r\nquit\r\n");
        let st = dc.drive(true, true, &m);
        assert_eq!(st, ConnState::Closed);
        assert!(String::from_utf8_lossy(&dc.io.written).starts_with("VERSION"));
    }

    #[test]
    fn drive_peer_close_flushes_then_closes() {
        let (mut dc, m) = driven(usize::MAX);
        dc.io.push(b"set k 0 0 1\r\nx\r\n");
        dc.io.eof = true;
        let st = dc.drive(true, true, &m);
        assert_eq!(st, ConnState::Closed);
        assert_eq!(String::from_utf8_lossy(&dc.io.written), "STORED\r\n");
    }

    #[test]
    fn drive_read_budget_yields_without_losing_input() {
        let (mut dc, m) = driven(usize::MAX);
        let n = DRIVE_READ_BUDGET + 8;
        for _ in 0..n {
            dc.io.push(b"version\r\n");
        }
        let st = dc.drive(true, true, &m);
        assert_eq!(st, ConnState::Open { wants_write: false });
        assert!(dc.wants_redrive(), "budget yield must request a re-drive");
        assert!(dc.io.reads <= DRIVE_READ_BUDGET);
        assert!(m.snapshot().conn_yields >= 1);
        // reactor re-drives with no new readiness events
        let st = dc.drive(false, false, &m);
        assert_eq!(st, ConnState::Open { wants_write: false });
        assert!(!dc.wants_redrive());
        let t = String::from_utf8_lossy(&dc.io.written);
        assert_eq!(t.matches("VERSION").count(), n);
    }

    #[test]
    fn drive_idle_performs_no_syscalls() {
        let (mut dc, m) = driven(usize::MAX);
        dc.io.push(b"get nope\r\n");
        dc.drive(true, true, &m);
        let (r, w) = (dc.io.reads, dc.io.writes);
        // no readiness edges, nothing buffered: drive must not touch
        // the socket at all (busy-spin guard)
        let st = dc.drive(false, false, &m);
        assert_eq!(st, ConnState::Open { wants_write: false });
        assert_eq!((dc.io.reads, dc.io.writes), (r, w));
    }

    #[test]
    fn drive_backpressure_bounds_output_and_resumes_in_order() {
        let (mut dc, m) = driven(0); // reader stalled: nothing flushes
        // one 1 KiB value, then a pipelined burst of gets whose
        // responses far exceed the high-water mark
        let mut script = Vec::new();
        script.extend_from_slice(format!("set k 0 0 1024\r\n{}\r\n", "x".repeat(1024)).as_bytes());
        let n_gets = 700; // ~700 KiB of responses > OUT_HIGH_WATER
        for _ in 0..n_gets {
            script.extend_from_slice(b"get k\r\n");
        }
        for chunk in script.chunks(8 * 1024) {
            dc.io.push(chunk);
        }
        let st = dc.drive(true, true, &m);
        assert_eq!(st, ConnState::Open { wants_write: true });
        // bounded: high-water plus at most one response of overshoot
        assert!(
            dc.out.len() <= OUT_HIGH_WATER + 2048,
            "output buffer ballooned to {}",
            dc.out.len()
        );
        assert!(m.snapshot().conn_yields >= 1);
        // the client finally reads: everything drains, byte-identical
        // to the unbounded reference, in order
        dc.io.write_cap = usize::MAX;
        for _ in 0..64 {
            let st = dc.drive(false, true, &m);
            if matches!(st, ConnState::Open { wants_write: false }) && !dc.wants_redrive() {
                break;
            }
        }
        assert!(!dc.has_pending_out());
        assert!(!dc.wants_redrive());
        assert_eq!(dc.io.written, reference_output(&script));
    }
}
