//! A strict TOML-subset reader for `slabforge.toml`.
//!
//! Supported grammar (everything the config needs, nothing more):
//! `[section]` and `[section.sub]` headers; `key = value` with string,
//! integer (decimal, `_` separators, `0x`), float, boolean, and
//! homogeneous arrays of those; `#` comments; blank lines. Keys are
//! flattened to `section.sub.key` paths.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Integer(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        match self {
            TomlValue::Array(xs) => xs.iter().map(TomlValue::as_usize).collect(),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// A flat `section.key -> value` document.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = lineno + 1;
            let stripped = strip_comment(raw).trim();
            if stripped.is_empty() {
                continue;
            }
            if let Some(rest) = stripped.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(TomlError {
                    line,
                    message: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty()
                    || !name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
                {
                    return Err(TomlError {
                        line,
                        message: format!("bad section name '{name}'"),
                    });
                }
                section = name.to_string();
                continue;
            }
            let (key, value_text) = stripped.split_once('=').ok_or(TomlError {
                line,
                message: "expected 'key = value'".into(),
            })?;
            let key = key.trim();
            if key.is_empty()
                || !key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(TomlError {
                    line,
                    message: format!("bad key '{key}'"),
                });
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(value_text.trim(), line)?;
            if doc.values.insert(full_key.clone(), value).is_some() {
                return Err(TomlError {
                    line,
                    message: format!("duplicate key '{full_key}'"),
                });
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<TomlValue, TomlError> {
    let err = |m: String| TomlError { line, message: m };
    if text.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        if inner.contains('"') {
            return Err(err("embedded quote in string".into()));
        }
        return Ok(TomlValue::String(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items: Result<Vec<TomlValue>, TomlError> = split_array_items(inner)
            .into_iter()
            .map(|item| parse_value(item.trim(), line))
            .collect();
        return Ok(TomlValue::Array(items?));
    }
    let clean = text.replace('_', "");
    if let Some(hex) = clean.strip_prefix("0x") {
        return i64::from_str_radix(hex, 16)
            .map(TomlValue::Integer)
            .map_err(|_| err(format!("bad hex integer '{text}'")));
    }
    if clean.contains('.') || clean.contains('e') || clean.contains('E') {
        return clean
            .parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| err(format!("bad float '{text}'")));
    }
    clean
        .parse::<i64>()
        .map(TomlValue::Integer)
        .map_err(|_| err(format!("bad value '{text}'")))
}

/// Split a flat (non-nested) array body on commas, respecting strings.
fn split_array_items(inner: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    let last = inner[start..].trim();
    if !last.is_empty() {
        items.push(&inner[start..]);
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = TomlDoc::parse(
            r#"
# top comment
listen = "127.0.0.1:11211"   # inline comment
threads = 4

[memory]
limit = 67_108_864
page_size = 0x100000
growth_factor = 1.25
use_cas = true

[optimizer]
enabled = false
slab_sizes = [304, 384, 480]
names = ["a", "b"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("listen").unwrap().as_str(), Some("127.0.0.1:11211"));
        assert_eq!(doc.get("threads").unwrap().as_i64(), Some(4));
        assert_eq!(doc.get("memory.limit").unwrap().as_usize(), Some(67_108_864));
        assert_eq!(doc.get("memory.page_size").unwrap().as_usize(), Some(1 << 20));
        assert_eq!(doc.get("memory.growth_factor").unwrap().as_f64(), Some(1.25));
        assert_eq!(doc.get("memory.use_cas").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("optimizer.enabled").unwrap().as_bool(), Some(false));
        assert_eq!(
            doc.get("optimizer.slab_sizes").unwrap().as_usize_vec(),
            Some(vec![304, 384, 480])
        );
        assert!(doc.get("missing").is_none());
    }

    #[test]
    fn nested_sections() {
        let doc = TomlDoc::parse("[a.b]\nc = 1\n").unwrap();
        assert_eq!(doc.get("a.b.c").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn int_as_float_coercion() {
        let doc = TomlDoc::parse("x = 2\n").unwrap();
        assert_eq!(doc.get("x").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = TomlDoc::parse("x = \"open\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(TomlDoc::parse("a = 1\na = 2\n").is_err());
        // same key in different sections is fine
        assert!(TomlDoc::parse("[s]\na = 1\n[t]\na = 2\n").is_ok());
    }

    #[test]
    fn negative_and_empty_arrays() {
        let doc = TomlDoc::parse("x = -5\ny = []\n").unwrap();
        assert_eq!(doc.get("x").unwrap().as_i64(), Some(-5));
        assert_eq!(doc.get("y").unwrap(), &TomlValue::Array(vec![]));
        // negative can't be usize
        assert_eq!(doc.get("x").unwrap().as_usize(), None);
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = TomlDoc::parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("x").unwrap().as_str(), Some("a#b"));
    }
}
