//! Configuration: a TOML-subset parser (serde/toml are not vendored in
//! this offline image — DESIGN.md §3), typed [`settings::Settings`],
//! and the CLI argument layer used by the `slabforge` launcher.

pub mod cli;
pub mod settings;
pub mod toml;

pub use settings::Settings;
