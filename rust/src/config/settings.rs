//! Typed runtime settings, loadable from `slabforge.toml` and
//! overridable from the CLI (`config::cli`).

use super::toml::{TomlDoc, TomlError};
use crate::slab::policy::ChunkSizePolicy;
use crate::slab::PAGE_SIZE;
use crate::store::maintainer::{DEFAULT_MAINTAINER_BATCH, DEFAULT_MAINTAINER_INTERVAL_MS};
use crate::store::migrate::DEFAULT_MIGRATE_BATCH;
use std::fmt;

/// Which optimization algorithm the auto-tuner runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's Algorithm 1: random ±1-byte moves, stop after 1000
    /// consecutive non-improving tries.
    PaperHillClimb,
    /// Batched steepest descent with shrinking step sizes (one fused
    /// PJRT call per step when the XLA backend is active).
    SteepestDescent,
    /// Exact optimum via divide-and-conquer DP (baseline/bound).
    DpOptimal,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "paper" | "hillclimb" => Some(Algorithm::PaperHillClimb),
            "steepest" => Some(Algorithm::SteepestDescent),
            "dp" | "optimal" => Some(Algorithm::DpOptimal),
            _ => None,
        }
    }
}

/// Which waste-evaluation backend scores candidate configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust exact evaluator.
    Rust,
    /// AOT XLA artifacts over PJRT (`artifacts/*.hlo.txt`).
    Xla,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "rust" => Some(Backend::Rust),
            "xla" | "pjrt" => Some(Backend::Xla),
            _ => None,
        }
    }
}

/// Auto-tuner settings (the paper's optimizer, run online).
#[derive(Clone, Debug)]
pub struct OptimizerSettings {
    pub enabled: bool,
    /// Seconds between retune evaluations.
    pub interval_secs: u64,
    /// Minimum sets observed before the first retune.
    pub min_samples: u64,
    /// Retune when predicted savings exceed this fraction of holes.
    pub min_improvement: f64,
    pub algorithm: Algorithm,
    pub backend: Backend,
    /// Directory holding `manifest.json` + HLO artifacts.
    pub artifacts_dir: String,
    pub seed: u64,
}

impl Default for OptimizerSettings {
    fn default() -> Self {
        OptimizerSettings {
            enabled: false,
            interval_secs: 60,
            min_samples: 10_000,
            min_improvement: 0.05,
            algorithm: Algorithm::SteepestDescent,
            backend: Backend::Rust,
            artifacts_dir: "artifacts".to_string(),
            seed: 0x51ab_f00d,
        }
    }
}

/// Complete server settings.
#[derive(Clone, Debug)]
pub struct Settings {
    /// TCP listen address (`host:port`).
    pub listen: String,
    /// Reactor (event-loop) threads in event mode; worker threads in
    /// legacy threaded mode.
    pub threads: usize,
    /// Event-driven epoll reactor (default) vs. legacy
    /// thread-per-connection.
    pub event_loop: bool,
    /// Cap on live connections; accepts beyond it are rejected
    /// (memcached `-c`).
    pub max_conns: usize,
    /// Close connections idle longer than this many seconds; 0 = never
    /// (memcached `-o idle_timeout`).
    pub idle_timeout_secs: u64,
    /// Per-reactor `SO_REUSEPORT` listeners (kernel-parallel accept) in
    /// event mode; off = single shared listener (`--no-reuseport`).
    pub reuseport: bool,
    /// UDP front-end on the same port (memcached 8-byte frame header;
    /// `--udp`).
    pub udp: bool,
    /// Pin each reactor thread to one CPU core (`--pin-cores`).
    pub pin_cores: bool,
    /// Store shards (each shard = one mutex + one allocator).
    pub shards: usize,
    /// Total cache memory across shards, bytes.
    pub mem_limit: usize,
    pub page_size: usize,
    pub use_cas: bool,
    /// Items an incremental slab migration moves per step while holding
    /// a shard's write lock — the bounded-pause knob for live
    /// reconfiguration (`slabs reconfigure` / the auto-tuner).
    pub migrate_batch: usize,
    /// Background maintenance thread (LRU demotion, migration pumping,
    /// post-drain slack shedding) — `memory.maintainer` / `--maintainer`.
    pub maintainer: bool,
    /// Milliseconds between maintenance passes
    /// (`memory.maintainer_interval_ms`).
    pub maintainer_interval_ms: u64,
    /// Max LRU demotions per shard per pass — the maintainer's
    /// write-lock lease bound (`memory.maintainer_batch`).
    pub maintainer_batch: usize,
    /// Global connection-buffer byte budget; over it, stalled
    /// connections are shed and accepting pauses. 0 = unlimited
    /// (`memory.conn_buffer_budget` / `--conn-buffer-budget`).
    pub conn_buffer_budget: usize,
    /// Path of the mmap-backed slab file enabling crash-consistent warm
    /// restart (`memory.file` / `--memory-file`). `None` (the default)
    /// keeps the cache purely in anonymous heap memory.
    pub memory_file: Option<String>,
    pub policy: ChunkSizePolicy,
    pub optimizer: OptimizerSettings,
    /// Tenants defined at startup (`--tenants name=prefix[:quota],...`
    /// / `tenants.rules`); more can be added at runtime via the
    /// `tenants` admin command. Empty = multi-tenancy inactive.
    pub tenants: Vec<crate::tenant::TenantSpec>,
    /// Maintainer passes between arbitration evaluations
    /// (`tenants.arbitrate_every` / `--tenant-arbitrate-every`);
    /// 0 disables arbitration.
    pub tenant_arbitrate_every: u64,
    /// Pairwise tenant size-histogram divergence (total-variation
    /// distance, 0..1) above which the optimizer learns per-tenant
    /// slab geometry (`tenants.divergence` / `--tenant-divergence`).
    pub tenant_divergence: f64,
    /// Per-shard item budget of one arbitration reclaim pass
    /// (`tenants.reclaim_batch` / `--tenant-reclaim-batch`).
    pub tenant_reclaim_batch: usize,
}

impl Default for Settings {
    fn default() -> Self {
        Settings {
            listen: "127.0.0.1:11211".to_string(),
            threads: 4,
            event_loop: true,
            max_conns: 1024,
            idle_timeout_secs: 0,
            reuseport: true,
            udp: false,
            pin_cores: false,
            shards: 4,
            mem_limit: 64 << 20,
            page_size: PAGE_SIZE,
            use_cas: true,
            migrate_batch: DEFAULT_MIGRATE_BATCH,
            maintainer: true,
            maintainer_interval_ms: DEFAULT_MAINTAINER_INTERVAL_MS,
            maintainer_batch: DEFAULT_MAINTAINER_BATCH,
            conn_buffer_budget: 0,
            memory_file: None,
            policy: ChunkSizePolicy::default(),
            optimizer: OptimizerSettings::default(),
            tenants: Vec::new(),
            tenant_arbitrate_every: crate::tenant::DEFAULT_ARBITRATE_EVERY,
            tenant_divergence: crate::tenant::DEFAULT_DIVERGENCE,
            tenant_reclaim_batch: crate::tenant::DEFAULT_RECLAIM_BATCH,
        }
    }
}

/// Settings-load failures.
#[derive(Debug)]
pub enum SettingsError {
    Io(std::io::Error),
    Toml(TomlError),
    Invalid(String),
}

impl fmt::Display for SettingsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SettingsError::Io(e) => write!(f, "cannot read config: {e}"),
            SettingsError::Toml(e) => write!(f, "{e}"),
            SettingsError::Invalid(m) => write!(f, "invalid setting: {m}"),
        }
    }
}

impl std::error::Error for SettingsError {}

impl Settings {
    /// Load from a TOML file, falling back to defaults per key.
    pub fn load(path: &str) -> Result<Settings, SettingsError> {
        let text = std::fs::read_to_string(path).map_err(SettingsError::Io)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Settings, SettingsError> {
        let doc = TomlDoc::parse(text).map_err(SettingsError::Toml)?;
        let mut s = Settings::default();
        let invalid = |k: &str| SettingsError::Invalid(format!("bad value for '{k}'"));

        if let Some(v) = doc.get("listen") {
            s.listen = v.as_str().ok_or_else(|| invalid("listen"))?.to_string();
        }
        if let Some(v) = doc.get("threads") {
            s.threads = v.as_usize().filter(|&n| n > 0).ok_or_else(|| invalid("threads"))?;
        }
        if let Some(v) = doc.get("event_loop") {
            s.event_loop = v.as_bool().ok_or_else(|| invalid("event_loop"))?;
        }
        if let Some(v) = doc.get("max_conns") {
            s.max_conns = v
                .as_usize()
                .filter(|&n| n > 0)
                .ok_or_else(|| invalid("max_conns"))?;
        }
        if let Some(v) = doc.get("idle_timeout_secs") {
            s.idle_timeout_secs = v.as_usize().ok_or_else(|| invalid("idle_timeout_secs"))? as u64;
        }
        if let Some(v) = doc.get("reuseport") {
            s.reuseport = v.as_bool().ok_or_else(|| invalid("reuseport"))?;
        }
        if let Some(v) = doc.get("udp") {
            s.udp = v.as_bool().ok_or_else(|| invalid("udp"))?;
        }
        if let Some(v) = doc.get("pin_cores") {
            s.pin_cores = v.as_bool().ok_or_else(|| invalid("pin_cores"))?;
        }
        if let Some(v) = doc.get("shards") {
            s.shards = v.as_usize().filter(|&n| n > 0).ok_or_else(|| invalid("shards"))?;
        }
        if let Some(v) = doc.get("memory.limit") {
            s.mem_limit = v.as_usize().filter(|&n| n > 0).ok_or_else(|| invalid("memory.limit"))?;
        }
        if let Some(v) = doc.get("memory.page_size") {
            s.page_size = v
                .as_usize()
                .filter(|&n| n >= 1024)
                .ok_or_else(|| invalid("memory.page_size"))?;
        }
        if let Some(v) = doc.get("memory.use_cas") {
            s.use_cas = v.as_bool().ok_or_else(|| invalid("memory.use_cas"))?;
        }
        if let Some(v) = doc.get("memory.migrate_batch") {
            s.migrate_batch = v
                .as_usize()
                .filter(|&n| n > 0)
                .ok_or_else(|| invalid("memory.migrate_batch"))?;
        }
        if let Some(v) = doc.get("memory.maintainer") {
            s.maintainer = v.as_bool().ok_or_else(|| invalid("memory.maintainer"))?;
        }
        if let Some(v) = doc.get("memory.maintainer_interval_ms") {
            s.maintainer_interval_ms = v
                .as_usize()
                .filter(|&n| n > 0)
                .ok_or_else(|| invalid("memory.maintainer_interval_ms"))?
                as u64;
        }
        if let Some(v) = doc.get("memory.maintainer_batch") {
            s.maintainer_batch = v
                .as_usize()
                .filter(|&n| n > 0)
                .ok_or_else(|| invalid("memory.maintainer_batch"))?;
        }
        if let Some(v) = doc.get("memory.conn_buffer_budget") {
            s.conn_buffer_budget = v
                .as_usize()
                .ok_or_else(|| invalid("memory.conn_buffer_budget"))?;
        }
        if let Some(v) = doc.get("memory.file") {
            let path = v.as_str().ok_or_else(|| invalid("memory.file"))?;
            if path.is_empty() {
                return Err(invalid("memory.file"));
            }
            s.memory_file = Some(path.to_string());
        }

        // slab policy: explicit sizes win over growth factor
        let chunk_min = match doc.get("memory.chunk_min") {
            Some(v) => v.as_usize().ok_or_else(|| invalid("memory.chunk_min"))?,
            None => 96,
        };
        let factor = match doc.get("memory.growth_factor") {
            Some(v) => v.as_f64().ok_or_else(|| invalid("memory.growth_factor"))?,
            None => 1.25,
        };
        s.policy = match doc.get("memory.slab_sizes") {
            Some(v) => ChunkSizePolicy::Explicit(
                v.as_usize_vec().ok_or_else(|| invalid("memory.slab_sizes"))?,
            ),
            None => ChunkSizePolicy::Geometric { chunk_min, factor },
        };

        let o = &mut s.optimizer;
        if let Some(v) = doc.get("optimizer.enabled") {
            o.enabled = v.as_bool().ok_or_else(|| invalid("optimizer.enabled"))?;
        }
        if let Some(v) = doc.get("optimizer.interval_secs") {
            o.interval_secs = v.as_usize().ok_or_else(|| invalid("optimizer.interval_secs"))? as u64;
        }
        if let Some(v) = doc.get("optimizer.min_samples") {
            o.min_samples = v.as_usize().ok_or_else(|| invalid("optimizer.min_samples"))? as u64;
        }
        if let Some(v) = doc.get("optimizer.min_improvement") {
            o.min_improvement = v.as_f64().ok_or_else(|| invalid("optimizer.min_improvement"))?;
        }
        if let Some(v) = doc.get("optimizer.algorithm") {
            let name = v.as_str().ok_or_else(|| invalid("optimizer.algorithm"))?;
            o.algorithm = Algorithm::parse(name)
                .ok_or_else(|| SettingsError::Invalid(format!("unknown algorithm '{name}'")))?;
        }
        if let Some(v) = doc.get("optimizer.backend") {
            let name = v.as_str().ok_or_else(|| invalid("optimizer.backend"))?;
            o.backend = Backend::parse(name)
                .ok_or_else(|| SettingsError::Invalid(format!("unknown backend '{name}'")))?;
        }
        if let Some(v) = doc.get("optimizer.artifacts_dir") {
            o.artifacts_dir = v.as_str().ok_or_else(|| invalid("optimizer.artifacts_dir"))?.to_string();
        }
        if let Some(v) = doc.get("optimizer.seed") {
            o.seed = v.as_usize().ok_or_else(|| invalid("optimizer.seed"))? as u64;
        }

        if let Some(v) = doc.get("tenants.rules") {
            let raw = v.as_str().ok_or_else(|| invalid("tenants.rules"))?;
            s.tenants = crate::tenant::TenantSpec::parse_list(raw)
                .map_err(SettingsError::Invalid)?;
        }
        if let Some(v) = doc.get("tenants.arbitrate_every") {
            s.tenant_arbitrate_every = v
                .as_usize()
                .ok_or_else(|| invalid("tenants.arbitrate_every"))?
                as u64;
        }
        if let Some(v) = doc.get("tenants.divergence") {
            s.tenant_divergence = v
                .as_f64()
                .filter(|d| (0.0..=1.0).contains(d))
                .ok_or_else(|| invalid("tenants.divergence"))?;
        }
        if let Some(v) = doc.get("tenants.reclaim_batch") {
            s.tenant_reclaim_batch = v
                .as_usize()
                .filter(|&n| n > 0)
                .ok_or_else(|| invalid("tenants.reclaim_batch"))?;
        }

        s.validate()?;
        Ok(s)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<(), SettingsError> {
        if self.mem_limit / self.shards < self.page_size {
            return Err(SettingsError::Invalid(format!(
                "memory.limit {} gives each of {} shards less than one {}-byte page",
                self.mem_limit, self.shards, self.page_size
            )));
        }
        self.policy
            .materialize(self.page_size)
            .map_err(|e| SettingsError::Invalid(e.to_string()))?;
        // dry-run the tenant specs against a throwaway registry so
        // `ShardedStore::new` can apply them infallibly
        crate::tenant::TenantRegistry::with_settings(
            self.page_size,
            &self.tenants,
            self.tenant_divergence,
            self.tenant_reclaim_batch,
        )
        .map_err(SettingsError::Invalid)?;
        Ok(())
    }

    /// Per-shard memory budget.
    pub fn shard_mem_limit(&self) -> usize {
        self.mem_limit / self.shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Settings::default().validate().unwrap();
    }

    #[test]
    fn full_toml_roundtrip() {
        let s = Settings::from_toml(
            r#"
listen = "0.0.0.0:11300"
threads = 8
shards = 2

[memory]
limit = 134_217_728
page_size = 1_048_576
growth_factor = 1.08
use_cas = false

[optimizer]
enabled = true
interval_secs = 30
algorithm = "paper"
backend = "xla"
artifacts_dir = "artifacts"
"#,
        )
        .unwrap();
        assert_eq!(s.listen, "0.0.0.0:11300");
        assert_eq!(s.threads, 8);
        assert_eq!(s.shards, 2);
        assert_eq!(s.mem_limit, 128 << 20);
        assert!(!s.use_cas);
        assert!(matches!(
            s.policy,
            ChunkSizePolicy::Geometric { factor, .. } if (factor - 1.08).abs() < 1e-9
        ));
        assert!(s.optimizer.enabled);
        assert_eq!(s.optimizer.interval_secs, 30);
        assert_eq!(s.optimizer.algorithm, Algorithm::PaperHillClimb);
        assert_eq!(s.optimizer.backend, Backend::Xla);
    }

    #[test]
    fn explicit_slab_sizes_override_factor() {
        let s = Settings::from_toml("[memory]\nslab_sizes = [304, 384, 480]\n").unwrap();
        assert_eq!(
            s.policy,
            ChunkSizePolicy::Explicit(vec![304, 384, 480])
        );
    }

    #[test]
    fn rejects_undersized_memory() {
        let e = Settings::from_toml("shards = 64\n[memory]\nlimit = 1_048_576\n").unwrap_err();
        assert!(matches!(e, SettingsError::Invalid(_)));
    }

    #[test]
    fn rejects_unknown_algorithm() {
        let e = Settings::from_toml("[optimizer]\nalgorithm = \"magic\"\n").unwrap_err();
        assert!(matches!(e, SettingsError::Invalid(_)));
    }

    #[test]
    fn rejects_bad_slab_sizes() {
        let e = Settings::from_toml("[memory]\nslab_sizes = [500, 400]\n").unwrap_err();
        assert!(matches!(e, SettingsError::Invalid(_)));
    }

    #[test]
    fn empty_toml_is_defaults() {
        let s = Settings::from_toml("").unwrap();
        assert_eq!(s.listen, Settings::default().listen);
        assert!(s.event_loop, "event-driven mode must be the default");
        assert_eq!(s.max_conns, 1024);
        assert_eq!(s.idle_timeout_secs, 0);
        assert_eq!(s.migrate_batch, 256);
    }

    #[test]
    fn migrate_batch_parses_and_validates() {
        let s = Settings::from_toml("[memory]\nmigrate_batch = 64\n").unwrap();
        assert_eq!(s.migrate_batch, 64);
        assert!(Settings::from_toml("[memory]\nmigrate_batch = 0\n").is_err());
    }

    #[test]
    fn maintainer_keys_parse_with_on_by_default() {
        let s = Settings::from_toml("").unwrap();
        assert!(s.maintainer, "maintainer must default on");
        assert_eq!(s.maintainer_interval_ms, 100);
        assert_eq!(s.maintainer_batch, 1024);
        let s = Settings::from_toml(
            "[memory]\nmaintainer = false\nmaintainer_interval_ms = 25\nmaintainer_batch = 64\n",
        )
        .unwrap();
        assert!(!s.maintainer);
        assert_eq!(s.maintainer_interval_ms, 25);
        assert_eq!(s.maintainer_batch, 64);
        assert!(Settings::from_toml("[memory]\nmaintainer_batch = 0\n").is_err());
        assert!(Settings::from_toml("[memory]\nmaintainer = 3\n").is_err());
    }

    #[test]
    fn conn_buffer_budget_parses_with_unlimited_default() {
        let s = Settings::from_toml("").unwrap();
        assert_eq!(s.conn_buffer_budget, 0, "default = unlimited");
        let s = Settings::from_toml("[memory]\nconn_buffer_budget = 8_388_608\n").unwrap();
        assert_eq!(s.conn_buffer_budget, 8 << 20);
        assert!(Settings::from_toml("[memory]\nconn_buffer_budget = \"big\"\n").is_err());
    }

    #[test]
    fn server_mode_keys_parse() {
        let s = Settings::from_toml(
            "event_loop = false\nmax_conns = 64\nidle_timeout_secs = 30\nthreads = 2\n",
        )
        .unwrap();
        assert!(!s.event_loop);
        assert_eq!(s.max_conns, 64);
        assert_eq!(s.idle_timeout_secs, 30);
        assert_eq!(s.threads, 2);
        assert!(Settings::from_toml("max_conns = 0\n").is_err());
        assert!(Settings::from_toml("event_loop = 3\n").is_err());
    }

    #[test]
    fn tenant_keys_parse_with_inactive_default() {
        let s = Settings::from_toml("").unwrap();
        assert!(s.tenants.is_empty(), "multi-tenancy must default off");
        assert_eq!(s.tenant_arbitrate_every, 10);
        assert!((s.tenant_divergence - 0.25).abs() < 1e-9);
        assert_eq!(s.tenant_reclaim_batch, 256);
        let s = Settings::from_toml(
            "[tenants]\nrules = \"app=app_:64,img=img_\"\narbitrate_every = 5\ndivergence = 0.4\nreclaim_batch = 128\n",
        )
        .unwrap();
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[0].name, "app");
        assert_eq!(s.tenants[0].quota_pages, 64);
        assert_eq!(s.tenant_arbitrate_every, 5);
        assert_eq!(s.tenant_reclaim_batch, 128);
        assert!(Settings::from_toml("[tenants]\nrules = \"broken\"\n").is_err());
        assert!(Settings::from_toml("[tenants]\ndivergence = 1.5\n").is_err());
        assert!(Settings::from_toml("[tenants]\nreclaim_batch = 0\n").is_err());
        // a spec list that overflows the tenant id space fails validate
        let many: Vec<String> = (0..20).map(|i| format!("t{i}=p{i}_")).collect();
        let toml = format!("[tenants]\nrules = \"{}\"\n", many.join(","));
        assert!(Settings::from_toml(&toml).is_err());
    }

    #[test]
    fn memory_file_parses_with_off_by_default() {
        let s = Settings::from_toml("").unwrap();
        assert!(s.memory_file.is_none(), "warm restart must default off");
        let s = Settings::from_toml("[memory]\nfile = \"/var/cache/slabforge.mem\"\n").unwrap();
        assert_eq!(s.memory_file.as_deref(), Some("/var/cache/slabforge.mem"));
        assert!(Settings::from_toml("[memory]\nfile = \"\"\n").is_err());
        assert!(Settings::from_toml("[memory]\nfile = 7\n").is_err());
    }

    #[test]
    fn networking_keys_parse_with_reuseport_on_by_default() {
        let s = Settings::from_toml("").unwrap();
        assert!(s.reuseport, "reuseport must default on");
        assert!(!s.udp, "udp must default off");
        assert!(!s.pin_cores, "pinning must default off");
        let s =
            Settings::from_toml("reuseport = false\nudp = true\npin_cores = true\n").unwrap();
        assert!(!s.reuseport);
        assert!(s.udp);
        assert!(s.pin_cores);
        assert!(Settings::from_toml("udp = 7\n").is_err());
        assert!(Settings::from_toml("reuseport = \"yes\"\n").is_err());
    }
}
