//! Tiny CLI argument layer (clap is not vendored — DESIGN.md §3).
//!
//! Grammar: `slabforge <subcommand> [--flag value]... [--switch]...`.
//! Flags may also be written `--flag=value`.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed command line: subcommand + flags + positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw arguments (excluding argv[0]). `known_switches` lists
    /// the boolean flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        known_switches: &[&str],
    ) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if flag.is_empty() {
                    return Err(CliError("bare '--' not supported".into()));
                }
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&flag) {
                    args.switches.push(flag.to_string());
                } else {
                    let v = iter
                        .next()
                        .ok_or_else(|| CliError(format!("--{flag} needs a value")))?;
                    args.flags.insert(flag.to_string(), v);
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("bad value '{v}' for --{name}"))),
        }
    }

    pub fn flag_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        Ok(self.flag_parse(name)?.unwrap_or(default))
    }

    /// Comma-separated usize list (`--sizes 304,384,480`).
    pub fn flag_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, CliError> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<usize>()
                        .map_err(|_| CliError(format!("bad list value '{p}' for --{name}")))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose", "full"]).unwrap()
    }

    #[test]
    fn subcommand_flags_positionals() {
        let a = parse("serve --listen 0.0.0.0:1121 --threads 8 --verbose extra1 extra2");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.flag("listen"), Some("0.0.0.0:1121"));
        assert_eq!(a.flag_or::<usize>("threads", 1).unwrap(), 8);
        assert!(a.switch("verbose"));
        assert!(!a.switch("full"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("optimize --seed=42 --sizes=304,384");
        assert_eq!(a.flag_or::<u64>("seed", 0).unwrap(), 42);
        assert_eq!(
            a.flag_usize_list("sizes").unwrap(),
            Some(vec![304, 384])
        );
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(vec!["x".into(), "--flag".into()], &[]).unwrap_err();
        assert!(e.0.contains("--flag"));
    }

    #[test]
    fn bad_parse_errors() {
        let a = parse("x --n abc");
        assert!(a.flag_parse::<usize>("n").is_err());
        assert!(parse("x --l 1,2,zzz").flag_usize_list("l").is_err());
    }

    #[test]
    fn defaults_when_absent() {
        let a = parse("serve");
        assert_eq!(a.flag_or::<usize>("threads", 4).unwrap(), 4);
        assert_eq!(a.flag_usize_list("sizes").unwrap(), None);
    }
}
