//! The unified command IR both protocol front-ends compile to.
//!
//! A [`Request`] is *opcode + key + flag set + optional data block*:
//! the classic text dialect (`protocol::parse`) and the meta dialect
//! (`protocol::meta`) both parse their wire grammar into this one
//! shape, and `server::conn` executes it against the store without
//! knowing which dialect produced it. Responses flow back through
//! [`ResponseWriter`](crate::protocol::writer::ResponseWriter), which
//! renders the dialect-appropriate wire format from the request's echo
//! flags.
//!
//! Line-phase requests **borrow** every byte (key, opaque token) from
//! the connection's receive buffer, so parsing a retrieval costs zero
//! heap allocations; storage commands convert to an owned
//! [`DataRequest`] before the connection waits for their data block.

use crate::store::store::StoreMode;

/// Which wire dialect a request arrived in (selects response rendering).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dialect {
    /// Classic text protocol (`get`/`set`/... with word responses).
    Classic,
    /// Meta protocol (`mg`/`ms`/`md`/`ma`/`mn` with code+flag responses).
    Meta,
}

/// What the request asks the server to *do* — the dialect-independent
/// operation the execution core switches on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Opcode {
    /// Retrieval: classic `get`/`gets`/`gat`/`gats` (multi-key) and
    /// meta `mg` (single key, optionally touch/vivify).
    Get,
    /// Storage (carries a data block): classic `set` family and meta
    /// `ms`; the exact behaviour is the request's [`StoreMode`].
    Store,
    /// Classic `delete` / meta `md` (optionally CAS-guarded).
    Delete,
    /// Classic `incr`/`decr` / meta `ma`.
    Arith,
    /// Classic `touch`.
    Touch,
    /// Meta `mn` — answers `MN` unconditionally; with quiet-mode
    /// pipelines it acts as the flush barrier.
    Noop,
    /// Meta `me` — per-key bookkeeping dump (slab class, LRU tier,
    /// last access, fetched bit, CAS) for debugging; no LRU effects.
    MetaDebug,
    Stats,
    FlushAll,
    Version,
    Verbosity,
    Quit,
    /// Extension: `slabs reconfigure <sizes>`.
    SlabsReconfigure,
    /// Extension: `slabs optimize`.
    SlabsOptimize,
    /// Extension: `failpoints [list|set <spec>|clear [name]]` —
    /// runtime control of the fault-injection registry
    /// (`util::failpoint`). The raw argument tail rides in `key`.
    Failpoints,
    /// Extension: `tenants [list|define ...|token ...|quota ...]` —
    /// runtime control of the multi-tenant registry
    /// (`tenant::TenantRegistry`). The raw argument tail rides in
    /// `key`, like [`Opcode::Failpoints`].
    Tenants,
}

/// Response-echo flags a request may ask for (meta `v f c t s k O`).
/// Stored as a bitset on the request; the writer renders whichever are
/// set, in canonical order `f c t s k O`.
pub mod want {
    /// `v` — return the value bytes (`VA` instead of `HD`).
    pub const VALUE: u16 = 1 << 0;
    /// `f` — echo the stored client flags.
    pub const FLAGS: u16 = 1 << 1;
    /// `c` — echo the item CAS.
    pub const CAS: u16 = 1 << 2;
    /// `t` — echo remaining TTL seconds (`-1` = unlimited).
    pub const TTL: u16 = 1 << 3;
    /// `s` — echo the value size.
    pub const SIZE: u16 = 1 << 4;
    /// `k` — echo the key (as transmitted, i.e. base64 when `b`).
    pub const KEY: u16 = 1 << 5;
    /// `O` — echo the request's opaque token.
    pub const OPAQUE: u16 = 1 << 6;
    /// `l` — echo seconds since the item's last access.
    pub const LA: u16 = 1 << 7;
    /// `h` — echo whether the item had been hit before (0/1).
    pub const HIT: u16 = 1 << 8;
}

/// Longest opaque (`O`) token accepted, per memcached.
pub const MAX_OPAQUE: usize = 32;

/// One parsed command line in either dialect — borrowed from the
/// receive buffer. Storage commands (`nbytes = Some`) are converted to
/// an owned [`DataRequest`] for the data-block phase.
#[derive(Debug, Clone, PartialEq)]
pub struct Request<'a> {
    pub op: Opcode,
    pub dialect: Dialect,
    /// The (decoded) key — or, for classic retrieval, the raw
    /// space-separated key tail of the command line.
    pub key: &'a [u8],
    /// The key as transmitted (base64 form under `b`) — what `k` echo
    /// must return.
    pub key_echo: &'a [u8],
    /// Storage behaviour for [`Opcode::Store`].
    pub mode: StoreMode,
    /// Client flags to store (classic `<flags>` / meta `F`).
    pub set_flags: u32,
    /// Storage/touch TTL (classic `<exptime>` / meta `T` on `ms`).
    pub exptime: u32,
    /// Data-block length (storage commands only).
    pub nbytes: Option<usize>,
    /// Compare-and-swap guard (classic `cas <token>` / meta `C`).
    pub cas_compare: Option<u64>,
    /// Explicit CAS value to store (meta `E`).
    pub cas_set: Option<u64>,
    /// Arithmetic delta (classic operand / meta `D`, default 1).
    pub delta: u64,
    /// Arithmetic direction (classic verb / meta `M`).
    pub incr: bool,
    /// Auto-vivify initial value for `ma` (meta `J`, default 0).
    pub arith_init: u64,
    /// Auto-vivify TTL on miss (meta `N`).
    pub vivify: Option<u32>,
    /// Touch-on-read TTL (classic `gat <exptime>` / meta `T` on
    /// `mg`/`ma`).
    pub touch_ttl: Option<u32>,
    /// Opaque echo token (meta `O`).
    pub opaque: &'a [u8],
    /// Echo-flag bitset ([`want`]).
    pub want: u16,
    /// Classic `gets`/`gats`: append the CAS to `VALUE` lines.
    pub with_cas: bool,
    /// Classic `noreply` (suppress everything) / meta `q` (suppress
    /// the *expected* outcome: misses for `mg`, successes for
    /// `ms`/`md`/`ma`).
    pub quiet: bool,
    /// Meta `b`: the key token is base64; decode before store access,
    /// echo in encoded form.
    pub b64_key: bool,
    /// Meta `u` (`mg`): serve the hit without bumping the LRU or
    /// refreshing the access time.
    pub no_bump: bool,
    /// Meta `I`: on `md`, mark the item stale instead of deleting it;
    /// on `ms` with `C`, a CAS-mismatched store marks the surviving
    /// item stale.
    pub invalidate: bool,
    /// Meta `R<ttl>` (`mg`): hand this request the recache win (`W`
    /// echo) when the hit's remaining TTL is below the threshold.
    pub recache: Option<u32>,
    /// `stats [arg]` argument.
    pub stats_arg: Option<&'a [u8]>,
    /// `slabs reconfigure` size list.
    pub sizes: Vec<usize>,
}

impl<'a> Request<'a> {
    /// A request with every field at its neutral default.
    pub fn new(op: Opcode, dialect: Dialect) -> Request<'a> {
        Request {
            op,
            dialect,
            key: b"",
            key_echo: b"",
            mode: StoreMode::Set,
            set_flags: 0,
            exptime: 0,
            nbytes: None,
            cas_compare: None,
            cas_set: None,
            delta: 1,
            incr: true,
            arith_init: 0,
            vivify: None,
            touch_ttl: None,
            opaque: b"",
            want: 0,
            with_cas: false,
            quiet: false,
            b64_key: false,
            no_bump: false,
            invalidate: false,
            recache: None,
            stats_arg: None,
            sizes: Vec::new(),
        }
    }

    pub fn classic(op: Opcode) -> Request<'a> {
        Request::new(op, Dialect::Classic)
    }

    pub fn meta(op: Opcode) -> Request<'a> {
        Request::new(op, Dialect::Meta)
    }

    /// Bytes of data block this request expects after its line.
    pub fn data_len(&self) -> Option<usize> {
        self.nbytes
    }

    /// Detach a storage request from the receive buffer so the
    /// connection can wait for its data block.
    pub fn to_data(&self) -> DataRequest {
        DataRequest {
            dialect: self.dialect,
            mode: self.mode,
            key: self.key.to_vec(),
            key_echo: self.key_echo.to_vec(),
            opaque: self.opaque.to_vec(),
            set_flags: self.set_flags,
            exptime: self.exptime,
            nbytes: self.nbytes.unwrap_or(0),
            cas_compare: self.cas_compare,
            cas_set: self.cas_set,
            want: self.want,
            quiet: self.quiet,
            b64_key: self.b64_key,
            invalidate: self.invalidate,
        }
    }
}

/// An owned storage request parked while its `<data block>\r\n` streams
/// in ([`Request::to_data`]).
#[derive(Debug, Clone, PartialEq)]
pub struct DataRequest {
    pub dialect: Dialect,
    pub mode: StoreMode,
    pub key: Vec<u8>,
    pub key_echo: Vec<u8>,
    pub opaque: Vec<u8>,
    pub set_flags: u32,
    pub exptime: u32,
    pub nbytes: usize,
    pub cas_compare: Option<u64>,
    pub cas_set: Option<u64>,
    pub want: u16,
    pub quiet: bool,
    /// The key was transmitted base64-encoded (`key` holds the decoded
    /// bytes, `key_echo` the encoded token).
    pub b64_key: bool,
    /// Meta `I` on `ms`: a CAS-mismatched store invalidates the
    /// surviving item (see [`Request::invalidate`]).
    pub invalidate: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_neutral() {
        let r = Request::meta(Opcode::Get);
        assert_eq!(r.delta, 1);
        assert!(r.incr);
        assert_eq!(r.want, 0);
        assert!(!r.quiet);
        assert_eq!(r.data_len(), None);
    }

    #[test]
    fn to_data_detaches_borrows() {
        let key = b"abc".to_vec();
        let mut r = Request::meta(Opcode::Store);
        r.key = key.as_slice();
        r.key_echo = key.as_slice();
        r.opaque = b"tok";
        r.nbytes = Some(5);
        r.want = want::CAS | want::OPAQUE;
        r.quiet = true;
        let d = r.to_data();
        drop(key);
        assert_eq!(d.key, b"abc");
        assert_eq!(d.opaque, b"tok");
        assert_eq!(d.nbytes, 5);
        assert_eq!(d.want, want::CAS | want::OPAQUE);
        assert!(d.quiet);
    }
}
