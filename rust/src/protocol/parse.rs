//! Text-protocol command parser.
//!
//! The connection layer feeds one `\r\n`-terminated command line at a
//! time; storage commands additionally carry a `<bytes>\r\n` data block
//! that the connection reads separately (`Command::data_len`).

use std::fmt;

/// Storage-command family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOp {
    Set,
    Add,
    Replace,
    Append,
    Prepend,
    Cas,
}

/// A parsed command line (data block, if any, arrives separately).
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    Get {
        keys: Vec<Vec<u8>>,
        with_cas: bool,
    },
    Store {
        op: StoreOp,
        key: Vec<u8>,
        flags: u32,
        exptime: u32,
        nbytes: usize,
        cas: u64,
        noreply: bool,
    },
    Delete {
        key: Vec<u8>,
        noreply: bool,
    },
    IncrDecr {
        key: Vec<u8>,
        delta: u64,
        incr: bool,
        noreply: bool,
    },
    Touch {
        key: Vec<u8>,
        exptime: u32,
        noreply: bool,
    },
    Stats {
        arg: Option<Vec<u8>>,
    },
    FlushAll {
        noreply: bool,
    },
    Version,
    Verbosity {
        noreply: bool,
    },
    Quit,
    /// Extension: `slabs reconfigure 304,384,480 [noreply]`.
    SlabsReconfigure {
        sizes: Vec<usize>,
        noreply: bool,
    },
    /// Extension: `slabs optimize` — run the learned optimizer now.
    SlabsOptimize,
}

impl Command {
    /// Bytes of data block this command expects after its line.
    pub fn data_len(&self) -> Option<usize> {
        match self {
            Command::Store { nbytes, .. } => Some(*nbytes),
            _ => None,
        }
    }

    pub fn noreply(&self) -> bool {
        match self {
            Command::Store { noreply, .. }
            | Command::Delete { noreply, .. }
            | Command::IncrDecr { noreply, .. }
            | Command::Touch { noreply, .. }
            | Command::FlushAll { noreply }
            | Command::Verbosity { noreply }
            | Command::SlabsReconfigure { noreply, .. } => *noreply,
            _ => false,
        }
    }
}

/// Client-visible parse failures (rendered as `ERROR`/`CLIENT_ERROR`).
#[derive(Debug, Clone, PartialEq)]
pub enum ParseError {
    /// Unknown command verb → `ERROR\r\n`.
    UnknownCommand,
    /// Understood verb, bad arguments → `CLIENT_ERROR <msg>\r\n`.
    Client(&'static str),
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseError::UnknownCommand => write!(f, "ERROR"),
            ParseError::Client(m) => write!(f, "CLIENT_ERROR {m}"),
        }
    }
}

fn tokens(line: &[u8]) -> Vec<&[u8]> {
    line.split(|&b| b == b' ').filter(|t| !t.is_empty()).collect()
}

fn parse_u32(tok: &[u8]) -> Result<u32, ParseError> {
    std::str::from_utf8(tok)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or(ParseError::Client("bad numeric argument"))
}

fn parse_u64(tok: &[u8]) -> Result<u64, ParseError> {
    std::str::from_utf8(tok)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or(ParseError::Client("bad numeric argument"))
}

fn parse_usize(tok: &[u8]) -> Result<usize, ParseError> {
    std::str::from_utf8(tok)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or(ParseError::Client("bad numeric argument"))
}

/// memcached also accepts negative exptimes (= already expired); we map
/// them to 0xFFFFFFF0 (far past, relative cutoff keeps them absolute).
fn parse_exptime(tok: &[u8]) -> Result<u32, ParseError> {
    let s = std::str::from_utf8(tok).map_err(|_| ParseError::Client("bad exptime"))?;
    if let Some(stripped) = s.strip_prefix('-') {
        stripped
            .parse::<u64>()
            .map_err(|_| ParseError::Client("bad exptime"))?;
        Ok(1) // 1 second after the epoch: always already expired
    } else {
        s.parse().map_err(|_| ParseError::Client("bad exptime"))
    }
}

fn is_noreply(tok: Option<&&[u8]>) -> bool {
    tok.is_some_and(|t| *t == b"noreply")
}

/// Fast-path split of a `get`/`gets` line: returns `(with_cas,
/// keys_tail)` without tokenizing or allocating, so the connection
/// layer can serve retrieval — by far the dominant verb — straight
/// from its receive buffer. Any other verb, and a keyless `get`,
/// return `None` and fall through to [`parse_command`] (which owns the
/// error strings).
#[inline]
pub fn split_get(line: &[u8]) -> Option<(bool, &[u8])> {
    let (with_cas, rest) = if let Some(r) = line.strip_prefix(b"get ") {
        (false, r)
    } else if let Some(r) = line.strip_prefix(b"gets ") {
        (true, r)
    } else {
        return None;
    };
    if rest.iter().all(|&b| b == b' ') {
        return None; // "get " with no keys -> CLIENT_ERROR via parse_command
    }
    Some((with_cas, rest))
}

/// Iterate the keys of a [`split_get`] tail (space-separated,
/// empties skipped), borrowing straight from the receive buffer.
#[inline]
pub fn get_keys(tail: &[u8]) -> impl Iterator<Item = &[u8]> {
    tail.split(|&b| b == b' ').filter(|t| !t.is_empty())
}

/// Parse one command line (without the trailing `\r\n`).
pub fn parse_command(line: &[u8]) -> Result<Command, ParseError> {
    let toks = tokens(line);
    let Some(&verb) = toks.first() else {
        return Err(ParseError::UnknownCommand);
    };
    match verb {
        b"get" | b"gets" => {
            if toks.len() < 2 {
                return Err(ParseError::Client("get requires at least one key"));
            }
            Ok(Command::Get {
                keys: toks[1..].iter().map(|k| k.to_vec()).collect(),
                with_cas: verb == b"gets",
            })
        }
        b"set" | b"add" | b"replace" | b"append" | b"prepend" | b"cas" => {
            let op = match verb {
                b"set" => StoreOp::Set,
                b"add" => StoreOp::Add,
                b"replace" => StoreOp::Replace,
                b"append" => StoreOp::Append,
                b"prepend" => StoreOp::Prepend,
                _ => StoreOp::Cas,
            };
            let want = if op == StoreOp::Cas { 6 } else { 5 };
            if toks.len() < want {
                return Err(ParseError::Client("bad command line format"));
            }
            let nbytes = parse_usize(toks[4])?;
            let cas = if op == StoreOp::Cas {
                parse_u64(toks[5])?
            } else {
                0
            };
            Ok(Command::Store {
                op,
                key: toks[1].to_vec(),
                flags: parse_u32(toks[2])?,
                exptime: parse_exptime(toks[3])?,
                nbytes,
                cas,
                noreply: is_noreply(toks.get(want)),
            })
        }
        b"delete" => {
            if toks.len() < 2 {
                return Err(ParseError::Client("delete requires a key"));
            }
            Ok(Command::Delete {
                key: toks[1].to_vec(),
                noreply: is_noreply(toks.get(2)),
            })
        }
        b"incr" | b"decr" => {
            if toks.len() < 3 {
                return Err(ParseError::Client("incr/decr require key and value"));
            }
            Ok(Command::IncrDecr {
                key: toks[1].to_vec(),
                delta: parse_u64(toks[2])?,
                incr: verb == b"incr",
                noreply: is_noreply(toks.get(3)),
            })
        }
        b"touch" => {
            if toks.len() < 3 {
                return Err(ParseError::Client("touch requires key and exptime"));
            }
            Ok(Command::Touch {
                key: toks[1].to_vec(),
                exptime: parse_exptime(toks[2])?,
                noreply: is_noreply(toks.get(3)),
            })
        }
        b"stats" => Ok(Command::Stats {
            arg: toks.get(1).map(|t| t.to_vec()),
        }),
        b"flush_all" => Ok(Command::FlushAll {
            noreply: is_noreply(toks.get(1)),
        }),
        b"version" => Ok(Command::Version),
        b"verbosity" => Ok(Command::Verbosity {
            noreply: is_noreply(toks.get(2)),
        }),
        b"quit" => Ok(Command::Quit),
        b"slabs" => match toks.get(1).copied() {
            Some(b"reconfigure") => {
                let Some(list) = toks.get(2) else {
                    return Err(ParseError::Client("slabs reconfigure requires sizes"));
                };
                let sizes: Result<Vec<usize>, ParseError> = list
                    .split(|&b| b == b',')
                    .map(parse_usize)
                    .collect();
                Ok(Command::SlabsReconfigure {
                    sizes: sizes?,
                    noreply: is_noreply(toks.get(3)),
                })
            }
            Some(b"optimize") => Ok(Command::SlabsOptimize),
            _ => Err(ParseError::UnknownCommand),
        },
        _ => Err(ParseError::UnknownCommand),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_single_and_multi() {
        assert_eq!(
            parse_command(b"get foo").unwrap(),
            Command::Get {
                keys: vec![b"foo".to_vec()],
                with_cas: false
            }
        );
        let c = parse_command(b"gets a b c").unwrap();
        match c {
            Command::Get { keys, with_cas } => {
                assert!(with_cas);
                assert_eq!(keys.len(), 3);
            }
            _ => panic!(),
        }
        assert!(parse_command(b"get").is_err());
    }

    #[test]
    fn set_line() {
        let c = parse_command(b"set foo 7 60 5").unwrap();
        match &c {
            Command::Store {
                op: StoreOp::Set,
                key,
                flags: 7,
                exptime: 60,
                nbytes: 5,
                cas: 0,
                noreply: false,
            } => assert_eq!(key, b"foo"),
            other => panic!("{other:?}"),
        }
        assert_eq!(c.data_len(), Some(5));
    }

    #[test]
    fn set_noreply() {
        let c = parse_command(b"set foo 0 0 3 noreply").unwrap();
        assert!(c.noreply());
    }

    #[test]
    fn cas_line() {
        let c = parse_command(b"cas k 1 0 2 99 noreply").unwrap();
        match c {
            Command::Store {
                op: StoreOp::Cas,
                cas: 99,
                noreply: true,
                ..
            } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn negative_exptime_expires_immediately() {
        let c = parse_command(b"set k 0 -1 3").unwrap();
        match c {
            Command::Store { exptime: 1, .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn incr_decr_touch_delete() {
        assert!(matches!(
            parse_command(b"incr n 5").unwrap(),
            Command::IncrDecr {
                delta: 5,
                incr: true,
                ..
            }
        ));
        assert!(matches!(
            parse_command(b"decr n 2 noreply").unwrap(),
            Command::IncrDecr {
                incr: false,
                noreply: true,
                ..
            }
        ));
        assert!(matches!(
            parse_command(b"touch k 300").unwrap(),
            Command::Touch { exptime: 300, .. }
        ));
        assert!(matches!(
            parse_command(b"delete k").unwrap(),
            Command::Delete { noreply: false, .. }
        ));
    }

    #[test]
    fn admin_commands() {
        assert_eq!(parse_command(b"stats").unwrap(), Command::Stats { arg: None });
        assert_eq!(
            parse_command(b"stats slabs").unwrap(),
            Command::Stats {
                arg: Some(b"slabs".to_vec())
            }
        );
        assert_eq!(parse_command(b"version").unwrap(), Command::Version);
        assert_eq!(parse_command(b"quit").unwrap(), Command::Quit);
        assert!(matches!(
            parse_command(b"flush_all noreply").unwrap(),
            Command::FlushAll { noreply: true }
        ));
    }

    #[test]
    fn slabs_extensions() {
        assert_eq!(
            parse_command(b"slabs reconfigure 304,384,480").unwrap(),
            Command::SlabsReconfigure {
                sizes: vec![304, 384, 480],
                noreply: false
            }
        );
        assert_eq!(parse_command(b"slabs optimize").unwrap(), Command::SlabsOptimize);
        assert!(parse_command(b"slabs unknown").is_err());
        assert!(parse_command(b"slabs reconfigure").is_err());
        assert!(parse_command(b"slabs reconfigure 1,x").is_err());
    }

    #[test]
    fn garbage_rejected() {
        assert_eq!(parse_command(b""), Err(ParseError::UnknownCommand));
        assert_eq!(parse_command(b"frobnicate x"), Err(ParseError::UnknownCommand));
        assert!(matches!(
            parse_command(b"set k 0 0 notanumber"),
            Err(ParseError::Client(_))
        ));
    }

    #[test]
    fn split_get_fast_path() {
        let (cas, tail) = split_get(b"get foo").unwrap();
        assert!(!cas);
        assert_eq!(get_keys(tail).collect::<Vec<_>>(), vec![b"foo".as_slice()]);

        let (cas, tail) = split_get(b"gets a  b c").unwrap();
        assert!(cas);
        assert_eq!(
            get_keys(tail).collect::<Vec<_>>(),
            vec![b"a".as_slice(), b"b".as_slice(), b"c".as_slice()]
        );

        // non-get verbs and keyless gets fall through to parse_command
        assert!(split_get(b"set k 0 0 1").is_none());
        assert!(split_get(b"get").is_none());
        assert!(split_get(b"get   ").is_none());
        assert!(split_get(b"getter x").is_none());
        assert!(split_get(b"").is_none());
    }

    #[test]
    fn extra_whitespace_tolerated() {
        let c = parse_command(b"set  foo   1  0  3").unwrap();
        assert!(matches!(c, Command::Store { flags: 1, .. }));
    }
}
