//! Classic text-dialect parser — one of the two front-ends that
//! compile onto the unified command IR ([`Request`]).
//!
//! The connection layer feeds one `\r\n`-terminated command line at a
//! time; storage commands additionally carry a `<bytes>\r\n` data block
//! that the connection reads separately (`Request::data_len`).
//! [`parse_command`] dispatches between this dialect and the meta
//! dialect (`protocol::meta`) by verb shape.

use super::meta;
use super::request::{Opcode, Request};
use crate::store::store::StoreMode;
use std::fmt;

/// Client-visible parse failures (rendered as `ERROR`/`CLIENT_ERROR`).
#[derive(Debug, Clone, PartialEq)]
pub enum ParseError {
    /// Unknown command verb → `ERROR\r\n`.
    UnknownCommand,
    /// Understood verb, bad arguments → `CLIENT_ERROR <msg>\r\n`.
    Client(&'static str),
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseError::UnknownCommand => write!(f, "ERROR"),
            ParseError::Client(m) => write!(f, "CLIENT_ERROR {m}"),
        }
    }
}

fn tokens(line: &[u8]) -> Vec<&[u8]> {
    line.split(|&b| b == b' ').filter(|t| !t.is_empty()).collect()
}

pub(crate) fn parse_u32(tok: &[u8]) -> Result<u32, ParseError> {
    std::str::from_utf8(tok)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or(ParseError::Client("bad numeric argument"))
}

pub(crate) fn parse_u64(tok: &[u8]) -> Result<u64, ParseError> {
    std::str::from_utf8(tok)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or(ParseError::Client("bad numeric argument"))
}

pub(crate) fn parse_usize(tok: &[u8]) -> Result<usize, ParseError> {
    std::str::from_utf8(tok)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or(ParseError::Client("bad numeric argument"))
}

/// What a negative exptime parses to: an **absolute** timestamp in the
/// distant past. It must sit above the store's 30-day relative cutoff
/// (`REALTIME_MAXDELTA`) or it would be misread as a relative offset
/// and the "already expired" item would live for that many seconds.
pub const EXPIRED_SENTINEL: u32 = 60 * 60 * 24 * 30 + 1;

/// memcached also accepts negative exptimes (= already expired); we map
/// them to [`EXPIRED_SENTINEL`].
pub(crate) fn parse_exptime(tok: &[u8]) -> Result<u32, ParseError> {
    let s = std::str::from_utf8(tok).map_err(|_| ParseError::Client("bad exptime"))?;
    if let Some(stripped) = s.strip_prefix('-') {
        stripped
            .parse::<u64>()
            .map_err(|_| ParseError::Client("bad exptime"))?;
        Ok(EXPIRED_SENTINEL)
    } else {
        s.parse().map_err(|_| ParseError::Client("bad exptime"))
    }
}

fn is_noreply(tok: Option<&&[u8]>) -> bool {
    tok.is_some_and(|t| *t == b"noreply")
}

/// Re-slice `line` from where `tok` starts (both must come from the
/// same buffer) — recovers the raw key tail of a retrieval line after
/// tokenization.
fn tail_from<'a>(line: &'a [u8], tok: &'a [u8]) -> &'a [u8] {
    let off = tok.as_ptr() as usize - line.as_ptr() as usize;
    &line[off..]
}

/// Fast-path split of a `get`/`gets` line: returns `(with_cas,
/// keys_tail)` without tokenizing or allocating, so the connection
/// layer can serve retrieval — by far the dominant verb — straight
/// from its receive buffer. Any other verb, and a keyless `get`,
/// return `None` and fall through to [`parse_command`] (which owns the
/// error strings).
#[inline]
pub fn split_get(line: &[u8]) -> Option<(bool, &[u8])> {
    let (with_cas, rest) = if let Some(r) = line.strip_prefix(b"get ") {
        (false, r)
    } else if let Some(r) = line.strip_prefix(b"gets ") {
        (true, r)
    } else {
        return None;
    };
    if rest.iter().all(|&b| b == b' ') {
        return None; // "get " with no keys -> CLIENT_ERROR via parse_command
    }
    Some((with_cas, rest))
}

/// Iterate the keys of a [`split_get`] tail (space-separated,
/// empties skipped), borrowing straight from the receive buffer.
#[inline]
pub fn get_keys(tail: &[u8]) -> impl Iterator<Item = &[u8]> {
    tail.split(|&b| b == b' ').filter(|t| !t.is_empty())
}

/// Parse one command line (without the trailing `\r\n`), dispatching to
/// the meta parser for `m?` verbs and the classic grammar otherwise.
pub fn parse_command(line: &[u8]) -> Result<Request<'_>, ParseError> {
    if meta::is_meta(line) {
        meta::parse_meta(line)
    } else {
        parse_classic(line)
    }
}

/// Parse one classic-dialect command line into the IR.
pub fn parse_classic(line: &[u8]) -> Result<Request<'_>, ParseError> {
    let toks = tokens(line);
    let Some(&verb) = toks.first() else {
        return Err(ParseError::UnknownCommand);
    };
    match verb {
        b"get" | b"gets" => {
            if toks.len() < 2 {
                return Err(ParseError::Client("get requires at least one key"));
            }
            let mut r = Request::classic(Opcode::Get);
            r.key = tail_from(line, toks[1]);
            r.with_cas = verb == b"gets";
            Ok(r)
        }
        b"gat" | b"gats" => {
            if toks.len() < 3 {
                return Err(ParseError::Client("gat requires exptime and at least one key"));
            }
            let mut r = Request::classic(Opcode::Get);
            r.touch_ttl = Some(parse_exptime(toks[1])?);
            r.key = tail_from(line, toks[2]);
            r.with_cas = verb == b"gats";
            Ok(r)
        }
        b"set" | b"add" | b"replace" | b"append" | b"prepend" | b"cas" => {
            let mode = match verb {
                b"set" | b"cas" => StoreMode::Set,
                b"add" => StoreMode::Add,
                b"replace" => StoreMode::Replace,
                b"append" => StoreMode::Append,
                _ => StoreMode::Prepend,
            };
            let is_cas = verb == b"cas";
            let want = if is_cas { 6 } else { 5 };
            if toks.len() < want {
                return Err(ParseError::Client("bad command line format"));
            }
            let mut r = Request::classic(Opcode::Store);
            r.mode = mode;
            r.key = toks[1];
            r.set_flags = parse_u32(toks[2])?;
            r.exptime = parse_exptime(toks[3])?;
            r.nbytes = Some(parse_usize(toks[4])?);
            if is_cas {
                r.cas_compare = Some(parse_u64(toks[5])?);
            }
            r.quiet = is_noreply(toks.get(want));
            Ok(r)
        }
        b"delete" => {
            if toks.len() < 2 {
                return Err(ParseError::Client("delete requires a key"));
            }
            let mut r = Request::classic(Opcode::Delete);
            r.key = toks[1];
            r.quiet = is_noreply(toks.get(2));
            Ok(r)
        }
        b"incr" | b"decr" => {
            if toks.len() < 3 {
                return Err(ParseError::Client("incr/decr require key and value"));
            }
            let mut r = Request::classic(Opcode::Arith);
            r.key = toks[1];
            r.delta = parse_u64(toks[2])?;
            r.incr = verb == b"incr";
            r.quiet = is_noreply(toks.get(3));
            Ok(r)
        }
        b"touch" => {
            if toks.len() < 3 {
                return Err(ParseError::Client("touch requires key and exptime"));
            }
            let mut r = Request::classic(Opcode::Touch);
            r.key = toks[1];
            r.exptime = parse_exptime(toks[2])?;
            r.quiet = is_noreply(toks.get(3));
            Ok(r)
        }
        b"stats" => {
            let mut r = Request::classic(Opcode::Stats);
            r.stats_arg = toks.get(1).copied();
            Ok(r)
        }
        b"flush_all" => {
            let mut r = Request::classic(Opcode::FlushAll);
            r.quiet = is_noreply(toks.get(1));
            Ok(r)
        }
        b"version" => Ok(Request::classic(Opcode::Version)),
        b"verbosity" => {
            let mut r = Request::classic(Opcode::Verbosity);
            r.quiet = is_noreply(toks.get(2));
            Ok(r)
        }
        b"quit" => Ok(Request::classic(Opcode::Quit)),
        b"slabs" => match toks.get(1).copied() {
            Some(b"reconfigure") => {
                let Some(list) = toks.get(2) else {
                    return Err(ParseError::Client("slabs reconfigure requires sizes"));
                };
                let sizes: Result<Vec<usize>, ParseError> =
                    list.split(|&b| b == b',').map(parse_usize).collect();
                let mut r = Request::classic(Opcode::SlabsReconfigure);
                r.sizes = sizes?;
                r.quiet = is_noreply(toks.get(3));
                Ok(r)
            }
            Some(b"optimize") => Ok(Request::classic(Opcode::SlabsOptimize)),
            _ => Err(ParseError::UnknownCommand),
        },
        b"failpoints" => {
            // whole raw tail (subcommand + spec) — the executor owns
            // the grammar so `set a=1in5,b=once` keeps its commas
            let mut r = Request::classic(Opcode::Failpoints);
            if let Some(first) = toks.get(1) {
                r.key = tail_from(line, first);
            }
            Ok(r)
        }
        b"tenants" => {
            // raw tail again: prefixes/tokens may hold any non-space
            // bytes, so the executor owns the grammar
            let mut r = Request::classic(Opcode::Tenants);
            if let Some(first) = toks.get(1) {
                r.key = tail_from(line, first);
            }
            Ok(r)
        }
        _ => Err(ParseError::UnknownCommand),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::request::Dialect;

    #[test]
    fn get_single_and_multi() {
        let r = parse_command(b"get foo").unwrap();
        assert_eq!(r.op, Opcode::Get);
        assert_eq!(r.dialect, Dialect::Classic);
        assert_eq!(r.key, b"foo");
        assert!(!r.with_cas);
        let r = parse_command(b"gets a b c").unwrap();
        assert!(r.with_cas);
        assert_eq!(get_keys(r.key).count(), 3);
        assert!(parse_command(b"get").is_err());
    }

    #[test]
    fn gat_lines() {
        let r = parse_command(b"gat 60 a b").unwrap();
        assert_eq!(r.op, Opcode::Get);
        assert_eq!(r.touch_ttl, Some(60));
        assert!(!r.with_cas);
        assert_eq!(
            get_keys(r.key).collect::<Vec<_>>(),
            vec![b"a".as_slice(), b"b".as_slice()]
        );
        let r = parse_command(b"gats 120 k").unwrap();
        assert!(r.with_cas);
        assert_eq!(r.touch_ttl, Some(120));
        assert!(parse_command(b"gat 60").is_err());
        assert!(parse_command(b"gat x k").is_err());
    }

    #[test]
    fn set_line() {
        let r = parse_command(b"set foo 7 60 5").unwrap();
        assert_eq!(r.op, Opcode::Store);
        assert_eq!(r.mode, StoreMode::Set);
        assert_eq!(r.key, b"foo");
        assert_eq!(r.set_flags, 7);
        assert_eq!(r.exptime, 60);
        assert_eq!(r.data_len(), Some(5));
        assert_eq!(r.cas_compare, None);
        assert!(!r.quiet);
    }

    #[test]
    fn set_noreply() {
        let r = parse_command(b"set foo 0 0 3 noreply").unwrap();
        assert!(r.quiet);
    }

    #[test]
    fn cas_line() {
        let r = parse_command(b"cas k 1 0 2 99 noreply").unwrap();
        assert_eq!(r.mode, StoreMode::Set);
        assert_eq!(r.cas_compare, Some(99));
        assert!(r.quiet);
    }

    #[test]
    fn negative_exptime_expires_immediately() {
        let r = parse_command(b"set k 0 -1 3").unwrap();
        assert_eq!(r.exptime, EXPIRED_SENTINEL);
        // the sentinel must read as an ABSOLUTE past time, not a
        // relative offset (memcached's 30-day cutoff)
        assert!(EXPIRED_SENTINEL > 60 * 60 * 24 * 30);
    }

    #[test]
    fn incr_decr_touch_delete() {
        let r = parse_command(b"incr n 5").unwrap();
        assert_eq!((r.op, r.delta, r.incr), (Opcode::Arith, 5, true));
        let r = parse_command(b"decr n 2 noreply").unwrap();
        assert!(!r.incr && r.quiet);
        let r = parse_command(b"touch k 300").unwrap();
        assert_eq!((r.op, r.exptime), (Opcode::Touch, 300));
        let r = parse_command(b"delete k").unwrap();
        assert_eq!((r.op, r.quiet), (Opcode::Delete, false));
    }

    #[test]
    fn admin_commands() {
        let r = parse_command(b"stats").unwrap();
        assert_eq!((r.op, r.stats_arg), (Opcode::Stats, None));
        let r = parse_command(b"stats slabs").unwrap();
        assert_eq!(r.stats_arg, Some(b"slabs".as_slice()));
        assert_eq!(parse_command(b"version").unwrap().op, Opcode::Version);
        assert_eq!(parse_command(b"quit").unwrap().op, Opcode::Quit);
        let r = parse_command(b"flush_all noreply").unwrap();
        assert_eq!((r.op, r.quiet), (Opcode::FlushAll, true));
    }

    #[test]
    fn failpoints_lines_keep_the_raw_tail() {
        let r = parse_command(b"failpoints").unwrap();
        assert_eq!((r.op, r.key), (Opcode::Failpoints, b"".as_slice()));
        let r = parse_command(b"failpoints set a=1in5,b=once").unwrap();
        assert_eq!(r.op, Opcode::Failpoints);
        assert_eq!(r.key, b"set a=1in5,b=once");
        let r = parse_command(b"failpoints clear a").unwrap();
        assert_eq!(r.key, b"clear a");
    }

    #[test]
    fn tenants_lines_keep_the_raw_tail() {
        let r = parse_command(b"tenants").unwrap();
        assert_eq!((r.op, r.key), (Opcode::Tenants, b"".as_slice()));
        let r = parse_command(b"tenants define acme user: 64").unwrap();
        assert_eq!(r.op, Opcode::Tenants);
        assert_eq!(r.key, b"define acme user: 64");
        let r = parse_command(b"tenants list").unwrap();
        assert_eq!(r.key, b"list");
    }

    #[test]
    fn slabs_extensions() {
        let r = parse_command(b"slabs reconfigure 304,384,480").unwrap();
        assert_eq!(r.op, Opcode::SlabsReconfigure);
        assert_eq!(r.sizes, vec![304, 384, 480]);
        assert!(!r.quiet);
        assert_eq!(
            parse_command(b"slabs optimize").unwrap().op,
            Opcode::SlabsOptimize
        );
        assert!(parse_command(b"slabs unknown").is_err());
        assert!(parse_command(b"slabs reconfigure").is_err());
        assert!(parse_command(b"slabs reconfigure 1,x").is_err());
    }

    #[test]
    fn garbage_rejected() {
        assert_eq!(parse_command(b""), Err(ParseError::UnknownCommand));
        assert_eq!(
            parse_command(b"frobnicate x"),
            Err(ParseError::UnknownCommand)
        );
        assert!(matches!(
            parse_command(b"set k 0 0 notanumber"),
            Err(ParseError::Client(_))
        ));
    }

    #[test]
    fn split_get_fast_path() {
        let (cas, tail) = split_get(b"get foo").unwrap();
        assert!(!cas);
        assert_eq!(get_keys(tail).collect::<Vec<_>>(), vec![b"foo".as_slice()]);

        let (cas, tail) = split_get(b"gets a  b c").unwrap();
        assert!(cas);
        assert_eq!(
            get_keys(tail).collect::<Vec<_>>(),
            vec![b"a".as_slice(), b"b".as_slice(), b"c".as_slice()]
        );

        // non-get verbs and keyless gets fall through to parse_command
        assert!(split_get(b"set k 0 0 1").is_none());
        assert!(split_get(b"get").is_none());
        assert!(split_get(b"get   ").is_none());
        assert!(split_get(b"getter x").is_none());
        assert!(split_get(b"").is_none());
    }

    #[test]
    fn extra_whitespace_tolerated() {
        let r = parse_classic(b"set  foo   1  0  3").unwrap();
        assert_eq!(r.set_flags, 1);
        // the retrieval tail keeps raw spacing; get_keys skips empties
        let r = parse_classic(b"get  a   b").unwrap();
        assert_eq!(get_keys(r.key).count(), 2);
    }
}
