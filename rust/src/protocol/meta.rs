//! Meta-dialect parser (`mg`/`ms`/`md`/`ma`/`mn`/`me`) — the second
//! front-end onto the command IR ([`Request`]). `me <key> [b]` is the
//! per-key bookkeeping dump (no echo flags).
//!
//! The meta protocol replaces per-command response grammar with one
//! compact shape: `<cmd> <key> <flag>*`, where each flag is a single
//! letter optionally followed by a token, and the response echoes the
//! requested flags back (`HD`/`VA`/`EN`/`NS`/`EX`/`NF` codes). Flags
//! implemented here:
//!
//! | flag | meaning |
//! |------|---------|
//! | `v`  | return value (`VA` response) |
//! | `f`  | echo stored client flags |
//! | `c`  | echo CAS |
//! | `t`  | echo remaining TTL (`-1` = unlimited) |
//! | `s`  | echo value size |
//! | `k`  | echo key |
//! | `l`  | `mg`: echo seconds since last access (accurate to the touch interval: read-lock fast-path hits do not refresh it) |
//! | `h`  | `mg`: echo hit-before (0/1, memcached's ITEM_FETCHED; forces the write path so the bit is read and set atomically) |
//! | `u`  | `mg`: no-LRU-bump read — serve the hit without touching recency state (and without flipping the fetched bit) |
//! | `I`  | `md`: mark the item stale instead of deleting it; `ms` with `C`: a CAS-mismatched store marks the survivor stale |
//! | `R<ttl>` | `mg`: win the recache race (`W`/`Z` echoes) when the hit's TTL is below the threshold |
//! | `O<tok>` | echo opaque token |
//! | `q`  | quiet: suppress misses (`mg`) / successes (`ms`/`md`/`ma`) |
//! | `b`  | key token is base64 |
//! | `T<ttl>` | `ms`: item TTL; `mg`/`ma`: touch TTL on hit |
//! | `N<ttl>` | `mg`/`ma`: vivify on miss with this TTL |
//! | `E<cas>` | `ms`/`ma`: store this CAS value; `mg`: CAS for a vivified item (invalid on `md`) |
//! | `C<cas>` | compare-and-swap guard (`ms`/`md`/`ma`) |
//! | `F<flags>` | `ms`: client flags to store |
//! | `D<delta>` | `ma`: delta (default 1) |
//! | `J<init>` | `ma`: vivify initial value (default 0) |
//! | `M<mode>` | `ms`: S/E/A/P/R = set/add/append/prepend/replace; `ma`: I/+ incr, D/- decr |
//!
//! Parsing is allocation-free: the verb/key/flag tokens are iterated in
//! place and every borrowed field of the produced [`Request`] points
//! into the receive buffer, keeping the `mg` hit path zero-alloc
//! end-to-end (`tests/hotpath_alloc.rs`).

use super::parse::{parse_exptime, parse_u32, parse_u64, parse_usize, ParseError};
use super::request::{want, Opcode, Request, MAX_OPAQUE};
use crate::store::item::key_is_valid;
use crate::store::store::StoreMode;

/// Cheap shape test: does this line use a meta verb? (`mg`, `ms`,
/// `md`, `ma`, `mn`, `me` followed by end-of-line or a space.)
#[inline]
pub fn is_meta(line: &[u8]) -> bool {
    line.len() >= 2
        && line[0] == b'm'
        && matches!(line[1], b'g' | b's' | b'd' | b'a' | b'n' | b'e')
        && (line.len() == 2 || line[2] == b' ')
}

/// Parse one meta command line (without the trailing `\r\n`).
pub fn parse_meta(line: &[u8]) -> Result<Request<'_>, ParseError> {
    let mut toks = line.split(|&b| b == b' ').filter(|t| !t.is_empty());
    let Some(verb) = toks.next() else {
        return Err(ParseError::UnknownCommand);
    };
    let op = match verb {
        b"mn" => return Ok(Request::meta(Opcode::Noop)),
        b"mg" => Opcode::Get,
        b"ms" => Opcode::Store,
        b"md" => Opcode::Delete,
        b"ma" => Opcode::Arith,
        b"me" => Opcode::MetaDebug,
        _ => return Err(ParseError::UnknownCommand),
    };
    let Some(key) = toks.next() else {
        return Err(ParseError::Client("missing key"));
    };
    let mut r = Request::meta(op);
    r.key = key;
    r.key_echo = key;
    if op == Opcode::MetaDebug {
        // the debug dump takes no echo flags — only `b` (base64 key)
        for t in toks {
            match t {
                b"b" => r.b64_key = true,
                _ => return Err(ParseError::Client("invalid flag")),
            }
        }
        if !r.b64_key && !key_is_valid(r.key) {
            return Err(ParseError::Client("bad key"));
        }
        return Ok(r);
    }
    if op == Opcode::Store {
        let Some(len) = toks.next() else {
            return Err(ParseError::Client("ms requires a data length"));
        };
        r.nbytes = Some(parse_usize(len)?);
    }
    for t in toks {
        let (flag, arg) = (t[0], &t[1..]);
        match flag {
            // argless flags with a trailing token (e.g. a fused "vq")
            // are malformed — reject loudly rather than silently
            // dropping the tail and changing semantics
            b'v' | b'f' | b'c' | b't' | b's' | b'k' | b'q' | b'b' | b'l' | b'h' | b'u' | b'I'
                if !arg.is_empty() =>
            {
                return Err(ParseError::Client("invalid flag"));
            }
            b'v' => r.want |= want::VALUE,
            b'f' => r.want |= want::FLAGS,
            b'c' => r.want |= want::CAS,
            b't' => r.want |= want::TTL,
            b's' => r.want |= want::SIZE,
            b'k' => r.want |= want::KEY,
            b'q' => r.quiet = true,
            b'b' => r.b64_key = true,
            b'l' if op == Opcode::Get => r.want |= want::LA,
            b'h' if op == Opcode::Get => r.want |= want::HIT,
            b'u' if op == Opcode::Get => r.no_bump = true,
            b'I' if matches!(op, Opcode::Delete | Opcode::Store) => r.invalidate = true,
            // R is a *remaining-TTL threshold*, not an expiry: plain
            // non-negative seconds, no absolute-timestamp rewriting
            b'R' if op == Opcode::Get => r.recache = Some(parse_u32(arg)?),
            b'O' => {
                if arg.is_empty() || arg.len() > MAX_OPAQUE {
                    return Err(ParseError::Client("bad opaque token"));
                }
                r.want |= want::OPAQUE;
                r.opaque = arg;
            }
            b'T' => {
                let ttl = parse_exptime(arg)?;
                if op == Opcode::Store {
                    r.exptime = ttl;
                } else {
                    r.touch_ttl = Some(ttl);
                }
            }
            b'N' => r.vivify = Some(parse_exptime(arg)?),
            b'E' => {
                // md never keeps the item, so an explicit CAS would be
                // silently meaningless — reject it loudly
                if op == Opcode::Delete {
                    return Err(ParseError::Client("invalid flag"));
                }
                r.cas_set = Some(parse_u64(arg)?);
            }
            b'C' => r.cas_compare = Some(parse_u64(arg)?),
            b'F' => r.set_flags = parse_u32(arg)?,
            b'D' => r.delta = parse_u64(arg)?,
            b'J' => r.arith_init = parse_u64(arg)?,
            b'M' => match (op, arg) {
                (Opcode::Store, b"S") => r.mode = StoreMode::Set,
                (Opcode::Store, b"E") => r.mode = StoreMode::Add,
                (Opcode::Store, b"A") => r.mode = StoreMode::Append,
                (Opcode::Store, b"P") => r.mode = StoreMode::Prepend,
                (Opcode::Store, b"R") => r.mode = StoreMode::Replace,
                (Opcode::Arith, b"I" | b"+") => r.incr = true,
                (Opcode::Arith, b"D" | b"-") => r.incr = false,
                _ => return Err(ParseError::Client("invalid mode")),
            },
            _ => return Err(ParseError::Client("invalid flag")),
        }
    }
    // raw (non-base64) keys must satisfy the text-protocol rules, and
    // violations error loudly here instead of silently missing
    // store-side (memcached parity); base64 keys may be fully binary
    // and are length-bounded by the connection's stack decode buffer
    if !r.b64_key && !key_is_valid(r.key) {
        return Err(ParseError::Client("bad key"));
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::request::Dialect;

    #[test]
    fn verb_shapes() {
        assert!(is_meta(b"mg key v"));
        assert!(is_meta(b"mn"));
        assert!(is_meta(b"ms k 3"));
        assert!(!is_meta(b"get key"));
        assert!(!is_meta(b"m"));
        assert!(is_meta(b"me key"));
        assert!(!is_meta(b"mget key"));
    }

    #[test]
    fn me_debug_line() {
        let r = parse_meta(b"me foo").unwrap();
        assert_eq!(r.op, Opcode::MetaDebug);
        assert_eq!(r.key, b"foo");
        assert!(!r.b64_key);
        let r = parse_meta(b"me Zm9v b").unwrap();
        assert!(r.b64_key);
        // no echo flags on me — anything else is rejected loudly
        assert!(parse_meta(b"me k v").is_err());
        assert!(parse_meta(b"me k q").is_err());
        assert_eq!(parse_meta(b"me"), Err(ParseError::Client("missing key")));
        assert_eq!(parse_meta(b"me a\x01b"), Err(ParseError::Client("bad key")));
    }

    #[test]
    fn mg_flags() {
        let r = parse_meta(b"mg foo v f c t k Oabc q b").unwrap();
        assert_eq!(r.op, Opcode::Get);
        assert_eq!(r.dialect, Dialect::Meta);
        assert_eq!(r.key, b"foo");
        assert_eq!(
            r.want,
            want::VALUE | want::FLAGS | want::CAS | want::TTL | want::KEY | want::OPAQUE
        );
        assert_eq!(r.opaque, b"abc");
        assert!(r.quiet);
        assert!(r.b64_key);
        assert_eq!(r.touch_ttl, None);
        assert_eq!(r.vivify, None);
    }

    #[test]
    fn mg_la_hit_and_nobump_flags() {
        let r = parse_meta(b"mg foo v l h u").unwrap();
        assert_eq!(r.want & want::LA, want::LA);
        assert_eq!(r.want & want::HIT, want::HIT);
        assert!(r.no_bump);
        let r = parse_meta(b"mg foo v").unwrap();
        assert_eq!(r.want & (want::LA | want::HIT), 0);
        assert!(!r.no_bump);
        // mg-only flags: rejected on the other verbs, and when fused
        assert!(parse_meta(b"ms k 1 l").is_err());
        assert!(parse_meta(b"md k h").is_err());
        assert!(parse_meta(b"ma k u").is_err());
        assert!(parse_meta(b"mg k l1").is_err(), "l takes no token");
        assert!(parse_meta(b"mg k uq").is_err(), "fused argless flags");
    }

    #[test]
    fn mg_touch_and_vivify() {
        let r = parse_meta(b"mg k T120 N60").unwrap();
        assert_eq!(r.touch_ttl, Some(120));
        assert_eq!(r.vivify, Some(60));
    }

    #[test]
    fn ms_line() {
        let r = parse_meta(b"ms foo 5 T60 F7 C9 E11 c k Oxy").unwrap();
        assert_eq!(r.op, Opcode::Store);
        assert_eq!(r.data_len(), Some(5));
        assert_eq!(r.exptime, 60); // T goes to the item TTL on ms
        assert_eq!(r.set_flags, 7);
        assert_eq!(r.cas_compare, Some(9));
        assert_eq!(r.cas_set, Some(11));
        assert_eq!(r.mode, StoreMode::Set);
        assert_eq!(r.want, want::CAS | want::KEY | want::OPAQUE);
    }

    #[test]
    fn ms_modes() {
        for (m, mode) in [
            (&b"ms k 1 MS"[..], StoreMode::Set),
            (b"ms k 1 ME", StoreMode::Add),
            (b"ms k 1 MA", StoreMode::Append),
            (b"ms k 1 MP", StoreMode::Prepend),
            (b"ms k 1 MR", StoreMode::Replace),
        ] {
            assert_eq!(parse_meta(m).unwrap().mode, mode, "{m:?}");
        }
        assert!(parse_meta(b"ms k 1 MX").is_err());
    }

    #[test]
    fn md_cas_guard() {
        let r = parse_meta(b"md foo C42 q Oz").unwrap();
        assert_eq!(r.op, Opcode::Delete);
        assert_eq!(r.cas_compare, Some(42));
        assert!(r.quiet);
        assert_eq!(r.opaque, b"z");
        // explicit CAS is meaningless on delete — rejected, not dropped
        assert_eq!(
            parse_meta(b"md foo E9"),
            Err(ParseError::Client("invalid flag"))
        );
    }

    #[test]
    fn ma_modes_and_tokens() {
        let r = parse_meta(b"ma n D5 MI J100 N30 v").unwrap();
        assert_eq!(r.op, Opcode::Arith);
        assert_eq!(r.delta, 5);
        assert!(r.incr);
        assert_eq!(r.arith_init, 100);
        assert_eq!(r.vivify, Some(30));
        assert!(r.want & want::VALUE != 0);
        let r = parse_meta(b"ma n MD").unwrap();
        assert!(!r.incr);
        assert_eq!(r.delta, 1, "delta defaults to 1");
        let r = parse_meta(b"ma n M-").unwrap();
        assert!(!r.incr);
        assert!(parse_meta(b"ma n MZ").is_err());
    }

    #[test]
    fn invalidate_and_recache_flags() {
        // md I: mark-stale delete
        let r = parse_meta(b"md foo I").unwrap();
        assert!(r.invalidate);
        // ms I rides along with a CAS compare
        let r = parse_meta(b"ms foo 3 C9 I").unwrap();
        assert!(r.invalidate);
        assert_eq!(r.cas_compare, Some(9));
        // mg R<ttl>: recache-win threshold
        let r = parse_meta(b"mg foo v R30").unwrap();
        assert_eq!(r.recache, Some(30));
        assert!(!r.invalidate);
        // I is argless; R needs a number; both are verb-gated
        assert!(parse_meta(b"md foo I1").is_err(), "I takes no token");
        assert!(parse_meta(b"mg foo I").is_err(), "I invalid on mg");
        assert!(parse_meta(b"mg foo R").is_err(), "R needs a number");
        assert!(parse_meta(b"mg foo Rx").is_err());
        assert!(parse_meta(b"ms foo 3 R30").is_err(), "R invalid on ms");
        assert!(parse_meta(b"ma foo R30").is_err(), "R invalid on ma");
    }

    #[test]
    fn mn_is_bare() {
        let r = parse_meta(b"mn").unwrap();
        assert_eq!(r.op, Opcode::Noop);
    }

    #[test]
    fn errors() {
        assert_eq!(parse_meta(b"mg"), Err(ParseError::Client("missing key")));
        assert_eq!(
            parse_meta(b"ms k"),
            Err(ParseError::Client("ms requires a data length"))
        );
        assert!(matches!(
            parse_meta(b"ms k notanumber"),
            Err(ParseError::Client(_))
        ));
        assert!(parse_meta(b"mg k z").is_err(), "unknown flag letter");
        assert!(parse_meta(b"mg k O").is_err(), "opaque needs a token");
        assert!(parse_meta(b"mg k Tx").is_err(), "T needs a number");
        assert!(parse_meta(b"mg k vq").is_err(), "fused argless flags");
        assert!(parse_meta(b"ms k 1 qx").is_err(), "q takes no token");
        assert!(parse_meta(b"mx k").is_err());
    }

    #[test]
    fn raw_key_violations_rejected_loudly() {
        let long = [b'k'; 251];
        let line = [b"mg " as &[u8], &long, b" v"].concat();
        assert_eq!(parse_meta(&line), Err(ParseError::Client("bad key")));
        // at exactly 250 it parses
        let line = [b"mg " as &[u8], &long[..250]].concat();
        assert!(parse_meta(&line).is_ok());
        // control bytes in a raw key are rejected (vivify must not be
        // able to insert a text-illegal key)...
        assert_eq!(
            parse_meta(b"mg a\x01b N60"),
            Err(ParseError::Client("bad key"))
        );
        assert_eq!(
            parse_meta(b"ma a\x01b N60"),
            Err(ParseError::Client("bad key"))
        );
        // ...but the same bytes are fine behind the b64 flag
        assert!(parse_meta(b"mg YQFi b N60").is_ok());
    }

    #[test]
    fn negative_ttl_tokens_expire_immediately() {
        use crate::protocol::parse::EXPIRED_SENTINEL;
        let r = parse_meta(b"mg k T-1").unwrap();
        assert_eq!(r.touch_ttl, Some(EXPIRED_SENTINEL));
        let r = parse_meta(b"ms k 1 T-5").unwrap();
        assert_eq!(r.exptime, EXPIRED_SENTINEL);
    }
}
