//! `stats`-family rendering — the measurement interface.
//!
//! * `stats`        — operation counters + item/memory totals.
//! * `stats slabs`  — per-class chunk geometry, usage, and **hole
//!   accounting** (`mem_requested`, `mem_wasted`): the numbers the
//!   paper's tables report.
//! * `stats sizes`  — the observed item-size histogram (what the
//!   optimizer learns from), bucketed like memcached's 32-byte rows.

use super::response::stat;
use crate::server::conn::OptimizeGauges;
use crate::server::metrics::ConnCounters;
use crate::slab::SlabStats;
use crate::store::migrate::MigrationGauges;
use crate::store::sharded::RestartSnapshot;
use crate::store::store::StoreStats;
use crate::tenant::TenantStat;
use crate::util::histogram::SizeHistogram;

/// Render plain `stats`.
pub fn render_general(
    out: &mut Vec<u8>,
    ops: &StoreStats,
    slabs: &SlabStats,
    items: usize,
    uptime_secs: u64,
    conns: &ConnCounters,
    restart: &RestartSnapshot,
) {
    stat(out, "uptime", uptime_secs);
    stat(out, "curr_connections", conns.curr);
    stat(out, "total_connections", conns.total);
    stat(out, "rejected_connections", conns.rejected);
    stat(out, "conn_yields", conns.yields);
    stat(out, "shed_connections", conns.shed);
    stat(out, "conn_buffer_bytes", conns.buffer_bytes);
    stat(out, "thread_restarts", conns.thread_restarts);
    stat(out, "reactor_cross_shard", conns.cross_shard);
    stat(out, "udp_datagrams_rx", conns.udp_rx);
    stat(out, "udp_datagrams_tx", conns.udp_tx);
    stat(out, "udp_oversized_drops", conns.udp_oversized);
    stat(out, "udp_bad_frames", conns.udp_bad);
    stat(out, "curr_items", items);
    stat(out, "cmd_get", ops.cmd_get);
    stat(out, "cmd_set", ops.cmd_set);
    stat(out, "get_hits", ops.get_hits);
    stat(out, "get_misses", ops.get_misses);
    stat(out, "delete_hits", ops.delete_hits);
    stat(out, "delete_misses", ops.delete_misses);
    stat(out, "incr_hits", ops.incr_hits);
    stat(out, "incr_misses", ops.incr_misses);
    stat(out, "decr_hits", ops.decr_hits);
    stat(out, "decr_misses", ops.decr_misses);
    stat(out, "cas_hits", ops.cas_hits);
    stat(out, "cas_misses", ops.cas_misses);
    stat(out, "cas_badval", ops.cas_badval);
    stat(out, "touch_hits", ops.touch_hits);
    stat(out, "touch_misses", ops.touch_misses);
    stat(out, "evictions", ops.evictions);
    stat(out, "expired_unfetched", ops.expired_reclaims);
    stat(out, "slab_reconfigures", ops.reconfigures);
    stat(out, "maintainer_runs", ops.maintainer_runs);
    stat(out, "maintainer_demoted", ops.maintainer_demoted);
    stat(out, "maintainer_pages_shed", ops.maintainer_pages_shed);
    stat(out, "seqlock_retries", ops.seqlock_retries);
    stat(out, "seqlock_fallbacks", ops.seqlock_fallbacks);
    stat(out, "lru_bump_queued", ops.lru_bump_queued);
    stat(out, "lru_bump_drained", ops.lru_bump_drained);
    stat(out, "lru_bump_dropped", ops.lru_bump_dropped);
    stat(out, "bytes", slabs.requested_bytes);
    stat(out, "bytes_allocated", slabs.allocated_bytes);
    stat(out, "bytes_wasted", slabs.hole_bytes);
    stat(out, "limit_maxbytes", slabs.page_budget * slabs.page_size);
    stat(out, "total_pages", slabs.pages_allocated);
    // Warm-restart gauges are boot-scoped: they describe how THIS process
    // came up and survive `stats reset` (window counters above restart at
    // zero after a warm boot — recovery is not traffic).
    stat(out, "restart_state", restart.state);
    if !restart.reason.is_empty() {
        stat(out, "restart_reason", &restart.reason);
    }
    stat(out, "restart_items_recovered", restart.items_recovered);
    stat(out, "restart_items_discarded", restart.items_discarded);
    stat(out, "restart_duration_ms", restart.duration_ms);
    out.extend_from_slice(b"END\r\n");
}

/// Render `stats slabs` (one row group per active class, plus the
/// incremental-migration and async-optimize gauges). While a
/// reconfiguration drains, per-class rows cover **both** generations,
/// so the hole accounting stays honest mid-migration. The `optimize_*`
/// gauges are where an async `slabs optimize` reports its outcome —
/// the control reply is just `OPTIMIZING`.
pub fn render_slabs(
    out: &mut Vec<u8>,
    slabs: &SlabStats,
    mig: &MigrationGauges,
    opt: &OptimizeGauges,
) {
    for (i, c) in slabs.per_class.iter().enumerate() {
        if c.pages == 0 {
            continue; // memcached omits classes with no pages
        }
        let id = i + 1; // memcached class ids start at 1
        stat(out, &format!("{id}:chunk_size"), c.chunk_size);
        stat(out, &format!("{id}:total_pages"), c.pages);
        stat(out, &format!("{id}:total_chunks"), c.total_chunks);
        stat(out, &format!("{id}:used_chunks"), c.used_chunks);
        stat(out, &format!("{id}:free_chunks"), c.free_chunks);
        stat(out, &format!("{id}:mem_requested"), c.requested_bytes);
        stat(out, &format!("{id}:mem_allocated"), c.allocated_bytes);
        stat(out, &format!("{id}:mem_wasted"), c.hole_bytes);
    }
    stat(out, "active_slabs", slabs.per_class.iter().filter(|c| c.pages > 0).count());
    stat(out, "total_malloced", slabs.pages_allocated * slabs.page_size);
    stat(out, "total_pages_free", slabs.pages_free);
    stat(out, "migration_active", mig.active_shards);
    stat(out, "migration_moved", mig.moved);
    stat(out, "migration_dropped", mig.dropped);
    stat(out, "migration_pages_reclaimed", mig.pages_reclaimed);
    stat(out, "migration_force_drained_pages", mig.force_drained_pages);
    stat(out, "migration_force_dropped", mig.force_dropped);
    stat(out, "migration_items_remaining", mig.items_remaining);
    stat(out, "optimize_pending", u64::from(opt.pending));
    stat(out, "optimize_runs", opt.runs);
    stat(out, "optimize_applied", opt.applied);
    stat(out, "optimize_last_recovery_bp", opt.last_recovery_bp);
    stat(out, "collector_overflow", opt.collector_overflow);
    out.extend_from_slice(b"END\r\n");
}

/// Render `stats tenants` — one `STAT <id>:<field>` row group per
/// defined tenant (id 0 is the default tenant), mirroring the
/// `stats slabs` per-class layout so existing stat scrapers parse it.
pub fn render_tenants(out: &mut Vec<u8>, tenants: &[TenantStat]) {
    for t in tenants {
        let id = t.id;
        stat(out, &format!("{id}:name"), &t.name);
        stat(out, &format!("{id}:get_hits"), t.hits);
        stat(out, &format!("{id}:get_misses"), t.misses);
        stat(out, &format!("{id}:cmd_get"), t.gets);
        stat(out, &format!("{id}:cmd_set"), t.sets);
        stat(out, &format!("{id}:bytes"), t.bytes_live);
        stat(out, &format!("{id}:curr_items"), t.items_live);
        stat(out, &format!("{id}:bytes_written"), t.bytes_written);
        stat(out, &format!("{id}:evictions"), t.evictions);
        stat(out, &format!("{id}:quota_evictions"), t.quota_evictions);
        stat(out, &format!("{id}:quota_pages"), t.quota_pages);
        stat(out, &format!("{id}:used_pages"), t.used_pages);
    }
    out.extend_from_slice(b"END\r\n");
}

/// Render `stats sizes` from the collector histogram (32-byte buckets,
/// memcached's format: `STAT <bucket_upper> <count>`).
pub fn render_sizes(out: &mut Vec<u8>, hist: &SizeHistogram) {
    let mut bucket_upper = 32usize;
    let mut in_bucket = 0u64;
    for (size, count) in hist.iter() {
        while size > bucket_upper {
            if in_bucket > 0 {
                stat(out, &bucket_upper.to_string(), in_bucket);
            }
            in_bucket = 0;
            bucket_upper += 32;
        }
        in_bucket += count;
    }
    if in_bucket > 0 {
        stat(out, &bucket_upper.to_string(), in_bucket);
    }
    out.extend_from_slice(b"END\r\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::SlabAllocator;

    fn slab_stats_with_items() -> SlabStats {
        let mut a = SlabAllocator::new(&ChunkSizePolicy::default(), 1 << 20, 8 << 20).unwrap();
        a.alloc(518).unwrap();
        a.alloc(100).unwrap();
        a.stats()
    }

    fn text(out: &[u8]) -> String {
        String::from_utf8(out.to_vec()).unwrap()
    }

    #[test]
    fn general_stats_contain_waste() {
        let mut out = Vec::new();
        let conns = ConnCounters {
            curr: 3,
            total: 9,
            rejected: 1,
            yields: 4,
            shed: 2,
            buffer_bytes: 8192,
            thread_restarts: 0,
            ..ConnCounters::default()
        };
        render_general(
            &mut out,
            &StoreStats::default(),
            &slab_stats_with_items(),
            2,
            5,
            &conns,
            &RestartSnapshot::default(),
        );
        let t = text(&out);
        assert!(t.contains("STAT curr_items 2"));
        assert!(t.contains("STAT bytes 618"));
        assert!(t.contains("STAT bytes_wasted 102")); // (600-518)+(120-100)
        assert!(t.contains("STAT curr_connections 3"));
        assert!(t.contains("STAT total_connections 9"));
        assert!(t.contains("STAT rejected_connections 1"));
        assert!(t.contains("STAT conn_yields 4"));
        assert!(t.contains("STAT shed_connections 2"));
        assert!(t.contains("STAT conn_buffer_bytes 8192"));
        assert!(t.contains("STAT thread_restarts 0"));
        assert!(t.ends_with("END\r\n"));
    }

    #[test]
    fn slabs_stats_rows() {
        let mut out = Vec::new();
        render_slabs(
            &mut out,
            &slab_stats_with_items(),
            &MigrationGauges::default(),
            &OptimizeGauges::default(),
        );
        let t = text(&out);
        // 518 -> class id 9 (600 bytes) with memcached numbering from 1
        assert!(t.contains(":chunk_size 600"), "{t}");
        assert!(t.contains(":mem_wasted 82"), "{t}");
        assert!(t.contains(":chunk_size 120"), "{t}");
        assert!(t.contains("STAT active_slabs 2"), "{t}");
        // inactive classes omitted
        assert!(!t.contains(":chunk_size 96\r"), "{t}");
        // idle migration gauges render as zeros
        assert!(t.contains("STAT migration_active 0"), "{t}");
        assert!(t.contains("STAT migration_moved 0"), "{t}");
    }

    #[test]
    fn slabs_stats_migration_gauges() {
        let mut out = Vec::new();
        let mig = MigrationGauges {
            active_shards: 2,
            moved: 1500,
            dropped: 3,
            pages_reclaimed: 7,
            force_drained_pages: 2,
            force_dropped: 3,
            items_remaining: 420,
        };
        let opt = OptimizeGauges {
            pending: true,
            runs: 4,
            applied: 2,
            last_recovery_bp: 3100,
            collector_overflow: 17,
        };
        render_slabs(&mut out, &slab_stats_with_items(), &mig, &opt);
        let t = text(&out);
        assert!(t.contains("STAT migration_active 2"), "{t}");
        assert!(t.contains("STAT migration_moved 1500"), "{t}");
        assert!(t.contains("STAT migration_dropped 3"), "{t}");
        assert!(t.contains("STAT migration_pages_reclaimed 7"), "{t}");
        assert!(t.contains("STAT migration_force_drained_pages 2"), "{t}");
        assert!(t.contains("STAT migration_force_dropped 3"), "{t}");
        assert!(t.contains("STAT migration_items_remaining 420"), "{t}");
        assert!(t.contains("STAT optimize_pending 1"), "{t}");
        assert!(t.contains("STAT optimize_runs 4"), "{t}");
        assert!(t.contains("STAT optimize_applied 2"), "{t}");
        assert!(t.contains("STAT optimize_last_recovery_bp 3100"), "{t}");
        assert!(t.contains("STAT collector_overflow 17"), "{t}");
    }

    #[test]
    fn tenants_stats_rows() {
        let mut out = Vec::new();
        let rows = vec![
            TenantStat {
                id: 0,
                name: "default".into(),
                gets: 10,
                hits: 7,
                misses: 3,
                sets: 4,
                bytes_live: 4096,
                items_live: 2,
                bytes_written: 9000,
                evictions: 1,
                quota_evictions: 0,
                quota_pages: 0,
                used_pages: 0,
            },
            TenantStat {
                id: 1,
                name: "acme".into(),
                quota_pages: 8,
                quota_evictions: 5,
                ..TenantStat::default()
            },
        ];
        render_tenants(&mut out, &rows);
        let t = text(&out);
        assert!(t.contains("STAT 0:name default"), "{t}");
        assert!(t.contains("STAT 0:get_hits 7"), "{t}");
        assert!(t.contains("STAT 0:get_misses 3"), "{t}");
        assert!(t.contains("STAT 0:cmd_set 4"), "{t}");
        assert!(t.contains("STAT 0:bytes 4096"), "{t}");
        assert!(t.contains("STAT 1:name acme"), "{t}");
        assert!(t.contains("STAT 1:quota_pages 8"), "{t}");
        assert!(t.contains("STAT 1:quota_evictions 5"), "{t}");
        assert!(t.ends_with("END\r\n"));
    }

    #[test]
    fn general_stats_contain_maintainer_counters() {
        let mut out = Vec::new();
        let ops = StoreStats {
            maintainer_runs: 12,
            maintainer_demoted: 340,
            maintainer_pages_shed: 2,
            ..StoreStats::default()
        };
        render_general(
            &mut out,
            &ops,
            &slab_stats_with_items(),
            0,
            0,
            &ConnCounters::default(),
            &RestartSnapshot::default(),
        );
        let t = text(&out);
        assert!(t.contains("STAT maintainer_runs 12"), "{t}");
        assert!(t.contains("STAT maintainer_demoted 340"), "{t}");
        assert!(t.contains("STAT maintainer_pages_shed 2"), "{t}");
    }

    #[test]
    fn general_stats_contain_optimistic_read_counters() {
        let mut out = Vec::new();
        let ops = StoreStats {
            seqlock_retries: 7,
            seqlock_fallbacks: 3,
            lru_bump_queued: 40,
            lru_bump_drained: 38,
            lru_bump_dropped: 2,
            ..StoreStats::default()
        };
        render_general(
            &mut out,
            &ops,
            &slab_stats_with_items(),
            0,
            0,
            &ConnCounters::default(),
            &RestartSnapshot::default(),
        );
        let t = text(&out);
        assert!(t.contains("STAT seqlock_retries 7"), "{t}");
        assert!(t.contains("STAT seqlock_fallbacks 3"), "{t}");
        assert!(t.contains("STAT lru_bump_queued 40"), "{t}");
        assert!(t.contains("STAT lru_bump_drained 38"), "{t}");
        assert!(t.contains("STAT lru_bump_dropped 2"), "{t}");
    }

    #[test]
    fn general_stats_contain_frontend_counters() {
        let mut out = Vec::new();
        let conns = ConnCounters {
            cross_shard: 11,
            udp_rx: 120,
            udp_tx: 150,
            udp_oversized: 2,
            udp_bad: 5,
            ..ConnCounters::default()
        };
        render_general(
            &mut out,
            &StoreStats::default(),
            &slab_stats_with_items(),
            0,
            0,
            &conns,
            &RestartSnapshot::default(),
        );
        let t = text(&out);
        assert!(t.contains("STAT reactor_cross_shard 11"), "{t}");
        assert!(t.contains("STAT udp_datagrams_rx 120"), "{t}");
        assert!(t.contains("STAT udp_datagrams_tx 150"), "{t}");
        assert!(t.contains("STAT udp_oversized_drops 2"), "{t}");
        assert!(t.contains("STAT udp_bad_frames 5"), "{t}");
    }

    #[test]
    fn general_stats_contain_restart_gauges() {
        let mut out = Vec::new();
        let restart = RestartSnapshot {
            state: "warm",
            reason: String::new(),
            items_recovered: 499,
            items_discarded: 1,
            duration_ms: 12,
        };
        render_general(
            &mut out,
            &StoreStats::default(),
            &slab_stats_with_items(),
            0,
            0,
            &ConnCounters::default(),
            &restart,
        );
        let t = text(&out);
        assert!(t.contains("STAT restart_state warm"), "{t}");
        assert!(!t.contains("restart_reason"), "{t}");
        assert!(t.contains("STAT restart_items_recovered 499"), "{t}");
        assert!(t.contains("STAT restart_items_discarded 1"), "{t}");
        assert!(t.contains("STAT restart_duration_ms 12"), "{t}");

        let mut out = Vec::new();
        let restart = RestartSnapshot {
            state: "cold",
            reason: "dirty-shutdown marker present".into(),
            ..RestartSnapshot::default()
        };
        render_general(
            &mut out,
            &StoreStats::default(),
            &slab_stats_with_items(),
            0,
            0,
            &ConnCounters::default(),
            &restart,
        );
        let t = text(&out);
        assert!(t.contains("STAT restart_state cold"), "{t}");
        assert!(t.contains("STAT restart_reason dirty-shutdown marker present"), "{t}");
    }

    #[test]
    fn sizes_histogram_buckets() {
        let mut h = SizeHistogram::new(4096);
        h.record_n(10, 3); // bucket 32
        h.record_n(33, 2); // bucket 64
        h.record_n(64, 1); // bucket 64
        h.record_n(1000, 5); // bucket 1024 (31*32=992 < 1000 <= 1024)
        let mut out = Vec::new();
        render_sizes(&mut out, &h);
        let t = text(&out);
        assert!(t.contains("STAT 32 3"), "{t}");
        assert!(t.contains("STAT 64 3"), "{t}");
        assert!(t.contains("STAT 1024 5"), "{t}");
        assert!(t.ends_with("END\r\n"));
    }

    #[test]
    fn sizes_empty() {
        let mut out = Vec::new();
        render_sizes(&mut out, &SizeHistogram::new(64));
        assert_eq!(text(&out), "END\r\n");
    }
}
