//! Memcached **text protocol**: command parsing, response rendering and
//! the `stats`-family introspection the paper's measurements come from
//! (`stats slabs` exposes per-class hole accounting), plus two
//! slabforge extensions:
//!
//! * `slabs reconfigure <size,...>` — live-apply a learned chunk-size
//!   configuration (the online analog of restarting with
//!   `-o slab_sizes=...`).
//! * `slabs optimize` — trigger the learned-slab-classes optimizer now.

pub mod parse;
pub mod response;
pub mod stats;

pub use parse::{parse_command, Command, ParseError, StoreOp};
