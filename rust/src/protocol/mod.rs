//! Protocol layer: **two wire dialects, one command IR**.
//!
//! * [`request`] — the unified IR ([`Request`]: opcode + key + flag
//!   set + optional data block) both front-ends compile to, executed
//!   dialect-blind by `server::conn`.
//! * [`parse`] — the classic text dialect (`get`/`set`/... plus the
//!   `gat`/`gats` get-and-touch verbs) and the verb dispatcher
//!   ([`parse_command`]).
//! * [`meta`] — the meta dialect (`mg`/`ms`/`md`/`ma`/`mn`) with its
//!   flag grammar (quiet pipelines, touch-on-read, vivify-on-miss,
//!   base64 keys, CAS-carrying delete/arith).
//! * [`writer`] — [`ResponseWriter`]: one semantic response surface
//!   rendered into whichever dialect the request arrived in, over the
//!   transport-pluggable [`RespSink`].
//! * [`response`] — low-level classic line encoders (the writer's
//!   byte layer; the hit path is allocation- and `fmt`-free).
//! * [`stats`] — `stats`-family introspection the paper's measurements
//!   come from (`stats slabs` exposes per-class hole accounting).
//!
//! Slabforge extensions (classic dialect):
//!
//! * `slabs reconfigure <size,...>` — live-apply a learned chunk-size
//!   configuration (the online analog of restarting with
//!   `-o slab_sizes=...`).
//! * `slabs optimize` — trigger the learned-slab-classes optimizer now.
//! * `stats reset` — zero the resettable counters (memcached parity).

pub mod meta;
pub mod parse;
pub mod request;
pub mod response;
pub mod stats;
pub mod writer;

pub use parse::{parse_command, ParseError};
pub use request::{want, DataRequest, Dialect, Opcode, Request};
pub use writer::{BufSink, RespSink, ResponseWriter};
