//! Response rendering: append protocol lines into the connection's
//! write buffer (no intermediate allocations on the hot path).

use crate::store::store::Value;

pub fn value(out: &mut Vec<u8>, key: &[u8], v: &Value, with_cas: bool) {
    out.extend_from_slice(b"VALUE ");
    out.extend_from_slice(key);
    if with_cas {
        append_fmt(out, format_args!(" {} {} {}", v.flags, v.value.len(), v.cas));
    } else {
        append_fmt(out, format_args!(" {} {}", v.flags, v.value.len()));
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(&v.value);
    out.extend_from_slice(b"\r\n");
}

pub fn end(out: &mut Vec<u8>) {
    out.extend_from_slice(b"END\r\n");
}

pub fn stored(out: &mut Vec<u8>) {
    out.extend_from_slice(b"STORED\r\n");
}

pub fn not_stored(out: &mut Vec<u8>) {
    out.extend_from_slice(b"NOT_STORED\r\n");
}

pub fn exists(out: &mut Vec<u8>) {
    out.extend_from_slice(b"EXISTS\r\n");
}

pub fn not_found(out: &mut Vec<u8>) {
    out.extend_from_slice(b"NOT_FOUND\r\n");
}

pub fn deleted(out: &mut Vec<u8>) {
    out.extend_from_slice(b"DELETED\r\n");
}

pub fn touched(out: &mut Vec<u8>) {
    out.extend_from_slice(b"TOUCHED\r\n");
}

pub fn ok(out: &mut Vec<u8>) {
    out.extend_from_slice(b"OK\r\n");
}

pub fn number(out: &mut Vec<u8>, n: u64) {
    append_fmt(out, format_args!("{n}"));
    out.extend_from_slice(b"\r\n");
}

pub fn version(out: &mut Vec<u8>, v: &str) {
    append_fmt(out, format_args!("VERSION {v}"));
    out.extend_from_slice(b"\r\n");
}

pub fn error(out: &mut Vec<u8>) {
    out.extend_from_slice(b"ERROR\r\n");
}

pub fn client_error(out: &mut Vec<u8>, msg: &str) {
    append_fmt(out, format_args!("CLIENT_ERROR {msg}"));
    out.extend_from_slice(b"\r\n");
}

pub fn server_error(out: &mut Vec<u8>, msg: &str) {
    append_fmt(out, format_args!("SERVER_ERROR {msg}"));
    out.extend_from_slice(b"\r\n");
}

pub fn stat(out: &mut Vec<u8>, name: &str, value: impl std::fmt::Display) {
    append_fmt(out, format_args!("STAT {name} {value}"));
    out.extend_from_slice(b"\r\n");
}

fn append_fmt(out: &mut Vec<u8>, args: std::fmt::Arguments<'_>) {
    use std::io::Write;
    out.write_fmt(args).expect("Vec write is infallible");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_line_format() {
        let mut out = Vec::new();
        let v = Value {
            value: b"world".to_vec(),
            flags: 7,
            cas: 42,
        };
        value(&mut out, b"hello", &v, false);
        assert_eq!(out, b"VALUE hello 7 5\r\nworld\r\n");
        out.clear();
        value(&mut out, b"hello", &v, true);
        assert_eq!(out, b"VALUE hello 7 5 42\r\nworld\r\n");
    }

    #[test]
    fn simple_lines() {
        let mut out = Vec::new();
        stored(&mut out);
        end(&mut out);
        number(&mut out, 15);
        stat(&mut out, "evictions", 3);
        client_error(&mut out, "oops");
        assert_eq!(
            out,
            b"STORED\r\nEND\r\n15\r\nSTAT evictions 3\r\nCLIENT_ERROR oops\r\n"
        );
    }

    #[test]
    fn binary_safe_values() {
        let mut out = Vec::new();
        let v = Value {
            value: vec![0, 1, 2, 255, 13, 10],
            flags: 0,
            cas: 0,
        };
        value(&mut out, b"bin", &v, false);
        assert!(out.windows(6).any(|w| w == [0, 1, 2, 255, 13, 10]));
    }
}
