//! Response rendering: append protocol lines into the connection's
//! write buffer. The hit path (`value_ref`) is allocation- and
//! `fmt`-free: header integers go through [`push_u64`] and the value
//! bytes are copied once, straight from the slab chunk the
//! [`ValueRef`] borrows.

use crate::store::store::{Value, ValueRef};
use crate::util::fmt::{push_u64, push_usize};

/// `VALUE <key> <flags> <bytes>[ <cas>]\r\n` — the header line alone,
/// without the data block. The writev scatter path (`server::conn`)
/// encodes the header into the output buffer and hands the chunk bytes
/// to the kernel as a separate iovec, skipping the chunk→buffer copy.
pub fn value_header(out: &mut Vec<u8>, key: &[u8], data_len: usize, flags: u32, cas: Option<u64>) {
    // header ~= "VALUE " + key + 3-4 integers + separators; 48 covers
    // the worst case (u32 + usize + u64 digits + spaces + CRLFs)
    out.reserve(key.len() + 48);
    out.extend_from_slice(b"VALUE ");
    out.extend_from_slice(key);
    out.push(b' ');
    push_u64(out, flags as u64);
    out.push(b' ');
    push_usize(out, data_len);
    if let Some(cas) = cas {
        out.push(b' ');
        push_u64(out, cas);
    }
    out.extend_from_slice(b"\r\n");
}

/// `VALUE <key> <flags> <bytes>[ <cas>]\r\n<data>\r\n` from a borrowed
/// value — the zero-copy get path's encoder, run under the shard lock.
pub fn value_ref(out: &mut Vec<u8>, key: &[u8], v: ValueRef<'_>, with_cas: bool) {
    out.reserve(key.len() + v.data.len() + 48);
    value_header(out, key, v.data.len(), v.flags, with_cas.then_some(v.cas));
    out.extend_from_slice(v.data);
    out.extend_from_slice(b"\r\n");
}

pub fn value(out: &mut Vec<u8>, key: &[u8], v: &Value, with_cas: bool) {
    value_ref(
        out,
        key,
        ValueRef {
            data: &v.value,
            flags: v.flags,
            cas: v.cas,
        },
        with_cas,
    );
}

pub fn end(out: &mut Vec<u8>) {
    out.extend_from_slice(b"END\r\n");
}

pub fn stored(out: &mut Vec<u8>) {
    out.extend_from_slice(b"STORED\r\n");
}

pub fn not_stored(out: &mut Vec<u8>) {
    out.extend_from_slice(b"NOT_STORED\r\n");
}

pub fn exists(out: &mut Vec<u8>) {
    out.extend_from_slice(b"EXISTS\r\n");
}

pub fn not_found(out: &mut Vec<u8>) {
    out.extend_from_slice(b"NOT_FOUND\r\n");
}

pub fn deleted(out: &mut Vec<u8>) {
    out.extend_from_slice(b"DELETED\r\n");
}

pub fn touched(out: &mut Vec<u8>) {
    out.extend_from_slice(b"TOUCHED\r\n");
}

pub fn ok(out: &mut Vec<u8>) {
    out.extend_from_slice(b"OK\r\n");
}

/// `stats reset` acknowledgement (memcached parity).
pub fn reset(out: &mut Vec<u8>) {
    out.extend_from_slice(b"RESET\r\n");
}

pub fn number(out: &mut Vec<u8>, n: u64) {
    push_u64(out, n);
    out.extend_from_slice(b"\r\n");
}

pub fn error(out: &mut Vec<u8>) {
    out.extend_from_slice(b"ERROR\r\n");
}

pub fn client_error(out: &mut Vec<u8>, msg: &str) {
    append_fmt(out, format_args!("CLIENT_ERROR {msg}"));
    out.extend_from_slice(b"\r\n");
}

pub fn server_error(out: &mut Vec<u8>, msg: &str) {
    append_fmt(out, format_args!("SERVER_ERROR {msg}"));
    out.extend_from_slice(b"\r\n");
}

pub fn stat(out: &mut Vec<u8>, name: &str, value: impl std::fmt::Display) {
    append_fmt(out, format_args!("STAT {name} {value}"));
    out.extend_from_slice(b"\r\n");
}

fn append_fmt(out: &mut Vec<u8>, args: std::fmt::Arguments<'_>) {
    use std::io::Write;
    out.write_fmt(args).expect("Vec write is infallible");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_line_format() {
        let mut out = Vec::new();
        let v = Value {
            value: b"world".to_vec(),
            flags: 7,
            cas: 42,
        };
        value(&mut out, b"hello", &v, false);
        assert_eq!(out, b"VALUE hello 7 5\r\nworld\r\n");
        out.clear();
        value(&mut out, b"hello", &v, true);
        assert_eq!(out, b"VALUE hello 7 5 42\r\nworld\r\n");
    }

    #[test]
    fn simple_lines() {
        let mut out = Vec::new();
        stored(&mut out);
        end(&mut out);
        number(&mut out, 15);
        stat(&mut out, "evictions", 3);
        client_error(&mut out, "oops");
        assert_eq!(
            out,
            b"STORED\r\nEND\r\n15\r\nSTAT evictions 3\r\nCLIENT_ERROR oops\r\n"
        );
    }

    #[test]
    fn value_ref_matches_value() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        let v = Value {
            value: b"payload".to_vec(),
            flags: u32::MAX,
            cas: u64::MAX,
        };
        value(&mut a, b"k", &v, true);
        value_ref(
            &mut b,
            b"k",
            ValueRef {
                data: b"payload",
                flags: u32::MAX,
                cas: u64::MAX,
            },
            true,
        );
        assert_eq!(a, b);
        assert_eq!(
            String::from_utf8_lossy(&a),
            format!("VALUE k {} 7 {}\r\npayload\r\n", u32::MAX, u64::MAX)
        );
    }

    #[test]
    fn binary_safe_values() {
        let mut out = Vec::new();
        let v = Value {
            value: vec![0, 1, 2, 255, 13, 10],
            flags: 0,
            cas: 0,
        };
        value(&mut out, b"bin", &v, false);
        assert!(out.windows(6).any(|w| w == [0, 1, 2, 255, 13, 10]));
    }
}
