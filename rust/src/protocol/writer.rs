//! Response abstraction: [`RespSink`] is *where* bytes go (buffer,
//! bounded socket-aware sink), [`ResponseWriter`] is *what* they say —
//! one semantic surface (`value`/`stored`/`not_found`/...) rendered
//! into whichever wire dialect the request arrived in. The execution
//! core in `server::conn` speaks only to the writer, which is what
//! lets two front-ends share it.
//!
//! Dialect differences the writer owns:
//!
//! * **Classic**: word responses (`STORED`, `VALUE k f n`, `END`,
//!   `DELETED`...); `noreply` suppresses *everything*.
//! * **Meta**: code + echo-flag responses (`HD f7 c42 kfoo`,
//!   `VA 5 c42`, `EN`, `NS`, `EX`, `NF`); `q` suppresses only the
//!   *expected* outcome — misses for `mg`, successes for
//!   `ms`/`md`/`ma` — while hits and errors always flow. Echo flags
//!   render in canonical order `f c t l h s k O`, then the win-race
//!   markers: `W` (this reader won the vivify/recache race), `Z` (a
//!   prior reader holds the win), `X` (the item is stale).

use super::request::{want, DataRequest, Dialect, Request};
use super::response;
use crate::store::store::{MetaHit, StoreError, ValueRef};
use crate::util::fmt::{push_i64, push_u64, push_usize, u64_digits};

/// Where protocol responses land. The writer appends every response
/// into `buf()`; `value()` is the one hook a transport-aware sink can
/// override to scatter a large value straight to the socket (`writev`)
/// instead of copying chunk → buffer. `saturated()` lets a bounded sink
/// pause command execution mid-pipeline (backpressure): the connection
/// stops parsing, keeps the unread tail buffered, and resumes when the
/// sink drains.
pub trait RespSink {
    fn buf(&mut self) -> &mut Vec<u8>;

    /// Encode one classic `VALUE` response (called under the shard
    /// lock, so implementations must not block indefinitely).
    fn value(&mut self, key: &[u8], v: ValueRef<'_>, with_cas: bool) {
        response::value_ref(self.buf(), key, v, with_cas);
    }

    /// Append a response data block + trailing CRLF whose header line
    /// is already encoded in `buf()` — the meta `VA` body. A
    /// socket-aware sink may hand large blocks to the kernel directly
    /// (scatter) instead of copying them into the buffer.
    fn append_data(&mut self, data: &[u8]) {
        let out = self.buf();
        out.extend_from_slice(data);
        out.extend_from_slice(b"\r\n");
    }

    /// True when the sink cannot absorb more responses right now.
    fn saturated(&self) -> bool {
        false
    }
}

/// Plain unbounded buffer sink — the in-memory/test path and the legacy
/// threaded server.
pub struct BufSink<'a>(pub &'a mut Vec<u8>);

impl RespSink for BufSink<'_> {
    fn buf(&mut self) -> &mut Vec<u8> {
        self.0
    }
}

/// Values a meta response may echo; `None` fields render nothing even
/// when requested (e.g. no CAS on an `EN` miss).
#[derive(Default, Clone, Copy)]
struct Echo<'e> {
    flags: Option<u32>,
    cas: Option<u64>,
    ttl: Option<i64>,
    /// Seconds since last access (the `l` echo).
    la: Option<u32>,
    /// Hit-before bit (the `h` echo).
    fetched: Option<bool>,
    size: Option<usize>,
    key: Option<&'e [u8]>,
    opaque: Option<&'e [u8]>,
    won: bool,
    /// Another reader already holds the recache win (the `Z` echo).
    lost: bool,
    /// The item was served stale (the `X` echo).
    stale: bool,
}

/// Per-request response renderer over a [`RespSink`].
pub struct ResponseWriter<'a, S: RespSink> {
    sink: &'a mut S,
    dialect: Dialect,
    quiet: bool,
    want: u16,
    key_echo: &'a [u8],
    opaque: &'a [u8],
    with_cas: bool,
}

impl<'a, S: RespSink> ResponseWriter<'a, S> {
    /// Writer for a line-phase request (borrows its echo tokens).
    pub fn for_request(sink: &'a mut S, req: &Request<'a>) -> ResponseWriter<'a, S> {
        ResponseWriter {
            sink,
            dialect: req.dialect,
            quiet: req.quiet,
            want: req.want,
            key_echo: req.key_echo,
            opaque: req.opaque,
            with_cas: req.with_cas,
        }
    }

    /// Writer for a data-phase (storage) request.
    pub fn for_data(sink: &'a mut S, req: &'a DataRequest) -> ResponseWriter<'a, S> {
        ResponseWriter {
            sink,
            dialect: req.dialect,
            quiet: req.quiet,
            want: req.want,
            key_echo: &req.key_echo,
            opaque: &req.opaque,
            with_cas: false,
        }
    }

    /// Classic-dialect writer with no echo state (admin commands).
    pub fn classic(sink: &'a mut S, quiet: bool) -> ResponseWriter<'a, S> {
        ResponseWriter {
            sink,
            dialect: Dialect::Classic,
            quiet,
            want: 0,
            key_echo: b"",
            opaque: b"",
            with_cas: false,
        }
    }

    /// Classic `noreply` swallows every response of the command.
    #[inline]
    fn gag(&self) -> bool {
        self.dialect == Dialect::Classic && self.quiet
    }

    /// Direct access to the sink's output buffer. The optimistic read
    /// path records a length mark before encoding and truncates back to
    /// it when the post-encode seqlock validation fails.
    #[inline]
    pub fn buf(&mut self) -> &mut Vec<u8> {
        self.sink.buf()
    }

    /// Append `<code>[ <size>]<echo flags>\r\n[<data>\r\n]`. The data
    /// block goes through [`RespSink::append_data`], so a socket-aware
    /// sink scatters large meta values exactly like classic `VALUE`s.
    fn meta_respond(&mut self, code: &[u8], e: &Echo<'_>, data: Option<&[u8]>) {
        let out = self.sink.buf();
        out.extend_from_slice(code);
        if let Some(d) = data {
            out.push(b' ');
            push_usize(out, d.len());
        }
        if self.want & want::FLAGS != 0 {
            if let Some(f) = e.flags {
                out.extend_from_slice(b" f");
                push_u64(out, f as u64);
            }
        }
        if self.want & want::CAS != 0 {
            if let Some(c) = e.cas {
                out.extend_from_slice(b" c");
                push_u64(out, c);
            }
        }
        if self.want & want::TTL != 0 {
            if let Some(t) = e.ttl {
                out.extend_from_slice(b" t");
                push_i64(out, t);
            }
        }
        if self.want & want::LA != 0 {
            if let Some(la) = e.la {
                out.extend_from_slice(b" l");
                push_u64(out, la as u64);
            }
        }
        if self.want & want::HIT != 0 {
            if let Some(h) = e.fetched {
                out.extend_from_slice(if h { b" h1" } else { b" h0" });
            }
        }
        if self.want & want::SIZE != 0 {
            if let Some(s) = e.size {
                out.extend_from_slice(b" s");
                push_usize(out, s);
            }
        }
        if self.want & want::KEY != 0 {
            if let Some(k) = e.key {
                out.extend_from_slice(b" k");
                out.extend_from_slice(k);
            }
        }
        if self.want & want::OPAQUE != 0 {
            if let Some(o) = e.opaque {
                out.extend_from_slice(b" O");
                out.extend_from_slice(o);
            }
        }
        if e.won {
            out.extend_from_slice(b" W");
        } else if e.lost {
            out.extend_from_slice(b" Z");
        }
        if e.stale {
            out.extend_from_slice(b" X");
        }
        out.extend_from_slice(b"\r\n");
        if let Some(d) = data {
            self.sink.append_data(d);
        }
    }

    /// Echo skeleton carrying the request identity (key + opaque).
    fn base_echo(&self) -> Echo<'a> {
        Echo {
            key: Some(self.key_echo),
            opaque: Some(self.opaque),
            ..Echo::default()
        }
    }

    // ------------------------------------------------------- retrieval

    /// A retrieval hit. `key` is the lookup key (classic rendering);
    /// meta rendering echoes the request's own key token. Meta hits are
    /// never quiet-suppressed (only misses are).
    pub fn value(&mut self, key: &[u8], v: ValueRef<'_>, hit: MetaHit) {
        match self.dialect {
            Dialect::Classic => {
                if self.gag() {
                    return;
                }
                self.sink.value(key, v, self.with_cas);
            }
            Dialect::Meta => {
                let e = Echo {
                    flags: Some(v.flags),
                    cas: Some(v.cas),
                    ttl: Some(hit.ttl),
                    la: Some(hit.la),
                    fetched: Some(hit.fetched),
                    size: Some(v.data.len()),
                    won: hit.won,
                    lost: hit.lost,
                    stale: hit.stale,
                    ..self.base_echo()
                };
                if self.want & want::VALUE != 0 {
                    self.meta_respond(b"VA", &e, Some(v.data));
                } else {
                    self.meta_respond(b"HD", &e, None);
                }
            }
        }
    }

    /// A retrieval miss. Classic emits nothing per-key (`END` closes
    /// the response); meta emits `EN` unless quiet.
    pub fn miss(&mut self) {
        if self.dialect == Dialect::Meta && !self.quiet {
            let e = self.base_echo();
            self.meta_respond(b"EN", &e, None);
        }
    }

    /// Classic retrieval terminator (`END`); meta has none.
    pub fn end(&mut self) {
        if self.dialect == Dialect::Classic && !self.gag() {
            response::end(self.sink.buf());
        }
    }

    // --------------------------------------------------------- storage

    /// Store succeeded; `cas` is the item's new CAS.
    pub fn stored(&mut self, cas: u64) {
        match self.dialect {
            Dialect::Classic => {
                if !self.gag() {
                    response::stored(self.sink.buf());
                }
            }
            Dialect::Meta => {
                if !self.quiet {
                    let e = Echo {
                        cas: Some(cas),
                        ..self.base_echo()
                    };
                    self.meta_respond(b"HD", &e, None);
                }
            }
        }
    }

    /// Store rejected by mode (add-on-present / replace-on-absent /
    /// concat-on-absent). Not quiet-suppressed in meta.
    pub fn not_stored(&mut self) {
        match self.dialect {
            Dialect::Classic => {
                if !self.gag() {
                    response::not_stored(self.sink.buf());
                }
            }
            Dialect::Meta => {
                let e = self.base_echo();
                self.meta_respond(b"NS", &e, None);
            }
        }
    }

    /// CAS guard mismatch. Not quiet-suppressed in meta.
    pub fn exists(&mut self) {
        match self.dialect {
            Dialect::Classic => {
                if !self.gag() {
                    response::exists(self.sink.buf());
                }
            }
            Dialect::Meta => {
                let e = self.base_echo();
                self.meta_respond(b"EX", &e, None);
            }
        }
    }

    /// Keyed mutation on an absent item. Not quiet-suppressed in meta.
    pub fn not_found(&mut self) {
        match self.dialect {
            Dialect::Classic => {
                if !self.gag() {
                    response::not_found(self.sink.buf());
                }
            }
            Dialect::Meta => {
                let e = self.base_echo();
                self.meta_respond(b"NF", &e, None);
            }
        }
    }

    /// Delete succeeded.
    pub fn deleted(&mut self) {
        match self.dialect {
            Dialect::Classic => {
                if !self.gag() {
                    response::deleted(self.sink.buf());
                }
            }
            Dialect::Meta => {
                if !self.quiet {
                    let e = self.base_echo();
                    self.meta_respond(b"HD", &e, None);
                }
            }
        }
    }

    /// Classic `touch` succeeded.
    pub fn touched(&mut self) {
        if !self.gag() {
            response::touched(self.sink.buf());
        }
    }

    /// Arithmetic succeeded: classic renders the bare number, meta
    /// `HD`/`VA` (with the new value as the data block under `v`).
    pub fn number(&mut self, n: u64, ttl: i64, cas: u64) {
        match self.dialect {
            Dialect::Classic => {
                if !self.gag() {
                    response::number(self.sink.buf(), n);
                }
            }
            Dialect::Meta => {
                if self.quiet {
                    return;
                }
                let mut digits = [0u8; 20];
                let i = u64_digits(n, &mut digits);
                let e = Echo {
                    cas: Some(cas),
                    ttl: Some(ttl),
                    size: Some(digits.len() - i),
                    ..self.base_echo()
                };
                if self.want & want::VALUE != 0 {
                    self.meta_respond(b"VA", &e, Some(&digits[i..]));
                } else {
                    self.meta_respond(b"HD", &e, None);
                }
            }
        }
    }

    // ----------------------------------------------------------- admin

    /// Meta `mn` barrier response — unconditional by design (it is the
    /// flush marker quiet pipelines wait for).
    pub fn noop(&mut self) {
        self.sink.buf().extend_from_slice(b"MN\r\n");
    }

    pub fn ok(&mut self) {
        if !self.gag() {
            response::ok(self.sink.buf());
        }
    }

    /// A raw status line (control-plane responses).
    pub fn line(&mut self, msg: &str) {
        if self.gag() {
            return;
        }
        let out = self.sink.buf();
        out.extend_from_slice(msg.as_bytes());
        out.extend_from_slice(b"\r\n");
    }

    pub fn client_error(&mut self, msg: &str) {
        if !self.gag() {
            response::client_error(self.sink.buf(), msg);
        }
    }

    pub fn server_error(&mut self, msg: &str) {
        if !self.gag() {
            response::server_error(self.sink.buf(), msg);
        }
    }

    /// Render a [`StoreError`] on the wire (same lines both dialects).
    pub fn store_error(&mut self, e: &StoreError) {
        match e {
            StoreError::BadKey => self.client_error("bad key"),
            StoreError::NonNumeric => {
                self.client_error("cannot increment or decrement non-numeric value")
            }
            StoreError::TooLarge { .. } => self.server_error("object too large for cache"),
            StoreError::OutOfMemory => self.server_error("out of memory storing object"),
            StoreError::Busy => self.server_error("slab migration already in progress"),
            StoreError::BadPolicy(_) => self.server_error("bad slab policy"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::request::Opcode;

    /// Meta request with canonical echo tokens for the writer tests.
    fn req(want: u16, quiet: bool) -> Request<'static> {
        let mut r = Request::meta(Opcode::Get);
        r.want = want;
        r.quiet = quiet;
        r.key_echo = b"kk";
        r.opaque = b"op";
        r
    }

    fn vref(data: &[u8]) -> ValueRef<'_> {
        ValueRef {
            data,
            flags: 7,
            cas: 42,
        }
    }

    fn hit(ttl: i64, won: bool) -> MetaHit {
        MetaHit {
            ttl,
            won,
            la: 0,
            fetched: false,
            stale: false,
            lost: false,
        }
    }

    #[test]
    fn meta_value_with_all_flags() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(
            want::VALUE | want::FLAGS | want::CAS | want::TTL | want::SIZE | want::KEY | want::OPAQUE,
            false,
        );
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.value(b"ignored", vref(b"hello"), hit(-1, false));
        assert_eq!(
            String::from_utf8_lossy(&out),
            "VA 5 f7 c42 t-1 s5 kkk Oop\r\nhello\r\n"
        );
    }

    #[test]
    fn meta_hd_when_no_value_flag() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(want::CAS, false);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.value(b"x", vref(b"hello"), hit(30, false));
        assert_eq!(String::from_utf8_lossy(&out), "HD c42\r\n");
    }

    #[test]
    fn meta_vivify_winner_marks_w() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(want::VALUE, false);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.value(b"x", vref(b""), hit(60, true));
        assert_eq!(String::from_utf8_lossy(&out), "VA 0 W\r\n\r\n");
    }

    #[test]
    fn meta_stale_and_lost_mark_x_and_z() {
        // Stale winner: gets both W (go recache) and X (bytes are stale).
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(want::VALUE, false);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        let mut h = hit(60, true);
        h.stale = true;
        w.value(b"x", vref(b"old"), h);
        assert_eq!(String::from_utf8_lossy(&out), "VA 3 W X\r\nold\r\n");

        // Stale loser: Z instead of W, still X.
        out.clear();
        let mut sink = BufSink(&mut out);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        let mut h = hit(60, false);
        h.stale = true;
        h.lost = true;
        w.value(b"x", vref(b"old"), h);
        assert_eq!(String::from_utf8_lossy(&out), "VA 3 Z X\r\nold\r\n");
    }

    #[test]
    fn meta_la_and_hit_echo_in_canonical_order() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(want::TTL | want::LA | want::HIT | want::SIZE, false);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.value(
            b"x",
            vref(b"hello"),
            MetaHit {
                ttl: 30,
                won: false,
                la: 7,
                fetched: true,
                stale: false,
                lost: false,
            },
        );
        assert_eq!(String::from_utf8_lossy(&out), "HD t30 l7 h1 s5\r\n");
        out.clear();
        let mut sink = BufSink(&mut out);
        let r = req(want::HIT, false);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.value(b"x", vref(b"v"), hit(-1, false));
        assert_eq!(String::from_utf8_lossy(&out), "HD h0\r\n");
    }

    #[test]
    fn meta_quiet_suppresses_miss_not_hit() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(want::VALUE, true);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.miss();
        w.value(b"x", vref(b"v"), hit(-1, false));
        assert_eq!(String::from_utf8_lossy(&out), "VA 1\r\nv\r\n");
    }

    #[test]
    fn meta_quiet_suppresses_success_not_errors() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(0, true);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.stored(9);
        w.deleted();
        w.number(5, -1, 1);
        w.not_stored();
        w.exists();
        w.not_found();
        assert_eq!(String::from_utf8_lossy(&out), "NS\r\nEX\r\nNF\r\n");
    }

    #[test]
    fn meta_miss_echoes_key_and_opaque() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(want::KEY | want::OPAQUE, false);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.miss();
        assert_eq!(String::from_utf8_lossy(&out), "EN kkk Oop\r\n");
    }

    #[test]
    fn meta_number_renders_value_block() {
        let mut out = Vec::new();
        let mut sink = BufSink(&mut out);
        let r = req(want::VALUE | want::TTL, false);
        let mut w = ResponseWriter::for_request(&mut sink, &r);
        w.number(1234, 55, 3);
        assert_eq!(String::from_utf8_lossy(&out), "VA 4 t55\r\n1234\r\n");
    }

    #[test]
    fn classic_noreply_gags_everything() {
        let mut out = Vec::new();
        {
            let mut sink = BufSink(&mut out);
            let mut w = ResponseWriter::classic(&mut sink, true);
            w.stored(1);
            w.not_found();
            w.client_error("nope");
            w.server_error("nope");
            w.number(3, -1, 0);
            w.end();
        }
        assert!(out.is_empty());
    }

    #[test]
    fn classic_words() {
        let mut out = Vec::new();
        {
            let mut sink = BufSink(&mut out);
            let mut w = ResponseWriter::classic(&mut sink, false);
            w.stored(1);
            w.not_stored();
            w.exists();
            w.not_found();
            w.deleted();
            w.touched();
            w.number(15, -1, 0);
            w.end();
        }
        assert_eq!(
            String::from_utf8(out).unwrap(),
            "STORED\r\nNOT_STORED\r\nEXISTS\r\nNOT_FOUND\r\nDELETED\r\nTOUCHED\r\n15\r\nEND\r\n"
        );
    }
}
