//! `slabforge` — memcached-compatible cache server with learned slab
//! classes (reproduction of Jhabakh Jai & Das, 2020).
//!
//! ```text
//! slabforge serve    [--config slabforge.toml] [--listen host:port]
//!                    [--mem-limit BYTES] [--shards N] [--growth-factor F]
//!                    [--slab-sizes a,b,c] [--optimizer] [--backend rust|xla]
//!                    [--algorithm paper|steepest|dp] [--artifacts DIR]
//!                    [--threads N] [--legacy-threads] [--max-conns N]
//!                    [--no-reuseport] [--udp] [--pin-cores]
//!                    [--idle-timeout SECS] [--migrate-batch N]
//!                    [--maintainer true|false] [--maintainer-interval-ms N]
//!                    [--maintainer-batch N] [--conn-buffer-budget BYTES]
//!                    [--tenants name=prefix[:quota],...]
//!                    [--tenant-arbitrate-every N] [--tenant-divergence F]
//!                    [--tenant-reclaim-batch N] [--memory-file PATH]
//! slabforge optimize --histogram sizes.csv [--k N] [--algorithm ...]
//!                    [--backend rust|xla] [--seed N]
//!                    # offline: emit a learned `-o slab_sizes` list
//! slabforge replay   --trace trace.csv [--mem-limit BYTES]
//! slabforge version
//! ```

use slabforge::config::cli::Args;
use slabforge::config::settings::{Algorithm, Backend, Settings};
use slabforge::optimizer::autotune::AutoTuner;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::optimizer::engine::{optimize, OptimizerParams, RustBackend};
use slabforge::optimizer::waste::WasteMap;
use slabforge::runtime::{XlaService, XlaWasteBackend};
use slabforge::server::{NoControl, Server};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::store::sharded::ShardedStore;
use slabforge::util::fmt::{human_bytes, human_count};
use slabforge::util::histogram::SizeHistogram;
use slabforge::workload::{Op, Trace};
use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

const SWITCHES: &[&str] = &[
    "optimizer",
    "help",
    "verbose",
    "legacy-threads",
    "no-reuseport",
    "udp",
    "pin-cores",
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(raw, SWITCHES) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("replay") => cmd_replay(&args),
        Some("version") => {
            println!("slabforge {}", env!("CARGO_PKG_VERSION"));
            0
        }
        _ => {
            eprintln!("{}", HELP);
            if args.switch("help") {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

const HELP: &str = "usage: slabforge <serve|optimize|replay|version> [--flags]\n\
                    see rust/src/main.rs header or README.md for details";

fn fail(msg: impl std::fmt::Display) -> i32 {
    eprintln!("error: {msg}");
    1
}

fn settings_from(args: &Args) -> Result<Settings, String> {
    let mut s = match args.flag("config") {
        Some(path) => Settings::load(path).map_err(|e| e.to_string())?,
        None => Settings::default(),
    };
    if let Some(l) = args.flag("listen") {
        s.listen = l.to_string();
    }
    if let Some(n) = args.flag_parse::<usize>("mem-limit").map_err(|e| e.to_string())? {
        s.mem_limit = n;
    }
    if let Some(n) = args.flag_parse::<usize>("shards").map_err(|e| e.to_string())? {
        s.shards = n;
    }
    if let Some(n) = args.flag_parse::<usize>("threads").map_err(|e| e.to_string())? {
        s.threads = n;
    }
    if args.switch("legacy-threads") {
        s.event_loop = false;
    }
    if let Some(n) = args.flag_parse::<usize>("max-conns").map_err(|e| e.to_string())? {
        s.max_conns = n;
    }
    if let Some(n) = args
        .flag_parse::<u64>("idle-timeout")
        .map_err(|e| e.to_string())?
    {
        s.idle_timeout_secs = n;
    }
    if args.switch("no-reuseport") {
        s.reuseport = false;
    }
    if args.switch("udp") {
        s.udp = true;
    }
    if args.switch("pin-cores") {
        s.pin_cores = true;
    }
    if let Some(n) = args
        .flag_parse::<usize>("migrate-batch")
        .map_err(|e| e.to_string())?
    {
        if n == 0 {
            return Err("--migrate-batch must be at least 1".into());
        }
        s.migrate_batch = n;
    }
    if let Some(on) = args
        .flag_parse::<bool>("maintainer")
        .map_err(|e| e.to_string())?
    {
        s.maintainer = on;
    }
    if let Some(n) = args
        .flag_parse::<u64>("maintainer-interval-ms")
        .map_err(|e| e.to_string())?
    {
        if n == 0 {
            return Err("--maintainer-interval-ms must be at least 1".into());
        }
        s.maintainer_interval_ms = n;
    }
    if let Some(n) = args
        .flag_parse::<usize>("maintainer-batch")
        .map_err(|e| e.to_string())?
    {
        if n == 0 {
            return Err("--maintainer-batch must be at least 1".into());
        }
        s.maintainer_batch = n;
    }
    if let Some(n) = args
        .flag_parse::<usize>("conn-buffer-budget")
        .map_err(|e| e.to_string())?
    {
        s.conn_buffer_budget = n;
    }
    if let Some(list) = args.flag("tenants") {
        s.tenants = slabforge::tenant::TenantSpec::parse_list(list)?;
    }
    if let Some(n) = args
        .flag_parse::<u64>("tenant-arbitrate-every")
        .map_err(|e| e.to_string())?
    {
        s.tenant_arbitrate_every = n;
    }
    if let Some(f) = args
        .flag_parse::<f64>("tenant-divergence")
        .map_err(|e| e.to_string())?
    {
        if !(0.0..=1.0).contains(&f) {
            return Err("--tenant-divergence must be within 0..=1".into());
        }
        s.tenant_divergence = f;
    }
    if let Some(n) = args
        .flag_parse::<usize>("tenant-reclaim-batch")
        .map_err(|e| e.to_string())?
    {
        if n == 0 {
            return Err("--tenant-reclaim-batch must be at least 1".into());
        }
        s.tenant_reclaim_batch = n;
    }
    if let Some(path) = args.flag("memory-file") {
        if path.is_empty() {
            return Err("--memory-file needs a path".into());
        }
        s.memory_file = Some(path.to_string());
    }
    if let Some(f) = args.flag_parse::<f64>("growth-factor").map_err(|e| e.to_string())? {
        s.policy = ChunkSizePolicy::Geometric {
            chunk_min: 96,
            factor: f,
        };
    }
    if let Some(sizes) = args.flag_usize_list("slab-sizes").map_err(|e| e.to_string())? {
        s.policy = ChunkSizePolicy::Explicit(sizes);
    }
    if args.switch("optimizer") {
        s.optimizer.enabled = true;
    }
    if let Some(b) = args.flag("backend") {
        s.optimizer.backend =
            Backend::parse(b).ok_or_else(|| format!("unknown backend '{b}'"))?;
    }
    if let Some(a) = args.flag("algorithm") {
        s.optimizer.algorithm =
            Algorithm::parse(a).ok_or_else(|| format!("unknown algorithm '{a}'"))?;
    }
    if let Some(d) = args.flag("artifacts") {
        s.optimizer.artifacts_dir = d.to_string();
    }
    s.validate().map_err(|e| e.to_string())?;
    Ok(s)
}

fn cmd_serve(args: &Args) -> i32 {
    let settings = match settings_from(args) {
        Ok(s) => s,
        Err(e) => return fail(e),
    };
    // Warm-restart aware construction: recovers from --memory-file when
    // the manifest validates, degrades loudly to cold otherwise.
    let (store, restart) = match slabforge::store::open_or_cold(&settings) {
        Ok((s, r)) => (Arc::new(s), r),
        Err(e) => return fail(e),
    };
    match restart.state {
        "warm" => eprintln!(
            "restart: warm ({} items recovered, {} expired discarded, {} ms)",
            restart.items_recovered, restart.items_discarded, restart.duration_ms
        ),
        "cold" => eprintln!("restart: cold ({})", restart.reason),
        _ => {}
    }
    let shutdown = Arc::new(AtomicBool::new(false));
    let collector = Arc::new(SizeCollector::default());
    store.set_observer(collector.clone());

    let (control, tuner_thread): (Arc<dyn slabforge::server::Control>, _) =
        if settings.optimizer.enabled {
            let tuner = match AutoTuner::new(
                store.clone(),
                collector.clone(),
                settings.optimizer.clone(),
                settings.page_size,
            ) {
                Ok(t) => t,
                Err(e) => return fail(e),
            };
            let handle = tuner.spawn(shutdown.clone());
            eprintln!(
                "optimizer: enabled ({:?}/{:?}, every {}s)",
                settings.optimizer.algorithm,
                settings.optimizer.backend,
                settings.optimizer.interval_secs
            );
            (tuner, Some(handle))
        } else {
            (Arc::new(NoControl), None)
        };

    let maintainer_thread = if settings.maintainer {
        eprintln!(
            "maintainer: enabled (every {}ms, batch {})",
            settings.maintainer_interval_ms, settings.maintainer_batch
        );
        Some(slabforge::store::spawn_maintainer(
            store.clone(),
            slabforge::store::MaintainerConfig {
                interval_ms: settings.maintainer_interval_ms,
                batch: settings.maintainer_batch,
                // when the optimizer thread runs, IT is the designated
                // migration driver; two pumpers would double write-lock
                // pressure on every shard during a drain
                pump_migration: !settings.optimizer.enabled,
                arbitrate_every: settings.tenant_arbitrate_every,
            },
            shutdown.clone(),
        ))
    } else {
        None
    };
    if !settings.tenants.is_empty() {
        eprintln!(
            "tenants: {} defined (arbitrate every {} passes, divergence {}, reclaim batch {})",
            settings.tenants.len(),
            settings.tenant_arbitrate_every,
            settings.tenant_divergence,
            settings.tenant_reclaim_batch
        );
    }

    let mode = if settings.event_loop {
        slabforge::server::ServeMode::Event
    } else {
        slabforge::server::ServeMode::Threaded
    };
    let idle = (settings.idle_timeout_secs > 0)
        .then(|| std::time::Duration::from_secs(settings.idle_timeout_secs));
    let server = Server::with_control(store.clone(), control)
        .mode(mode)
        .reactor_threads(settings.threads)
        .max_conns(settings.max_conns)
        .idle_timeout(idle)
        .conn_buffer_budget(settings.conn_buffer_budget)
        .reuseport(settings.reuseport)
        .udp(settings.udp)
        .pin_cores(settings.pin_cores);
    let handle = match server.start(&settings.listen) {
        Ok(h) => h,
        Err(e) => return fail(format!("cannot bind {}: {e}", settings.listen)),
    };
    eprintln!(
        "slabforge listening on {} ({}, {} shards, {} limit, {} classes, max {} conns)",
        handle.addr(),
        if handle.reactors() > 0 {
            let mut m = format!("epoll reactor x{}", handle.reactors());
            if handle.reuseport() {
                m.push_str(", reuseport");
            }
            if settings.udp {
                m.push_str(", udp");
            }
            if settings.pin_cores {
                m.push_str(", pinned");
            }
            m
        } else {
            "threaded".to_string()
        },
        settings.shards,
        human_bytes(settings.mem_limit as f64),
        store.chunk_sizes().len(),
        settings.max_conns,
    );

    serve_until_signal(
        handle,
        &shutdown,
        &store,
        &settings,
        tuner_thread,
        maintainer_thread,
    )
}

/// Park until SIGTERM/SIGINT, then drain connections, stop the
/// background mutators, persist the warm-restart manifest (when
/// `--memory-file` is active), and exit.
#[cfg(target_os = "linux")]
fn serve_until_signal(
    handle: slabforge::server::ServerHandle,
    tuner_shutdown: &AtomicBool,
    store: &Arc<ShardedStore>,
    settings: &Settings,
    tuner_thread: Option<std::thread::JoinHandle<()>>,
    maintainer_thread: Option<std::thread::JoinHandle<()>>,
) -> i32 {
    let term = slabforge::server::sys::install_term_flag();
    while !term.load(std::sync::atomic::Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(250));
    }
    eprintln!("slabforge: signal received, draining connections");
    tuner_shutdown.store(true, std::sync::atomic::Ordering::SeqCst);
    handle.shutdown();
    // Join every background mutator before the export: a tuner kicking
    // off a retune mid-manifest would split the snapshot across chunk
    // generations (the writer detects that and degrades cold, but a
    // clean join preserves the warm restart).
    for (name, t) in [("optimizer", tuner_thread), ("maintainer", maintainer_thread)] {
        if let Some(t) = t {
            if t.join().is_err() {
                eprintln!("slabforge: {name} thread panicked during shutdown");
            }
        }
    }
    match slabforge::store::write_manifest(store, settings) {
        Ok(()) if store.region().is_some() => {
            eprintln!("slabforge: warm-restart manifest written");
            0
        }
        Ok(()) => 0,
        Err(e) => {
            eprintln!("slabforge: manifest write failed ({e}); next start will be cold");
            1
        }
    }
}

#[cfg(not(target_os = "linux"))]
fn serve_until_signal(
    _handle: slabforge::server::ServerHandle,
    _tuner_shutdown: &AtomicBool,
    _store: &Arc<ShardedStore>,
    _settings: &Settings,
    _tuner_thread: Option<std::thread::JoinHandle<()>>,
    _maintainer_thread: Option<std::thread::JoinHandle<()>>,
) -> i32 {
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `optimize`: offline — learn slab sizes from a histogram CSV
/// (`size,count` per line) and print the `-o slab_sizes`-style result.
fn cmd_optimize(args: &Args) -> i32 {
    let Some(path) = args.flag("histogram") else {
        return fail("--histogram FILE required (CSV 'size,count')");
    };
    let hist = match load_histogram_csv(Path::new(path)) {
        Ok(h) => h,
        Err(e) => return fail(e),
    };
    let algorithm = match args.flag("algorithm") {
        Some(a) => match Algorithm::parse(a) {
            Some(a) => a,
            None => return fail(format!("unknown algorithm '{a}'")),
        },
        None => Algorithm::SteepestDescent,
    };
    let seed = args.flag_or::<u64>("seed", 0x51ab_f00d).unwrap_or(0x51ab_f00d);
    let current = match args.flag_usize_list("slab-sizes") {
        Ok(Some(sizes)) => sizes,
        _ => slabforge::slab::geometry::memcached_default_sizes(),
    };
    let params = OptimizerParams {
        algorithm,
        seed,
        ..Default::default()
    };

    let use_xla = args.flag("backend") == Some("xla");
    let report = if use_xla {
        let dir = args.flag("artifacts").unwrap_or("artifacts");
        let service = match XlaService::start(Path::new(dir)) {
            Ok(s) => s,
            Err(e) => return fail(e),
        };
        let backend = XlaWasteBackend::new(&service, &hist);
        optimize(&backend, &hist, &current, &params)
    } else {
        let backend = RustBackend::new(WasteMap::from_histogram(&hist));
        optimize(&backend, &hist, &current, &params)
    };

    println!("# slabforge optimize ({:?}, backend {})", report.algorithm, report.backend);
    println!("# items:      {}", human_count(hist.total_items()));
    println!("# old waste:  {} bytes", human_count(report.old_waste));
    println!("# new waste:  {} bytes", human_count(report.new_waste));
    println!("# recovered:  {:.2}%", report.recovery() * 100.0);
    println!("# old span:   {:?}", report.old_span);
    println!("# new span:   {:?}", report.new_span);
    let list = report
        .new_config
        .iter()
        .map(|c| c.to_string())
        .collect::<Vec<_>>()
        .join(",");
    println!("-o slab_sizes={list}");
    0
}

fn load_histogram_csv(path: &Path) -> Result<SizeHistogram, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut hist = SizeHistogram::new(16384);
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || (i == 0 && line.starts_with("size")) {
            continue;
        }
        let (s, c) = line
            .split_once(',')
            .ok_or_else(|| format!("line {}: expected 'size,count'", i + 1))?;
        let size: usize = s.trim().parse().map_err(|_| format!("line {}: bad size", i + 1))?;
        let count: u64 = c.trim().parse().map_err(|_| format!("line {}: bad count", i + 1))?;
        hist.record_n(size, count);
    }
    Ok(hist)
}

/// `replay`: run a trace file against an embedded store, print stats.
fn cmd_replay(args: &Args) -> i32 {
    let Some(path) = args.flag("trace") else {
        return fail("--trace FILE required");
    };
    let trace = match Trace::load(Path::new(path)) {
        Ok(t) => t,
        Err(e) => return fail(e),
    };
    let settings = match settings_from(args) {
        Ok(s) => s,
        Err(e) => return fail(e),
    };
    let store = match ShardedStore::new(&settings) {
        Ok(s) => s,
        Err(e) => return fail(e),
    };
    let started = std::time::Instant::now();
    let mut errors = 0u64;
    for op in &trace.ops {
        let r = match op {
            Op::Set { key, value_len } => store
                .set(key.as_bytes(), &vec![0u8; *value_len], 0, 0)
                .is_ok(),
            Op::Get { key } => {
                store.get(key.as_bytes());
                true
            }
            Op::Delete { key } => {
                store.delete(key.as_bytes());
                true
            }
        };
        if !r {
            errors += 1;
        }
    }
    let elapsed = started.elapsed();
    let slabs = store.slab_stats();
    println!(
        "replayed {} ops in {:.3}s ({:.0} ops/s), errors {}",
        human_count(trace.ops.len() as u64),
        elapsed.as_secs_f64(),
        trace.ops.len() as f64 / elapsed.as_secs_f64(),
        errors
    );
    println!(
        "items {}  bytes {}  holes {} ({:.2}% of allocated)",
        human_count(store.len() as u64),
        human_bytes(slabs.requested_bytes as f64),
        human_bytes(slabs.hole_bytes as f64),
        slabs.hole_fraction() * 100.0
    );
    0
}
