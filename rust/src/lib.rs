//! # slabforge
//!
//! A memcached-compatible cache server with **learned slab classes** — a
//! from-scratch reproduction of *“Learning Slab Classes to Alleviate
//! Memory Holes in Memcached”* (Jhabakh Jai & Das, CS.DC 2020).
//!
//! Memcached's slab allocator rounds every stored item up to the chunk
//! size of the nearest larger slab class; the difference is a **memory
//! hole** (internal fragmentation), ~10 % of cache memory on log-normal
//! traffic. The paper's contribution is a greedy hill-climbing optimizer
//! that learns the observed item-size distribution and re-derives the
//! slab chunk sizes to minimize total holes. `slabforge` implements the
//! full substrate (slab allocator, item store, LRU, text protocol, TCP
//! server) plus the optimizer as a first-class online feature, with the
//! numeric hot loop (batched waste evaluation over candidate
//! configurations) AOT-compiled from JAX/Pallas to XLA and executed via
//! PJRT — python never runs on the request path.
//!
//! ## Layout
//!
//! * [`slab`] — pages / chunks / classes; the allocator whose holes we fight
//! * [`store`] — hash table, segmented LRU, eviction, expiry; the KV engine
//! * [`protocol`] — memcached text protocol + `stats`-family introspection
//! * [`server`] / [`client`] — sharded epoll-reactor TCP front end
//!   (legacy threaded mode behind a flag) and a blocking client
//! * [`workload`] — deterministic traffic generators (the paper's
//!   log-normals and the §6.1 adversarial patterns)
//! * [`optimizer`] — the paper's Algorithm 1 plus batched steepest
//!   descent and an exact DP lower bound; online histogram collection
//!   and the auto-retuning coordinator
//! * [`tenant`] — multi-tenant layer: request attribution (key prefix /
//!   meta `O` token), per-tenant stats + size histograms, soft quotas
//!   and Memshare-style need-based memory arbitration
//! * [`runtime`] — PJRT engine loading the AOT `artifacts/*.hlo.txt`
//! * [`config`] — TOML-subset config + CLI
//! * [`benchkit`] — measurement harness used by `rust/benches/*`
//! * [`util`] — RNG, histograms, JSON, formatting

pub mod benchkit;
pub mod client;
pub mod config;
pub mod optimizer;
pub mod protocol;
pub mod runtime;
pub mod server;
pub mod slab;
pub mod store;
pub mod tenant;
pub mod testutil;
pub mod util;
pub mod workload;
