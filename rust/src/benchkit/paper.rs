//! Paper-experiment lab: shared machinery for regenerating the paper's
//! Tables 1–5 and Figures 1–10, used by `examples/reproduce_paper.rs`
//! and the `bench_tables`/`bench_figures` benches.

use crate::benchkit::CsvWriter;
use crate::config::settings::Algorithm;
use crate::optimizer::engine::{optimize, OptimizeReport, OptimizerParams, RustBackend, WasteBackend};
use crate::optimizer::waste::WasteMap;
use crate::slab::geometry::memcached_default_sizes;
use crate::util::histogram::SizeHistogram;
use crate::util::rng::Pcg64;
use crate::workload::spec::PaperExperiment;
use std::path::Path;

/// One regenerated table row (paper vs measured).
#[derive(Clone, Debug)]
pub struct TableRow {
    pub table: u32,
    pub items: usize,
    pub old_span: Vec<u32>,
    pub new_span: Vec<u32>,
    pub old_waste: u64,
    pub new_waste: u64,
    pub recovery: f64,
    pub paper_old_waste: u64,
    pub paper_new_waste: u64,
    pub paper_recovery: f64,
    pub report: OptimizeReport,
}

impl TableRow {
    /// Scale measured waste to the paper's 1 M items for comparison.
    pub fn waste_per_item(&self) -> (f64, f64) {
        (
            self.old_waste as f64 / self.items as f64,
            self.new_waste as f64 / self.items as f64,
        )
    }
}

/// Sample `items` item totals from the experiment's reconstructed
/// log-normal into a byte-granular histogram.
pub fn experiment_histogram(e: &PaperExperiment, items: usize, seed: u64) -> SizeHistogram {
    let mut h = SizeHistogram::new(16384);
    let mut rng = Pcg64::new(seed);
    let d = e.distribution();
    for _ in 0..items {
        h.record(d.sample(&mut rng, 70, 16384));
    }
    h
}

/// Run one table experiment against a [`WasteBackend`].
pub fn run_experiment_with<B: WasteBackend>(
    e: &PaperExperiment,
    hist: &SizeHistogram,
    backend: &B,
    algorithm: Algorithm,
    seed: u64,
) -> TableRow {
    let current = memcached_default_sizes();
    let params = OptimizerParams {
        algorithm,
        seed,
        ..Default::default()
    };
    let report = optimize(backend, hist, &current, &params);
    TableRow {
        table: e.table,
        items: hist.total_items() as usize,
        old_span: report.old_span.clone(),
        new_span: report.new_span.clone(),
        old_waste: report.old_waste,
        new_waste: report.new_waste,
        recovery: report.recovery(),
        paper_old_waste: e.paper_old_waste,
        paper_new_waste: e.paper_new_waste,
        paper_recovery: e.paper_recovery(),
        report,
    }
}

/// Run one table experiment on the rust backend (the default path).
pub fn run_experiment(
    e: &PaperExperiment,
    items: usize,
    seed: u64,
    algorithm: Algorithm,
) -> TableRow {
    let hist = experiment_histogram(e, items, seed);
    let backend = RustBackend::new(WasteMap::from_histogram(&hist));
    run_experiment_with(e, &hist, &backend, algorithm, seed)
}

/// Render a table row as the paper formats it.
pub fn render_table(row: &TableRow) -> String {
    let (old_per, new_per) = row.waste_per_item();
    format!(
        "TABLE {t}  (μ = {mu}, {n} items)\n\
         | Measurement Metric    | Old Configuration | New Configuration |\n\
         |-----------------------|-------------------|-------------------|\n\
         | Available Chunk Sizes | {old:?} | {new:?} |\n\
         | Memory wasted (bytes) | {ow} | {nw} |\n\
         measured recovery {rec:.2}%   (paper: {prec:.2}%)\n\
         measured waste/item {old_per:.1} -> {new_per:.1} B   (paper: {pold:.1} -> {pnew:.1} B)\n",
        t = row.table,
        mu = match row.table {
            1 => 518,
            2 => 1210,
            3 => 2109,
            4 => 4133,
            _ => 8131,
        },
        n = row.items,
        old = row.old_span,
        new = row.new_span,
        ow = row.old_waste,
        nw = row.new_waste,
        rec = row.recovery * 100.0,
        prec = row.paper_recovery * 100.0,
        old_per = old_per,
        new_per = new_per,
        pold = row.paper_old_waste as f64 / 1e6,
        pnew = row.paper_new_waste as f64 / 1e6,
    )
}

/// Write the figure pair for one experiment: the size-frequency
/// histogram plus old/new class-boundary verticals (Figures 1–10 are
/// five such pairs).
pub fn write_figure_csvs(
    e: &PaperExperiment,
    hist: &SizeHistogram,
    row: &TableRow,
    out_dir: &Path,
) -> std::io::Result<(std::path::PathBuf, std::path::PathBuf)> {
    let fig_old = 2 * e.table - 1; // figs 1,3,5,7,9 = old config
    let fig_new = 2 * e.table; // figs 2,4,6,8,10 = new config
    let mut old_csv = CsvWriter::new(
        out_dir.join(format!("fig{fig_old}.csv")),
        "kind,size,frequency",
    );
    let mut new_csv = CsvWriter::new(
        out_dir.join(format!("fig{fig_new}.csv")),
        "kind,size,frequency",
    );
    for (size, count) in hist.iter() {
        let fields = ["hist".to_string(), size.to_string(), count.to_string()];
        old_csv.row(&fields);
        new_csv.row(&fields);
    }
    for &c in &row.old_span {
        old_csv.row(&["class".to_string(), c.to_string(), String::new()]);
    }
    for &c in &row.new_span {
        new_csv.row(&["class".to_string(), c.to_string(), String::new()]);
    }
    Ok((old_csv.finish()?, new_csv.finish()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::PAPER_EXPERIMENTS;

    #[test]
    fn t1_reproduces_paper_shape_at_small_scale() {
        let e = &PAPER_EXPERIMENTS[0];
        let row = run_experiment(e, 30_000, 1, Algorithm::SteepestDescent);
        // shape: recovery in the paper's ballpark (47 % ± 12 points)
        assert!(
            (0.35..0.65).contains(&row.recovery),
            "T1 recovery {}",
            row.recovery
        );
        // old span is exactly the paper's default classes
        assert_eq!(row.old_span, &[304, 384, 480, 600, 752, 944]);
        // old waste/item within 25 % of the paper's 62 B
        let (old_per, _) = row.waste_per_item();
        assert!((46.0..78.0).contains(&old_per), "waste/item {old_per}");
    }

    #[test]
    fn all_tables_recover_waste() {
        for e in &PAPER_EXPERIMENTS {
            let row = run_experiment(e, 20_000, 2, Algorithm::SteepestDescent);
            assert!(
                row.recovery > 0.20,
                "T{}: recovery {}",
                e.table,
                row.recovery
            );
            assert!(row.new_waste < row.old_waste);
        }
    }

    #[test]
    fn figure_csvs_written() {
        let e = &PAPER_EXPERIMENTS[0];
        let hist = experiment_histogram(e, 5_000, 3);
        let backend = RustBackend::new(WasteMap::from_histogram(&hist));
        let row = run_experiment_with(e, &hist, &backend, Algorithm::SteepestDescent, 3);
        let dir = std::env::temp_dir().join(format!("slabforge-figs-{}", std::process::id()));
        let (old, new) = write_figure_csvs(e, &hist, &row, &dir).unwrap();
        let old_text = std::fs::read_to_string(&old).unwrap();
        assert!(old_text.starts_with("kind,size,frequency\n"));
        // every old-span class marker present (span depends on sample min)
        assert_eq!(old_text.matches("class,").count(), row.old_span.len());
        let new_text = std::fs::read_to_string(&new).unwrap();
        assert!(new_text.matches("class,").count() == row.new_span.len());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn render_table_contains_paper_fields() {
        let e = &PAPER_EXPERIMENTS[4];
        let row = run_experiment(e, 10_000, 4, Algorithm::SteepestDescent);
        let text = render_table(&row);
        assert!(text.contains("TABLE 5"));
        assert!(text.contains("Available Chunk Sizes"));
        assert!(text.contains("8880"), "{text}");
    }
}
