//! Measurement harness for `rust/benches/*` (criterion is not vendored
//! in this offline image — DESIGN.md §3): warmup + timed iterations,
//! robust summary statistics, markdown/CSV table rendering.

use crate::util::fmt::{human_duration, human_rate};
use std::time::{Duration, Instant};

pub mod paper;

/// Summary statistics over per-iteration samples.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Work units per iteration (for rate reporting), default 1.
    pub units_per_iter: f64,
    /// Extra workload dimensions (e.g. `("connections", 256.0)`),
    /// emitted as additional keys in the JSON artifact so trajectory
    /// diffs can filter by scenario shape.
    pub dims: Vec<(String, f64)>,
}

impl Summary {
    pub fn from_samples(name: &str, mut samples: Vec<Duration>, units_per_iter: f64) -> Summary {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Summary {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: samples[n - 1],
            units_per_iter,
            dims: Vec::new(),
        }
    }

    /// Attach a workload dimension (builder-style).
    pub fn with_dim(mut self, name: &str, value: f64) -> Summary {
        self.dims.push((name.to_string(), value));
        self
    }

    /// Work units per second at the mean.
    pub fn rate(&self) -> f64 {
        self.units_per_iter / self.mean.as_secs_f64()
    }

    pub fn row(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {} |",
            self.name,
            self.iters,
            human_duration(self.mean),
            human_duration(self.p50),
            human_duration(self.p99),
            human_rate(self.rate()),
        )
    }
}

/// Options for a timed run.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    pub warmup: usize,
    pub iters: usize,
    /// Work units one iteration performs (ops, items, evaluations).
    pub units_per_iter: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: 3,
            iters: 20,
            units_per_iter: 1.0,
        }
    }
}

/// Time a closure: `warmup` unrecorded runs, then `iters` samples.
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> Summary {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(opts.iters);
    for _ in 0..opts.iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Summary::from_samples(name, samples, opts.units_per_iter)
}

/// Render a markdown table of summaries.
pub fn table(title: &str, rows: &[Summary]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n### {title}\n\n"));
    out.push_str("| bench | iters | mean | p50 | p99 | rate |\n");
    out.push_str("|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&r.row());
        out.push('\n');
    }
    out
}

/// Write bench summaries as a `BENCH_*.json` artifact — stable keys so
/// successive PRs can diff throughput (`scripts/bench_server_smoke.sh`
/// consumes this).
pub fn write_json(
    path: impl Into<std::path::PathBuf>,
    title: &str,
    rows: &[Summary],
) -> std::io::Result<std::path::PathBuf> {
    let path = path.into();
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"title\": {title:?},\n"));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let mut dims = String::new();
        for (k, v) in &r.dims {
            dims.push_str(&format!(", {k:?}: {v}"));
        }
        s.push_str(&format!(
            "    {{\"name\": {:?}, \"iters\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \"units_per_iter\": {}, \"ops_per_sec\": {:.1}{}}}{}\n",
            r.name,
            r.iters,
            r.mean.as_nanos(),
            r.p50.as_nanos(),
            r.p99.as_nanos(),
            r.units_per_iter,
            r.rate(),
            dims,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&path, s)?;
    Ok(path)
}

/// Simple CSV writer for results/ artifacts (figures, sweeps).
pub struct CsvWriter {
    path: std::path::PathBuf,
    lines: Vec<String>,
}

impl CsvWriter {
    pub fn new<P: Into<std::path::PathBuf>>(path: P, header: &str) -> CsvWriter {
        CsvWriter {
            path: path.into(),
            lines: vec![header.to_string()],
        }
    }

    pub fn row(&mut self, fields: &[String]) {
        self.lines.push(fields.join(","));
    }

    pub fn finish(self) -> std::io::Result<std::path::PathBuf> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&self.path, self.lines.join("\n") + "\n")?;
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_statistics() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = Summary::from_samples("t", samples, 10.0);
        assert_eq!(s.iters, 100);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.max, Duration::from_micros(100));
        assert_eq!(s.p50, Duration::from_micros(51));
        assert!(s.rate() > 0.0);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let opts = BenchOpts {
            warmup: 2,
            iters: 5,
            units_per_iter: 1.0,
        };
        let s = bench("count", &opts, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn table_renders() {
        let s = Summary::from_samples("x", vec![Duration::from_millis(1)], 1.0);
        let t = table("T", &[s]);
        assert!(t.contains("### T"));
        assert!(t.contains("| x |"));
    }

    #[test]
    fn json_writer_parses_back() {
        let dir = std::env::temp_dir().join(format!("slabforge-json-{}", std::process::id()));
        let rows = vec![
            Summary::from_samples("a bench", vec![Duration::from_millis(2)], 100.0)
                .with_dim("connections", 256.0),
            Summary::from_samples("b", vec![Duration::from_micros(5)], 1.0),
        ];
        let path = write_json(dir.join("BENCH_t.json"), "T", &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(doc.get("title").and_then(|t| t.as_str()), Some("T"));
        let parsed = doc.get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(
            parsed[0].get("name").and_then(|n| n.as_str()),
            Some("a bench")
        );
        assert_eq!(
            parsed[0].get("mean_ns").and_then(|m| m.as_usize()),
            Some(2_000_000)
        );
        assert_eq!(
            parsed[0].get("connections").and_then(|c| c.as_usize()),
            Some(256),
            "workload dims must round-trip through the artifact"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn csv_writer_roundtrip() {
        let dir = std::env::temp_dir().join(format!("slabforge-csv-{}", std::process::id()));
        let mut w = CsvWriter::new(dir.join("t.csv"), "a,b");
        w.row(&["1".into(), "2".into()]);
        let path = w.finish().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
