//! Standard base64 (RFC 4648, `+/` alphabet) — the meta protocol's `b`
//! flag transmits binary-safe keys as base64 tokens. Decode writes into
//! a caller-provided buffer so the request hot path stays
//! allocation-free; encode allocates and is only used by clients and
//! tests.

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

#[inline]
fn sextet(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some((c - b'A') as u32),
        b'a'..=b'z' => Some((c - b'a' + 26) as u32),
        b'0'..=b'9' => Some((c - b'0' + 52) as u32),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Decode `input` (padding optional) into `out`; returns the decoded
/// length. `Err(())` on an invalid character, bad length, or when the
/// decoded form does not fit `out`.
pub fn decode(input: &[u8], out: &mut [u8]) -> Result<usize, ()> {
    let body = match input {
        [head @ .., b'=', b'='] => head,
        [head @ .., b'='] => head,
        _ => input,
    };
    if body.len() % 4 == 1 {
        return Err(()); // 6 leftover bits can never form a byte
    }
    let mut n = 0usize;
    let mut acc = 0u32;
    let mut bits = 0u32;
    for &c in body {
        let v = sextet(c).ok_or(())?;
        acc = (acc << 6) | v;
        bits += 6;
        if bits >= 8 {
            bits -= 8;
            if n >= out.len() {
                return Err(());
            }
            out[n] = (acc >> bits) as u8;
            n += 1;
        }
    }
    Ok(n)
}

/// Encode with padding (client-side convenience; allocates).
pub fn encode(input: &[u8]) -> String {
    let mut out = String::with_capacity(input.len().div_ceil(3) * 4);
    for chunk in input.chunks(3) {
        let b = [
            chunk[0],
            chunk.get(1).copied().unwrap_or(0),
            chunk.get(2).copied().unwrap_or(0),
        ];
        let v = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(ALPHABET[(v >> 18) as usize & 63] as char);
        out.push(ALPHABET[(v >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(v >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[v as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(s: &[u8]) {
        let enc = encode(s);
        let mut buf = [0u8; 300];
        let n = decode(enc.as_bytes(), &mut buf).unwrap();
        assert_eq!(&buf[..n], s, "roundtrip {s:?} via {enc}");
    }

    #[test]
    fn known_vectors() {
        assert_eq!(encode(b""), "");
        assert_eq!(encode(b"f"), "Zg==");
        assert_eq!(encode(b"fo"), "Zm8=");
        assert_eq!(encode(b"foo"), "Zm9v");
        assert_eq!(encode(b"foob"), "Zm9vYg==");
        assert_eq!(encode(b"fooba"), "Zm9vYmE=");
        assert_eq!(encode(b"foobar"), "Zm9vYmFy");
    }

    #[test]
    fn decode_padded_and_unpadded() {
        let mut buf = [0u8; 16];
        assert_eq!(decode(b"Zm9v", &mut buf).unwrap(), 3);
        assert_eq!(&buf[..3], b"foo");
        assert_eq!(decode(b"Zm8=", &mut buf).unwrap(), 2);
        assert_eq!(&buf[..2], b"fo");
        assert_eq!(decode(b"Zm8", &mut buf).unwrap(), 2);
        assert_eq!(&buf[..2], b"fo");
        assert_eq!(decode(b"", &mut buf).unwrap(), 0);
    }

    #[test]
    fn decode_rejects_garbage() {
        let mut buf = [0u8; 16];
        assert!(decode(b"a b c", &mut buf).is_err()); // whitespace
        assert!(decode(b"Zm!v", &mut buf).is_err()); // invalid char
        assert!(decode(b"A", &mut buf).is_err()); // impossible length
        let mut tiny = [0u8; 1];
        assert!(decode(b"Zm9v", &mut tiny).is_err()); // overflow
    }

    #[test]
    fn binary_roundtrips() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"hello world");
        roundtrip(&[0u8, 1, 2, 255, 13, 10, 127]);
        roundtrip(&[0xde; 250]);
    }
}
