//! Shared utilities: deterministic RNG, size histograms, a minimal JSON
//! reader (the image has no network, so no serde — see DESIGN.md §3
//! substitutions), and human-readable formatting.

pub mod b64;
pub mod failpoint;
pub mod fmt;
pub mod histogram;
pub mod json;
pub mod rng;
pub mod supervisor;
