//! Thread supervision: background loops (maintainer, autotuner,
//! reactor workers) run each iteration under `catch_unwind`. A panic is
//! logged, counted in the process-wide `thread_restarts` stat, and
//! followed by a capped exponential backoff before the loop body is
//! re-entered — the thread itself never dies, so in-flight state (most
//! importantly a two-generation migration parked inside a shard) is
//! picked back up on the next iteration.
//!
//! The restart counter is a process-global because the threads it
//! covers span modules that must not depend on `server::Metrics`
//! (store-level maintainer, optimizer-level autotuner); `stats`
//! rendering samples it alongside the per-server counters.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

static RESTARTS: AtomicU64 = AtomicU64::new(0);

/// First pause after a panic.
pub const BACKOFF_START_MS: u64 = 10;
/// Backoff ceiling: a permanently-crashing loop retries at 1 Hz-ish,
/// it does not spin.
pub const BACKOFF_CAP_MS: u64 = 1_000;

/// Total supervised-thread panics survived by this process.
pub fn thread_restarts() -> u64 {
    RESTARTS.load(Ordering::Relaxed)
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<String>()
        .map(String::as_str)
        .or_else(|| payload.downcast_ref::<&str>().copied())
        .unwrap_or("<non-string panic payload>")
}

/// Run `body` (one loop iteration) repeatedly until `shutdown` is set.
/// A panicking iteration is caught, logged, counted, and retried after
/// a capped exponential backoff; a clean iteration resets the backoff.
/// The backoff sleeps in small slices so shutdown stays prompt even
/// while a crashing thread is cooling down.
pub fn supervise<F: FnMut()>(name: &str, shutdown: &AtomicBool, mut body: F) {
    let mut backoff = BACKOFF_START_MS;
    while !shutdown.load(Ordering::SeqCst) {
        match catch_unwind(AssertUnwindSafe(&mut body)) {
            Ok(()) => backoff = BACKOFF_START_MS,
            Err(payload) => {
                RESTARTS.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "slabforge: {name} thread panicked: {}; restarting in {backoff}ms",
                    panic_message(payload.as_ref())
                );
                let mut waited = 0u64;
                while waited < backoff && !shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(10));
                    waited += 10;
                }
                backoff = (backoff * 2).min(BACKOFF_CAP_MS);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn panicking_iterations_are_survived_and_counted() {
        let shutdown = Arc::new(AtomicBool::new(false));
        let iters = Arc::new(AtomicUsize::new(0));
        let before = thread_restarts();
        let t = {
            let shutdown = shutdown.clone();
            let iters = iters.clone();
            std::thread::spawn(move || {
                supervise("test-loop", &shutdown, || {
                    let n = iters.fetch_add(1, Ordering::SeqCst);
                    if n < 3 {
                        panic!("boom {n}");
                    }
                    std::thread::sleep(Duration::from_millis(5));
                })
            })
        };
        // three panics at 10/20/40ms backoff, then healthy iterations
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while iters.load(Ordering::SeqCst) < 6 {
            assert!(std::time::Instant::now() < deadline, "loop never recovered");
            std::thread::sleep(Duration::from_millis(10));
        }
        shutdown.store(true, Ordering::SeqCst);
        t.join().expect("supervised thread itself must not die");
        assert!(
            thread_restarts() - before >= 3,
            "each caught panic bumps thread_restarts"
        );
    }

    #[test]
    fn shutdown_is_prompt_even_mid_backoff() {
        let shutdown = Arc::new(AtomicBool::new(false));
        let t = {
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                supervise("crashy", &shutdown, || panic!("always"));
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        shutdown.store(true, Ordering::SeqCst);
        let start = std::time::Instant::now();
        t.join().unwrap();
        assert!(
            start.elapsed() < Duration::from_millis(500),
            "join after shutdown took {:?}",
            start.elapsed()
        );
    }
}
