//! Zero-dependency failpoints: named, runtime-armed fault-injection
//! points at the system's fallible boundaries (DESIGN.md §3 — nothing
//! is vendored, so this is a from-scratch reduction of the classic
//! `fail`-crate idea to the schedules the chaos suite needs).
//!
//! A **site** is a line of code asking [`fired`] whether to misbehave;
//! what "misbehave" means is fixed per site and encoded in its name
//! (`store.item_alloc` returns `OutOfMemory`, `sys.writev.short`
//! truncates the write, `maintainer.pass.panic` panics...). A **point**
//! is a site armed with a schedule:
//!
//! | spec        | fires                                        |
//! |-------------|----------------------------------------------|
//! | `off`       | never                                        |
//! | `once`      | first evaluation only, then disarms itself   |
//! | `always`    | every evaluation                             |
//! | `1inN`      | every Nth evaluation (deterministic counter) |
//! | `after(N)`  | every evaluation after the first N           |
//! | `pause`     | never — but blocks the caller while armed (a |
//! |             | sync point for serializing thread races)     |
//!
//! Points are armed via the `SLABFORGE_FAILPOINTS` environment variable
//! (`name=spec,name=spec,...`, read once on first use) or at runtime
//! through the `failpoints` debug protocol command.
//!
//! **Disarmed cost.** With no point armed, [`fired`] is one relaxed
//! atomic load and a predictable branch — cheap enough for the request
//! hot path, and allocation-free (the zero-alloc guards in
//! `tests/hotpath_alloc.rs` run with this code compiled in).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::{Duration, Instant};

/// Armed-point count; `UNINIT` until the env var has been consulted.
static ARMED: AtomicUsize = AtomicUsize::new(UNINIT);
const UNINIT: usize = usize::MAX;

/// Longest a `pause` point will hold its caller — a forgotten disarm
/// must degrade to slow, not to a deadlocked test run.
const PAUSE_CAP: Duration = Duration::from_secs(10);

/// When a point fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Schedule {
    Off,
    Once,
    Always,
    /// Every `n`th evaluation (deterministic, counter-based).
    OneIn(u64),
    /// Every evaluation after the first `n`.
    After(u64),
    /// Never fires; blocks the evaluating thread while armed.
    Pause,
}

impl Schedule {
    fn parse(spec: &str) -> Result<Schedule, String> {
        let s = spec.trim();
        if let Some(n) = s.strip_prefix("1in") {
            let n: u64 = n.parse().map_err(|_| format!("bad count in '{s}'"))?;
            if n == 0 {
                return Err("1in0 is meaningless".into());
            }
            return Ok(Schedule::OneIn(n));
        }
        if let Some(rest) = s.strip_prefix("after(") {
            let n: u64 = rest
                .strip_suffix(')')
                .and_then(|d| d.parse().ok())
                .ok_or_else(|| format!("bad count in '{s}'"))?;
            return Ok(Schedule::After(n));
        }
        match s {
            "off" => Ok(Schedule::Off),
            "once" => Ok(Schedule::Once),
            "always" => Ok(Schedule::Always),
            "pause" => Ok(Schedule::Pause),
            _ => Err(format!(
                "unknown failpoint spec '{s}' (want off|once|always|1inN|after(N)|pause)"
            )),
        }
    }

    fn render(&self) -> String {
        match self {
            Schedule::Off => "off".into(),
            Schedule::Once => "once".into(),
            Schedule::Always => "always".into(),
            Schedule::OneIn(n) => format!("1in{n}"),
            Schedule::After(n) => format!("after({n})"),
            Schedule::Pause => "pause".into(),
        }
    }
}

struct Point {
    name: String,
    schedule: Schedule,
    /// Evaluations since arming (schedules count against this).
    evals: u64,
    /// Times the point actually fired.
    fires: u64,
}

fn registry() -> &'static Mutex<Vec<Point>> {
    static R: OnceLock<Mutex<Vec<Point>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock() -> std::sync::MutexGuard<'static, Vec<Point>> {
    // a panicking failpoint (that is the product) must not poison the
    // registry for every later check
    registry().lock().unwrap_or_else(PoisonError::into_inner)
}

/// Read `SLABFORGE_FAILPOINTS` exactly once, before the first
/// evaluation or mutation that needs the registry.
fn ensure_env() {
    static ENV: OnceLock<()> = OnceLock::new();
    ENV.get_or_init(|| {
        if let Ok(spec) = std::env::var("SLABFORGE_FAILPOINTS") {
            for pair in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                match pair.split_once('=') {
                    Some((name, sched)) => {
                        if let Err(e) = arm_locked(name.trim(), sched.trim()) {
                            eprintln!("slabforge: SLABFORGE_FAILPOINTS: {e}");
                        }
                    }
                    None => eprintln!(
                        "slabforge: SLABFORGE_FAILPOINTS: '{pair}' is not name=spec"
                    ),
                }
            }
        }
        recount();
    });
}

/// Recompute the hot-path gate from the registry.
fn recount() {
    let n = lock().iter().filter(|p| p.schedule != Schedule::Off).count();
    ARMED.store(n, Ordering::Relaxed);
}

fn arm_locked(name: &str, spec: &str) -> Result<(), String> {
    if name.is_empty() {
        return Err("empty failpoint name".into());
    }
    let schedule = Schedule::parse(spec)?;
    let mut reg = lock();
    match reg.iter_mut().find(|p| p.name == name) {
        Some(p) => {
            p.schedule = schedule;
            p.evals = 0;
            p.fires = 0;
        }
        None => reg.push(Point {
            name: name.to_string(),
            schedule,
            evals: 0,
            fires: 0,
        }),
    }
    Ok(())
}

/// Arm (or re-arm) a point. `spec` grammar: `off`, `once`, `always`,
/// `1inN`, `after(N)`, `pause`. Re-arming resets the counters.
pub fn arm(name: &str, spec: &str) -> Result<(), String> {
    ensure_env();
    arm_locked(name, spec)?;
    recount();
    Ok(())
}

/// Arm a comma-separated list (`name=spec,name=spec`) — the grammar of
/// both the env var and the `failpoints set` protocol command.
pub fn arm_list(list: &str) -> Result<(), String> {
    ensure_env();
    for pair in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (name, spec) = pair
            .split_once('=')
            .ok_or_else(|| format!("'{pair}' is not name=spec"))?;
        arm_locked(name.trim(), spec.trim())?;
    }
    recount();
    Ok(())
}

/// Disarm one point (no-op when it was never armed).
pub fn disarm(name: &str) {
    ensure_env();
    if let Some(p) = lock().iter_mut().find(|p| p.name == name) {
        p.schedule = Schedule::Off;
    }
    recount();
}

/// Disarm everything (the chaos suite's between-schedules reset).
pub fn disarm_all() {
    ensure_env();
    for p in lock().iter_mut() {
        p.schedule = Schedule::Off;
    }
    recount();
}

/// `(name, spec, fires)` for every point ever armed in this process.
pub fn list() -> Vec<(String, String, u64)> {
    ensure_env();
    lock()
        .iter()
        .map(|p| (p.name.clone(), p.schedule.render(), p.fires))
        .collect()
}

/// Times `name` has fired since it was last (re-)armed.
pub fn fire_count(name: &str) -> u64 {
    ensure_env();
    lock()
        .iter()
        .find(|p| p.name == name)
        .map_or(0, |p| p.fires)
}

enum Verdict {
    No,
    Yes,
    Paused,
}

#[cold]
fn eval_slow(name: &str) -> bool {
    ensure_env();
    loop {
        let verdict = {
            let mut reg = lock();
            let Some(p) = reg.iter_mut().find(|p| p.name == name) else {
                return false;
            };
            p.evals += 1;
            match p.schedule {
                Schedule::Off => Verdict::No,
                Schedule::Always => {
                    p.fires += 1;
                    Verdict::Yes
                }
                Schedule::Once => {
                    p.schedule = Schedule::Off;
                    p.fires += 1;
                    Verdict::Yes
                }
                Schedule::OneIn(n) => {
                    if p.evals % n == 0 {
                        p.fires += 1;
                        Verdict::Yes
                    } else {
                        Verdict::No
                    }
                }
                Schedule::After(n) => {
                    if p.evals > n {
                        p.fires += 1;
                        Verdict::Yes
                    } else {
                        Verdict::No
                    }
                }
                Schedule::Pause => Verdict::Paused,
            }
        };
        match verdict {
            Verdict::Yes => {
                // `once` exhausting itself may close the hot-path gate
                recount();
                return true;
            }
            Verdict::No => return false,
            Verdict::Paused => {
                // sync point: hold the caller until disarmed (bounded,
                // so a forgotten disarm cannot deadlock a test run)
                let start = Instant::now();
                while start.elapsed() < PAUSE_CAP {
                    std::thread::sleep(Duration::from_millis(1));
                    let reg = lock();
                    let still = reg
                        .iter()
                        .find(|p| p.name == name)
                        .is_some_and(|p| p.schedule == Schedule::Pause);
                    if !still {
                        break;
                    }
                }
                return false;
            }
        }
    }
}

/// Evaluate a failpoint site. Disarmed: one relaxed load, `false`.
#[inline(always)]
pub fn fired(name: &str) -> bool {
    match ARMED.load(Ordering::Relaxed) {
        0 => false,
        _ => eval_slow(name),
    }
}

/// Panic-injection helper for supervised-thread sites.
#[inline(always)]
pub fn maybe_panic(name: &str) {
    if fired(name) {
        panic!("failpoint {name} fired");
    }
}

/// RAII arming for tests: disarms the point when dropped.
pub struct Guard(&'static str);

impl Drop for Guard {
    fn drop(&mut self) {
        disarm(self.0);
    }
}

/// Arm a point for the lifetime of the returned [`Guard`].
pub fn armed(name: &'static str, spec: &str) -> Result<Guard, String> {
    arm(name, spec)?;
    Ok(Guard(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    // every test uses its own point names: the registry is
    // process-global and the test harness is multi-threaded

    #[test]
    fn disarmed_points_never_fire() {
        assert!(!fired("fp.test.unarmed"));
        assert_eq!(fire_count("fp.test.unarmed"), 0);
    }

    #[test]
    fn once_fires_exactly_once_then_disarms() {
        let _g = armed("fp.test.once", "once").unwrap();
        assert!(fired("fp.test.once"));
        assert!(!fired("fp.test.once"));
        assert!(!fired("fp.test.once"));
        assert_eq!(fire_count("fp.test.once"), 1);
    }

    #[test]
    fn one_in_n_is_deterministic() {
        let _g = armed("fp.test.1in3", "1in3").unwrap();
        let hits: Vec<bool> = (0..9).map(|_| fired("fp.test.1in3")).collect();
        assert_eq!(
            hits,
            [false, false, true, false, false, true, false, false, true]
        );
        assert_eq!(fire_count("fp.test.1in3"), 3);
    }

    #[test]
    fn after_skips_a_prefix_then_always_fires() {
        let _g = armed("fp.test.after", "after(2)").unwrap();
        assert!(!fired("fp.test.after"));
        assert!(!fired("fp.test.after"));
        assert!(fired("fp.test.after"));
        assert!(fired("fp.test.after"));
    }

    #[test]
    fn always_and_rearm_reset_counters() {
        let _g = armed("fp.test.always", "always").unwrap();
        assert!(fired("fp.test.always"));
        assert!(fired("fp.test.always"));
        assert_eq!(fire_count("fp.test.always"), 2);
        arm("fp.test.always", "off").unwrap();
        assert!(!fired("fp.test.always"));
        arm("fp.test.always", "always").unwrap();
        assert_eq!(fire_count("fp.test.always"), 0, "re-arm resets");
    }

    #[test]
    fn guard_disarms_on_drop() {
        {
            let _g = armed("fp.test.guard", "always").unwrap();
            assert!(fired("fp.test.guard"));
        }
        assert!(!fired("fp.test.guard"));
    }

    #[test]
    fn pause_blocks_until_disarmed() {
        arm("fp.test.pause", "pause").unwrap();
        let t = std::thread::spawn(|| {
            let start = Instant::now();
            assert!(!fired("fp.test.pause"), "pause never fires");
            start.elapsed()
        });
        std::thread::sleep(Duration::from_millis(50));
        disarm("fp.test.pause");
        let held = t.join().unwrap();
        assert!(held >= Duration::from_millis(40), "held {held:?}");
        assert!(held < PAUSE_CAP, "released promptly, not by the cap");
    }

    #[test]
    fn spec_grammar_round_trips_and_rejects_garbage() {
        for spec in ["off", "once", "always", "1in20", "after(100)", "pause"] {
            let s = Schedule::parse(spec).unwrap();
            assert_eq!(s.render(), spec);
        }
        for bad in ["", "sometimes", "1in0", "1inx", "after(", "after(x)"] {
            assert!(Schedule::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn arm_list_parses_the_env_grammar() {
        arm_list("fp.test.la=once, fp.test.lb=1in5").unwrap();
        assert!(fired("fp.test.la"));
        assert!(!fired("fp.test.la"));
        assert!(arm_list("fp.test.lc").is_err());
        assert!(arm_list("fp.test.ld=nope").is_err());
        disarm("fp.test.lb");
    }

    #[test]
    fn list_reports_spec_and_fires() {
        arm("fp.test.list", "1in1").unwrap();
        assert!(fired("fp.test.list"));
        let rows = list();
        let row = rows.iter().find(|(n, _, _)| n == "fp.test.list").unwrap();
        assert_eq!((row.1.as_str(), row.2), ("1in1", 1));
        disarm("fp.test.list");
    }
}
