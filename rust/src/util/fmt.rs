//! Human-readable formatting helpers for logs, stats output and benches,
//! plus the hot-path integer formatter the protocol encoder uses.

/// Append the decimal representation of `n` to `out` (itoa-style).
///
/// The protocol hot path writes `VALUE <key> <flags> <len> [<cas>]`
/// headers for every hit; going through `core::fmt` there costs a
/// `Formatter` state machine and padding logic per integer. This digs
/// digits into a stack buffer instead — no allocation, no `fmt`.
#[inline]
pub fn push_u64(out: &mut Vec<u8>, n: u64) {
    let mut tmp = [0u8; 20];
    let start = u64_digits(n, &mut tmp);
    out.extend_from_slice(&tmp[start..]);
}

/// Render `n`'s decimal digits into the tail of `buf`, returning the
/// start index (the digits occupy `buf[start..]`). Shared by
/// [`push_u64`] and callers that need the byte count before the bytes
/// (the meta `VA <size>` arithmetic response).
#[inline]
pub fn u64_digits(n: u64, buf: &mut [u8; 20]) -> usize {
    // u64::MAX has 20 decimal digits
    let mut i = buf.len();
    let mut x = n;
    loop {
        i -= 1;
        buf[i] = b'0' + (x % 10) as u8;
        x /= 10;
        if x == 0 {
            break;
        }
    }
    i
}

/// [`push_u64`] for `usize` operands (lengths, counts).
#[inline]
pub fn push_usize(out: &mut Vec<u8>, n: usize) {
    push_u64(out, n as u64);
}

/// Signed [`push_u64`] — the meta protocol's `t` (TTL) response flag
/// renders `-1` for items that never expire.
#[inline]
pub fn push_i64(out: &mut Vec<u8>, n: i64) {
    if n < 0 {
        out.push(b'-');
        push_u64(out, n.unsigned_abs());
    } else {
        push_u64(out, n as u64);
    }
}

/// Format a byte count with binary units (`1.5 MiB`).
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes;
    let mut unit = 0;
    while v.abs() >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{} {}", v as i64, UNITS[unit])
    } else {
        format!("{:.2} {}", v, UNITS[unit])
    }
}

/// Format a count with thousands separators (`1,234,567`).
pub fn human_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Format a duration in adaptive units.
pub fn human_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Format a rate (ops/sec) in adaptive units.
pub fn human_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} Gop/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} Mop/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} Kop/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} op/s")
    }
}

/// Percentage with one decimal (`47.1%`).
pub fn human_pct(fraction: f64) -> String {
    format!("{:.2}%", fraction * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(0.0), "0 B");
        assert_eq!(human_bytes(1023.0), "1023 B");
        assert_eq!(human_bytes(1024.0), "1.00 KiB");
        assert_eq!(human_bytes(1536.0), "1.50 KiB");
        assert_eq!(human_bytes(1024.0 * 1024.0), "1.00 MiB");
        assert_eq!(human_bytes(28.0 * 1024.0 * 1024.0 * 1024.0 * 1024.0), "28.00 TiB");
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(0), "0");
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1000), "1,000");
        assert_eq!(human_count(62_013_552), "62,013,552");
    }

    #[test]
    fn durations() {
        assert_eq!(human_duration(Duration::from_nanos(10)), "10 ns");
        assert_eq!(human_duration(Duration::from_micros(5)), "5.00 µs");
        assert_eq!(human_duration(Duration::from_millis(125)), "125.00 ms");
        assert_eq!(human_duration(Duration::from_secs(2)), "2.00 s");
    }

    #[test]
    fn rates_and_pct() {
        assert_eq!(human_rate(500.0), "500.0 op/s");
        assert_eq!(human_rate(2_500_000.0), "2.50 Mop/s");
        assert_eq!(human_pct(0.4709), "47.09%");
    }

    #[test]
    fn push_u64_matches_display() {
        for n in [
            0u64,
            1,
            9,
            10,
            99,
            100,
            12345,
            u32::MAX as u64,
            u64::MAX - 1,
            u64::MAX,
        ] {
            let mut out = Vec::new();
            push_u64(&mut out, n);
            assert_eq!(out, n.to_string().into_bytes(), "n={n}");
        }
        // appends, never overwrites
        let mut out = b"x ".to_vec();
        push_usize(&mut out, 42);
        assert_eq!(out, b"x 42");
    }

    #[test]
    fn push_i64_matches_display() {
        for n in [0i64, 1, -1, 42, -42, i64::MAX, i64::MIN] {
            let mut out = Vec::new();
            push_i64(&mut out, n);
            assert_eq!(out, n.to_string().into_bytes(), "n={n}");
        }
    }
}
