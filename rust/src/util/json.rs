//! Minimal JSON reader for `artifacts/manifest.json` and
//! `artifacts/testvectors.json`.
//!
//! serde is not vendored in this offline image (DESIGN.md §3), and the
//! runtime only needs to *read* two small machine-generated files, so we
//! ship a strict recursive-descent parser instead. Supports the full
//! JSON value grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Array of numbers as `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_array()?.iter().map(Json::as_f64).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match b {
                            0xc0..=0xdf => 2,
                            0xe0..=0xef => 3,
                            0xf0..=0xf7 => 4,
                            _ => return Err(self.err("bad utf-8")),
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Number(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Number(-1500.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::String("hi\n".into())
        );
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn f64_vec_access() {
        let v = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.as_f64_vec(), Some(vec![1.0, 2.5, 3.0]));
        assert_eq!(Json::parse("[1, \"x\"]").unwrap().as_f64_vec(), None);
    }

    #[test]
    fn large_exact_integers_roundtrip() {
        // waste values are integers < 2^53; f64 holds them exactly
        let v = Json::parse("9007199254740991").unwrap();
        assert_eq!(v.as_f64(), Some(9007199254740991.0));
        assert_eq!(v.as_usize(), Some(9007199254740991));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("café é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" \n\t{ \"a\" : [ ] } \r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 0);
    }
}
