//! Deterministic pseudo-random numbers for workload generation and the
//! paper's randomized hill climber.
//!
//! crates.io is unreachable in this image, so instead of `rand` we ship a
//! small, well-known generator: **PCG64 (XSL-RR 128/64)** seeded via
//! SplitMix64, plus the distribution samplers the workloads need
//! (uniform, normal via Box–Muller, log-normal, zipf, geometric-decay).
//! Everything is reproducible from a single `u64` seed.

/// PCG XSL-RR 128/64 — O'Neill's PCG with 128-bit state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// SplitMix64: seed-expansion for PCG initialization.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream derived from seed).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let lo = splitmix64(&mut s) as u128;
        let hi = splitmix64(&mut s) as u128;
        let inc_lo = splitmix64(&mut s) as u128;
        let inc_hi = splitmix64(&mut s) as u128;
        let mut rng = Pcg64 {
            state: (hi << 64) | lo,
            inc: (((inc_hi << 64) | inc_lo) << 1) | 1,
            spare_normal: None,
        };
        rng.next_u64(); // decorrelate the first output from the raw seed
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, n)`; unbiased via rejection (Lemire-style widening).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Widening multiply with rejection of the biased zone.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi]` (inclusive).
    #[inline]
    pub fn gen_range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            // Avoid ln(0).
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.next_normal()
    }

    /// Log-normal parameterized by its **median** and log-space sigma —
    /// the parameterization DESIGN.md §3 reconstructs from the paper.
    #[inline]
    pub fn lognormal(&mut self, median: f64, sigma_ln: f64) -> f64 {
        (median.ln() + sigma_ln * self.next_normal()).exp()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (rejection
    /// sampling, Jim Gray's method) — used for key popularity.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        if s <= 0.0 {
            return self.gen_range(n);
        }
        // Inverse-CDF over the harmonic approximation.
        let nf = n as f64;
        loop {
            let u = self.next_f64();
            let x = if (s - 1.0).abs() < 1e-9 {
                nf.powf(u) // H(x) ~ ln x for s = 1
            } else {
                let h = (nf.powf(1.0 - s) - 1.0) * u + 1.0;
                h.powf(1.0 / (1.0 - s))
            };
            // x lands in [1, n+1): rank k in 1..=n maps to 0-based k-1.
            let k = x.floor() as u64;
            if (1..=n).contains(&k) {
                return k - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Pcg64::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg64::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(2);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal(10.0, 2.0);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.05, "sd {}", var.sqrt());
    }

    #[test]
    fn lognormal_median() {
        let mut r = Pcg64::new(4);
        let mut xs: Vec<f64> = (0..100_001).map(|_| r.lognormal(518.0, 0.126)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!(
            (median - 518.0).abs() / 518.0 < 0.02,
            "median {median} != 518"
        );
    }

    #[test]
    fn zipf_rank_zero_most_popular() {
        let mut r = Pcg64::new(5);
        let mut counts = [0u32; 16];
        for _ in 0..20_000 {
            counts[r.zipf(16, 1.1) as usize] += 1;
        }
        assert!(counts[0] > counts[5]);
        assert!(counts[0] > counts[15]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
