//! Byte-granular item-size histograms — the optimizer's input.
//!
//! The paper's algorithm consumes "the probability distribution of the
//! frequency of occurrence of an item for given item sizes". We keep the
//! exact per-byte counts up to a cap, and fold anything larger into a
//! coarse geometric tail (waste above the cap is dominated by the chunk
//! geometry anyway). [`SizeHistogram::bucketize`] resamples into the
//! fixed `(hist, sizes)` arrays the AOT artifact expects.

use crate::util::fmt::human_bytes;

/// Exact size-frequency histogram with a byte-granular head.
#[derive(Clone, Debug)]
pub struct SizeHistogram {
    /// `counts[i]` = number of items of total size `i + 1` bytes.
    counts: Vec<u64>,
    /// Sizes above `counts.len()`: (size, count) pairs, sorted.
    overflow: Vec<(usize, u64)>,
    total_items: u64,
    total_bytes: u128,
    max_size: usize,
}

impl SizeHistogram {
    /// A histogram tracking sizes `1..=cap` exactly.
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        SizeHistogram {
            counts: vec![0; cap],
            overflow: Vec::new(),
            total_items: 0,
            total_bytes: 0,
            max_size: 0,
        }
    }

    /// Exact-head capacity in bytes.
    pub fn cap(&self) -> usize {
        self.counts.len()
    }

    /// Record `n` items of `size` bytes.
    pub fn record_n(&mut self, size: usize, n: u64) {
        if n == 0 {
            return;
        }
        assert!(size > 0, "zero-sized item");
        if size <= self.counts.len() {
            self.counts[size - 1] += n;
        } else {
            match self.overflow.binary_search_by_key(&size, |&(s, _)| s) {
                Ok(i) => self.overflow[i].1 += n,
                Err(i) => self.overflow.insert(i, (size, n)),
            }
        }
        self.total_items += n;
        self.total_bytes += size as u128 * n as u128;
        self.max_size = self.max_size.max(size);
    }

    /// Record one item.
    #[inline]
    pub fn record(&mut self, size: usize) {
        self.record_n(size, 1);
    }

    pub fn total_items(&self) -> u64 {
        self.total_items
    }

    pub fn total_bytes(&self) -> u128 {
        self.total_bytes
    }

    /// Largest size seen (0 when empty).
    pub fn max_size(&self) -> usize {
        self.max_size
    }

    /// Count for an exact size.
    pub fn count(&self, size: usize) -> u64 {
        if size == 0 {
            0
        } else if size <= self.counts.len() {
            self.counts[size - 1]
        } else {
            self.overflow
                .binary_search_by_key(&size, |&(s, _)| s)
                .map(|i| self.overflow[i].1)
                .unwrap_or(0)
        }
    }

    /// Iterate `(size, count)` over non-zero entries, ascending.
    pub fn iter(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i + 1, c))
            .chain(self.overflow.iter().copied())
    }

    /// Distinct sizes with non-zero count.
    pub fn distinct_sizes(&self) -> usize {
        self.iter().count()
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &SizeHistogram) {
        for (size, count) in other.iter() {
            self.record_n(size, count);
        }
    }

    /// Percentile (0.0..=1.0) of the size distribution, by item count.
    pub fn percentile(&self, p: f64) -> usize {
        assert!((0.0..=1.0).contains(&p));
        if self.total_items == 0 {
            return 0;
        }
        let target = ((self.total_items as f64) * p).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (size, count) in self.iter() {
            seen += count;
            if seen >= target {
                return size;
            }
        }
        self.max_size
    }

    /// Resample into the fixed `(hist, sizes)` f64 arrays of the AOT
    /// artifact: `s_buckets` buckets of equal width covering
    /// `1..=max(cap_hint, max_size)`. Each bucket's representative size
    /// is its **upper edge** — a conservative (never underestimating)
    /// waste model that is *exact* when the bucket width is 1 byte,
    /// which holds for every paper workload (sizes ≤ 16 KiB, S = 16384).
    pub fn bucketize(&self, s_buckets: usize, cap_hint: usize) -> BucketizedHistogram {
        let span = self.max_size.max(cap_hint).max(s_buckets);
        let width = span.div_ceil(s_buckets);
        let mut hist = vec![0.0f64; s_buckets];
        let mut sizes = vec![0.0f64; s_buckets];
        for (b, size) in sizes.iter_mut().enumerate() {
            *size = ((b + 1) * width) as f64; // upper edge
        }
        for (size, count) in self.iter() {
            let b = ((size - 1) / width).min(s_buckets - 1);
            hist[b] += count as f64;
        }
        BucketizedHistogram { hist, sizes, width }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{} items, {} total, sizes [{}..{}], p50={}",
            self.total_items,
            human_bytes(self.total_bytes as f64),
            self.iter().next().map(|(s, _)| s).unwrap_or(0),
            self.max_size,
            self.percentile(0.5),
        )
    }
}

/// Fixed-shape resampling of a [`SizeHistogram`] (artifact input form).
#[derive(Clone, Debug)]
pub struct BucketizedHistogram {
    /// Item counts per bucket (f64 for the f64 artifact ABI).
    pub hist: Vec<f64>,
    /// Representative (upper-edge) size per bucket.
    pub sizes: Vec<f64>,
    /// Bucket width in bytes (1 = exact).
    pub width: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_count() {
        let mut h = SizeHistogram::new(1024);
        h.record(100);
        h.record(100);
        h.record(1024);
        assert_eq!(h.count(100), 2);
        assert_eq!(h.count(1024), 1);
        assert_eq!(h.count(99), 0);
        assert_eq!(h.total_items(), 3);
        assert_eq!(h.total_bytes(), 1224);
        assert_eq!(h.max_size(), 1024);
    }

    #[test]
    fn overflow_sizes_tracked() {
        let mut h = SizeHistogram::new(128);
        h.record(1000);
        h.record(1000);
        h.record(5000);
        assert_eq!(h.count(1000), 2);
        assert_eq!(h.count(5000), 1);
        assert_eq!(h.max_size(), 5000);
        let all: Vec<_> = h.iter().collect();
        assert_eq!(all, vec![(1000, 2), (5000, 1)]);
    }

    #[test]
    fn percentiles() {
        let mut h = SizeHistogram::new(100);
        for s in 1..=100 {
            h.record(s);
        }
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(0.5), 50);
        assert_eq!(h.percentile(1.0), 100);
    }

    #[test]
    fn merge_sums() {
        let mut a = SizeHistogram::new(64);
        let mut b = SizeHistogram::new(64);
        a.record_n(10, 3);
        b.record_n(10, 4);
        b.record_n(200, 1);
        a.merge(&b);
        assert_eq!(a.count(10), 7);
        assert_eq!(a.count(200), 1);
        assert_eq!(a.total_items(), 8);
    }

    #[test]
    fn bucketize_width_one_is_exact() {
        let mut h = SizeHistogram::new(256);
        h.record_n(5, 2);
        h.record_n(256, 9);
        let b = h.bucketize(256, 256);
        assert_eq!(b.width, 1);
        assert_eq!(b.hist[4], 2.0);
        assert_eq!(b.hist[255], 9.0);
        assert_eq!(b.sizes[4], 5.0);
        assert_eq!(b.sizes[255], 256.0);
        assert_eq!(b.hist.iter().sum::<f64>(), 11.0);
    }

    #[test]
    fn bucketize_coarse_uses_upper_edge() {
        let mut h = SizeHistogram::new(1000);
        h.record(1); // bucket 0
        h.record(100); // bucket (100-1)/width
        let b = h.bucketize(10, 1000);
        assert_eq!(b.width, 100);
        assert_eq!(b.sizes[0], 100.0);
        assert_eq!(b.hist[0], 2.0); // both land in the first bucket
        assert_eq!(b.hist.iter().sum::<f64>(), 2.0);
    }

    #[test]
    fn bucketize_overflow_clamped_to_last_bucket() {
        let mut h = SizeHistogram::new(100);
        h.record(10_000);
        let b = h.bucketize(16, 100);
        assert_eq!(b.hist[15], 1.0);
    }

    #[test]
    fn empty_histogram() {
        let h = SizeHistogram::new(16);
        assert_eq!(h.total_items(), 0);
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.distinct_sizes(), 0);
        let b = h.bucketize(16, 16);
        assert_eq!(b.hist.iter().sum::<f64>(), 0.0);
    }
}
