//! Segmented LRU — memcached 1.5's HOT/WARM/COLD scheme, per slab class.
//!
//! New items enter HOT; HOT and WARM are capped to a fraction of the
//! class's items and overflow into COLD; a COLD item that gets accessed
//! is promoted to WARM. Eviction for a class walks COLD tail → WARM
//! tail → HOT tail. Lists are intrusive (`ItemMeta::{prev,next,tier}`),
//! ids never move in memory.
//!
//! Cap enforcement is **not** done inline on the write path: `insert`
//! and `touch` only link/move the item (O(1)), and the background
//! maintainer (`store::maintainer`) drains over-cap tails into COLD in
//! bounded batches via [`ClassLru::rebalance_step`] — memcached's
//! `lru_maintainer` split. Until a rebalance runs the tiers may be
//! over cap; eviction still works because the candidate walk falls
//! back COLD → WARM → HOT.

use super::arena::{Arena, Tier, NIL};

/// Fraction caps, mirroring memcached's `hot_lru_pct`/`warm_lru_pct`
/// defaults (percent of the class's item count).
pub const HOT_PCT: usize = 20;
pub const WARM_PCT: usize = 40;

/// One intrusive doubly-linked list.
#[derive(Clone, Debug)]
pub struct LruList {
    head: u32,
    tail: u32,
    len: usize,
}

impl LruList {
    pub fn new() -> Self {
        LruList {
            head: NIL,
            tail: NIL,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn head(&self) -> Option<u32> {
        (self.head != NIL).then_some(self.head)
    }

    pub fn tail(&self) -> Option<u32> {
        (self.tail != NIL).then_some(self.tail)
    }

    /// Push an (unlinked) id at the head.
    pub fn push_head(&mut self, id: u32, arena: &mut Arena) {
        let m = arena.get_mut(id);
        debug_assert!(m.prev == NIL && m.next == NIL);
        m.next = self.head;
        m.prev = NIL;
        if self.head != NIL {
            arena.get_mut(self.head).prev = id;
        }
        self.head = id;
        if self.tail == NIL {
            self.tail = id;
        }
        self.len += 1;
    }

    /// Unlink an id from this list.
    pub fn unlink(&mut self, id: u32, arena: &mut Arena) {
        let (prev, next) = {
            let m = arena.get(id);
            (m.prev, m.next)
        };
        if prev != NIL {
            arena.get_mut(prev).next = next;
        } else {
            debug_assert_eq!(self.head, id);
            self.head = next;
        }
        if next != NIL {
            arena.get_mut(next).prev = prev;
        } else {
            debug_assert_eq!(self.tail, id);
            self.tail = prev;
        }
        let m = arena.get_mut(id);
        m.prev = NIL;
        m.next = NIL;
        self.len -= 1;
    }

    /// Pop the tail (the eviction candidate).
    pub fn pop_tail(&mut self, arena: &mut Arena) -> Option<u32> {
        let id = self.tail;
        if id == NIL {
            return None;
        }
        self.unlink(id, arena);
        Some(id)
    }

    /// Iterate head→tail (most→least recent).
    pub fn iter<'a>(&self, arena: &'a Arena) -> LruIter<'a> {
        LruIter {
            arena,
            cur: self.head,
        }
    }
}

impl Default for LruList {
    fn default() -> Self {
        Self::new()
    }
}

pub struct LruIter<'a> {
    arena: &'a Arena,
    cur: u32,
}

impl Iterator for LruIter<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        if self.cur == NIL {
            return None;
        }
        let id = self.cur;
        self.cur = self.arena.get(id).next;
        Some(id)
    }
}

/// The three tiers of one slab class.
#[derive(Clone, Debug, Default)]
pub struct ClassLru {
    pub hot: LruList,
    pub warm: LruList,
    pub cold: LruList,
}

impl ClassLru {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn total(&self) -> usize {
        self.hot.len() + self.warm.len() + self.cold.len()
    }

    fn list(&mut self, tier: Tier) -> &mut LruList {
        match tier {
            Tier::Hot => &mut self.hot,
            Tier::Warm => &mut self.warm,
            Tier::Cold => &mut self.cold,
        }
    }

    /// Insert a new item at the HOT head — O(1), no cap enforcement
    /// (the maintainer demotes over-cap tails off-thread).
    pub fn insert(&mut self, id: u32, arena: &mut Arena) {
        arena.get_mut(id).tier = Tier::Hot as u8;
        self.hot.push_head(id, arena);
    }

    /// Remove an item from whichever tier holds it.
    pub fn remove(&mut self, id: u32, arena: &mut Arena) {
        let tier = Tier::from_u8(arena.get(id).tier);
        self.list(tier).unlink(id, arena);
    }

    /// Touch on access: HOT/WARM bump to their head; COLD promotes to
    /// WARM (memcached's ITEM_ACTIVE promotion). O(1) — caps are
    /// enforced by the maintainer, not here.
    pub fn touch(&mut self, id: u32, arena: &mut Arena) {
        let tier = Tier::from_u8(arena.get(id).tier);
        match tier {
            Tier::Hot => {
                self.hot.unlink(id, arena);
                self.hot.push_head(id, arena);
            }
            Tier::Warm => {
                self.warm.unlink(id, arena);
                self.warm.push_head(id, arena);
            }
            Tier::Cold => {
                self.cold.unlink(id, arena);
                arena.get_mut(id).tier = Tier::Warm as u8;
                self.warm.push_head(id, arena);
            }
        }
    }

    /// Current HOT/WARM caps (fractions of this class's item count).
    fn caps(&self) -> (usize, usize) {
        let total = self.total();
        (
            (total * HOT_PCT / 100).max(1),
            (total * WARM_PCT / 100).max(1),
        )
    }

    /// True when both fraction caps hold (the maintained steady state).
    pub fn is_balanced(&self) -> bool {
        let (hot_cap, warm_cap) = self.caps();
        self.hot.len() <= hot_cap && self.warm.len() <= warm_cap
    }

    /// Demote up to `max_moves` over-cap HOT/WARM tails into COLD (the
    /// maintainer's bounded batch). Returns the demotions performed;
    /// `< max_moves` means this class is now balanced.
    pub fn rebalance_step(&mut self, arena: &mut Arena, max_moves: usize) -> usize {
        let (hot_cap, warm_cap) = self.caps();
        let mut moved = 0;
        while self.hot.len() > hot_cap && moved < max_moves {
            let id = self.hot.pop_tail(arena).unwrap();
            arena.get_mut(id).tier = Tier::Cold as u8;
            self.cold.push_head(id, arena);
            moved += 1;
        }
        while self.warm.len() > warm_cap && moved < max_moves {
            let id = self.warm.pop_tail(arena).unwrap();
            arena.get_mut(id).tier = Tier::Cold as u8;
            self.cold.push_head(id, arena);
            moved += 1;
        }
        moved
    }

    /// The next eviction victim: COLD tail, else WARM tail, else HOT
    /// tail. Does not unlink.
    pub fn eviction_candidate(&self) -> Option<u32> {
        self.cold
            .tail()
            .or_else(|| self.warm.tail())
            .or_else(|| self.hot.tail())
    }

    /// Iterate all ids most→least recent within each tier
    /// (hot, then warm, then cold) — migration snapshot order.
    pub fn iter_all<'a>(&'a self, arena: &'a Arena) -> impl Iterator<Item = u32> + 'a {
        self.hot
            .iter(arena)
            .chain(self.warm.iter(arena))
            .chain(self.cold.iter(arena))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::class::ChunkLoc;
    use crate::slab::ChunkHandle;
    use crate::store::arena::ItemMeta;

    fn item() -> ItemMeta {
        ItemMeta {
            hash: 0,
            handle: ChunkHandle {
                class: 0,
                loc: ChunkLoc { page: 0, chunk: 0 },
            },
            chunk_addr: 0,
            klen: 0,
            vlen: 0,
            flags: 0,
            exptime: 0,
            time: 0,
            cas: 0,
            total: 0,
            hnext: NIL,
            prev: NIL,
            next: NIL,
            pg_prev: NIL,
            pg_next: NIL,
            tier: 0,
            fetched: false,
            stale: false,
            win_sent: false,
            gen: 0,
            live: true,
            tenant: 0,
        }
    }

    /// Drain a class to its balanced steady state (test convenience).
    fn settle(c: &mut ClassLru, a: &mut Arena) {
        while c.rebalance_step(a, 16) > 0 {}
    }

    #[test]
    fn list_order_mru_first() {
        let mut a = Arena::new();
        let mut l = LruList::new();
        let i1 = a.insert(item());
        let i2 = a.insert(item());
        let i3 = a.insert(item());
        l.push_head(i1, &mut a);
        l.push_head(i2, &mut a);
        l.push_head(i3, &mut a);
        assert_eq!(l.iter(&a).collect::<Vec<_>>(), vec![i3, i2, i1]);
        assert_eq!(l.tail(), Some(i1));
        assert_eq!(l.pop_tail(&mut a), Some(i1));
        assert_eq!(l.len(), 2);
    }

    #[test]
    fn unlink_middle() {
        let mut a = Arena::new();
        let mut l = LruList::new();
        let ids: Vec<u32> = (0..5).map(|_| a.insert(item())).collect();
        for &id in &ids {
            l.push_head(id, &mut a);
        }
        l.unlink(ids[2], &mut a);
        let got: Vec<u32> = l.iter(&a).collect();
        assert_eq!(got, vec![ids[4], ids[3], ids[1], ids[0]]);
    }

    #[test]
    fn inserts_are_hot_until_maintained_then_overflow_cold() {
        let mut a = Arena::new();
        let mut c = ClassLru::new();
        let ids: Vec<u32> = (0..10).map(|_| a.insert(item())).collect();
        for &id in &ids {
            c.insert(id, &mut a);
        }
        // no inline rebalance: the write path leaves everything HOT
        assert_eq!(c.hot.len(), 10, "insert must be link-only");
        assert!(!c.is_balanced());
        settle(&mut c, &mut a);
        // caps: hot <= max(10*20%,1)=2, warm <= 4
        assert!(c.hot.len() <= 2, "hot={}", c.hot.len());
        assert_eq!(c.total(), 10);
        assert!(c.cold.len() >= 4);
        assert!(c.is_balanced());
    }

    #[test]
    fn rebalance_step_is_bounded() {
        let mut a = Arena::new();
        let mut c = ClassLru::new();
        for _ in 0..100 {
            let id = a.insert(item());
            c.insert(id, &mut a);
        }
        // 100 hot, cap 20: a budget-3 step demotes exactly 3
        assert_eq!(c.rebalance_step(&mut a, 3), 3);
        assert_eq!(c.hot.len(), 97);
        settle(&mut c, &mut a);
        assert!(c.hot.len() <= 20);
        assert_eq!(c.rebalance_step(&mut a, 16), 0, "balanced -> no work");
    }

    #[test]
    fn cold_access_promotes_to_warm() {
        let mut a = Arena::new();
        let mut c = ClassLru::new();
        let ids: Vec<u32> = (0..10).map(|_| a.insert(item())).collect();
        for &id in &ids {
            c.insert(id, &mut a);
        }
        settle(&mut c, &mut a);
        let victim = c.cold.tail().unwrap();
        c.touch(victim, &mut a);
        assert_eq!(Tier::from_u8(a.get(victim).tier), Tier::Warm);
    }

    #[test]
    fn eviction_prefers_cold_tail() {
        let mut a = Arena::new();
        let mut c = ClassLru::new();
        let ids: Vec<u32> = (0..10).map(|_| a.insert(item())).collect();
        for &id in &ids {
            c.insert(id, &mut a);
        }
        settle(&mut c, &mut a);
        let v = c.eviction_candidate().unwrap();
        assert_eq!(Tier::from_u8(a.get(v).tier), Tier::Cold);
        // empty cold+warm: falls back to hot
        let mut solo = ClassLru::new();
        let one = a.insert(item());
        solo.insert(one, &mut a);
        assert_eq!(solo.eviction_candidate(), Some(one));
    }

    #[test]
    fn remove_from_any_tier() {
        let mut a = Arena::new();
        let mut c = ClassLru::new();
        let ids: Vec<u32> = (0..10).map(|_| a.insert(item())).collect();
        for &id in &ids {
            c.insert(id, &mut a);
        }
        settle(&mut c, &mut a);
        let total_before = c.total();
        let cold_item = c.cold.tail().unwrap();
        c.remove(cold_item, &mut a);
        assert_eq!(c.total(), total_before - 1);
    }

    #[test]
    fn iter_all_covers_everything() {
        let mut a = Arena::new();
        let mut c = ClassLru::new();
        let ids: Vec<u32> = (0..25).map(|_| a.insert(item())).collect();
        for &id in &ids {
            c.insert(id, &mut a);
        }
        let mut seen: Vec<u32> = c.iter_all(&a).collect();
        seen.sort_unstable();
        let mut want = ids.clone();
        want.sort_unstable();
        assert_eq!(seen, want);
    }
}
