//! Crash-consistent warm restart (`--memory-file`).
//!
//! When enabled, every slab page lives in one mmap-backed file
//! ([`SlabRegion`]) and a clean shutdown writes a versioned **metadata
//! manifest** next to it (`<file>.meta`): slab-class geometry (including
//! learned / auto-tuned chunk sizes), the per-page class+occupancy map,
//! every live item's index entry (location, sizes, flags, expiry, CAS,
//! LRU tier — **not** its bytes), the tenant registry, per-shard CAS
//! high-water marks, and the absolute-time epoch of the shutdown. The
//! next start re-mmaps the file, revalidates everything, and rebuilds
//! the hash table and LRU chains from the manifest in bounded batches —
//! recovery is metadata-only and never copies a value byte.
//!
//! ## Invalidation: degrade loudly, never serve garbage
//!
//! *Any* of the following forces a cold start (fresh, empty cache) with
//! the reason exported via `stats` (`restart_state cold`, `restart_reason
//! ...`) and logged at startup:
//!
//! * dirty-shutdown marker present (`<file>.dirty` — created at every
//!   start, removed only by a clean manifest write, so kill-9 leaves it)
//! * manifest missing, truncated, wrong magic/version, or checksum
//!   mismatch
//! * geometry drift: page size, shard count, per-shard page budget, or
//!   CAS mode differ from the running configuration; memory-file size
//!   mismatch
//! * wall-clock regression past the persisted epoch (expired items
//!   could otherwise resurrect)
//! * page-map / item-index integrity walk failure (misaligned,
//!   out-of-range, or double-claimed page offsets; items pointing at
//!   unmapped pages, out-of-range chunks, impossible key/value sizes,
//!   duplicate chunks)
//! * tenant-registry restore failure, or a post-restore
//!   `check_integrity` failure on any shard
//!
//! Items whose TTL lapsed while the server was down are discarded
//! during the walk (counted in `restart_items_discarded`) — expiry is
//! revalidated against the persisted epoch and the current clock, so a
//! warm restart can never resurrect an expired item.
//!
//! On a warm start the persisted (possibly learned) chunk-size table
//! **wins over the configured policy**: the store boots with exactly
//! the geometry the items were carved into, and the auto-tuner resumes
//! from it. Delete the memory file (or its manifest) to re-apply a
//! changed `--slab-sizes`/growth-factor configuration. Likewise the
//! persisted tenant registry wins; configured tenant specs are only
//! applied for names the manifest does not already define.
//!
//! A manifest is consumed (deleted) by the start that reads it, and an
//! in-progress slab migration is force-completed before export, so the
//! manifest always describes a single consistent generation.

use crate::config::Settings;
use crate::slab::allocator::MIGRATION_PAGE_SLACK;
use crate::slab::policy::ChunkSizePolicy;
use crate::slab::SlabRegion;
use crate::store::sharded::ShardedStore;
use crate::store::store::Clock;
use crate::util::failpoint;
use std::collections::HashMap;
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Manifest magic + format version. Bump the version on any layout
/// change: an old manifest then degrades to a cold start instead of
/// being misparsed.
const MAGIC: &[u8; 8] = b"SLABWARM";
const VERSION: u32 = 1;

/// Items restored per shard write-lock lease — recovery holds no lock
/// longer than one bounded batch, mirroring the migration discipline.
const RESTORE_BATCH: usize = 4096;

/// One persisted item-index entry. Everything needed to rebuild the
/// item's arena record; key and value bytes stay in the mapped chunk.
#[derive(Clone, Debug)]
pub(crate) struct ItemRecord {
    pub class: u16,
    pub page: u32,
    pub chunk: u32,
    pub klen: u8,
    pub vlen: u32,
    pub flags: u32,
    pub exptime: u32,
    pub time: u32,
    pub cas: u64,
    pub total: u32,
    pub tier: u8,
    pub fetched: bool,
    pub tenant: u8,
}

/// How a boot obtained its contents — the startup banner / stats row.
#[derive(Clone, Debug)]
pub struct RestartReport {
    /// `"disabled"`, `"warm"`, or `"cold"`.
    pub state: &'static str,
    /// Why a cold start degraded (empty otherwise).
    pub reason: String,
    pub items_recovered: u64,
    pub items_discarded: u64,
    pub duration_ms: u64,
}

fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(suffix);
    PathBuf::from(s)
}

pub fn manifest_path(memory_file: &Path) -> PathBuf {
    sibling(memory_file, ".meta")
}

pub fn dirty_path(memory_file: &Path) -> PathBuf {
    sibling(memory_file, ".dirty")
}

fn unix_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Per-shard page budget in pages (must match the allocator's own
/// `(mem_limit / shards).max(page_size) / page_size` computation).
fn per_shard_pages(settings: &Settings) -> usize {
    ((settings.mem_limit / settings.shards).max(settings.page_size) / settings.page_size).max(1)
}

/// Region capacity: every shard's budget plus its migration slack, so
/// `take()` can never fail before the allocator's own budget does.
fn region_pages(settings: &Settings) -> usize {
    settings.shards * (per_shard_pages(settings) + MIGRATION_PAGE_SLACK)
}

// ---------------------------------------------------------------------------
// serialization primitives (little-endian, length-prefixed)
// ---------------------------------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}
fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    assert!(b.len() <= u16::MAX as usize);
    put_u16(out, b.len() as u16);
    out.extend_from_slice(b);
}

/// FNV-1a 64 over the manifest body (same hash family as the key hash —
/// not cryptographic, but catches truncation and torn writes).
fn checksum(body: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in body {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Bounds-checked little-endian reader over the manifest body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| format!("manifest truncated at byte {}", self.pos))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.u16()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ---------------------------------------------------------------------------
// parsed manifest
// ---------------------------------------------------------------------------

struct TenantEntry {
    name: String,
    prefixes: Vec<Vec<u8>>,
    tokens: Vec<Vec<u8>>,
    quota_pages: u64,
}

struct ShardEntry {
    cas_high: u64,
    /// `(class, page_slot, region_offset)` of every occupied page.
    page_map: Vec<(u16, u32, u64)>,
    /// LRU-ordered (hot → warm → cold, most → least recent per tier).
    items: Vec<ItemRecord>,
}

struct Manifest {
    epoch: u64,
    page_size: u64,
    per_shard_pages: u64,
    shards: u32,
    use_cas: bool,
    tenants: Vec<TenantEntry>,
    chunk_sizes: Vec<usize>,
    shard_entries: Vec<ShardEntry>,
}

impl Manifest {
    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(64 * 1024);
        put_u64(&mut b, self.epoch);
        put_u64(&mut b, self.page_size);
        put_u64(&mut b, self.per_shard_pages);
        put_u32(&mut b, self.shards);
        put_u8(&mut b, self.use_cas as u8);
        put_u8(&mut b, self.tenants.len() as u8);
        for t in &self.tenants {
            put_bytes(&mut b, t.name.as_bytes());
            put_u64(&mut b, t.quota_pages);
            put_u16(&mut b, t.prefixes.len() as u16);
            for p in &t.prefixes {
                put_bytes(&mut b, p);
            }
            put_u16(&mut b, t.tokens.len() as u16);
            for tok in &t.tokens {
                put_bytes(&mut b, tok);
            }
        }
        put_u16(&mut b, self.chunk_sizes.len() as u16);
        for &c in &self.chunk_sizes {
            put_u64(&mut b, c as u64);
        }
        for s in &self.shard_entries {
            put_u64(&mut b, s.cas_high);
            put_u32(&mut b, s.page_map.len() as u32);
            for &(class, slot, offset) in &s.page_map {
                put_u16(&mut b, class);
                put_u32(&mut b, slot);
                put_u64(&mut b, offset);
            }
            put_u64(&mut b, s.items.len() as u64);
            for it in &s.items {
                put_u16(&mut b, it.class);
                put_u32(&mut b, it.page);
                put_u32(&mut b, it.chunk);
                put_u8(&mut b, it.klen);
                put_u32(&mut b, it.vlen);
                put_u32(&mut b, it.flags);
                put_u32(&mut b, it.exptime);
                put_u32(&mut b, it.time);
                put_u64(&mut b, it.cas);
                put_u32(&mut b, it.total);
                put_u8(&mut b, it.tier);
                put_u8(&mut b, it.fetched as u8);
                put_u8(&mut b, it.tenant);
            }
        }
        b
    }

    fn decode(body: &[u8]) -> Result<Manifest, String> {
        let mut r = Reader::new(body);
        let epoch = r.u64()?;
        let page_size = r.u64()?;
        let per_shard_pages = r.u64()?;
        let shards = r.u32()?;
        if shards == 0 || shards > 4096 {
            return Err(format!("implausible shard count {shards}"));
        }
        let use_cas = r.u8()? != 0;
        let ntenants = r.u8()? as usize;
        let mut tenants = Vec::with_capacity(ntenants);
        for _ in 0..ntenants {
            let name = String::from_utf8(r.bytes()?)
                .map_err(|_| "tenant name is not utf-8".to_string())?;
            let quota_pages = r.u64()?;
            let nprefix = r.u16()? as usize;
            let mut prefixes = Vec::with_capacity(nprefix);
            for _ in 0..nprefix {
                prefixes.push(r.bytes()?);
            }
            let ntok = r.u16()? as usize;
            let mut tokens = Vec::with_capacity(ntok);
            for _ in 0..ntok {
                tokens.push(r.bytes()?);
            }
            tenants.push(TenantEntry {
                name,
                prefixes,
                tokens,
                quota_pages,
            });
        }
        let nsizes = r.u16()? as usize;
        let mut chunk_sizes = Vec::with_capacity(nsizes);
        for _ in 0..nsizes {
            chunk_sizes.push(r.u64()? as usize);
        }
        let mut shard_entries = Vec::with_capacity(shards as usize);
        for _ in 0..shards {
            let cas_high = r.u64()?;
            let npages = r.u32()? as usize;
            let mut page_map = Vec::with_capacity(npages);
            for _ in 0..npages {
                let class = r.u16()?;
                let slot = r.u32()?;
                let offset = r.u64()?;
                page_map.push((class, slot, offset));
            }
            let nitems = r.u64()? as usize;
            let mut items = Vec::with_capacity(nitems.min(1 << 20));
            for _ in 0..nitems {
                items.push(ItemRecord {
                    class: r.u16()?,
                    page: r.u32()?,
                    chunk: r.u32()?,
                    klen: r.u8()?,
                    vlen: r.u32()?,
                    flags: r.u32()?,
                    exptime: r.u32()?,
                    time: r.u32()?,
                    cas: r.u64()?,
                    total: r.u32()?,
                    tier: r.u8()?,
                    fetched: r.u8()? != 0,
                    tenant: r.u8()?,
                });
            }
            shard_entries.push(ShardEntry {
                cas_high,
                page_map,
                items,
            });
        }
        if !r.done() {
            return Err("trailing bytes after manifest body".to_string());
        }
        Ok(Manifest {
            epoch,
            page_size,
            per_shard_pages,
            shards,
            use_cas,
            tenants,
            chunk_sizes,
            shard_entries,
        })
    }
}

// ---------------------------------------------------------------------------
// manifest write (clean shutdown)
// ---------------------------------------------------------------------------

/// Persist the cache metadata for the next boot. Call **after** the
/// listeners have drained (no concurrent mutators). No-op when
/// persistence is off. On success the dirty marker is removed — the
/// one and only "shutdown was clean" signal the next boot trusts.
pub fn write_manifest(store: &ShardedStore, settings: &Settings) -> Result<(), String> {
    let Some(region) = store.region() else {
        return Ok(());
    };
    if failpoint::fired("restart.manifest.write_fail") {
        return Err("failpoint restart.manifest.write_fail".to_string());
    }
    // A manifest describes exactly one chunk geometry: force any
    // in-flight migration to a single consistent generation first.
    while store.migration_step_all() {}

    // Slab bytes must be durable before the metadata that points into
    // them.
    region
        .sync()
        .map_err(|e| format!("msync of memory file failed: {e}"))?;

    let chunk_sizes = store.chunk_sizes();
    let shards = store.shard_count();
    let mut shard_entries = Vec::with_capacity(shards);
    let mut use_cas = true;
    for i in 0..shards {
        let g = store.shard_read(i);
        if g.migration_active() {
            return Err(format!("shard {i} started a new migration mid-export"));
        }
        if g.chunk_sizes() != chunk_sizes.as_slice() {
            return Err(format!("shard {i} geometry diverged post-drain"));
        }
        if i == 0 {
            use_cas = g.cas_enabled();
        }
        shard_entries.push(ShardEntry {
            cas_high: g.cas_high_water(),
            page_map: g.export_page_map(),
            items: g.export_items(),
        });
    }

    let tenants = store
        .tenants()
        .rules_snapshot()
        .into_iter()
        .filter(|r| r.id != 0) // the default tenant is implicit
        .map(|r| TenantEntry {
            name: r.name,
            prefixes: r.prefixes,
            tokens: r.tokens,
            quota_pages: r.quota_pages,
        })
        .collect();

    let manifest = Manifest {
        epoch: unix_now(),
        page_size: store.page_size() as u64,
        per_shard_pages: per_shard_pages(settings) as u64,
        shards: shards as u32,
        use_cas,
        tenants,
        chunk_sizes,
        shard_entries,
    };

    let body = manifest.encode();
    let mut file = Vec::with_capacity(body.len() + 28);
    file.extend_from_slice(MAGIC);
    file.extend_from_slice(&VERSION.to_le_bytes());
    file.extend_from_slice(&(body.len() as u64).to_le_bytes());
    file.extend_from_slice(&checksum(&body).to_le_bytes());
    file.extend_from_slice(&body);

    let meta = manifest_path(region.path());
    let tmp = sibling(region.path(), ".meta.tmp");
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| format!("cannot create {}: {e}", tmp.display()))?;
        f.write_all(&file)
            .map_err(|e| format!("manifest write failed: {e}"))?;
        f.sync_all().map_err(|e| format!("manifest fsync failed: {e}"))?;
    }
    std::fs::rename(&tmp, &meta)
        .map_err(|e| format!("manifest rename failed: {e}"))?;
    // Only now is the shutdown provably clean.
    std::fs::remove_file(dirty_path(region.path()))
        .map_err(|e| format!("cannot clear dirty marker: {e}"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// startup (warm or cold)
// ---------------------------------------------------------------------------

/// Build the store for this boot: warm from the memory file + manifest
/// when both validate end-to-end, else a loud cold start; plain heap
/// store when `--memory-file` is off. Always returns a serving store —
/// the report says how it was obtained.
pub fn open_or_cold(settings: &Settings) -> Result<(ShardedStore, RestartReport), String> {
    let Some(path) = settings.memory_file.clone() else {
        let store = ShardedStore::new(settings).map_err(|e| e.to_string())?;
        store.set_restart(0, "", 0, 0, 0);
        return Ok((
            store,
            RestartReport {
                state: "disabled",
                reason: String::new(),
                items_recovered: 0,
                items_discarded: 0,
                duration_ms: 0,
            },
        ));
    };
    let path = PathBuf::from(path);
    let started = Instant::now();
    match try_warm(settings, &path) {
        Ok((store, recovered, discarded)) => {
            // The manifest is consumed by the boot that used it; the
            // dirty marker stands until the next clean shutdown.
            let _ = std::fs::remove_file(manifest_path(&path));
            if let Err(e) = std::fs::write(dirty_path(&path), b"booted\n") {
                return Err(format!("cannot write dirty marker: {e}"));
            }
            let duration_ms = started.elapsed().as_millis() as u64;
            store.set_restart(1, "", recovered, discarded, duration_ms);
            Ok((
                store,
                RestartReport {
                    state: "warm",
                    reason: String::new(),
                    items_recovered: recovered,
                    items_discarded: discarded,
                    duration_ms,
                },
            ))
        }
        Err(reason) => {
            let (store, reason) = build_cold(settings, &path, reason)?;
            let duration_ms = started.elapsed().as_millis() as u64;
            store.set_restart(2, &reason, 0, 0, duration_ms);
            Ok((
                store,
                RestartReport {
                    state: "cold",
                    reason,
                    items_recovered: 0,
                    items_discarded: 0,
                    duration_ms,
                },
            ))
        }
    }
}

/// Cold start with persistence still desired: recreate the region
/// (truncating whatever was in the file), drop any stale manifest, and
/// plant the dirty marker. If even the region cannot be mapped, fall
/// back to a heap-only store — the cache must come up regardless.
fn build_cold(
    settings: &Settings,
    path: &Path,
    mut reason: String,
) -> Result<(ShardedStore, String), String> {
    let _ = std::fs::remove_file(manifest_path(path));
    let region = match SlabRegion::create(path, settings.page_size, region_pages(settings)) {
        Ok(r) => Some(r),
        Err(e) => {
            reason = format!("{reason}; memory file unusable ({e}), persistence off this boot");
            None
        }
    };
    if region.is_some() {
        if let Err(e) = std::fs::write(dirty_path(path), b"booted\n") {
            return Err(format!("cannot write dirty marker: {e}"));
        }
    }
    let store = ShardedStore::with_region(
        settings.policy.clone(),
        settings.page_size,
        settings.mem_limit,
        settings.use_cas,
        settings.shards,
        Clock::System,
        region,
    )
    .map_err(|e| e.to_string())?;
    apply_runtime_settings(&store, settings);
    for spec in &settings.tenants {
        store
            .tenants()
            .define(&spec.name, &spec.prefix, Some(spec.quota_pages))
            .map_err(|e| format!("tenant spec '{}': {e}", spec.name))?;
    }
    Ok((store, reason))
}

/// Knobs `ShardedStore::new` would have applied.
fn apply_runtime_settings(store: &ShardedStore, settings: &Settings) {
    store.set_migrate_batch(settings.migrate_batch);
    store
        .tenants()
        .set_tuning(settings.tenant_divergence, settings.tenant_reclaim_batch);
}

/// The whole warm path; any `Err` is a cold-start reason.
fn try_warm(settings: &Settings, path: &Path) -> Result<(ShardedStore, u64, u64), String> {
    if dirty_path(path).exists() {
        return Err("dirty shutdown marker present (previous run did not exit cleanly)".into());
    }
    let meta = manifest_path(path);
    let raw = std::fs::read(&meta)
        .map_err(|e| format!("cannot read manifest {}: {e}", meta.display()))?;

    // header
    if raw.len() < 28 || &raw[..8] != MAGIC {
        return Err("manifest magic mismatch".into());
    }
    let version = u32::from_le_bytes(raw[8..12].try_into().unwrap());
    if version != VERSION {
        return Err(format!("manifest version {version}, expected {VERSION}"));
    }
    let body_len = u64::from_le_bytes(raw[12..20].try_into().unwrap()) as usize;
    let stored_sum = u64::from_le_bytes(raw[20..28].try_into().unwrap());
    let body = raw
        .get(28..28 + body_len)
        .filter(|b| raw.len() == 28 + b.len())
        .ok_or("manifest length mismatch")?;
    if checksum(body) != stored_sum {
        return Err("manifest checksum mismatch".into());
    }
    let manifest = Manifest::decode(body)?;

    // geometry must match the running configuration exactly
    if manifest.page_size != settings.page_size as u64 {
        return Err(format!(
            "page size changed ({} persisted, {} configured)",
            manifest.page_size, settings.page_size
        ));
    }
    if manifest.shards != settings.shards as u32 {
        return Err(format!(
            "shard count changed ({} persisted, {} configured)",
            manifest.shards, settings.shards
        ));
    }
    if manifest.per_shard_pages != per_shard_pages(settings) as u64 {
        return Err(format!(
            "memory budget changed ({} persisted pages/shard, {} configured)",
            manifest.per_shard_pages,
            per_shard_pages(settings)
        ));
    }
    if manifest.use_cas != settings.use_cas {
        return Err("CAS mode changed".into());
    }
    let now = unix_now();
    if now < manifest.epoch {
        return Err(format!(
            "clock regressed past shutdown epoch ({now} < {})",
            manifest.epoch
        ));
    }

    // the persisted (possibly learned) geometry becomes the boot policy
    let policy = ChunkSizePolicy::Explicit(manifest.chunk_sizes.clone());
    let classes = policy
        .materialize(settings.page_size)
        .map_err(|e| format!("persisted chunk sizes invalid: {e}"))?;
    drop(classes);

    let region = SlabRegion::open(path, settings.page_size, region_pages(settings))
        .map_err(|e| format!("cannot map memory file: {e}"))?;

    // ------------------------------------------------- integrity walk
    // Validate every page and item reference before touching a store,
    // and split item records into keep / expired. `used` lists per
    // (shard, class, slot) are derived from *kept* items only, so an
    // expired item's chunk returns straight to the free list.
    let now32 = now as u32;
    let mut discarded = 0u64;
    let mut seen_offsets: HashSet<u64> = HashSet::new();
    // per shard: (class, slot) -> chunk capacity of that page
    let mut plans: Vec<RestorePlan> = Vec::with_capacity(manifest.shards as usize);
    for (si, shard) in manifest.shard_entries.iter().enumerate() {
        if failpoint::fired("restart.recover.torn_page") {
            return Err("failpoint restart.recover.torn_page".into());
        }
        if shard.page_map.len() > per_shard_pages(settings) + MIGRATION_PAGE_SLACK {
            return Err(format!(
                "shard {si} page map exceeds its budget ({} pages)",
                shard.page_map.len()
            ));
        }
        let mut pages: HashMap<(u16, u32), PagePlan> = HashMap::new();
        for &(class, slot, offset) in &shard.page_map {
            let chunk_size = *manifest
                .chunk_sizes
                .get(class as usize)
                .ok_or_else(|| format!("shard {si} page in unknown class {class}"))?;
            if offset % settings.page_size as u64 != 0 {
                return Err(format!("shard {si} page offset {offset} misaligned"));
            }
            if !seen_offsets.insert(offset) {
                return Err(format!("page offset {offset} claimed twice"));
            }
            let capacity = (settings.page_size / chunk_size) as u32;
            if pages
                .insert((class, slot), PagePlan {
                    offset,
                    capacity,
                    chunk_size,
                    used: Vec::new(),
                })
                .is_some()
            {
                return Err(format!("shard {si} page slot ({class},{slot}) duplicated"));
            }
        }
        let mut kept: Vec<ItemRecord> = Vec::with_capacity(shard.items.len());
        let mut seen_chunks: HashSet<(u16, u32, u32)> = HashSet::new();
        for rec in &shard.items {
            let plan = pages.get_mut(&(rec.class, rec.page)).ok_or_else(|| {
                format!(
                    "shard {si} item points at unmapped page ({},{})",
                    rec.class, rec.page
                )
            })?;
            let klen = rec.klen as usize;
            if rec.chunk >= plan.capacity
                || rec.tier > 2
                || rec.tenant as usize >= crate::tenant::MAX_TENANTS
                || !(1..=crate::store::item::MAX_KEY_LEN).contains(&klen)
                || klen + rec.vlen as usize > plan.chunk_size
                || rec.total as usize > plan.chunk_size
            {
                return Err(format!(
                    "shard {si} item record corrupt (class {} page {} chunk {})",
                    rec.class, rec.page, rec.chunk
                ));
            }
            if !seen_chunks.insert((rec.class, rec.page, rec.chunk)) {
                return Err(format!(
                    "shard {si} chunk ({},{},{}) referenced twice",
                    rec.class, rec.page, rec.chunk
                ));
            }
            if rec.exptime != 0 && rec.exptime <= now32 {
                discarded += 1; // TTL lapsed while we were down
                continue;
            }
            plan.used.push(rec.chunk);
            kept.push(rec.clone());
        }
        plans.push(RestorePlan {
            cas_high: shard.cas_high,
            pages,
            items: kept,
        });
    }

    // ------------------------------------------------- build + restore
    let store = ShardedStore::with_region(
        policy,
        settings.page_size,
        settings.mem_limit,
        settings.use_cas,
        settings.shards,
        Clock::System,
        Some(region.clone()),
    )
    .map_err(|e| format!("store construction failed: {e}"))?;
    apply_runtime_settings(&store, settings);
    restore_tenants(&store, &manifest.tenants)?;
    // configured specs fill in only names the manifest didn't define
    let persisted: HashSet<String> = store
        .tenants()
        .rules_snapshot()
        .into_iter()
        .map(|r| r.name)
        .collect();
    for spec in &settings.tenants {
        if !persisted.contains(&spec.name) {
            store
                .tenants()
                .define(&spec.name, &spec.prefix, Some(spec.quota_pages))
                .map_err(|e| format!("tenant spec '{}': {e}", spec.name))?;
        }
    }

    let mut recovered = 0u64;
    for (si, plan) in plans.into_iter().enumerate() {
        recovered += restore_shard(&store, &region, si, plan)
            .map_err(|e| format!("shard {si}: {e}"))?;
        store
            .shard_read(si)
            .check_integrity()
            .map_err(|e| format!("shard {si} failed post-restore integrity check: {e}"))?;
    }
    Ok((store, recovered, discarded))
}

struct PagePlan {
    offset: u64,
    capacity: u32,
    chunk_size: usize,
    /// Chunk indices of surviving items (free list = the complement).
    used: Vec<u32>,
}

struct RestorePlan {
    cas_high: u64,
    pages: HashMap<(u16, u32), PagePlan>,
    items: Vec<ItemRecord>,
}

/// Restore one shard: adopt its pages at their persisted slots, then
/// re-link items tier by tier in bounded batches (one write-lock lease
/// per [`RESTORE_BATCH`] items). Within a tier the manifest order is
/// head → tail, so each tier is replayed in reverse through
/// `push_head` to land in the exact persisted recency order.
fn restore_shard(
    store: &ShardedStore,
    region: &SlabRegion,
    si: usize,
    plan: RestorePlan,
) -> Result<u64, String> {
    {
        let mut g = store.shard_write(si);
        let mut slots: Vec<(&(u16, u32), &PagePlan)> = plan.pages.iter().collect();
        slots.sort_by_key(|(k, _)| **k);
        for (&(class, slot), page) in slots {
            let buf = region
                .claim(page.offset)
                .map_err(|e| format!("page ({class},{slot}): {e}"))?;
            g.restore_page(class, slot, buf, &page.used)
                .map_err(|e| format!("page ({class},{slot}): {e}"))?;
        }
        g.set_cas_floor(plan.cas_high);
    }
    let mut batches: Vec<&ItemRecord> = Vec::with_capacity(plan.items.len());
    for tier in 0u8..3 {
        batches.extend(plan.items.iter().filter(|r| r.tier == tier).rev());
    }
    let mut restored = 0u64;
    for batch in batches.chunks(RESTORE_BATCH) {
        let mut g = store.shard_write(si);
        for rec in batch {
            g.restore_item(rec)?;
            restored += 1;
        }
        // lock released between batches: recovery never holds a shard
        // longer than one bounded lease
    }
    Ok(restored)
}

/// Rebuild the tenant registry exactly as persisted. Ids must come out
/// identical — items carry stamped tenant ids, so a drifted registry
/// would mis-attribute every recovered byte.
fn restore_tenants(store: &ShardedStore, tenants: &[TenantEntry]) -> Result<(), String> {
    for (i, t) in tenants.iter().enumerate() {
        let expect = (i + 1) as u8; // manifest skips the implicit default (id 0)
        let first = t
            .prefixes
            .first()
            .ok_or_else(|| format!("tenant '{}' has no prefix rule", t.name))?;
        let id = store
            .tenants()
            .define(&t.name, first, Some(t.quota_pages))
            .map_err(|e| format!("tenant '{}': {e}", t.name))?;
        if id != expect {
            return Err(format!(
                "tenant '{}' restored as id {id}, expected {expect}",
                t.name
            ));
        }
        for p in &t.prefixes[1..] {
            store
                .tenants()
                .define(&t.name, p, None)
                .map_err(|e| format!("tenant '{}': {e}", t.name))?;
        }
        for tok in &t.tokens {
            store
                .tenants()
                .set_token(&t.name, tok)
                .map_err(|e| format!("tenant '{}': {e}", t.name))?;
        }
    }
    Ok(())
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::sync::{Mutex, OnceLock};

    /// Failpoint registry and temp files are process-global; serialize.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static M: OnceLock<Mutex<()>> = OnceLock::new();
        M.get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn settings(path: &Path) -> Settings {
        Settings {
            memory_file: Some(path.display().to_string()),
            page_size: 1 << 16,
            mem_limit: 1 << 22, // 64 pages over 2 shards
            shards: 2,
            ..Settings::default()
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "slabforge-restart-{}-{name}.mem",
            std::process::id()
        ));
        cleanup(&p);
        p
    }

    fn cleanup(p: &Path) {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(manifest_path(p));
        let _ = std::fs::remove_file(dirty_path(p));
    }

    #[test]
    fn roundtrip_recovers_values_geometry_and_cas() {
        let _s = serial();
        let path = tmp("roundtrip");
        let s = settings(&path);
        {
            let (store, report) = open_or_cold(&s).unwrap();
            assert_eq!(report.state, "cold", "first boot has nothing to recover");
            for i in 0..500u32 {
                let k = format!("key-{i}");
                let v = vec![(i % 251) as u8; 40 + (i as usize % 300)];
                store.set(k.as_bytes(), &v, i, 0).unwrap();
            }
            store.delete(b"key-7");
            store
                .tenants()
                .define("acme", b"key-1", Some(4))
                .unwrap();
            store.tenants().set_token("acme", b"tok-acme").unwrap();
            write_manifest(&store, &s).unwrap();
        }
        let (store, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "warm", "clean shutdown must restart warm");
        assert_eq!(report.items_recovered, 499);
        assert_eq!(store.len(), 499);
        for i in 0..500u32 {
            let k = format!("key-{i}");
            let got = store.get(k.as_bytes());
            if i == 7 {
                assert!(got.is_none(), "deleted key must stay deleted");
                continue;
            }
            let v = got.unwrap_or_else(|| panic!("{k} lost across restart"));
            assert_eq!(v.flags, i);
            assert_eq!(v.data, vec![(i % 251) as u8; 40 + (i as usize % 300)]);
        }
        // CAS must stay monotonic per key (the high-water mark is
        // per-shard, and a key always routes to the same shard):
        // overwriting any recovered key yields a strictly larger CAS
        for i in [0u32, 123, 499] {
            let k = format!("key-{i}");
            let old = store.get(k.as_bytes()).unwrap().cas;
            store.set(k.as_bytes(), b"rewritten", 0, 0).unwrap();
            let new = store.get(k.as_bytes()).unwrap().cas;
            assert!(new > old, "CAS regressed for {k}: {old} -> {new}");
        }
        // tenant registry restored
        let rules = store.tenants().rules_snapshot();
        let acme = rules.iter().find(|r| r.name == "acme").unwrap();
        assert_eq!(acme.quota_pages, 4);
        assert_eq!(acme.tokens, vec![b"tok-acme".to_vec()]);
        store.check_integrity().unwrap();
        cleanup(&path);
    }

    #[test]
    fn learned_geometry_survives_restart() {
        let _s = serial();
        let path = tmp("geometry");
        let s = settings(&path);
        let learned = vec![200usize, 333, 480, 1024, 1 << 16];
        {
            let (store, _) = open_or_cold(&s).unwrap();
            store.set(b"pin", b"v", 0, 0).unwrap();
            store
                .reconfigure(ChunkSizePolicy::Explicit(learned.clone()))
                .unwrap();
            write_manifest(&store, &s).unwrap();
        }
        let (store, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "warm");
        assert_eq!(
            store.chunk_sizes(),
            learned,
            "learned classes must be the boot geometry, not the configured policy"
        );
        assert_eq!(store.get(b"pin").unwrap().data, b"v");
        cleanup(&path);
    }

    #[test]
    fn dirty_marker_and_mismatches_force_cold() {
        let _s = serial();
        let path = tmp("invalidate");
        let s = settings(&path);
        let populate = |s: &Settings| {
            let (store, _) = open_or_cold(s).unwrap();
            store.set(b"k", b"v", 0, 0).unwrap();
            write_manifest(&store, s).unwrap();
        };

        // kill-9: dirty marker never cleared
        populate(&s);
        std::fs::write(dirty_path(&path), b"crash").unwrap();
        let (store, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "cold");
        assert!(report.reason.contains("dirty"), "{}", report.reason);
        assert!(store.get(b"k").is_none(), "cold start must be empty");
        assert_eq!(store.restart_snapshot().state, "cold");
        drop(store);

        // checksum: flip one body byte
        populate(&s);
        let meta = manifest_path(&path);
        let mut raw = std::fs::read(&meta).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xFF;
        std::fs::write(&meta, &raw).unwrap();
        let (_, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "cold");
        assert!(report.reason.contains("checksum"), "{}", report.reason);

        // geometry: shard count changed between runs
        populate(&s);
        let mut s4 = s.clone();
        s4.shards = 4;
        let (_, report) = open_or_cold(&s4).unwrap();
        assert_eq!(report.state, "cold");
        assert!(report.reason.contains("shard count"), "{}", report.reason);

        // version: future manifest
        populate(&s);
        let mut raw = std::fs::read(&meta).unwrap();
        raw[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&meta, &raw).unwrap();
        let (_, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "cold");
        assert!(report.reason.contains("version"), "{}", report.reason);

        // missing manifest entirely
        populate(&s);
        std::fs::remove_file(&meta).unwrap();
        let (_, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "cold");
        cleanup(&path);
    }

    #[test]
    fn expired_items_never_resurrect() {
        let _s = serial();
        let path = tmp("expiry");
        let s = settings(&path);
        {
            let (store, _) = open_or_cold(&s).unwrap();
            store.set(b"keeper", b"v", 0, 0).unwrap();
            // absolute exptime 1 second in the past at shutdown: dead on
            // arrival at any later boot
            let past = unix_now() as u32 - 1;
            store.set(b"ghost", b"v", 0, past).unwrap();
            write_manifest(&store, &s).unwrap();
        }
        let (store, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "warm");
        assert_eq!(report.items_discarded, 1);
        assert!(store.get(b"ghost").is_none(), "expired item resurrected");
        assert_eq!(store.get(b"keeper").unwrap().data, b"v");
        store.check_integrity().unwrap();
        cleanup(&path);
    }

    #[test]
    fn manifest_write_failpoint_leaves_dirty_marker() {
        let _s = serial();
        let path = tmp("fp-write");
        let s = settings(&path);
        {
            let (store, _) = open_or_cold(&s).unwrap();
            store.set(b"k", b"v", 0, 0).unwrap();
            let _g = failpoint::armed("restart.manifest.write_fail", "once").unwrap();
            assert!(write_manifest(&store, &s).is_err());
        }
        assert!(dirty_path(&path).exists(), "failed write must not clear dirty");
        let (_, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "cold", "aborted manifest ⇒ cold start");
        cleanup(&path);
    }

    #[test]
    fn torn_page_failpoint_degrades_to_cold() {
        let _s = serial();
        let path = tmp("fp-torn");
        let s = settings(&path);
        {
            let (store, _) = open_or_cold(&s).unwrap();
            store.set(b"k", b"v", 0, 0).unwrap();
            write_manifest(&store, &s).unwrap();
        }
        let _g = failpoint::armed("restart.recover.torn_page", "once").unwrap();
        let (store, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "cold");
        assert!(report.reason.contains("torn_page"), "{}", report.reason);
        assert!(store.get(b"k").is_none());
        cleanup(&path);
    }

    #[test]
    fn mmap_failpoint_degrades_to_heap_only_cold() {
        let _s = serial();
        let path = tmp("fp-mmap");
        let s = settings(&path);
        let _g = failpoint::armed("restart.mmap.fail", "always").unwrap();
        let (store, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "cold");
        assert!(
            report.reason.contains("persistence off"),
            "{}",
            report.reason
        );
        assert!(store.region().is_none(), "heap fallback expected");
        // still a fully working cache
        store.set(b"k", b"v", 0, 0).unwrap();
        assert_eq!(store.get(b"k").unwrap().data, b"v");
        cleanup(&path);
    }

    #[test]
    fn stats_reset_and_flush_contract() {
        let _s = serial();
        let path = tmp("contract");
        let s = settings(&path);
        {
            let (store, _) = open_or_cold(&s).unwrap();
            store.set(b"k", b"v", 0, 0).unwrap();
            write_manifest(&store, &s).unwrap();
        }
        let (store, _) = open_or_cold(&s).unwrap();
        // recovery gauges are boot-scoped: `stats reset` zeroes window
        // counters but leaves restart_* standing
        store.get(b"k").unwrap();
        store.reset_stats();
        let snap = store.restart_snapshot();
        assert_eq!(snap.state, "warm");
        assert_eq!(snap.items_recovered, 1);
        assert_eq!(store.stats().cmd_get, 0, "window counters reset");
        // flush_all empties the cache; a following clean shutdown
        // persists the emptiness (no stale items reappear)
        store.flush_all();
        write_manifest(&store, &s).unwrap();
        let (store, report) = open_or_cold(&s).unwrap();
        assert_eq!(report.state, "warm");
        assert_eq!(report.items_recovered, 0, "flushed items must stay gone");
        assert!(store.get(b"k").is_none());
        cleanup(&path);
    }
}
