//! Primitives for the optimistic (lock-free) read path.
//!
//! Three building blocks, all shared between a shard's `KvStore`
//! (writer side, behind the shard `RwLock`) and the shard itself
//! (reader side, *outside* the lock):
//!
//! * [`SeqStripes`] — 64 cache-padded seqlock counters per shard.
//!   Writers bump the stripe of every hash whose reader-visible state
//!   they mutate (odd = mutation in flight); optimistic readers
//!   snapshot the stripe, copy what they need, and [`SeqStripes::
//!   validate`] that the stripe never moved. The stripe of a hash is
//!   its low 6 bits, which combined with the hash table's ≥ 64-bucket
//!   floor guarantees *every item chained in one bucket shares one
//!   stripe* — so chain-relink writes (which touch a neighbour item,
//!   not the item being removed) are still observable by any reader of
//!   that chain.
//! * [`BumpRing`] — a bounded MPSC ring (Vyukov-style) carrying
//!   deferred read-side effects: LRU bumps, access-time refreshes and
//!   fetched-bit sets become [`BumpEvent`]s enqueued by lock-free
//!   readers and drained by the maintainer thread under one short
//!   write-lock lease per pass. Overflow policy is drop-bump: recency
//!   goes slightly stale, correctness is unaffected, and the drop is
//!   counted (`lru_bump_dropped`).
//! * [`ReadLanes`] — read-path statistics striped across 8 cache-line
//!   padded lanes (indexed by a thread-local lane id) so the hot get
//!   path never bounces a shared counter cache line between reader
//!   threads.
//!
//! ## Seqlock protocol
//!
//! Writer (always under the shard write lock, so stripes never race
//! each other):
//!
//! ```text
//! seq.fetch_add(1, AcqRel);   // odd: mutation in flight; later writes
//!                             // cannot be reordered before this
//! ... mutate reader-visible state ...
//! seq.fetch_add(1, Release);  // even again; mutations cannot leak after
//! ```
//!
//! Reader:
//!
//! ```text
//! s1 = seq.load(Acquire);        // odd -> writer active, retry
//! ... volatile copies ...
//! fence(Acquire); s2 = seq.load(Relaxed);
//! valid iff s1 == s2 (and s1 even)
//! ```
//!
//! Nested writer guards on one stripe are deliberately a no-op: an
//! eviction performed while an outer [`StripeGuard`] already holds the
//! stripe odd must *not* flip it back to even mid-mutation, and the
//! outer guard's window already covers the nested mutation.

use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};

/// Stripes per shard. Must be a power of two, and must not exceed the
/// hash table's minimum bucket count (see `HashTable::with_buckets`):
/// that floor is what makes "stripe of the hash" equal "stripe of the
/// bucket" so one guard covers a whole chain.
pub const STRIPES: usize = 64;

/// One seqlock counter on its own cache line.
#[repr(align(64))]
#[derive(Default)]
struct PaddedSeq(AtomicU64);

/// Per-shard striped seqlock (see module docs for the protocol).
pub struct SeqStripes {
    seqs: [PaddedSeq; STRIPES],
}

impl Default for SeqStripes {
    fn default() -> Self {
        SeqStripes::new()
    }
}

impl SeqStripes {
    pub fn new() -> SeqStripes {
        SeqStripes {
            seqs: std::array::from_fn(|_| PaddedSeq::default()),
        }
    }

    /// Stripe index of a key hash (low bits — shared by every item in
    /// the hash-table bucket the key chains into).
    #[inline]
    pub fn stripe_of(hash: u64) -> usize {
        (hash & (STRIPES as u64 - 1)) as usize
    }

    /// Reader: snapshot a stripe. Odd means a writer is mid-mutation.
    #[inline]
    pub fn begin_read(&self, stripe: usize) -> u64 {
        self.seqs[stripe].0.load(Ordering::Acquire)
    }

    /// Reader: did the stripe stay put since [`begin_read`]? Implies
    /// every volatile copy made in between was consistent.
    ///
    /// [`begin_read`]: SeqStripes::begin_read
    #[inline]
    pub fn validate(&self, stripe: usize, seen: u64) -> bool {
        fence(Ordering::Acquire);
        seen & 1 == 0 && self.seqs[stripe].0.load(Ordering::Relaxed) == seen
    }

    /// Writer: mark a mutation window on the stripe of `hash`. Caller
    /// must hold the shard write lock (single mutator per stripe).
    #[inline]
    pub fn guard(&self, hash: u64) -> StripeGuard<'_> {
        self.guard_stripe(Self::stripe_of(hash))
    }

    /// Writer: mutation window on an explicit stripe index (used by the
    /// hash table when relinking whole buckets during expansion).
    #[inline]
    pub fn guard_stripe(&self, stripe: usize) -> StripeGuard<'_> {
        let seq = &self.seqs[stripe].0;
        // already odd: an outer guard on this stripe is active (e.g. an
        // eviction nested inside a store) — its window covers us
        if seq.load(Ordering::Relaxed) & 1 == 1 {
            return StripeGuard { seq: None };
        }
        // AcqRel: subsequent mutations cannot be reordered before the
        // odd transition
        seq.fetch_add(1, Ordering::AcqRel);
        StripeGuard { seq: Some(seq) }
    }
}

/// RAII writer window on one stripe (see [`SeqStripes::guard`]).
pub struct StripeGuard<'a> {
    /// `None` when nested inside an outer guard on the same stripe.
    seq: Option<&'a AtomicU64>,
}

impl Drop for StripeGuard<'_> {
    #[inline]
    fn drop(&mut self) {
        if let Some(seq) = self.seq {
            // Release: the mutations cannot leak past the even transition
            seq.fetch_add(1, Ordering::Release);
        }
    }
}

// ====================================================================
// Published pointers: what the lock-free reader is allowed to touch
// ====================================================================

/// Arena item-slot array, published for lock-free readers. The writer
/// republishes on every growth; retired arrays are kept alive (see
/// `Arena`) so a reader holding a stale base pointer dereferences
/// frozen — never freed — memory.
#[derive(Default)]
pub struct ArenaPub {
    /// Base address of the `ItemMeta` slot array.
    pub base: AtomicUsize,
    /// Number of initialized slots (readers bound-check ids against it).
    pub len: AtomicUsize,
}

/// Immutable snapshot of the hash table's bucket-array geometry. The
/// table republishes a fresh boxed view whenever an array appears,
/// moves or retires; superseded views and bucket arrays are parked in
/// the table's graveyard, so any snapshot a reader loaded stays
/// dereferenceable for the table's lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct TableView {
    /// Base address of the primary bucket array (`u32` heads).
    pub prim_base: usize,
    /// Primary index mask (`buckets - 1`).
    pub prim_mask: u64,
    /// Base address of the pre-expansion array (0 = no expansion).
    pub old_base: usize,
    /// Old index mask (meaningless when `old_base == 0`).
    pub old_mask: u64,
}

/// Atomic cell holding the current [`TableView`] pointer.
pub struct TablePub {
    view: std::sync::atomic::AtomicPtr<TableView>,
}

impl TablePub {
    /// Starts with a null view; the owning table publishes immediately.
    pub fn new() -> TablePub {
        TablePub {
            view: std::sync::atomic::AtomicPtr::new(std::ptr::null_mut()),
        }
    }

    /// Writer: swing the view pointer. The caller owns (and must keep
    /// alive) both the new and the previously published box.
    pub fn publish(&self, view: *mut TableView) {
        self.view.store(view, Ordering::Release);
    }

    /// Reader: copy the current view. Returns `None` before the first
    /// publish (never happens for a constructed table).
    #[inline]
    pub fn snapshot(&self) -> Option<TableView> {
        let p = self.view.load(Ordering::Acquire);
        // SAFETY: a non-null view pointer is always a Box the owning
        // table keeps alive (graveyarded on republish) for as long as
        // any reader can exist.
        unsafe { p.as_ref().copied() }
    }
}

impl Default for TablePub {
    fn default() -> Self {
        TablePub::new()
    }
}

// ====================================================================
// Deferred read-side effects
// ====================================================================

/// One deferred read-side effect: "this read would have bumped the
/// item's LRU position / access time / fetched bit". Applied later by
/// the maintainer under the shard write lock, after re-validating that
/// the slot still holds the same item (`live` + `gen` + `cas`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BumpEvent {
    /// Arena slot id of the item at enqueue time.
    pub id: u32,
    /// Item generation tag at enqueue time.
    pub gen: u8,
    /// Item CAS at enqueue time (slot-reuse guard).
    pub cas: u64,
    /// Coarse clock at enqueue time (becomes the new access time).
    pub now: u32,
}

/// Capacity of each shard's deferred-bump ring. Power of two.
pub const BUMP_RING_CAP: usize = 2048;

struct RingSlot {
    seq: AtomicUsize,
    val: UnsafeCell<BumpEvent>,
}

/// Bounded multi-producer single-consumer ring (Vyukov's bounded MPMC
/// queue, used here MPSC: readers produce, the maintainer consumes).
/// `push` is lock-free and allocation-free; a full ring rejects the
/// event (drop-bump overflow policy).
pub struct BumpRing {
    slots: Box<[RingSlot]>,
    mask: usize,
    enqueue: AtomicUsize,
    dequeue: AtomicUsize,
}

// SAFETY: slot payloads are only written by the producer that won the
// slot via CAS on `enqueue` (published by the slot's `seq` store) and
// only read by the single consumer after observing that publish.
unsafe impl Send for BumpRing {}
unsafe impl Sync for BumpRing {}

impl BumpRing {
    pub fn new(capacity: usize) -> BumpRing {
        let cap = capacity.next_power_of_two().max(2);
        let slots = (0..cap)
            .map(|i| RingSlot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(BumpEvent::default()),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        BumpRing {
            slots,
            mask: cap - 1,
            enqueue: AtomicUsize::new(0),
            dequeue: AtomicUsize::new(0),
        }
    }

    /// Enqueue from any reader thread. `false` = ring full (drop-bump).
    pub fn push(&self, ev: BumpEvent) -> bool {
        let mut pos = self.enqueue.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS gives this thread sole
                        // write access to the slot until the seq store.
                        unsafe { *slot.val.get() = ev };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return true;
                    }
                    Err(cur) => pos = cur,
                }
            } else if diff < 0 {
                return false; // full
            } else {
                pos = self.enqueue.load(Ordering::Relaxed);
            }
        }
    }

    /// Dequeue one event. Single consumer (the maintainer).
    pub fn pop(&self) -> Option<BumpEvent> {
        let pos = self.dequeue.load(Ordering::Relaxed);
        let slot = &self.slots[pos & self.mask];
        let seq = slot.seq.load(Ordering::Acquire);
        if (seq as isize) - (pos.wrapping_add(1) as isize) < 0 {
            return None; // empty
        }
        // SAFETY: single consumer; the Acquire load above synchronizes
        // with the producer's Release publish of this slot.
        let ev = unsafe { *slot.val.get() };
        slot.seq
            .store(pos.wrapping_add(self.mask).wrapping_add(1), Ordering::Release);
        self.dequeue.store(pos.wrapping_add(1), Ordering::Relaxed);
        Some(ev)
    }

    /// Drain up to `max` events into `out` (consumer side).
    pub fn drain_into(&self, out: &mut Vec<BumpEvent>, max: usize) {
        while out.len() < max {
            match self.pop() {
                Some(ev) => out.push(ev),
                None => break,
            }
        }
    }
}

// ====================================================================
// Striped read counters
// ====================================================================

/// Lanes per shard for read-path counters. Power of two.
pub const LANES: usize = 8;

/// One lane of read counters, padded to a cache line (7 × 8 B = 56 B).
#[repr(align(64))]
#[derive(Default)]
pub struct ReadLane {
    pub gets: AtomicU64,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub retries: AtomicU64,
    pub fallbacks: AtomicU64,
    pub bump_queued: AtomicU64,
    pub bump_dropped: AtomicU64,
}

/// Aggregated totals of a shard's [`ReadLanes`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReadLaneTotals {
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub retries: u64,
    pub fallbacks: u64,
    pub bump_queued: u64,
    pub bump_dropped: u64,
}

/// Cache-line striped read-path counters. Each thread hashes to one
/// lane (sticky thread-local assignment), so concurrent readers on
/// different cores do not share a counter cache line.
pub struct ReadLanes {
    lanes: [ReadLane; LANES],
}

impl Default for ReadLanes {
    fn default() -> Self {
        ReadLanes::new()
    }
}

static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);
thread_local! {
    static LANE: usize = NEXT_LANE.fetch_add(1, Ordering::Relaxed) & (LANES - 1);
}

impl ReadLanes {
    pub fn new() -> ReadLanes {
        ReadLanes {
            lanes: std::array::from_fn(|_| ReadLane::default()),
        }
    }

    /// The calling thread's lane.
    #[inline]
    pub fn lane(&self) -> &ReadLane {
        &self.lanes[LANE.with(|l| *l)]
    }

    pub fn totals(&self) -> ReadLaneTotals {
        let mut t = ReadLaneTotals::default();
        for l in &self.lanes {
            t.gets += l.gets.load(Ordering::Relaxed);
            t.hits += l.hits.load(Ordering::Relaxed);
            t.misses += l.misses.load(Ordering::Relaxed);
            t.retries += l.retries.load(Ordering::Relaxed);
            t.fallbacks += l.fallbacks.load(Ordering::Relaxed);
            t.bump_queued += l.bump_queued.load(Ordering::Relaxed);
            t.bump_dropped += l.bump_dropped.load(Ordering::Relaxed);
        }
        t
    }

    pub fn reset(&self) {
        for l in &self.lanes {
            l.gets.store(0, Ordering::Relaxed);
            l.hits.store(0, Ordering::Relaxed);
            l.misses.store(0, Ordering::Relaxed);
            l.retries.store(0, Ordering::Relaxed);
            l.fallbacks.store(0, Ordering::Relaxed);
            l.bump_queued.store(0, Ordering::Relaxed);
            l.bump_dropped.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn stripe_guard_parity() {
        let s = SeqStripes::new();
        let h = 0x1234_5678_u64;
        let stripe = SeqStripes::stripe_of(h);
        let before = s.begin_read(stripe);
        assert_eq!(before & 1, 0);
        {
            let _g = s.guard(h);
            assert_eq!(s.begin_read(stripe) & 1, 1, "odd inside the window");
        }
        let after = s.begin_read(stripe);
        assert_eq!(after, before + 2);
        assert!(s.validate(stripe, after));
        assert!(!s.validate(stripe, before));
    }

    #[test]
    fn nested_guard_on_same_stripe_is_noop() {
        let s = SeqStripes::new();
        let h = 64 + 5; // stripe 5
        let outer = s.guard(h);
        let v = s.begin_read(5);
        assert_eq!(v & 1, 1);
        {
            let _inner = s.guard(h);
            assert_eq!(s.begin_read(5), v, "nested guard must not move the seq");
        }
        assert_eq!(s.begin_read(5), v, "inner drop must not end the window");
        drop(outer);
        assert_eq!(s.begin_read(5) & 1, 0);
    }

    #[test]
    fn guards_on_distinct_stripes_are_independent() {
        let s = SeqStripes::new();
        let _a = s.guard(0);
        let _b = s.guard(1);
        assert_eq!(s.begin_read(0) & 1, 1);
        assert_eq!(s.begin_read(1) & 1, 1);
        assert_eq!(s.begin_read(2) & 1, 0);
    }

    #[test]
    fn stripe_of_matches_bucket_low_bits() {
        // the invariant the read path depends on: with >= 64 buckets,
        // hash & (buckets-1) and hash & 63 agree in the low 6 bits
        for hash in [0u64, 63, 64, 0xdead_beef, u64::MAX] {
            for buckets in [64u64, 128, 1 << 20] {
                assert_eq!(
                    (hash & (buckets - 1)) & 63,
                    SeqStripes::stripe_of(hash) as u64
                );
            }
        }
    }

    #[test]
    fn ring_fifo_and_overflow() {
        let r = BumpRing::new(4);
        for i in 0..4u32 {
            assert!(r.push(BumpEvent {
                id: i,
                ..BumpEvent::default()
            }));
        }
        assert!(!r.push(BumpEvent::default()), "full ring rejects");
        for i in 0..4u32 {
            assert_eq!(r.pop().unwrap().id, i);
        }
        assert_eq!(r.pop(), None);
        // slots recycle
        assert!(r.push(BumpEvent {
            id: 9,
            ..BumpEvent::default()
        }));
        assert_eq!(r.pop().unwrap().id, 9);
    }

    #[test]
    fn ring_concurrent_producers_lose_nothing() {
        let r = Arc::new(BumpRing::new(4096));
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..512u32 {
                    assert!(r.push(BumpEvent {
                        id: t * 1000 + i,
                        ..BumpEvent::default()
                    }));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = Vec::new();
        while let Some(ev) = r.pop() {
            seen.push(ev.id);
        }
        assert_eq!(seen.len(), 4 * 512);
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 4 * 512, "no duplicates, no losses");
    }

    #[test]
    fn lanes_total_and_reset() {
        let lanes = ReadLanes::new();
        lanes.lane().gets.fetch_add(3, Ordering::Relaxed);
        lanes.lane().hits.fetch_add(2, Ordering::Relaxed);
        lanes.lane().bump_dropped.fetch_add(1, Ordering::Relaxed);
        let t = lanes.totals();
        assert_eq!((t.gets, t.hits, t.bump_dropped), (3, 2, 1));
        lanes.reset();
        assert_eq!(lanes.totals(), ReadLaneTotals::default());
    }

    #[test]
    fn table_pub_roundtrip() {
        let p = TablePub::new();
        assert!(p.snapshot().is_none());
        let v = Box::new(TableView {
            prim_base: 0x1000,
            prim_mask: 63,
            old_base: 0,
            old_mask: 0,
        });
        let raw = Box::into_raw(v);
        p.publish(raw);
        let s = p.snapshot().unwrap();
        assert_eq!(s.prim_base, 0x1000);
        assert_eq!(s.prim_mask, 63);
        // re-box to free (the real owner keeps superseded views alive)
        unsafe { drop(Box::from_raw(raw)) };
    }
}
