//! Incremental slab migration: the paper's central operation —
//! re-learning chunk geometry online — as a **bounded-pause drain**
//! instead of a stop-the-world rebuild.
//!
//! ## How it works
//!
//! [`KvStore::begin_migration`] flips the store to a new generation:
//! the allocator's class table is swapped for the new geometry (O(1) —
//! no item is touched), the per-class LRUs move into [`MigrationState`]
//! as the *old* generation, and the store's generation tag advances so
//! every existing item is recognisably old. From that instant:
//!
//! * **writes** land in the new geometry; any rewrite of an old item
//!   (set over, append, incr, cas) migrates it as a side effect;
//! * **reads** resolve items in either generation (the allocator keeps
//!   both class tables readable);
//! * [`KvStore::migrate_step`] moves at most `max_items` items per call
//!   — the only work done under the shard write lock — walking each old
//!   class coldest-first so relative recency survives the move;
//! * a fully drained old page dissolves into the allocator's free-page
//!   pool and is re-carved for the new geometry, bounding transient
//!   memory to the page budget plus a constant slack (no 2× copy).
//!
//! Under memory pressure (budget exhausted, nothing of the new
//! generation to evict) the migrator force-drains the old page with the
//! fewest live items — memcached's slab-rebalance move, applied to the
//! cheapest page — trading the coldest few items for forward progress.
//!
//! The drain is complete when no old item remains; the final page
//! release and the [`MigrationReport`] happen in
//! `maybe_finish_migration`, reached from `migrate_step` (and from
//! `flush_all`, which empties both generations at once).

use super::lru::ClassLru;
use super::store::{KvStore, MigrationReport, StoreError};
use crate::slab::policy::ChunkSizePolicy;
use crate::slab::SlabError;

/// Items moved per [`KvStore::migrate_step`] when the caller does not
/// supply a budget (the `migrate_batch` setting overrides per store).
pub const DEFAULT_MIGRATE_BATCH: usize = 256;

/// Per-shard state of an in-flight incremental migration.
pub struct MigrationState {
    /// The draining generation's per-class LRUs (parallel to the
    /// allocator's old class table).
    pub(crate) old_lrus: Vec<ClassLru>,
    /// Live items still in the old generation; 0 ⇒ drain complete.
    pub(crate) old_items: usize,
    /// Items copied into the new geometry so far (steps + rewrites).
    pub(crate) moved: usize,
    /// Items lost to the drain: no room under budget + slack, or on a
    /// force-drained page.
    pub(crate) dropped: usize,
    /// Old pages recycled into the free-page pool so far.
    pub(crate) pages_reclaimed: usize,
    /// Pages reclaimed by force-drain (subset of `pages_reclaimed`).
    pub(crate) force_drained_pages: usize,
    /// Items dropped by force-draining an enumerated page (subset of
    /// `dropped`; the rest fell to the no-room fallback).
    pub(crate) force_dropped: usize,
    pub(crate) hole_bytes_before: u64,
    pub(crate) pages_before: usize,
}

/// Migration gauges for `stats slabs` (merged across shards by
/// `ShardedStore::migration_gauges`). Counters are lifetime totals;
/// `active_shards` / `items_remaining` describe the in-flight drain.
#[derive(Clone, Debug, Default)]
pub struct MigrationGauges {
    /// Shards with a drain in flight (0 or 1 for a single store).
    pub active_shards: u64,
    pub moved: u64,
    pub dropped: u64,
    pub pages_reclaimed: u64,
    /// Pages reclaimed by force-drain under full-budget pressure
    /// (subset of `pages_reclaimed`).
    pub force_drained_pages: u64,
    /// Items dropped by force-draining an enumerated page — with the
    /// per-page index, drops are exactly the residents of the pages we
    /// enumerate (subset of `dropped`; the remainder is the terminal
    /// no-room fallback).
    pub force_dropped: u64,
    /// Old-generation items still awaiting the drain.
    pub items_remaining: u64,
}

impl KvStore {
    /// True while an incremental migration is draining.
    #[inline]
    pub fn migration_active(&self) -> bool {
        self.migration.is_some()
    }

    /// Report of the most recently completed migration, if any.
    pub fn last_migration(&self) -> Option<&MigrationReport> {
        self.last_migration.as_ref()
    }

    /// Migration gauges: lifetime totals plus the in-flight drain.
    pub fn migration_gauges(&self) -> MigrationGauges {
        let mut g = self.mig_totals.clone();
        if let Some(m) = &self.migration {
            g.active_shards = 1;
            g.moved += m.moved as u64;
            g.dropped += m.dropped as u64;
            g.pages_reclaimed += m.pages_reclaimed as u64;
            g.force_drained_pages += m.force_drained_pages as u64;
            g.force_dropped += m.force_dropped as u64;
            g.items_remaining = m.old_items as u64;
        }
        g
    }

    /// Start an incremental migration to `new_policy`. O(1) in the
    /// number of items: geometry and generation flip immediately (new
    /// writes land in the new layout, reads resolve both), and the
    /// actual drain happens in subsequent [`migrate_step`] calls.
    ///
    /// Fails with [`StoreError::Busy`] while a previous drain is still
    /// running and [`StoreError::BadPolicy`] for an invalid geometry
    /// (nothing is touched in either case).
    ///
    /// [`migrate_step`]: KvStore::migrate_step
    pub fn begin_migration(&mut self, new_policy: ChunkSizePolicy) -> Result<(), StoreError> {
        if self.migration.is_some() {
            return Err(StoreError::Busy);
        }
        let before = self.alloc.stats();
        self.alloc
            .begin_migration(&new_policy)
            .map_err(|e| match e {
                SlabError::Policy(p) => StoreError::BadPolicy(p.to_string()),
                other => StoreError::BadPolicy(other.to_string()),
            })?;
        let new_lrus: Vec<ClassLru> = (0..self.alloc.chunk_sizes().len())
            .map(|_| ClassLru::new())
            .collect();
        let old_lrus = std::mem::replace(&mut self.lrus, new_lrus);
        self.gen = self.gen.wrapping_add(1);
        self.policy = new_policy;
        self.migration = Some(MigrationState {
            old_lrus,
            old_items: self.arena.len(),
            moved: 0,
            dropped: 0,
            pages_reclaimed: 0,
            force_drained_pages: 0,
            force_dropped: 0,
            hole_bytes_before: before.hole_bytes,
            pages_before: before.pages_allocated,
        });
        // an empty store drains instantly
        self.maybe_finish_migration();
        Ok(())
    }

    /// Drive the drain: move at most `max_items` old-generation items
    /// into the new geometry (coldest-first per class), then release
    /// any old pages that drained. This is the only migration work done
    /// under the shard write lock — callers alternate steps with
    /// regular traffic. Returns `true` while the migration is still
    /// active after the step.
    pub fn migrate_step(&mut self, max_items: usize) -> bool {
        if self.migration.is_none() {
            return false;
        }
        // failpoints sit at the entry, BEFORE any unlink/move: a panic
        // or injected failure here leaves the two-generation state
        // exactly as it was, so the next pumper resumes the drain
        crate::util::failpoint::maybe_panic("migrate.step.panic");
        if crate::util::failpoint::fired("migrate.step.fail") {
            return true; // "made no progress this step" — still active
        }
        for _ in 0..max_items.max(1) {
            let Some((class, id)) = self.next_drain_victim() else {
                break;
            };
            let (handle, klen, vlen, total, hash, expired, tenant) = {
                let m = self.arena.get(id);
                (
                    m.handle,
                    m.klen as usize,
                    m.vlen as usize,
                    m.total as usize,
                    m.hash,
                    self.is_expired(m),
                    m.tenant,
                )
            };
            if expired {
                // lazy reclaim instead of a pointless move
                self.unlink_and_free(id, hash);
                self.stats.expired_reclaims += 1;
                continue;
            }
            // unlink from the old LRU and the old page index first so a
            // force-drain during the allocation below can never free
            // the item being moved
            {
                let mig = self.migration.as_mut().expect("active migration");
                mig.old_lrus[class].remove(id, &mut self.arena);
            }
            self.page_unlink(id);
            match self.migrate_alloc(total) {
                Some(new_handle) => {
                    // the new chunk is filled before the stripe window
                    // opens: a reader can only reach it through the
                    // handle/addr flip below, which the window covers
                    self.alloc.migrate_copy(handle, new_handle, klen + vlen);
                    let new_addr = self.alloc.chunk(new_handle).as_ptr() as usize;
                    self.alloc.free_old(handle, total);
                    let gen = self.gen;
                    {
                        let seq = self.seq.clone();
                        let _g = seq.guard(hash);
                        let m = self.arena.get_mut(id);
                        m.handle = new_handle;
                        m.gen = gen;
                        m.chunk_addr = new_addr;
                    }
                    self.lrus[new_handle.class as usize].insert(id, &mut self.arena);
                    self.page_link(id);
                    let mig = self.migration.as_mut().expect("active migration");
                    mig.moved += 1;
                    mig.old_items -= 1;
                }
                None => {
                    // no room even after force-drains: the item is lost
                    // (the paper's restart would have lost everything)
                    {
                        let seq = self.seq.clone();
                        let _g = seq.guard(hash);
                        self.table.remove(id, hash, &mut self.arena);
                        self.arena.remove(id);
                    }
                    self.alloc.free_old(handle, total);
                    // a drop leaves residency — moves keep the stamp
                    // and change no totals, so only this branch reports
                    self.tenant_on_free(tenant, total);
                    let mig = self.migration.as_mut().expect("active migration");
                    mig.dropped += 1;
                    mig.old_items -= 1;
                }
            }
        }
        let freed = self.alloc.release_old_drained_pages();
        if let Some(mig) = self.migration.as_mut() {
            mig.pages_reclaimed += freed;
        }
        self.maybe_finish_migration();
        self.migration.is_some()
    }

    /// Coldest item of the lowest-indexed old class that still has one.
    fn next_drain_victim(&self) -> Option<(usize, u32)> {
        let mig = self.migration.as_ref()?;
        mig.old_lrus
            .iter()
            .enumerate()
            .find_map(|(ci, lru)| lru.eviction_candidate().map(|id| (ci, id)))
    }

    /// Allocate a new-generation chunk for a migrating item. Never
    /// evicts new-generation items (a drain must not churn what it just
    /// moved); when the budget is exhausted it force-drains the
    /// emptiest old page and retries.
    fn migrate_alloc(&mut self, total: usize) -> Option<crate::slab::ChunkHandle> {
        loop {
            match self.alloc.alloc(total) {
                Ok(h) => return Some(h),
                Err(SlabError::TooLarge { .. }) => return None,
                Err(SlabError::NeedEviction { .. }) => {
                    if !self.force_drain_old_page() {
                        return None;
                    }
                }
                Err(SlabError::Policy(_)) => unreachable!("policy validated at begin"),
            }
        }
    }

    /// Drop every item on the emptiest drainable old page and release
    /// it into the free-page pool — memcached's slab-rebalance move,
    /// aimed at the cheapest page. Victims are enumerated through the
    /// **per-page item index** (`ItemMeta::{pg_prev,pg_next}` chains
    /// headed in the class table), so resolving page→items costs
    /// O(chunks/page) instead of an O(class items) LRU walk — and the
    /// drop set is exactly the residents of the page we enumerate.
    /// Pages pinned by an in-flight move (a chunk whose item is
    /// temporarily unlinked from both indexes) cannot fully drain, so
    /// candidates are tried in ascending occupancy until one actually
    /// releases. Returns `true` when a page was reclaimed (so an
    /// allocation retry can succeed).
    pub(crate) fn force_drain_old_page(&mut self) -> bool {
        // entry failpoint (before any drop): an injected `false` sends
        // the caller down its real exhaustion path (`OutOfMemory` for
        // the set path, item-drop for `migrate_alloc`)
        if crate::util::failpoint::fired("migrate.force_drain.fail") {
            return false;
        }
        let mut candidates = self.alloc.old_page_occupancy();
        candidates.sort_unstable_by_key(|&(_, _, used)| used);
        for (class, page, used) in candidates {
            // walk the page's item chain: O(items on this page)
            let victims = self.page_residents(true, class, page);
            if (victims.len() as u32) < used {
                // pinned: dropping the chain residents cannot release it
                continue;
            }
            let n = victims.len();
            for (id, hash) in victims {
                self.unlink_and_free(id, hash); // routes old, maintains old_items
            }
            let freed = self.alloc.release_old_drained_pages();
            if let Some(mig) = self.migration.as_mut() {
                mig.dropped += n;
                mig.force_dropped += n;
                mig.pages_reclaimed += freed;
                mig.force_drained_pages += freed;
            }
            self.stats.evictions += n as u64;
            if freed > 0 {
                return true;
            }
        }
        false
    }

    /// Complete the migration once the old generation is empty: release
    /// its remaining (drained) pages, record the report, bump
    /// `slab_reconfigures`.
    pub(crate) fn maybe_finish_migration(&mut self) {
        let drained = self.migration.as_ref().is_some_and(|m| m.old_items == 0);
        if !drained {
            return;
        }
        let mut mig = self.migration.take().expect("checked above");
        mig.pages_reclaimed += self.alloc.finish_migration();
        self.mig_totals.moved += mig.moved as u64;
        self.mig_totals.dropped += mig.dropped as u64;
        self.mig_totals.pages_reclaimed += mig.pages_reclaimed as u64;
        self.mig_totals.force_drained_pages += mig.force_drained_pages as u64;
        self.mig_totals.force_dropped += mig.force_dropped as u64;
        self.mig_totals.items_remaining = 0;
        self.stats.reconfigures += 1;
        let after = self.alloc.stats();
        self.last_migration = Some(MigrationReport {
            items_moved: mig.moved,
            items_dropped: mig.dropped,
            hole_bytes_before: mig.hole_bytes_before,
            hole_bytes_after: after.hole_bytes,
            pages_before: mig.pages_before,
            pages_after: after.pages_allocated,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::allocator::MIGRATION_PAGE_SLACK;
    use crate::store::store::{Clock, KvStore};

    fn store_with(page_size: usize, mem: usize) -> KvStore {
        KvStore::new(
            ChunkSizePolicy::default(),
            page_size,
            mem,
            true,
            Clock::System,
        )
        .unwrap()
    }

    /// total_item_size(5-byte key, 455-byte value, cas) = 518.
    fn fill_518(s: &mut KvStore, n: u32) {
        for i in 0..n {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
    }

    #[test]
    fn begin_is_o1_and_serving_continues_between_steps() {
        let mut s = store_with(1 << 20, 32 << 20);
        fill_518(&mut s, 2000);
        s.begin_migration(ChunkSizePolicy::Explicit(vec![518])).unwrap();
        assert!(s.migration_active());
        assert_eq!(s.chunk_sizes(), &[518, 1 << 20]);

        let mut steps = 0;
        loop {
            let active = s.migrate_step(128);
            steps += 1;
            // gets are served mid-drain, resolving both generations
            assert_eq!(s.get(b"k0000").unwrap().value.len(), 455);
            assert_eq!(s.get(b"k1999").unwrap().value.len(), 455);
            // new writes land while the drain is in flight (exact-fit
            // sized so the final hole assertion stays meaningful)
            s.set(format!("n{steps:04}").as_bytes(), &vec![b'y'; 455], 0, 0)
                .unwrap();
            if !active {
                break;
            }
        }
        assert!(steps >= 2000 / 128, "drain must take multiple steps");
        let report = s.last_migration().unwrap();
        assert_eq!(report.items_moved, 2000);
        assert_eq!(report.items_dropped, 0);
        assert_eq!(report.hole_bytes_after, 0, "518 items in 518 chunks");
        assert_eq!(s.len(), 2000 + steps);
    }

    #[test]
    fn memory_bounded_by_budget_plus_slack_throughout() {
        let mut s = store_with(1 << 20, 8 << 20); // 8-page budget
        fill_518(&mut s, 8000); // ~4.1 MiB requested -> ~5 pages of 600s
        let budget = s.slab_stats().page_budget;
        s.begin_migration(ChunkSizePolicy::Explicit(vec![518])).unwrap();
        while s.migrate_step(64) {
            let st = s.slab_stats();
            assert!(
                st.pages_allocated + st.pages_free <= budget + MIGRATION_PAGE_SLACK,
                "resident {}+{} pages exceeds budget {budget} + slack",
                st.pages_allocated,
                st.pages_free
            );
        }
        let st = s.slab_stats();
        assert!(st.pages_allocated + st.pages_free <= budget + MIGRATION_PAGE_SLACK);
        assert_eq!(s.last_migration().unwrap().items_dropped, 0);
        assert_eq!(s.len(), 8000);
    }

    #[test]
    fn cas_and_flags_preserved_across_step_boundaries() {
        let mut s = store_with(1 << 20, 32 << 20);
        s.set(b"token", b"payload", 42, 0).unwrap();
        let before = s.get(b"token").unwrap();
        fill_518(&mut s, 500);
        s.begin_migration(ChunkSizePolicy::Explicit(vec![200, 518])).unwrap();
        // partial drain: the item may sit in either generation now
        s.migrate_step(50);
        let mid = s.get(b"token").unwrap();
        assert_eq!(mid.cas, before.cas, "cas must survive the move");
        assert_eq!(mid.flags, 42);
        assert_eq!(mid.value, b"payload");
        while s.migrate_step(50) {}
        let after = s.get(b"token").unwrap();
        assert_eq!(after.cas, before.cas);
        assert_eq!(after.flags, 42);
        // the preserved token still wins a cas
        assert_eq!(
            s.cas(b"token", b"new", 0, 0, before.cas).unwrap(),
            crate::store::store::CasResult::Stored
        );
    }

    #[test]
    fn incr_delete_and_append_land_on_old_items_mid_drain() {
        let mut s = store_with(1 << 20, 32 << 20);
        s.set(b"counter", b"10", 0, 0).unwrap();
        s.set(b"doomed", b"bye", 0, 0).unwrap();
        s.set(b"grow", b"seed", 0, 0).unwrap();
        fill_518(&mut s, 1000);
        s.begin_migration(ChunkSizePolicy::Explicit(vec![100, 518])).unwrap();
        // nothing stepped yet: every target below is still old-gen
        assert_eq!(s.migration_gauges().items_remaining, 1003);
        // incr on an old item migrates it as a side effect
        assert_eq!(s.incr_decr(b"counter", 5, true).unwrap(), Some(15));
        // delete on an old item frees the old chunk directly
        assert!(s.delete(b"doomed"));
        assert!(s.get(b"doomed").is_none());
        // append migrates too (and must read the old bytes correctly)
        assert!(s.concat(b"grow", b"-appended", true).unwrap());
        assert_eq!(s.migration_gauges().items_remaining, 1000);
        while s.migrate_step(100) {}
        assert_eq!(s.get(b"counter").unwrap().value, b"15");
        assert_eq!(s.get(b"grow").unwrap().value, b"seed-appended");
        let r = s.last_migration().unwrap();
        // counter + grow moved via rewrites, doomed left via delete:
        // all three count toward drain completion without being stepped
        assert_eq!(r.items_moved + r.items_dropped, 1002);
    }

    #[test]
    fn hole_accounting_sums_generations_honestly() {
        let mut s = store_with(1 << 20, 32 << 20);
        fill_518(&mut s, 1000); // hole = 82 per item in the 600 class
        assert_eq!(s.slab_stats().hole_bytes, 82 * 1000);
        s.begin_migration(ChunkSizePolicy::Explicit(vec![518])).unwrap();
        let mut mid_checked = false;
        while s.migrate_step(100) {
            let g = s.migration_gauges();
            let st = s.slab_stats();
            // moved items sit hole-free in 518 chunks; the rest still
            // carry their 82-byte hole in the old 600 class
            assert_eq!(st.requested_bytes, 518 * 1000);
            assert_eq!(st.hole_bytes, 82 * g.items_remaining);
            assert_eq!(st.allocated_bytes - st.requested_bytes, st.hole_bytes);
            mid_checked = true;
        }
        assert!(mid_checked, "drain must be observable mid-flight");
        assert_eq!(s.slab_stats().hole_bytes, 0);
    }

    #[test]
    fn gauges_track_drain_and_reset() {
        let mut s = store_with(1 << 20, 32 << 20);
        fill_518(&mut s, 300);
        assert_eq!(s.migration_gauges().active_shards, 0);
        s.begin_migration(ChunkSizePolicy::Explicit(vec![518])).unwrap();
        s.migrate_step(100);
        let g = s.migration_gauges();
        assert_eq!(g.active_shards, 1);
        assert_eq!(g.moved, 100);
        assert_eq!(g.items_remaining, 200);
        while s.migrate_step(100) {}
        let g = s.migration_gauges();
        assert_eq!(g.active_shards, 0);
        assert_eq!(g.moved, 300);
        assert_eq!(g.items_remaining, 0);
        assert!(g.pages_reclaimed >= 1, "old pages must recycle");
    }

    #[test]
    fn second_begin_while_draining_is_busy() {
        let mut s = store_with(1 << 20, 32 << 20);
        fill_518(&mut s, 100);
        s.begin_migration(ChunkSizePolicy::Explicit(vec![518])).unwrap();
        assert_eq!(
            s.begin_migration(ChunkSizePolicy::Explicit(vec![600])),
            Err(StoreError::Busy)
        );
        while s.migrate_step(100) {}
        // after the drain a new migration may start
        s.begin_migration(ChunkSizePolicy::Explicit(vec![600])).unwrap();
    }

    #[test]
    fn bad_policy_rejected_without_touching_state() {
        let mut s = store_with(1 << 20, 32 << 20);
        fill_518(&mut s, 10);
        let before = s.chunk_sizes().to_vec();
        match s.begin_migration(ChunkSizePolicy::Explicit(vec![900, 400])) {
            Err(StoreError::BadPolicy(_)) => {}
            other => panic!("{other:?}"),
        }
        assert!(!s.migration_active());
        assert_eq!(s.chunk_sizes(), &before[..]);
        assert_eq!(s.get(b"k0000").unwrap().value.len(), 455);
    }

    #[test]
    fn full_cache_drain_force_reclaims_pages_not_two_x() {
        // 64 KiB pages, 16-page budget, cache filled to eviction
        let mut s = store_with(64 << 10, 1 << 20);
        for i in 0..4000u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        assert!(s.stats().evictions > 0, "cache must be full");
        let live_before = s.len();
        let budget = s.slab_stats().page_budget;
        s.begin_migration(ChunkSizePolicy::Explicit(vec![520, 620, 950])).unwrap();
        while s.migrate_step(64) {
            let st = s.slab_stats();
            assert!(st.pages_allocated + st.pages_free <= budget + MIGRATION_PAGE_SLACK);
        }
        let r = s.last_migration().unwrap().clone();
        assert_eq!(r.items_moved + r.items_dropped, live_before);
        // tighter packing: the drain must not shed more than a sliver
        assert!(
            r.items_dropped * 10 <= live_before,
            "dropped {} of {live_before}",
            r.items_dropped
        );
        assert!(s.migration_gauges().pages_reclaimed > 0);
    }

    #[test]
    fn force_drain_resolves_pages_in_o_items_on_page() {
        // Full cache: the first migrate_step must force-drain an old
        // page to make room. With the per-page item index, resolving
        // page→items walks only that page's residents — the step
        // counter stays O(chunks/page), independent of the ~1700 items
        // resident in the class (the old LRU walk was O(class items)
        // per reclaimed page).
        let mut s = store_with(64 << 10, 1 << 20); // 16-page budget
        for i in 0..4000u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        assert!(s.stats().evictions > 0, "cache must be full");
        let live = s.len() as u64;
        assert!(live > 1000, "live {live}");
        s.begin_migration(ChunkSizePolicy::Explicit(vec![520, 620, 950]))
            .unwrap();
        assert_eq!(s.page_scan_steps(), 0);
        s.migrate_step(1); // forces at least one page reclaim
        let scanned = s.page_scan_steps();
        assert!(scanned >= 1, "force-drain must have walked a page chain");
        // 518-byte items sit in 600-byte chunks: ≤ 109 chunks per 64 KiB
        // page. At most two chains walked (the in-flight item can pin
        // its own page, forcing one skip).
        let per_page: u64 = (64 << 10) / 600;
        assert!(
            scanned <= 2 * per_page,
            "scanned {scanned} items for one page reclaim (page holds ≤ {per_page})"
        );
        assert!(
            scanned < live / 4,
            "scan ({scanned}) must not approach class size ({live})"
        );
        let g = s.migration_gauges();
        assert!(g.force_drained_pages >= 1);
        assert_eq!(g.force_dropped, g.dropped, "all drops from enumerated pages");
    }

    #[test]
    fn flush_all_mid_drain_finishes_migration() {
        let mut s = store_with(1 << 20, 32 << 20);
        fill_518(&mut s, 200);
        s.begin_migration(ChunkSizePolicy::Explicit(vec![518])).unwrap();
        s.migrate_step(50);
        s.flush_all();
        assert!(!s.migration_active(), "flush empties both generations");
        assert_eq!(s.len(), 0);
        assert_eq!(s.slab_stats().requested_bytes, 0);
    }
}
