//! Item-metadata arena: fixed-size records addressed by `u32` ids, with
//! intrusive links for both the hash chains and the LRU lists (the same
//! layout trick as memcached's `_stritem`, minus the pointers).

use crate::slab::ChunkHandle;

/// Sentinel id for "no item".
pub const NIL: u32 = u32::MAX;

/// LRU tier (memcached 1.5 segmented LRU).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Hot = 0,
    Warm = 1,
    Cold = 2,
}

impl Tier {
    pub fn from_u8(v: u8) -> Tier {
        match v {
            0 => Tier::Hot,
            1 => Tier::Warm,
            _ => Tier::Cold,
        }
    }
}

/// Per-item metadata record (the chunk holds `[key][value]` bytes).
#[derive(Clone, Debug)]
pub struct ItemMeta {
    pub hash: u64,
    pub handle: ChunkHandle,
    pub klen: u16,
    pub vlen: u32,
    pub flags: u32,
    /// Absolute unix expiry, 0 = never.
    pub exptime: u32,
    /// Set/update time (drives `flush_all` and age stats).
    pub time: u32,
    pub cas: u64,
    /// Accounted total size (header + key + value + tail).
    pub total: u32,
    /// Hash-chain next.
    pub hnext: u32,
    /// LRU links.
    pub prev: u32,
    pub next: u32,
    /// Per-page item chain (all items whose chunk lives on the same
    /// page of the same generation): the page→items index that lets a
    /// page drain enumerate its residents in O(chunks/page).
    pub pg_prev: u32,
    pub pg_next: u32,
    pub tier: u8,
    /// The item has been served by a write-path fetch since it was
    /// stored (memcached's ITEM_FETCHED; the meta `h` echo). Read-lock
    /// fast-path hits inside TOUCH_INTERVAL cannot set it.
    pub fetched: bool,
    /// Slab-geometry generation the chunk belongs to. During an
    /// incremental migration, items whose tag differs from the store's
    /// current generation still live in the old (draining) allocator
    /// generation.
    pub gen: u8,
    /// True while the record is live (guards against stale ids).
    pub live: bool,
}

impl ItemMeta {
    fn vacant() -> Self {
        ItemMeta {
            hash: 0,
            handle: ChunkHandle {
                class: 0,
                loc: crate::slab::class::ChunkLoc { page: 0, chunk: 0 },
            },
            klen: 0,
            vlen: 0,
            flags: 0,
            exptime: 0,
            time: 0,
            cas: 0,
            total: 0,
            hnext: NIL,
            prev: NIL,
            next: NIL,
            pg_prev: NIL,
            pg_next: NIL,
            tier: Tier::Hot as u8,
            fetched: false,
            gen: 0,
            live: false,
        }
    }
}

/// Slab-style arena of [`ItemMeta`] with id recycling.
pub struct Arena {
    items: Vec<ItemMeta>,
    free: Vec<u32>,
    live: usize,
}

impl Arena {
    pub fn new() -> Self {
        Arena {
            items: Vec::new(),
            free: Vec::new(),
            live: 0,
        }
    }

    /// Number of live records.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Insert a record, returning its id.
    pub fn insert(&mut self, mut meta: ItemMeta) -> u32 {
        meta.live = true;
        match self.free.pop() {
            Some(id) => {
                self.items[id as usize] = meta;
                self.live += 1;
                id
            }
            None => {
                let id = self.items.len() as u32;
                assert!(id != NIL, "arena exhausted");
                self.items.push(meta);
                self.live += 1;
                id
            }
        }
    }

    /// Remove a record, recycling its id.
    pub fn remove(&mut self, id: u32) -> ItemMeta {
        let slot = &mut self.items[id as usize];
        assert!(slot.live, "remove of dead id {id}");
        let meta = std::mem::replace(slot, ItemMeta::vacant());
        self.free.push(id);
        self.live -= 1;
        meta
    }

    #[inline]
    pub fn get(&self, id: u32) -> &ItemMeta {
        let m = &self.items[id as usize];
        debug_assert!(m.live, "access of dead id {id}");
        m
    }

    #[inline]
    pub fn get_mut(&mut self, id: u32) -> &mut ItemMeta {
        let m = &mut self.items[id as usize];
        debug_assert!(m.live, "access of dead id {id}");
        m
    }

    /// Iterate live ids (arbitrary order).
    pub fn iter_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.items
            .iter()
            .enumerate()
            .filter(|(_, m)| m.live)
            .map(|(i, _)| i as u32)
    }
}

impl Default for Arena {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ItemMeta {
        let mut m = ItemMeta::vacant();
        m.klen = 3;
        m
    }

    #[test]
    fn insert_get_remove() {
        let mut a = Arena::new();
        let id = a.insert(meta());
        assert_eq!(a.len(), 1);
        assert_eq!(a.get(id).klen, 3);
        let m = a.remove(id);
        assert_eq!(m.klen, 3);
        assert_eq!(a.len(), 0);
    }

    #[test]
    fn ids_recycled() {
        let mut a = Arena::new();
        let id1 = a.insert(meta());
        a.remove(id1);
        let id2 = a.insert(meta());
        assert_eq!(id1, id2);
    }

    #[test]
    #[should_panic(expected = "dead id")]
    fn double_remove_panics() {
        let mut a = Arena::new();
        let id = a.insert(meta());
        a.remove(id);
        a.remove(id);
    }

    #[test]
    fn iter_ids_only_live() {
        let mut a = Arena::new();
        let i1 = a.insert(meta());
        let i2 = a.insert(meta());
        let i3 = a.insert(meta());
        a.remove(i2);
        let ids: Vec<u32> = a.iter_ids().collect();
        assert_eq!(ids, vec![i1, i3]);
    }
}
