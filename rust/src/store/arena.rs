//! Item-metadata arena: fixed-size records addressed by `u32` ids, with
//! intrusive links for both the hash chains and the LRU lists (the same
//! layout trick as memcached's `_stritem`, minus the pointers).
//!
//! The slot array is published (base pointer + initialized length)
//! through an [`ArenaPub`] for the optimistic read path: lock-free
//! readers volatile-copy `ItemMeta` records straight out of the array
//! and validate the copy against the shard's seqlock stripes. Two
//! consequences shape the implementation:
//!
//! * **Slots never move while readable.** Growth allocates a fresh
//!   array, copies, republishes, and parks the superseded allocation in
//!   a graveyard instead of freeing it — a reader holding a stale base
//!   pointer dereferences frozen memory and its seqlock validation
//!   (the insert that grew the arena bumped its stripe) rejects any
//!   stale conclusion. Growth is geometric, so graveyard bytes total
//!   less than the current array.
//! * **Records are `Copy`** so readers can `ptr::read_volatile` a whole
//!   record; every field is a plain integer/bool, so a torn copy can
//!   produce stale or inconsistent *combinations* but never an invalid
//!   bit pattern — and inconsistent combinations are exactly what the
//!   seqlock validation rejects.

use super::optimistic::ArenaPub;
use crate::slab::ChunkHandle;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Initial slot capacity (pre-sized so small stores never retire an
/// array at all).
const INITIAL_CAP: usize = 1024;

/// Sentinel id for "no item".
pub const NIL: u32 = u32::MAX;

/// LRU tier (memcached 1.5 segmented LRU).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Hot = 0,
    Warm = 1,
    Cold = 2,
}

impl Tier {
    pub fn from_u8(v: u8) -> Tier {
        match v {
            0 => Tier::Hot,
            1 => Tier::Warm,
            _ => Tier::Cold,
        }
    }
}

/// Per-item metadata record (the chunk holds `[key][value]` bytes).
#[derive(Clone, Copy, Debug)]
pub struct ItemMeta {
    pub hash: u64,
    pub handle: ChunkHandle,
    /// Base address of the item's chunk (`[key][value]` bytes). Kept in
    /// sync with `handle` at every assignment site so the optimistic
    /// read path can reach the bytes without traversing the allocator.
    /// Chunk buffers are never unmapped while a reader could hold this
    /// address (freed page buffers age through the allocator's limbo
    /// list for at least one maintainer pass).
    pub chunk_addr: usize,
    pub klen: u16,
    pub vlen: u32,
    pub flags: u32,
    /// Absolute unix expiry, 0 = never.
    pub exptime: u32,
    /// Set/update time (drives `flush_all` and age stats).
    pub time: u32,
    pub cas: u64,
    /// Accounted total size (header + key + value + tail).
    pub total: u32,
    /// Hash-chain next.
    pub hnext: u32,
    /// LRU links.
    pub prev: u32,
    pub next: u32,
    /// Per-page item chain (all items whose chunk lives on the same
    /// page of the same generation): the page→items index that lets a
    /// page drain enumerate its residents in O(chunks/page).
    pub pg_prev: u32,
    pub pg_next: u32,
    pub tier: u8,
    /// The item has been served by a write-path fetch since it was
    /// stored (memcached's ITEM_FETCHED; the meta `h` echo). Read-lock
    /// fast-path hits inside TOUCH_INTERVAL cannot set it.
    pub fetched: bool,
    /// Marked stale by an invalidation (`md I` / losing `ms I C`):
    /// still served, but meta gets echo `X` and hand exactly one
    /// client the recache win (memcached's ITEM_STALE).
    pub stale: bool,
    /// A recache/stale `W` win has already been handed out for the
    /// current staleness window (memcached's ITEM_TOKEN_SENT); later
    /// readers see `Z` until a rewrite clears it.
    pub win_sent: bool,
    /// Slab-geometry generation the chunk belongs to. During an
    /// incremental migration, items whose tag differs from the store's
    /// current generation still live in the old (draining) allocator
    /// generation.
    pub gen: u8,
    /// True while the record is live (guards against stale ids).
    pub live: bool,
    /// Owning tenant (attribution stamp; 0 = default tenant). Travels
    /// with the item through migration moves, so per-tenant byte
    /// accounting survives geometry changes.
    pub tenant: u8,
}

impl ItemMeta {
    fn vacant() -> Self {
        ItemMeta {
            hash: 0,
            handle: ChunkHandle {
                class: 0,
                loc: crate::slab::class::ChunkLoc { page: 0, chunk: 0 },
            },
            chunk_addr: 0,
            klen: 0,
            vlen: 0,
            flags: 0,
            exptime: 0,
            time: 0,
            cas: 0,
            total: 0,
            hnext: NIL,
            prev: NIL,
            next: NIL,
            pg_prev: NIL,
            pg_next: NIL,
            tier: Tier::Hot as u8,
            fetched: false,
            stale: false,
            win_sent: false,
            gen: 0,
            live: false,
            tenant: 0,
        }
    }
}

/// Slab-style arena of [`ItemMeta`] with id recycling.
pub struct Arena {
    items: Vec<ItemMeta>,
    free: Vec<u32>,
    live: usize,
    /// Base/len published to lock-free readers.
    publish: Arc<ArenaPub>,
    /// Superseded slot arrays, kept mapped for stale-pointer readers.
    retired: Vec<Vec<ItemMeta>>,
}

impl Arena {
    pub fn new() -> Self {
        let a = Arena {
            items: Vec::with_capacity(INITIAL_CAP),
            free: Vec::new(),
            live: 0,
            publish: Arc::new(ArenaPub::default()),
            retired: Vec::new(),
        };
        a.republish();
        a
    }

    /// Handle for the optimistic read path.
    pub fn publish_handle(&self) -> Arc<ArenaPub> {
        self.publish.clone()
    }

    /// Publish the current base pointer and initialized length. Release
    /// ordering pairs with the readers' Acquire loads, so a reader that
    /// observes the new length also observes the pushed record.
    fn republish(&self) {
        self.publish
            .base
            .store(self.items.as_ptr() as usize, Ordering::Release);
        self.publish.len.store(self.items.len(), Ordering::Release);
    }

    /// Grow without ever invalidating a published pointer: allocate the
    /// doubled array, copy, swap, and park the old allocation.
    fn grow_for_push(&mut self) {
        if self.items.len() < self.items.capacity() {
            return;
        }
        let mut bigger = Vec::with_capacity((self.items.capacity() * 2).max(INITIAL_CAP));
        bigger.extend_from_slice(&self.items);
        let old = std::mem::replace(&mut self.items, bigger);
        if !old.is_empty() {
            self.retired.push(old);
        }
        self.republish();
    }

    /// Number of live records.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Insert a record, returning its id.
    pub fn insert(&mut self, mut meta: ItemMeta) -> u32 {
        meta.live = true;
        match self.free.pop() {
            Some(id) => {
                self.items[id as usize] = meta;
                self.live += 1;
                id
            }
            None => {
                let id = self.items.len() as u32;
                assert!(id != NIL, "arena exhausted");
                self.grow_for_push();
                self.items.push(meta);
                self.republish();
                self.live += 1;
                id
            }
        }
    }

    /// Remove a record, recycling its id.
    pub fn remove(&mut self, id: u32) -> ItemMeta {
        let slot = &mut self.items[id as usize];
        assert!(slot.live, "remove of dead id {id}");
        let meta = std::mem::replace(slot, ItemMeta::vacant());
        self.free.push(id);
        self.live -= 1;
        meta
    }

    #[inline]
    pub fn get(&self, id: u32) -> &ItemMeta {
        let m = &self.items[id as usize];
        debug_assert!(m.live, "access of dead id {id}");
        m
    }

    /// Bounds- and liveness-checked access: `None` for out-of-range or
    /// vacant ids. Used to validate deferred bump events, whose ids may
    /// be arbitrarily stale by the time the maintainer applies them.
    #[inline]
    pub fn get_checked(&self, id: u32) -> Option<&ItemMeta> {
        self.items.get(id as usize).filter(|m| m.live)
    }

    #[inline]
    pub fn get_mut(&mut self, id: u32) -> &mut ItemMeta {
        let m = &mut self.items[id as usize];
        debug_assert!(m.live, "access of dead id {id}");
        m
    }

    /// Iterate live ids (arbitrary order).
    pub fn iter_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.items
            .iter()
            .enumerate()
            .filter(|(_, m)| m.live)
            .map(|(i, _)| i as u32)
    }
}

impl Default for Arena {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ItemMeta {
        let mut m = ItemMeta::vacant();
        m.klen = 3;
        m
    }

    #[test]
    fn insert_get_remove() {
        let mut a = Arena::new();
        let id = a.insert(meta());
        assert_eq!(a.len(), 1);
        assert_eq!(a.get(id).klen, 3);
        let m = a.remove(id);
        assert_eq!(m.klen, 3);
        assert_eq!(a.len(), 0);
    }

    #[test]
    fn ids_recycled() {
        let mut a = Arena::new();
        let id1 = a.insert(meta());
        a.remove(id1);
        let id2 = a.insert(meta());
        assert_eq!(id1, id2);
    }

    #[test]
    #[should_panic(expected = "dead id")]
    fn double_remove_panics() {
        let mut a = Arena::new();
        let id = a.insert(meta());
        a.remove(id);
        a.remove(id);
    }

    #[test]
    fn growth_republishes_and_retires_old_array() {
        let mut a = Arena::new();
        let p = a.publish_handle();
        let base0 = p.base.load(Ordering::Relaxed);
        assert_ne!(base0, 0);
        assert_eq!(p.len.load(Ordering::Relaxed), 0);
        for _ in 0..(INITIAL_CAP + 1) {
            a.insert(meta());
        }
        assert_eq!(p.len.load(Ordering::Relaxed), INITIAL_CAP + 1);
        assert_eq!(
            p.base.load(Ordering::Relaxed),
            a.items.as_ptr() as usize,
            "published base tracks the live array"
        );
        assert_eq!(a.retired.len(), 1, "superseded array parked, not freed");
        assert_eq!(
            a.retired[0].as_ptr() as usize, base0,
            "the parked array is the one readers may still hold"
        );
    }

    #[test]
    fn iter_ids_only_live() {
        let mut a = Arena::new();
        let i1 = a.insert(meta());
        let i2 = a.insert(meta());
        let i3 = a.insert(meta());
        a.remove(i2);
        let ids: Vec<u32> = a.iter_ids().collect();
        assert_eq!(ids, vec![i1, i3]);
    }
}
