//! The single-shard KV engine: memcached command semantics over the
//! slab allocator, plus the paper's hooks (size observation on every
//! set, live slab reconfiguration — incremental, see `store::migrate`).

use super::arena::{Arena, ItemMeta, Tier, NIL};
use super::hashtable::HashTable;
use super::item::{hash_key, key_ok, total_item_size};
use super::lru::ClassLru;
use super::migrate::{MigrationGauges, MigrationState};
use super::optimistic::{ArenaPub, BumpEvent, SeqStripes, TablePub};
use crate::slab::class::ChunkLoc;
use crate::slab::policy::ChunkSizePolicy;
use crate::slab::{ChunkHandle, PageBuf, SlabAllocator, SlabError, SlabRegion, SlabStats};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

/// Relative-vs-absolute expiry cutoff (memcached: 30 days).
const REALTIME_MAXDELTA: u32 = 60 * 60 * 24 * 30;

/// Eviction attempts per allocation before giving up (memcached tries a
/// handful of tail items; we allow a generous walk).
const MAX_EVICT_ATTEMPTS: usize = 64;

/// Observes accounted item sizes on every successful store — the
/// optimizer's histogram collector implements this.
pub trait SizeObserver: Send + Sync {
    fn observe(&self, total_size: usize);
}

/// Per-tenant accounting hooks — implemented by
/// [`TenantRegistry`](crate::tenant::TenantRegistry). Every item
/// carries its owner's stamp (`ItemMeta::tenant`); the store reports
/// each resident-byte transition so the registry's live gauges stay
/// exact across overwrites, evictions, expiry, flushes, and migration
/// drops (migration *moves* keep the stamp and change no totals).
pub trait TenantSink: Send + Sync {
    /// `total` item bytes became resident, owned by `tenant`.
    fn on_store(&self, tenant: u8, total: usize);
    /// `total` item bytes left residency.
    fn on_free(&self, tenant: u8, total: usize);
    /// An item of `tenant` was evicted (`quota` = arbitration reclaim
    /// rather than allocation pressure).
    fn on_evict(&self, tenant: u8, quota: bool);
}

/// Wall clock with a manual override for deterministic expiry tests.
#[derive(Clone)]
pub enum Clock {
    System,
    /// Fixed "now" in unix seconds, adjustable from tests.
    Manual(Arc<AtomicU64>),
}

impl Clock {
    pub fn manual(start: u64) -> (Clock, Arc<AtomicU64>) {
        let cell = Arc::new(AtomicU64::new(start));
        (Clock::Manual(cell.clone()), cell)
    }

    #[inline]
    pub fn now(&self) -> u32 {
        match self {
            Clock::System => SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs() as u32)
                .unwrap_or(0),
            Clock::Manual(cell) => cell.load(Ordering::Relaxed) as u32,
        }
    }
}

/// Store-level failures (protocol maps these onto error lines).
#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    BadKey,
    /// Larger than the biggest chunk.
    TooLarge { size: usize, max: usize },
    /// Could not free space in the target class.
    OutOfMemory,
    /// incr/decr on a non-numeric value.
    NonNumeric,
    /// A slab migration is already draining; one at a time.
    Busy,
    /// Rejected chunk-size configuration (validated before any shard
    /// is touched).
    BadPolicy(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::BadKey => write!(f, "bad key"),
            StoreError::TooLarge { size, max } => {
                write!(f, "object too large for cache ({size} > {max})")
            }
            StoreError::OutOfMemory => write!(f, "out of memory storing object"),
            StoreError::NonNumeric => {
                write!(f, "cannot increment or decrement non-numeric value")
            }
            StoreError::Busy => write!(f, "slab migration already in progress"),
            StoreError::BadPolicy(m) => write!(f, "bad slab policy: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

/// Result of a `cas` store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CasResult {
    Stored,
    Exists,
    NotFound,
}

/// Storage behaviour of a [`KvStore::meta_set`] — the classic verbs
/// and the meta `M` mode switch name the same five semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreMode {
    /// Unconditional store (`set` / `ms`).
    Set,
    /// Store only if absent (`add` / `ms ... ME`).
    Add,
    /// Store only if present (`replace` / `ms ... MR`).
    Replace,
    /// Append to an existing value (`append` / `ms ... MA`).
    Append,
    /// Prepend to an existing value (`prepend` / `ms ... MP`).
    Prepend,
}

/// Options for [`KvStore::meta_set`] — the store-side surface the meta
/// `ms` flag grammar (and the classic storage verbs) compile onto.
#[derive(Debug, Clone, Copy)]
pub struct MetaSetOpts {
    pub mode: StoreMode,
    /// Client flags to store (classic `<flags>` / meta `F`).
    pub flags: u32,
    /// Item TTL (classic `<exptime>` / meta `T`).
    pub exptime: u32,
    /// Store only if the existing item's CAS matches (classic `cas` /
    /// meta `C`).
    pub cas_compare: Option<u64>,
    /// Store with this explicit CAS value instead of the next counter
    /// value (meta `E`).
    pub cas_set: Option<u64>,
    /// The key arrived base64-encoded (meta `b`): exempt from the
    /// text-protocol character rules, length bound still applies.
    pub binary_key: bool,
    /// Meta `I` on `ms` with `C`: a CAS-mismatched store marks the
    /// surviving item **stale** (and re-arms its recache win) instead of
    /// leaving it untouched — the writer knows the data it lost to is
    /// newer than what the cache holds.
    pub invalidate: bool,
    /// Owning tenant stamped onto the stored item (attribution happens
    /// at the connection layer; 0 = default tenant).
    pub tenant: u8,
}

impl MetaSetOpts {
    /// Plain unconditional `set` with the given flags/TTL.
    pub fn set(flags: u32, exptime: u32) -> MetaSetOpts {
        MetaSetOpts {
            mode: StoreMode::Set,
            flags,
            exptime,
            cas_compare: None,
            cas_set: None,
            binary_key: false,
            invalidate: false,
            tenant: 0,
        }
    }
}

/// Outcome of a [`KvStore::meta_set`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetOutcome {
    /// Stored; `cas` is the item's new CAS value (meta `c` echo).
    Stored { cas: u64 },
    /// Mode precondition failed (add-on-present, replace/concat-on-absent).
    NotStored,
    /// `cas_compare` mismatch.
    Exists,
    /// `cas_compare` given but the key is absent.
    NotFound,
}

/// Outcome of a CAS-guarded delete ([`KvStore::delete_cas`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeleteOutcome {
    Deleted,
    NotFound,
    /// CAS guard mismatch; the item survives.
    Exists,
}

/// Options for [`KvStore::arith`] — classic `incr`/`decr` and the meta
/// `ma` flag grammar.
#[derive(Debug, Clone, Copy)]
pub struct ArithOpts {
    pub delta: u64,
    /// `true` = increment (wrapping), `false` = decrement (floors at 0).
    pub incr: bool,
    /// Mutate only if the item's CAS matches (meta `C`).
    pub cas_compare: Option<u64>,
    /// On miss, auto-create with `(ttl, initial_value)` (meta `N`/`J`).
    pub vivify: Option<(u32, u64)>,
    /// Refresh the item TTL on success (meta `T`).
    pub new_ttl: Option<u32>,
    /// Store this explicit CAS value on success (meta `E`).
    pub cas_set: Option<u64>,
    /// The key arrived base64-encoded (meta `b`): a vivify may insert
    /// it even when it violates the text-protocol character rules.
    pub binary_key: bool,
    /// Owning tenant for the rewritten/vivified item (0 = default).
    pub tenant: u8,
}

impl ArithOpts {
    /// Classic `incr`/`decr`.
    pub fn classic(delta: u64, incr: bool) -> ArithOpts {
        ArithOpts {
            delta,
            incr,
            cas_compare: None,
            vivify: None,
            new_ttl: None,
            cas_set: None,
            binary_key: false,
            tenant: 0,
        }
    }
}

/// Outcome of a [`KvStore::arith`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArithOutcome {
    /// New value after the operation (or the vivified initial value),
    /// with the metadata the meta dialect echoes.
    Value { value: u64, ttl: i64, cas: u64 },
    NotFound,
    /// CAS guard mismatch; the item is untouched.
    Exists,
}

/// Options for [`KvStore::meta_get`] — the flag-driven retrieval
/// extras of the meta `mg` command (and classic `gat` via `touch`).
#[derive(Debug, Clone, Copy, Default)]
pub struct MetaGetOpts {
    /// Refresh the TTL on hit (touch-on-read: meta `T`, classic `gat`).
    pub touch: Option<u32>,
    /// On miss, create an empty item with this TTL and serve it as a
    /// "won" hit (meta `N`).
    pub vivify: Option<u32>,
    /// Explicit CAS for a vivified insert (meta `E`).
    pub vivify_cas: Option<u64>,
    /// The key arrived base64-encoded (meta `b`): a vivify may insert
    /// it even when it violates the text-protocol character rules.
    pub binary_key: bool,
    /// Meta `u`: serve the hit without an LRU bump or access-time
    /// refresh (and without flipping the fetched bit) — a read that
    /// leaves recency state untouched.
    pub no_bump: bool,
    /// The request asked for the `h` (hit-before) echo: the lookup must
    /// take the write path so the fetched bit is both read and set
    /// accurately.
    pub wants_hit_before: bool,
    /// Meta `R<ttl>`: when the hit's remaining TTL has fallen below
    /// this threshold, hand exactly one client the recache win (`W`
    /// echo) so it refreshes the item before it expires; losers see
    /// `Z`. Stale items (see [`MetaSetOpts::invalidate`]) always run
    /// the same win race regardless of TTL.
    pub recache: Option<u32>,
    /// Owning tenant for a vivified insert (0 = default).
    pub tenant: u8,
}

/// Per-hit metadata the meta read path hands its visitor alongside the
/// borrowed value bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetaHit {
    /// Remaining TTL in seconds; `-1` = never expires.
    pub ttl: i64,
    /// The miss was vivified (`mg ... N`): this caller "won" the right
    /// to recache and the value is the fresh empty item.
    pub won: bool,
    /// Seconds since the item's last (write-path) access — the meta `l`
    /// echo. Read-lock fast-path hits do not refresh it, so it is
    /// accurate to within [`TOUCH_INTERVAL`].
    pub la: u32,
    /// The item had been fetched before this request (meta `h` echo;
    /// memcached's ITEM_FETCHED).
    pub fetched: bool,
    /// The item is stale (invalidated but still resident): the value is
    /// served with the `X` echo so the client knows to treat it as a
    /// hint, not truth.
    pub stale: bool,
    /// The recache/stale win was already claimed by an earlier request
    /// (`Z` echo): serve the value but do not recache.
    pub lost: bool,
}

/// Snapshot of one item's bookkeeping — the meta `me` debug command
/// ([`KvStore::debug_item`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ItemDebug {
    /// Remaining TTL in seconds; `-1` = never expires.
    pub ttl: i64,
    /// Seconds since the last (write-path) access.
    pub la: u32,
    pub cas: u64,
    /// Served by a write-path fetch since stored (ITEM_FETCHED).
    pub fetched: bool,
    /// Slab class holding the item's chunk.
    pub class: u16,
    /// Segmented-LRU tier.
    pub tier: Tier,
    /// Value length in bytes.
    pub vlen: u32,
}

/// A fetched value.
#[derive(Debug, Clone, PartialEq)]
pub struct Value {
    pub value: Vec<u8>,
    pub flags: u32,
    pub cas: u64,
}

/// Borrowed view of a stored value: the zero-copy read path hands this
/// to a visitor while the item's bytes still live in the slab chunk, so
/// the visitor can copy them straight into a response buffer (one copy,
/// chunk → wire) instead of materialising an intermediate [`Value`].
#[derive(Debug, Clone, Copy)]
pub struct ValueRef<'a> {
    pub data: &'a [u8],
    pub flags: u32,
    pub cas: u64,
}

/// How long (seconds) an access keeps an item "recently used" before
/// the next hit pays a write-locked LRU bump — memcached's
/// `ITEM_UPDATE_INTERVAL`. Reads inside the window are served under a
/// shard *read* lock with no LRU mutation at all.
pub const TOUCH_INTERVAL: u32 = 60;

/// Outcome of a read-only probe ([`KvStore::peek`]).
pub enum PeekOutcome<R> {
    /// Live, recently-bumped item; the visitor ran.
    Hit(R),
    /// Definitively absent.
    Miss,
    /// Present but the store must mutate to serve it correctly —
    /// expired (lazy reclaim) or outside [`TOUCH_INTERVAL`] (LRU bump).
    /// The caller retries on the write path.
    NeedsWrite,
}

/// Store operation counters (`stats`).
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    pub cmd_get: u64,
    pub cmd_set: u64,
    pub get_hits: u64,
    pub get_misses: u64,
    pub delete_hits: u64,
    pub delete_misses: u64,
    pub incr_hits: u64,
    pub incr_misses: u64,
    pub decr_hits: u64,
    pub decr_misses: u64,
    pub cas_hits: u64,
    pub cas_misses: u64,
    pub cas_badval: u64,
    pub touch_hits: u64,
    pub touch_misses: u64,
    pub evictions: u64,
    pub expired_reclaims: u64,
    pub flush_cmds: u64,
    pub reconfigures: u64,
    /// Background maintenance passes over this store
    /// ([`KvStore::maintain`]). NOTE: counted per shard — the
    /// aggregated `stats` value is maintainer passes × shard count.
    pub maintainer_runs: u64,
    /// HOT/WARM→COLD demotions performed by the maintainer (the
    /// rebalance work the set path no longer does inline).
    pub maintainer_demoted: u64,
    /// Post-migration slack pages returned to the OS by the maintainer.
    pub maintainer_pages_shed: u64,
    /// Optimistic-read attempts that failed seqlock validation and
    /// retried (aggregated from the shard's read lanes).
    pub seqlock_retries: u64,
    /// Optimistic reads that exhausted their retries (or hit a
    /// condition the lock-free path cannot serve) and fell back to the
    /// locked path (aggregated from the shard's read lanes).
    pub seqlock_fallbacks: u64,
    /// Deferred LRU bumps enqueued by optimistic read hits
    /// (aggregated from the shard's read lanes).
    pub lru_bump_queued: u64,
    /// Deferred LRU bumps the maintainer validated and applied.
    pub lru_bump_drained: u64,
    /// Deferred LRU bumps dropped because the shard's ring was full
    /// (recency goes slightly stale; correctness unaffected).
    pub lru_bump_dropped: u64,
}

/// Outcome of a completed slab reconfiguration
/// ([`KvStore::reconfigure`], or [`KvStore::last_migration`] after an
/// incremental drain finishes).
#[derive(Debug, Clone)]
pub struct MigrationReport {
    pub items_moved: usize,
    /// Items that no longer fit under the page budget (+ slack).
    pub items_dropped: usize,
    pub hole_bytes_before: u64,
    pub hole_bytes_after: u64,
    pub pages_before: usize,
    pub pages_after: usize,
}

impl MigrationReport {
    /// The paper's headline metric: fraction of wasted memory recovered.
    pub fn waste_recovered_fraction(&self) -> f64 {
        if self.hole_bytes_before == 0 {
            0.0
        } else {
            1.0 - self.hole_bytes_after as f64 / self.hole_bytes_before as f64
        }
    }
}

/// One shard of the cache.
pub struct KvStore {
    pub(crate) alloc: SlabAllocator,
    pub(crate) arena: Arena,
    pub(crate) table: HashTable,
    /// Seqlock stripes shared with the shard's lock-free read path:
    /// every mutation of reader-visible state (arena records reachable
    /// through hash chains, chain links, chunk bytes) runs inside a
    /// [`StripeGuard`] window on the stripe of the item's hash.
    ///
    /// [`StripeGuard`]: super::optimistic::StripeGuard
    pub(crate) seq: Arc<SeqStripes>,
    pub(crate) lrus: Vec<ClassLru>,
    clock: Clock,
    use_cas: bool,
    cas_counter: u64,
    pub(crate) stats: StoreStats,
    observer: Option<Arc<dyn SizeObserver>>,
    /// Per-tenant accounting sink (the tenant registry).
    tenants: Option<Arc<dyn TenantSink>>,
    pub(crate) policy: ChunkSizePolicy,
    /// Current slab-geometry generation; items tagged with an older
    /// generation still live in the allocator's draining class table.
    pub(crate) gen: u8,
    /// In-flight incremental migration, if any (see `store::migrate`).
    pub(crate) migration: Option<MigrationState>,
    /// Report of the most recently completed migration.
    pub(crate) last_migration: Option<MigrationReport>,
    /// Lifetime migration gauges (completed drains), merged with the
    /// in-flight state by [`KvStore::migration_gauges`].
    pub(crate) mig_totals: MigrationGauges,
    /// Items visited while resolving page→items through the per-page
    /// index (force-drain + slack shedding) — the O(chunks/page) proof
    /// counter the step-count tests read.
    pub(crate) page_scan_steps: u64,
}

impl KvStore {
    pub fn new(
        policy: ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
        use_cas: bool,
        clock: Clock,
    ) -> Result<Self, SlabError> {
        KvStore::with_region(policy, page_size, mem_limit, use_cas, clock, None)
    }

    /// Like [`KvStore::new`], but carving slab pages from an
    /// mmap-backed region when one is attached (warm restart).
    pub(crate) fn with_region(
        policy: ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
        use_cas: bool,
        clock: Clock,
        region: Option<SlabRegion>,
    ) -> Result<Self, SlabError> {
        let alloc = SlabAllocator::with_region(&policy, page_size, mem_limit, region)?;
        let lrus = (0..alloc.chunk_sizes().len())
            .map(|_| ClassLru::new())
            .collect();
        let seq = Arc::new(SeqStripes::new());
        Ok(KvStore {
            alloc,
            arena: Arena::new(),
            table: HashTable::with_buckets_and_seq(1024, seq.clone()),
            seq,
            lrus,
            clock,
            use_cas,
            cas_counter: 0,
            stats: StoreStats::default(),
            observer: None,
            tenants: None,
            policy,
            gen: 0,
            migration: None,
            last_migration: None,
            mig_totals: MigrationGauges::default(),
            page_scan_steps: 0,
        })
    }

    /// Attach a per-set size observer (the optimizer's collector).
    pub fn set_observer(&mut self, obs: Arc<dyn SizeObserver>) {
        self.observer = Some(obs);
    }

    /// Attach the per-tenant accounting sink (the tenant registry).
    pub fn set_tenant_sink(&mut self, sink: Arc<dyn TenantSink>) {
        self.tenants = Some(sink);
    }

    #[inline]
    fn tenant_on_store(&self, tenant: u8, total: usize) {
        if let Some(s) = &self.tenants {
            s.on_store(tenant, total);
        }
    }

    #[inline]
    pub(crate) fn tenant_on_free(&self, tenant: u8, total: usize) {
        if let Some(s) = &self.tenants {
            s.on_free(tenant, total);
        }
    }

    #[inline]
    fn tenant_on_evict(&self, tenant: u8, quota: bool) {
        if let Some(s) = &self.tenants {
            s.on_evict(tenant, quota);
        }
    }

    pub fn len(&self) -> usize {
        self.arena.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arena.is_empty()
    }

    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    pub fn slab_stats(&self) -> SlabStats {
        self.alloc.stats()
    }

    pub fn chunk_sizes(&self) -> &[usize] {
        self.alloc.chunk_sizes()
    }

    pub fn policy(&self) -> &ChunkSizePolicy {
        &self.policy
    }

    /// Current absolute time.
    pub fn now(&self) -> u32 {
        self.clock.now()
    }

    /// Everything the shard's lock-free read path is allowed to touch:
    /// the stripe counters, the published arena slot array, the
    /// published table geometry and the clock. All other store state
    /// stays behind the shard `RwLock`.
    pub(crate) fn read_handles(&self) -> (Arc<SeqStripes>, Arc<ArenaPub>, Arc<TablePub>, Clock) {
        (
            self.seq.clone(),
            self.arena.publish_handle(),
            self.table.publish_handle(),
            self.clock.clone(),
        )
    }

    /// Memcached exptime normalization: 0 = never, ≤ 30 days = relative,
    /// larger = absolute unix time.
    fn normalize_exptime(&self, exptime: u32) -> u32 {
        if exptime == 0 {
            0
        } else if exptime <= REALTIME_MAXDELTA {
            self.clock.now() + exptime
        } else {
            exptime
        }
    }

    pub(crate) fn is_expired(&self, meta: &ItemMeta) -> bool {
        meta.exptime != 0 && meta.exptime <= self.clock.now()
    }

    /// Remaining TTL in seconds (`-1` = never expires) — the meta `t`
    /// response flag.
    fn ttl_of(&self, meta: &ItemMeta) -> i64 {
        self.ttl_from_exp(meta.exptime)
    }

    /// [`ttl_of`](KvStore::ttl_of) from a raw absolute exptime.
    fn ttl_from_exp(&self, exp: u32) -> i64 {
        if exp == 0 {
            -1
        } else {
            exp as i64 - self.clock.now() as i64
        }
    }

    // ------------------------------------------------------------ internals

    /// Is this item's chunk in the old (draining) generation?
    #[inline]
    pub(crate) fn is_old_gen(&self, item_gen: u8) -> bool {
        self.migration.is_some() && item_gen != self.gen
    }

    /// Thread `id` onto the head of its page's item chain (the per-page
    /// index). Must run *after* `handle`/`gen` are current: the chain
    /// lives in whichever generation's class table owns the chunk.
    pub(crate) fn page_link(&mut self, id: u32) {
        let (class, page, old) = {
            let m = self.arena.get(id);
            (m.handle.class, m.handle.loc.page, self.is_old_gen(m.gen))
        };
        let head = self.alloc.page_item_head(old, class, page);
        {
            let m = self.arena.get_mut(id);
            m.pg_prev = NIL;
            m.pg_next = head;
        }
        if head != NIL {
            self.arena.get_mut(head).pg_prev = id;
        }
        self.alloc.set_page_item_head(old, class, page, id);
    }

    /// Enumerate a page's residents through its item chain —
    /// O(items on this page). Returns `(id, hash)` pairs and bumps the
    /// step counter the O(chunks/page) tests read. Shared by the
    /// migration force-drain and the maintainer's slack shedding so
    /// the walk (and its accounting) cannot diverge.
    pub(crate) fn page_residents(&mut self, old: bool, class: u16, page: u32) -> Vec<(u32, u64)> {
        let mut out = Vec::new();
        let mut cur = self.alloc.page_item_head(old, class, page);
        while cur != NIL {
            self.page_scan_steps += 1;
            let m = self.arena.get(cur);
            out.push((cur, m.hash));
            cur = m.pg_next;
        }
        out
    }

    /// Remove `id` from its page's item chain. Must run while
    /// `handle`/`gen` still describe the chunk being vacated.
    pub(crate) fn page_unlink(&mut self, id: u32) {
        let (class, page, old, prev, next) = {
            let m = self.arena.get(id);
            (
                m.handle.class,
                m.handle.loc.page,
                self.is_old_gen(m.gen),
                m.pg_prev,
                m.pg_next,
            )
        };
        if prev != NIL {
            self.arena.get_mut(prev).pg_next = next;
        } else {
            debug_assert_eq!(self.alloc.page_item_head(old, class, page), id);
            self.alloc.set_page_item_head(old, class, page, next);
        }
        if next != NIL {
            self.arena.get_mut(next).pg_prev = prev;
        }
        let m = self.arena.get_mut(id);
        m.pg_prev = NIL;
        m.pg_next = NIL;
    }

    /// Read an item's chunk from whichever generation holds it.
    #[inline]
    pub(crate) fn item_chunk(&self, m: &ItemMeta) -> &[u8] {
        self.alloc.chunk_gen(self.is_old_gen(m.gen), m.handle)
    }

    /// Bump an item's recency in whichever generation's LRU holds it.
    /// Old and new class tables differ mid-drain, so indexing the wrong
    /// one would corrupt LRU links — every recency bump must go through
    /// here. Returns whether the item is in the old generation.
    fn touch_lru(&mut self, id: u32) -> bool {
        let (class, old) = {
            let m = self.arena.get(id);
            (m.handle.class as usize, self.is_old_gen(m.gen))
        };
        if old {
            let mig = self.migration.as_mut().expect("old item implies migration");
            mig.old_lrus[class].touch(id, &mut self.arena);
        } else {
            self.lrus[class].touch(id, &mut self.arena);
        }
        old
    }

    fn find_live(&mut self, key: &[u8], hash: u64) -> Option<u32> {
        let id = {
            let arena = &self.arena;
            let alloc = &self.alloc;
            let gen = self.gen;
            let migrating = self.migration.is_some();
            self.table.find(hash, arena, |id| {
                let m = arena.get(id);
                let chunk = alloc.chunk_gen(migrating && m.gen != gen, m.handle);
                &chunk[..m.klen as usize] == key
            })?
        };
        if self.is_expired(self.arena.get(id)) {
            self.unlink_and_free(id, hash);
            self.stats.expired_reclaims += 1;
            return None;
        }
        Some(id)
    }

    pub(crate) fn unlink_and_free(&mut self, id: u32, hash: u64) {
        // the chain relink, the slot vacate and the chunk free are all
        // reader-visible: one stripe window covers them (nested no-op
        // when an outer store op already holds this stripe)
        let seq = self.seq.clone();
        let _g = seq.guard(hash);
        self.table.remove(id, hash, &mut self.arena);
        self.page_unlink(id);
        let (class, old) = {
            let m = self.arena.get(id);
            (m.handle.class as usize, self.is_old_gen(m.gen))
        };
        if old {
            let mig = self.migration.as_mut().expect("old item implies migration");
            mig.old_lrus[class].remove(id, &mut self.arena);
            mig.old_items -= 1;
            let meta = self.arena.remove(id);
            self.alloc.free_old(meta.handle, meta.total as usize);
            self.tenant_on_free(meta.tenant, meta.total as usize);
        } else {
            self.lrus[class].remove(id, &mut self.arena);
            let meta = self.arena.remove(id);
            self.alloc.free(meta.handle, meta.total as usize);
            self.tenant_on_free(meta.tenant, meta.total as usize);
        }
    }

    /// Allocate a chunk, evicting from the target class when the page
    /// budget is exhausted (memcached's default `-M off` behaviour).
    /// During a migration, a class with nothing of its own to evict
    /// force-drains the emptiest old-generation page instead, recycling
    /// it into the new geometry.
    pub(crate) fn alloc_with_eviction(&mut self, total: usize) -> Result<ChunkHandle, StoreError> {
        // failpoint: alloc-failure storms surface to clients as
        // `SERVER_ERROR out of memory storing object`, never a hang
        if crate::util::failpoint::fired("store.item_alloc") {
            return Err(StoreError::OutOfMemory);
        }
        for _ in 0..MAX_EVICT_ATTEMPTS {
            match self.alloc.alloc(total) {
                Ok(h) => return Ok(h),
                Err(SlabError::TooLarge { size, max }) => {
                    return Err(StoreError::TooLarge { size, max })
                }
                Err(SlabError::NeedEviction { class }) => {
                    let victim = self.lrus[class as usize].eviction_candidate();
                    match victim {
                        Some(id) => {
                            let (hash, victim_tenant) = {
                                let m = self.arena.get(id);
                                (m.hash, m.tenant)
                            };
                            self.unlink_and_free(id, hash);
                            self.stats.evictions += 1;
                            self.tenant_on_evict(victim_tenant, false);
                        }
                        None if self.migration.is_some() => {
                            if !self.force_drain_old_page() {
                                return Err(StoreError::OutOfMemory);
                            }
                        }
                        None => return Err(StoreError::OutOfMemory),
                    }
                }
                Err(SlabError::Policy(_)) => unreachable!("policy validated at build"),
            }
        }
        Err(StoreError::OutOfMemory)
    }

    fn next_cas(&mut self) -> u64 {
        self.cas_counter += 1;
        self.cas_counter
    }

    /// Resolve an item's new CAS: an explicit override (the meta `E`
    /// flag) advances the counter past itself so later items stay
    /// unique; otherwise take the next counter value.
    fn resolve_cas(&mut self, cas_override: Option<u64>) -> u64 {
        match cas_override {
            Some(c) => {
                self.cas_counter = self.cas_counter.max(c);
                c
            }
            None => self.next_cas(),
        }
    }

    /// Insert a brand-new item (caller ensured the key is absent) and
    /// return its CAS. `cas_override` stores an explicit CAS value (the
    /// meta `E` flag); the counter is advanced past it so later items
    /// stay unique.
    fn insert_new(
        &mut self,
        key: &[u8],
        hash: u64,
        value: &[u8],
        flags: u32,
        exptime_abs: u32,
        cas_override: Option<u64>,
        tenant: u8,
    ) -> Result<u64, StoreError> {
        let total = total_item_size(key.len(), value.len(), self.use_cas);
        // allocation (and any evictions it performs — those guard their
        // own stripes) plus the chunk fill happen before this item's
        // stripe window opens: the chunk is unreachable until the table
        // insert links it
        let handle = self.alloc_with_eviction(total)?;
        let chunk = self.alloc.chunk_mut(handle);
        chunk[..key.len()].copy_from_slice(key);
        chunk[key.len()..key.len() + value.len()].copy_from_slice(value);
        let chunk_addr = chunk.as_ptr() as usize;
        let cas = self.resolve_cas(cas_override);
        let now = self.clock.now();
        let seq = self.seq.clone();
        let _g = seq.guard(hash);
        let id = self.arena.insert(ItemMeta {
            hash,
            handle,
            chunk_addr,
            klen: key.len() as u16,
            vlen: value.len() as u32,
            flags,
            exptime: exptime_abs,
            time: now,
            cas,
            total: total as u32,
            hnext: NIL,
            prev: NIL,
            next: NIL,
            pg_prev: NIL,
            pg_next: NIL,
            tier: 0,
            fetched: false,
            stale: false,
            win_sent: false,
            gen: self.gen,
            live: true,
            tenant,
        });
        self.table.insert(id, hash, &mut self.arena);
        self.lrus[handle.class as usize].insert(id, &mut self.arena);
        self.page_link(id);
        if let Some(obs) = &self.observer {
            obs.observe(total);
        }
        self.tenant_on_store(tenant, total);
        Ok(cas)
    }

    /// Replace the value bytes of an existing item, reallocating across
    /// classes when the new total no longer fits the current chunk.
    /// Items still in the old (draining) generation are migrated to the
    /// current geometry by any rewrite, so every mutation makes drain
    /// progress. Returns the item's new CAS (`cas_override` = the meta
    /// `E` flag).
    fn replace_value_bytes(
        &mut self,
        id: u32,
        new_value: &[u8],
        cas_override: Option<u64>,
        tenant: u8,
    ) -> Result<u64, StoreError> {
        let (handle, klen, old_total, item_gen, hash, old_tenant) = {
            let m = self.arena.get(id);
            (
                m.handle,
                m.klen as usize,
                m.total as usize,
                m.gen,
                m.hash,
                m.tenant,
            )
        };
        let new_total = total_item_size(klen, new_value.len(), self.use_cas);
        // one stripe window over the whole rewrite: readers must never
        // see a half-updated (handle, chunk_addr, vlen, cas) record or
        // in-place chunk bytes mid-overwrite (evictions inside the
        // allocation guard their own stripes; same-stripe nesting is a
        // no-op covered by this window)
        let seq = self.seq.clone();
        let _g = seq.guard(hash);
        if self.is_old_gen(item_gen) {
            // migrate on rewrite: new chunk in the current geometry
            let key: Vec<u8> = self.item_chunk(self.arena.get(id))[..klen].to_vec();
            let old_class = handle.class as usize;
            // unlink first (LRU + page index) so neither the eviction
            // walk nor a force-drain can pick the item being moved
            {
                let mig = self.migration.as_mut().expect("old item implies migration");
                mig.old_lrus[old_class].remove(id, &mut self.arena);
            }
            self.page_unlink(id);
            let new_handle = match self.alloc_with_eviction(new_total) {
                Ok(h) => h,
                Err(e) => {
                    // restore: the item survives the failed rewrite
                    let mig = self.migration.as_mut().expect("still migrating");
                    mig.old_lrus[old_class].insert(id, &mut self.arena);
                    self.page_link(id);
                    return Err(e);
                }
            };
            let chunk = self.alloc.chunk_mut(new_handle);
            chunk[..klen].copy_from_slice(&key);
            chunk[klen..klen + new_value.len()].copy_from_slice(new_value);
            let new_addr = chunk.as_ptr() as usize;
            self.alloc.free_old(handle, old_total);
            {
                let mig = self.migration.as_mut().expect("still migrating");
                mig.old_items -= 1;
                mig.moved += 1;
            }
            self.lrus[new_handle.class as usize].insert(id, &mut self.arena);
            let gen = self.gen;
            let m = self.arena.get_mut(id);
            m.handle = new_handle;
            m.gen = gen;
            m.chunk_addr = new_addr;
            self.page_link(id);
        } else {
            let chunk_size = self.alloc.chunk_size_of(handle.class);
            if new_total <= chunk_size {
                // in-place rewrite
                let chunk = self.alloc.chunk_mut(handle);
                chunk[klen..klen + new_value.len()].copy_from_slice(new_value);
                self.alloc.reaccount(handle, old_total, new_total);
            } else {
                // move to a larger chunk; copy key + new value
                let key: Vec<u8> = self.alloc.chunk(handle)[..klen].to_vec();
                let new_handle = self.alloc_with_eviction(new_total)?;
                debug_assert!(self.arena.get(id).live, "victim eviction freed self");
                let chunk = self.alloc.chunk_mut(new_handle);
                chunk[..klen].copy_from_slice(&key);
                chunk[klen..klen + new_value.len()].copy_from_slice(new_value);
                let new_addr = chunk.as_ptr() as usize;
                self.page_unlink(id);
                self.alloc.free(handle, old_total);
                // move LRU membership to the new class
                let old_class = handle.class as usize;
                let new_class = new_handle.class as usize;
                if old_class != new_class {
                    self.lrus[old_class].remove(id, &mut self.arena);
                    self.lrus[new_class].insert(id, &mut self.arena);
                }
                {
                    let m = self.arena.get_mut(id);
                    m.handle = new_handle;
                    m.chunk_addr = new_addr;
                }
                self.page_link(id);
            }
        }
        let cas = self.resolve_cas(cas_override);
        let now = self.clock.now();
        let m = self.arena.get_mut(id);
        m.vlen = new_value.len() as u32;
        m.total = new_total as u32;
        m.cas = cas;
        m.time = now;
        // a rewrite stores a new value: the hit-before bit starts over
        // (memcached parity — a store clears ITEM_FETCHED)
        m.fetched = false;
        // ... and a rewrite recaches: staleness and the win token are
        // spent the moment fresh bytes land
        m.stale = false;
        m.win_sent = false;
        // a rewrite re-attributes the item to the writing tenant
        m.tenant = tenant;
        if let Some(obs) = &self.observer {
            obs.observe(new_total);
        }
        self.tenant_on_free(old_tenant, old_total);
        self.tenant_on_store(tenant, new_total);
        Ok(cas)
    }

    // ----------------------------------------------------------- operations

    /// The unified storage primitive both protocol dialects execute:
    /// mode-gated store with optional CAS guard and explicit CAS value.
    /// The classic verbs (`set`/`add`/`replace`/`cas`/`append`/
    /// `prepend`) are thin wrappers over this.
    pub fn meta_set(
        &mut self,
        key: &[u8],
        value: &[u8],
        opts: &MetaSetOpts,
    ) -> Result<SetOutcome, StoreError> {
        if !key_ok(key, opts.binary_key) {
            return Err(StoreError::BadKey);
        }
        self.stats.cmd_set += 1;
        let hash = hash_key(key);
        let existing = self.find_live(key, hash);
        match opts.mode {
            StoreMode::Add => {
                if existing.is_some() {
                    return Ok(SetOutcome::NotStored);
                }
            }
            StoreMode::Replace => {
                if existing.is_none() {
                    return Ok(SetOutcome::NotStored);
                }
            }
            StoreMode::Append | StoreMode::Prepend => {
                let Some(id) = existing else {
                    return Ok(SetOutcome::NotStored);
                };
                if let Some(c) = opts.cas_compare {
                    if self.arena.get(id).cas != c {
                        self.stats.cas_badval += 1;
                        return Ok(SetOutcome::Exists);
                    }
                    self.stats.cas_hits += 1;
                }
                let (klen, vlen) = {
                    let m = self.arena.get(id);
                    (m.klen as usize, m.vlen as usize)
                };
                let old = self.item_chunk(self.arena.get(id))[klen..klen + vlen].to_vec();
                let mut merged = Vec::with_capacity(old.len() + value.len());
                if opts.mode == StoreMode::Append {
                    merged.extend_from_slice(&old);
                    merged.extend_from_slice(value);
                } else {
                    merged.extend_from_slice(value);
                    merged.extend_from_slice(&old);
                }
                let cas = self.replace_value_bytes(id, &merged, opts.cas_set, opts.tenant)?;
                return Ok(SetOutcome::Stored { cas });
            }
            StoreMode::Set => {}
        }
        if let Some(c) = opts.cas_compare {
            match existing {
                None => {
                    self.stats.cas_misses += 1;
                    return Ok(SetOutcome::NotFound);
                }
                Some(id) if self.arena.get(id).cas != c => {
                    self.stats.cas_badval += 1;
                    if opts.invalidate {
                        // `ms ... C I`: the losing writer knows the
                        // resident data is newer than its own view, so
                        // mark it stale and re-arm the recache win.
                        // Stale is reader-visible (the optimistic path
                        // copies it), so bump the stripe around it.
                        let seq = self.seq.clone();
                        let _g = seq.guard(hash);
                        let m = self.arena.get_mut(id);
                        m.stale = true;
                        m.win_sent = false;
                    }
                    return Ok(SetOutcome::Exists);
                }
                Some(_) => self.stats.cas_hits += 1,
            }
        }
        let exptime = self.normalize_exptime(opts.exptime);
        if let Some(id) = existing {
            self.unlink_and_free(id, hash);
        }
        let cas = self.insert_new(key, hash, value, opts.flags, exptime, opts.cas_set, opts.tenant)?;
        Ok(SetOutcome::Stored { cas })
    }

    /// `set`: unconditional store.
    pub fn set(
        &mut self,
        key: &[u8],
        value: &[u8],
        flags: u32,
        exptime: u32,
    ) -> Result<(), StoreError> {
        self.meta_set(key, value, &MetaSetOpts::set(flags, exptime))
            .map(|_| ())
    }

    /// `add`: store only if absent. Returns false when the key exists.
    pub fn add(
        &mut self,
        key: &[u8],
        value: &[u8],
        flags: u32,
        exptime: u32,
    ) -> Result<bool, StoreError> {
        let opts = MetaSetOpts {
            mode: StoreMode::Add,
            ..MetaSetOpts::set(flags, exptime)
        };
        Ok(matches!(
            self.meta_set(key, value, &opts)?,
            SetOutcome::Stored { .. }
        ))
    }

    /// `replace`: store only if present. Returns false when absent.
    pub fn replace(
        &mut self,
        key: &[u8],
        value: &[u8],
        flags: u32,
        exptime: u32,
    ) -> Result<bool, StoreError> {
        let opts = MetaSetOpts {
            mode: StoreMode::Replace,
            ..MetaSetOpts::set(flags, exptime)
        };
        Ok(matches!(
            self.meta_set(key, value, &opts)?,
            SetOutcome::Stored { .. }
        ))
    }

    /// `cas`: store if the token matches.
    pub fn cas(
        &mut self,
        key: &[u8],
        value: &[u8],
        flags: u32,
        exptime: u32,
        cas: u64,
    ) -> Result<CasResult, StoreError> {
        let opts = MetaSetOpts {
            cas_compare: Some(cas),
            ..MetaSetOpts::set(flags, exptime)
        };
        Ok(match self.meta_set(key, value, &opts)? {
            SetOutcome::Stored { .. } => CasResult::Stored,
            SetOutcome::Exists => CasResult::Exists,
            SetOutcome::NotFound | SetOutcome::NotStored => CasResult::NotFound,
        })
    }

    /// `append`/`prepend`. Returns false when the key is absent.
    pub fn concat(
        &mut self,
        key: &[u8],
        data: &[u8],
        append: bool,
    ) -> Result<bool, StoreError> {
        let opts = MetaSetOpts {
            mode: if append {
                StoreMode::Append
            } else {
                StoreMode::Prepend
            },
            ..MetaSetOpts::set(0, 0)
        };
        Ok(matches!(
            self.meta_set(key, data, &opts)?,
            SetOutcome::Stored { .. }
        ))
    }

    /// `get`/`gets` (allocating convenience wrapper over [`get_with`]).
    ///
    /// [`get_with`]: KvStore::get_with
    pub fn get(&mut self, key: &[u8]) -> Option<Value> {
        self.get_with(key, |v| Value {
            value: v.data.to_vec(),
            flags: v.flags,
            cas: v.cas,
        })
    }

    /// Zero-copy `get`: run `f` over the value bytes in place (in the
    /// slab chunk) instead of copying them out. Full get semantics:
    /// stats, lazy expiry reclaim, LRU bump, access-time refresh.
    pub fn get_with<R, F: FnOnce(ValueRef<'_>) -> R>(&mut self, key: &[u8], f: F) -> Option<R> {
        self.stats.cmd_get += 1;
        let hash = hash_key(key);
        let Some(id) = self.find_live(key, hash) else {
            self.stats.get_misses += 1;
            return None;
        };
        self.stats.get_hits += 1;
        let old = self.touch_lru(id);
        // refresh the access time so the next TOUCH_INTERVAL seconds of
        // hits on this key can be served by `peek` under a read lock
        let now = self.clock.now();
        {
            let m = self.arena.get_mut(id);
            m.time = now;
            m.fetched = true;
        }
        let m = self.arena.get(id);
        let chunk = self.alloc.chunk_gen(old, m.handle);
        Some(f(ValueRef {
            data: &chunk[m.klen as usize..m.klen as usize + m.vlen as usize],
            flags: m.flags,
            cas: m.cas,
        }))
    }

    /// Shared lookup for the read-only probes: `Hit` only when the item
    /// is live, unexpired, and (unless `allow_stale`, the meta `u`
    /// no-bump read) recently bumped.
    fn peek_find(&self, key: &[u8], allow_stale: bool) -> PeekOutcome<u32> {
        let hash = hash_key(key);
        let found = self.table.find(hash, &self.arena, |id| {
            let m = self.arena.get(id);
            let chunk = self.item_chunk(m);
            &chunk[..m.klen as usize] == key
        });
        let Some(id) = found else {
            return PeekOutcome::Miss;
        };
        let m = self.arena.get(id);
        if self.is_expired(m) {
            return PeekOutcome::NeedsWrite; // write path reclaims it
        }
        if !allow_stale && self.clock.now().saturating_sub(m.time) >= TOUCH_INTERVAL {
            return PeekOutcome::NeedsWrite; // write path bumps the LRU
        }
        PeekOutcome::Hit(id)
    }

    /// Read-only probe for the concurrent fast path: looks the key up
    /// and, when the item is live and was accessed within
    /// [`TOUCH_INTERVAL`], runs `f` over its bytes without touching any
    /// store state — callable under a shared (read) lock. Expired or
    /// recency-stale items report [`PeekOutcome::NeedsWrite`] and the
    /// caller falls back to [`get_with`] under an exclusive lock.
    ///
    /// Does NOT count stats (no `&mut`); callers account fast-path
    /// reads themselves (see `ShardedStore`).
    ///
    /// [`get_with`]: KvStore::get_with
    pub fn peek<R, F: FnMut(ValueRef<'_>) -> R>(&self, key: &[u8], f: &mut F) -> PeekOutcome<R> {
        match self.peek_find(key, false) {
            PeekOutcome::Miss => PeekOutcome::Miss,
            PeekOutcome::NeedsWrite => PeekOutcome::NeedsWrite,
            PeekOutcome::Hit(id) => {
                let m = self.arena.get(id);
                let chunk = self.item_chunk(m);
                PeekOutcome::Hit(f(ValueRef {
                    data: &chunk[m.klen as usize..m.klen as usize + m.vlen as usize],
                    flags: m.flags,
                    cas: m.cas,
                }))
            }
        }
    }

    /// [`peek`](KvStore::peek) with per-hit metadata (remaining TTL,
    /// last-access age) — the meta `mg` read fast path. Same contract:
    /// read-only, stat-free, `NeedsWrite` when serving would require
    /// mutation. A `u` (no-bump) request serves recency-stale items
    /// here too: with no LRU bump wanted, staleness needs no write.
    pub fn peek_meta<R, F: FnMut(ValueRef<'_>, MetaHit) -> R>(
        &self,
        key: &[u8],
        opts: &MetaGetOpts,
        f: &mut F,
    ) -> PeekOutcome<R> {
        match self.peek_find(key, opts.no_bump) {
            PeekOutcome::Miss => PeekOutcome::Miss,
            PeekOutcome::NeedsWrite => PeekOutcome::NeedsWrite,
            PeekOutcome::Hit(id) => {
                let m = self.arena.get(id);
                if m.stale {
                    // the stale win race mutates win_sent
                    return PeekOutcome::NeedsWrite;
                }
                let ttl = self.ttl_of(m);
                if let Some(r) = opts.recache {
                    if ttl >= 0 && ttl < r as i64 {
                        // ditto for the early-recache win race
                        return PeekOutcome::NeedsWrite;
                    }
                }
                let chunk = self.item_chunk(m);
                let hit = MetaHit {
                    ttl,
                    won: false,
                    la: self.clock.now().saturating_sub(m.time),
                    fetched: m.fetched,
                    stale: false,
                    lost: false,
                };
                PeekOutcome::Hit(f(
                    ValueRef {
                        data: &chunk[m.klen as usize..m.klen as usize + m.vlen as usize],
                        flags: m.flags,
                        cas: m.cas,
                    },
                    hit,
                ))
            }
        }
    }

    /// Meta retrieval under the write lock: full get semantics plus the
    /// flag-driven extras — [`MetaGetOpts::touch`] refreshes the TTL on
    /// hit (touch-on-read, also classic `gat`), [`MetaGetOpts::vivify`]
    /// creates an empty item on miss and serves it as a "won" hit
    /// (`mg ... N`). `Ok(None)` is a plain miss; `Err` surfaces a
    /// failed vivify allocation (the client must not mistake memory
    /// exhaustion for a miss).
    pub fn meta_get<R, F: FnOnce(ValueRef<'_>, MetaHit) -> R>(
        &mut self,
        key: &[u8],
        opts: &MetaGetOpts,
        f: F,
    ) -> Result<Option<R>, StoreError> {
        self.stats.cmd_get += 1;
        let hash = hash_key(key);
        if let Some(id) = self.find_live(key, hash) {
            self.stats.get_hits += 1;
            // capture the pre-request access metadata (the l/h echoes)
            let now = self.clock.now();
            let (la, fetched_before) = {
                let m = self.arena.get(id);
                (now.saturating_sub(m.time), m.fetched)
            };
            let old = if opts.no_bump {
                // `u`: no LRU bump, no access-time refresh, no fetched
                // flip — the read leaves recency state untouched
                let m = self.arena.get(id);
                self.is_old_gen(m.gen)
            } else {
                let old = self.touch_lru(id);
                let m = self.arena.get_mut(id);
                m.time = now;
                m.fetched = true;
                old
            };
            if let Some(t) = opts.touch {
                let exp = self.normalize_exptime(t);
                self.arena.get_mut(id).exptime = exp;
                self.stats.touch_hits += 1;
            }
            let (stale, ttl) = {
                let m = self.arena.get(id);
                (m.stale, self.ttl_of(m))
            };
            // the stale/early-recache win race: the first reader to
            // arrive after an invalidation (or once the TTL sinks under
            // the `R` threshold) wins the right to recache (`W`); every
            // later reader loses (`Z`) until a rewrite clears the token
            let recache_due = match opts.recache {
                Some(r) => ttl >= 0 && ttl < r as i64,
                None => false,
            };
            let (mut won, mut lost) = (false, false);
            if stale || recache_due {
                let m = self.arena.get_mut(id);
                if m.win_sent {
                    lost = true;
                } else {
                    m.win_sent = true;
                    won = true;
                }
            }
            let m = self.arena.get(id);
            let hit = MetaHit {
                ttl,
                won,
                la,
                fetched: fetched_before,
                stale,
                lost,
            };
            let chunk = self.alloc.chunk_gen(old, m.handle);
            return Ok(Some(f(
                ValueRef {
                    data: &chunk[m.klen as usize..m.klen as usize + m.vlen as usize],
                    flags: m.flags,
                    cas: m.cas,
                },
                hit,
            )));
        }
        self.stats.get_misses += 1;
        if opts.touch.is_some() {
            self.stats.touch_misses += 1;
        }
        let Some(ttl) = opts.vivify else {
            return Ok(None);
        };
        if !key_ok(key, opts.binary_key) {
            return Ok(None); // unviable vivify: report the plain miss
        }
        let exp = self.normalize_exptime(ttl);
        self.stats.cmd_set += 1;
        self.insert_new(key, hash, b"", 0, exp, opts.vivify_cas, opts.tenant)?;
        // an absolute-past vivify TTL creates an already-expired item;
        // find_live reclaims it and the request reports a plain miss
        let Some(id) = self.find_live(key, hash) else {
            return Ok(None);
        };
        let m = self.arena.get(id);
        let hit = MetaHit {
            ttl: self.ttl_of(m),
            won: true,
            la: 0,
            fetched: false,
            stale: false,
            lost: false,
        };
        let chunk = self.alloc.chunk_gen(false, m.handle);
        Ok(Some(f(
            ValueRef {
                data: &chunk[m.klen as usize..m.klen as usize + m.vlen as usize],
                flags: m.flags,
                cas: m.cas,
            },
            hit,
        )))
    }

    /// CAS-guarded delete — classic `delete` (no guard) and meta `md`
    /// (`C` flag) share this primitive. With `invalidate` (meta
    /// `md ... I`) the item is **marked stale** instead of removed: it
    /// keeps serving (echoing `X`), its CAS is bumped so in-flight
    /// CAS stores lose, and the recache win token is re-armed so
    /// exactly one later reader is told to refresh it.
    pub fn delete_cas(&mut self, key: &[u8], cas: Option<u64>, invalidate: bool) -> DeleteOutcome {
        let hash = hash_key(key);
        match self.find_live(key, hash) {
            Some(id) => {
                if let Some(c) = cas {
                    if self.arena.get(id).cas != c {
                        self.stats.cas_badval += 1;
                        return DeleteOutcome::Exists;
                    }
                }
                if invalidate {
                    // stale and cas are reader-visible: stripe-guard
                    // the combined mutation like any other write
                    let new_cas = self.next_cas();
                    let seq = self.seq.clone();
                    let _g = seq.guard(hash);
                    let m = self.arena.get_mut(id);
                    m.stale = true;
                    m.win_sent = false;
                    m.cas = new_cas;
                } else {
                    self.unlink_and_free(id, hash);
                }
                self.stats.delete_hits += 1;
                DeleteOutcome::Deleted
            }
            None => {
                self.stats.delete_misses += 1;
                DeleteOutcome::NotFound
            }
        }
    }

    /// `delete`. Returns true when the key existed.
    pub fn delete(&mut self, key: &[u8]) -> bool {
        matches!(self.delete_cas(key, None, false), DeleteOutcome::Deleted)
    }

    /// The unified arithmetic primitive: CAS-guarded, optionally
    /// vivifying incr/decr. Classic `incr`/`decr` and meta `ma` both
    /// execute this.
    pub fn arith(&mut self, key: &[u8], opts: &ArithOpts) -> Result<ArithOutcome, StoreError> {
        let hash = hash_key(key);
        let Some(id) = self.find_live(key, hash) else {
            if let Some((ttl, init)) = opts.vivify {
                if key_ok(key, opts.binary_key) {
                    let exp = self.normalize_exptime(ttl);
                    self.stats.cmd_set += 1;
                    let repr = init.to_string();
                    let cas = self.insert_new(
                        key,
                        hash,
                        repr.as_bytes(),
                        0,
                        exp,
                        opts.cas_set,
                        opts.tenant,
                    )?;
                    if opts.incr {
                        self.stats.incr_hits += 1;
                    } else {
                        self.stats.decr_hits += 1;
                    }
                    return Ok(ArithOutcome::Value {
                        value: init,
                        ttl: self.ttl_from_exp(exp),
                        cas,
                    });
                }
            }
            if opts.incr {
                self.stats.incr_misses += 1;
            } else {
                self.stats.decr_misses += 1;
            }
            return Ok(ArithOutcome::NotFound);
        };
        if let Some(c) = opts.cas_compare {
            if self.arena.get(id).cas != c {
                self.stats.cas_badval += 1;
                return Ok(ArithOutcome::Exists);
            }
        }
        let (klen, vlen) = {
            let m = self.arena.get(id);
            (m.klen as usize, m.vlen as usize)
        };
        let bytes = &self.item_chunk(self.arena.get(id))[klen..klen + vlen];
        let text = std::str::from_utf8(bytes).map_err(|_| StoreError::NonNumeric)?;
        let current: u64 = text.trim_end().parse().map_err(|_| StoreError::NonNumeric)?;
        let next = if opts.incr {
            current.wrapping_add(opts.delta)
        } else {
            current.saturating_sub(opts.delta)
        };
        let repr = next.to_string();
        let cas = self.replace_value_bytes(id, repr.as_bytes(), opts.cas_set, opts.tenant)?;
        if let Some(t) = opts.new_ttl {
            let exp = self.normalize_exptime(t);
            self.arena.get_mut(id).exptime = exp;
        }
        if opts.incr {
            self.stats.incr_hits += 1;
        } else {
            self.stats.decr_hits += 1;
        }
        let ttl = self.ttl_of(self.arena.get(id));
        Ok(ArithOutcome::Value {
            value: next,
            ttl,
            cas,
        })
    }

    /// `incr`/`decr`. `Ok(None)` = not found.
    pub fn incr_decr(
        &mut self,
        key: &[u8],
        delta: u64,
        incr: bool,
    ) -> Result<Option<u64>, StoreError> {
        Ok(
            match self.arith(key, &ArithOpts::classic(delta, incr))? {
                ArithOutcome::Value { value, .. } => Some(value),
                ArithOutcome::NotFound | ArithOutcome::Exists => None,
            },
        )
    }

    /// `touch`: refresh expiry. Returns true when the key existed.
    pub fn touch(&mut self, key: &[u8], exptime: u32) -> bool {
        let hash = hash_key(key);
        match self.find_live(key, hash) {
            Some(id) => {
                let exp = self.normalize_exptime(exptime);
                self.touch_lru(id);
                self.arena.get_mut(id).exptime = exp;
                self.stats.touch_hits += 1;
                true
            }
            None => {
                self.stats.touch_misses += 1;
                false
            }
        }
    }

    /// `stats reset`: zero the cumulative operation counters
    /// (memcached parity — gauges like item counts are untouched).
    pub fn reset_stats(&mut self) {
        self.stats = StoreStats::default();
    }

    /// Read-only bookkeeping lookup for the meta `me` debug command:
    /// no stats, no LRU bump, no lazy reclaim (an expired item reports
    /// as absent and is left for the next write-path lookup).
    pub fn debug_item(&self, key: &[u8]) -> Option<ItemDebug> {
        let hash = hash_key(key);
        let id = self.table.find(hash, &self.arena, |id| {
            let m = self.arena.get(id);
            &self.item_chunk(m)[..m.klen as usize] == key
        })?;
        let m = self.arena.get(id);
        if self.is_expired(m) {
            return None;
        }
        Some(ItemDebug {
            ttl: self.ttl_of(m),
            la: self.clock.now().saturating_sub(m.time),
            cas: m.cas,
            fetched: m.fetched,
            class: m.handle.class,
            tier: Tier::from_u8(m.tier),
            vlen: m.vlen,
        })
    }

    // -------------------------------------------- background maintenance

    /// One bounded maintenance pass (the background maintainer's unit
    /// of work, run under a short write-lock lease):
    ///
    /// 1. demote up to `max_moves` over-cap HOT/WARM tails into COLD
    ///    across this store's classes — the tier-rebalance work the set
    ///    path no longer does inline;
    /// 2. outside a migration, shed post-drain budget overshoot (the ≤
    ///    [`MIGRATION_PAGE_SLACK`] carved-over pages a drain into a
    ///    less-dense geometry can leave behind), returning the memory
    ///    to the OS — likewise bounded to `max_moves` evictions per
    ///    pass, so a dense victim page drains across passes instead of
    ///    stalling this lease.
    ///
    /// Returns `(demoted, pages_shed)`.
    ///
    /// [`MIGRATION_PAGE_SLACK`]: crate::slab::allocator::MIGRATION_PAGE_SLACK
    pub fn maintain(&mut self, max_moves: usize) -> (usize, usize) {
        // age freed page buffers one limbo phase: a buffer condemned
        // before the previous pass can no longer be reached by any
        // optimistic reader (the free bumped its stripe; readers
        // re-validate before every dereference)
        self.alloc.drain_limbo();
        let mut demoted = 0;
        for lru in &mut self.lrus {
            if demoted >= max_moves {
                break;
            }
            demoted += lru.rebalance_step(&mut self.arena, max_moves - demoted);
        }
        let pages_shed = if self.migration.is_none() {
            self.shed_slack_page(max_moves)
        } else {
            0
        };
        self.stats.maintainer_runs += 1;
        self.stats.maintainer_demoted += demoted as u64;
        self.stats.maintainer_pages_shed += pages_shed as u64;
        (demoted, pages_shed)
    }

    /// Apply a batch of deferred read-side effects ([`BumpEvent`]s
    /// drained from the shard's ring). Each event is re-validated —
    /// the arena slot must still be live and hold the same logical
    /// item (generation tag + CAS) — then the LRU bump, access-time
    /// refresh and fetched-bit set the optimistic hit skipped are
    /// performed. Invalid events are silently dropped: the item was
    /// deleted, replaced or migrated since the read, so its recency
    /// state is no longer ours to touch. Returns the number applied.
    pub(crate) fn apply_deferred(&mut self, events: &[BumpEvent]) -> u64 {
        let mut applied = 0u64;
        for ev in events {
            let valid = matches!(
                self.arena.get_checked(ev.id),
                Some(m) if m.gen == ev.gen && m.cas == ev.cas
            );
            if !valid {
                continue;
            }
            self.touch_lru(ev.id);
            let m = self.arena.get_mut(ev.id);
            // never move the access time backwards: a write-path hit
            // may have refreshed it after this event was queued
            m.time = m.time.max(ev.now);
            m.fetched = true;
            applied += 1;
        }
        self.stats.lru_bump_drained += applied;
        applied
    }

    /// True when every class's HOT/WARM fraction caps hold (the state
    /// the maintainer converges to).
    pub fn lru_balanced(&self) -> bool {
        self.lrus.iter().all(|l| l.is_balanced())
    }

    /// Per-class `(hot, warm, cold)` tier sizes — test/diagnostic probe.
    pub fn lru_tier_sizes(&self) -> Vec<(usize, usize, usize)> {
        self.lrus
            .iter()
            .map(|l| (l.hot.len(), l.warm.len(), l.cold.len()))
            .collect()
    }

    /// Items visited through the per-page index so far (force-drain and
    /// slack shedding) — the step counter the O(chunks/page) tests read.
    pub fn page_scan_steps(&self) -> u64 {
        self.page_scan_steps
    }

    /// Structural self-check (test support): every live arena id is
    /// linked in exactly one LRU tier of exactly one generation, and the
    /// slab hole identity holds. Returns a description of the first
    /// violation.
    pub fn check_integrity(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        let mut visit = |lru: &ClassLru, arena: &Arena| -> Result<(), String> {
            for id in lru.iter_all(arena) {
                if !seen.insert(id) {
                    return Err(format!("id {id} linked twice"));
                }
            }
            Ok(())
        };
        for lru in &self.lrus {
            visit(lru, &self.arena)?;
        }
        if let Some(mig) = &self.migration {
            for lru in &mig.old_lrus {
                visit(lru, &self.arena)?;
            }
        }
        if seen.len() != self.arena.len() {
            return Err(format!(
                "{} ids linked in LRUs but {} live in the arena",
                seen.len(),
                self.arena.len()
            ));
        }
        let st = self.alloc.stats();
        if st.allocated_bytes - st.requested_bytes != st.hole_bytes {
            return Err("hole identity violated".into());
        }
        Ok(())
    }

    /// Shed budget overshoot: drop pooled buffers first; if carved
    /// pages still exceed the strict budget, release drained
    /// current-generation pages, then (if needed) evict residents of
    /// the emptiest current page — enumerated in O(chunks/page)
    /// through the per-page index, at most `max_evict` items per call
    /// so the write-lock lease stays short even for a dense page (the
    /// partially drained page is finished by subsequent passes).
    fn shed_slack_page(&mut self, max_evict: usize) -> usize {
        let before = self.alloc.resident_pages();
        self.alloc.trim_free_pool();
        if self.alloc.pages_allocated() > self.alloc.page_budget() {
            self.alloc.release_current_drained_pages();
            if self.alloc.pages_allocated() > self.alloc.page_budget() {
                // only the minimum-occupancy page is wanted — no sort
                let candidate = self
                    .alloc
                    .page_occupancy()
                    .into_iter()
                    .min_by_key(|&(_, _, used)| used);
                if let Some((class, page, used)) = candidate {
                    let mut victims = self.page_residents(false, class, page);
                    debug_assert_eq!(victims.len() as u32, used, "page chain out of sync");
                    victims.truncate(max_evict.max(1));
                    let n = victims.len() as u64;
                    for (id, hash) in victims {
                        self.unlink_and_free(id, hash);
                    }
                    self.stats.evictions += n;
                    self.alloc.release_current_drained_pages();
                }
            }
            self.alloc.trim_free_pool();
        }
        before - self.alloc.resident_pages()
    }

    /// `flush_all` (eager variant: reclaims immediately).
    pub fn flush_all(&mut self) {
        self.stats.flush_cmds += 1;
        let ids: Vec<u32> = self.arena.iter_ids().collect();
        for id in ids {
            let hash = self.arena.get(id).hash;
            self.unlink_and_free(id, hash);
        }
        // flushing everything also empties the draining generation
        self.maybe_finish_migration();
    }

    /// Arbitration enforcement: evict up to `max_items` of the coldest
    /// items owned by tenants in `mask` (bit *i* = tenant *i*) — the
    /// mechanism behind soft quotas and need-based reallocation
    /// (`TenantRegistry::arbitration_mask`). Walks each class's
    /// COLD→WARM→HOT tails backward under a bounded scan budget so a
    /// single call stays a short write-lock lease; repeated maintainer
    /// passes converge instead of one stop-the-world sweep. Freed
    /// chunks drain pages back into the allocator's free-page pool,
    /// where needier tenants' writes (or the in-flight incremental
    /// migration) re-carve them. Returns the number evicted.
    pub fn reclaim_tenants(&mut self, mask: u64, max_items: usize) -> usize {
        if mask == 0 || max_items == 0 {
            return 0;
        }
        let mut victims: Vec<(u32, u64, u8)> = Vec::new();
        let scan_budget = max_items.saturating_mul(8).max(64);
        let mut scanned = 0usize;
        'outer: for class in 0..self.lrus.len() {
            let tails = [
                self.lrus[class].cold.tail(),
                self.lrus[class].warm.tail(),
                self.lrus[class].hot.tail(),
            ];
            for tail in tails {
                let mut cur = tail;
                while let Some(id) = cur {
                    if victims.len() >= max_items || scanned >= scan_budget {
                        break 'outer;
                    }
                    scanned += 1;
                    let m = self.arena.get(id);
                    let prev = m.prev;
                    if mask & (1u64 << (m.tenant & 63)) != 0 {
                        victims.push((id, m.hash, m.tenant));
                    }
                    cur = (prev != NIL).then_some(prev);
                }
            }
        }
        let n = victims.len();
        for (id, hash, tenant) in victims {
            self.unlink_and_free(id, hash);
            self.stats.evictions += 1;
            self.tenant_on_evict(tenant, true);
        }
        n
    }

    /// Visit `(key, meta_total_size)` for every live item.
    pub fn for_each_item<F: FnMut(&[u8], usize)>(&self, mut f: F) {
        for id in self.arena.iter_ids() {
            let m = self.arena.get(id);
            let chunk = self.item_chunk(m);
            f(&chunk[..m.klen as usize], m.total as usize);
        }
    }

    // ---------------------------------------------------------- warm restart

    /// This shard's CAS high-water mark (the manifest persists it so a
    /// warm restart never re-issues a CAS an old client already saw).
    pub(crate) fn cas_high_water(&self) -> u64 {
        self.cas_counter
    }

    /// Seed the CAS counter from a persisted high-water mark.
    pub(crate) fn set_cas_floor(&mut self, floor: u64) {
        self.cas_counter = self.cas_counter.max(floor);
    }

    #[inline]
    pub(crate) fn cas_enabled(&self) -> bool {
        self.use_cas
    }

    /// Export every live item as a manifest record, in LRU order per
    /// class (hot → warm → cold, most → least recent within each tier)
    /// so recovery can rebuild identical recency chains. Keys and
    /// values are *not* copied: they already live in the mapped chunks
    /// the records point into. Requires a fully drained migration (the
    /// manifest writer forces one first).
    pub(crate) fn export_items(&self) -> Vec<super::restart::ItemRecord> {
        debug_assert!(self.migration.is_none(), "export during migration");
        let mut out = Vec::with_capacity(self.arena.len());
        for lru in &self.lrus {
            for id in lru.iter_all(&self.arena) {
                let m = self.arena.get(id);
                out.push(super::restart::ItemRecord {
                    class: m.handle.class,
                    page: m.handle.loc.page,
                    chunk: m.handle.loc.chunk,
                    klen: m.klen,
                    vlen: m.vlen,
                    flags: m.flags,
                    exptime: m.exptime,
                    time: m.time,
                    cas: m.cas,
                    total: m.total,
                    tier: m.tier,
                    fetched: m.fetched,
                    tenant: m.tenant,
                });
            }
        }
        out
    }

    /// `(class, page_slot, region_offset)` of every occupied page — the
    /// manifest's page map.
    pub(crate) fn export_page_map(&self) -> Vec<(u16, u32, u64)> {
        self.alloc.page_map()
    }

    /// Adopt a recovered page at its persisted `(class, slot)`.
    pub(crate) fn restore_page(
        &mut self,
        class: u16,
        slot: u32,
        buf: PageBuf,
        used: &[u32],
    ) -> Result<(), String> {
        self.alloc.restore_page(class, slot, buf, used)
    }

    /// Re-link one recovered item: the chunk bytes are already in place
    /// (adopted with the page), so this rebuilds metadata only — arena
    /// record, hash-chain entry, LRU link at its persisted tier, page
    /// chain, hole accounting, tenant gauges. The caller has validated
    /// the record against the page map and discarded expired items; the
    /// key is re-read from the chunk and re-hashed. The size observer is
    /// deliberately *not* fed: learner windows restart at zero (the
    /// documented `stats reset` contract for recovery).
    pub(crate) fn restore_item(&mut self, rec: &super::restart::ItemRecord) -> Result<(), String> {
        let class = rec.class as usize;
        if class >= self.lrus.len() {
            return Err(format!("item in class {} of {}", rec.class, self.lrus.len()));
        }
        let chunk_size = self.alloc.chunk_size_of(rec.class);
        let klen = rec.klen as usize;
        if !(1..=super::item::MAX_KEY_LEN).contains(&klen)
            || klen + rec.vlen as usize > chunk_size
            || rec.total as usize > chunk_size
        {
            return Err(format!(
                "item geometry corrupt (klen {klen}, vlen {}, total {}, chunk {chunk_size})",
                rec.vlen, rec.total
            ));
        }
        let handle = ChunkHandle {
            class: rec.class,
            loc: ChunkLoc {
                page: rec.page,
                chunk: rec.chunk,
            },
        };
        let (hash, chunk_addr) = {
            let chunk = self.alloc.chunk(handle);
            (hash_key(&chunk[..klen]), chunk.as_ptr() as usize)
        };
        let seq = self.seq.clone();
        let _g = seq.guard(hash);
        let id = self.arena.insert(ItemMeta {
            hash,
            handle,
            chunk_addr,
            klen: rec.klen,
            vlen: rec.vlen,
            flags: rec.flags,
            exptime: rec.exptime,
            time: rec.time,
            cas: rec.cas,
            total: rec.total,
            hnext: NIL,
            prev: NIL,
            next: NIL,
            pg_prev: NIL,
            pg_next: NIL,
            tier: rec.tier,
            fetched: rec.fetched,
            stale: false,
            win_sent: false,
            gen: self.gen,
            live: true,
            tenant: rec.tenant,
        });
        self.table.insert(id, hash, &mut self.arena);
        // records arrive reversed per tier, so push_head rebuilds the
        // persisted order exactly; the tier tag is already on the item
        match Tier::from_u8(rec.tier) {
            Tier::Hot => self.lrus[class].hot.push_head(id, &mut self.arena),
            Tier::Warm => self.lrus[class].warm.push_head(id, &mut self.arena),
            Tier::Cold => self.lrus[class].cold.push_head(id, &mut self.arena),
        }
        self.page_link(id);
        // the chunk was marked used by restore_page with zero requested
        // bytes; account the item's true size so the hole identity holds
        self.alloc.reaccount(handle, 0, rec.total as usize);
        self.tenant_on_store(rec.tenant, rec.total as usize);
        Ok(())
    }

    // ------------------------------------------------- live reconfiguration

    /// Migrate every item into a new chunk geometry — the online
    /// equivalent of restarting memcached with `-o slab_sizes=...`.
    ///
    /// Blocking convenience over the incremental machinery in
    /// `store::migrate`: kicks off a migration and drives
    /// [`migrate_step`] to completion. Items move coldest-first within
    /// each old class, so relative recency is preserved; items that
    /// cannot fit under the page budget (plus the constant page slack)
    /// are dropped, counted in the report. Peak memory is bounded by
    /// `mem_limit` + [`MIGRATION_PAGE_SLACK`] pages — old pages drain
    /// into a free-page pool and are re-carved for the new geometry.
    ///
    /// Concurrent callers (`ShardedStore`, the auto-tuner) instead use
    /// [`begin_migration`] + [`migrate_step`] directly, releasing the
    /// shard lock between steps.
    ///
    /// [`begin_migration`]: KvStore::begin_migration
    /// [`migrate_step`]: KvStore::migrate_step
    /// [`MIGRATION_PAGE_SLACK`]: crate::slab::allocator::MIGRATION_PAGE_SLACK
    pub fn reconfigure(&mut self, new_policy: ChunkSizePolicy) -> Result<MigrationReport, StoreError> {
        self.begin_migration(new_policy)?;
        while self.migrate_step(super::migrate::DEFAULT_MIGRATE_BATCH) {}
        Ok(self
            .last_migration
            .clone()
            .expect("migration just completed"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::PAGE_SIZE;

    fn store(mem: usize) -> KvStore {
        KvStore::new(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            mem,
            true,
            Clock::System,
        )
        .unwrap()
    }

    #[test]
    fn set_get_roundtrip() {
        let mut s = store(8 << 20);
        s.set(b"hello", b"world", 7, 0).unwrap();
        let v = s.get(b"hello").unwrap();
        assert_eq!(v.value, b"world");
        assert_eq!(v.flags, 7);
        assert!(v.cas > 0);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn set_overwrites() {
        let mut s = store(8 << 20);
        s.set(b"k", b"v1", 0, 0).unwrap();
        s.set(b"k", b"v2-longer-value", 0, 0).unwrap();
        assert_eq!(s.get(b"k").unwrap().value, b"v2-longer-value");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn add_replace_semantics() {
        let mut s = store(8 << 20);
        assert!(s.add(b"k", b"v", 0, 0).unwrap());
        assert!(!s.add(b"k", b"v2", 0, 0).unwrap());
        assert_eq!(s.get(b"k").unwrap().value, b"v");
        assert!(s.replace(b"k", b"v3", 0, 0).unwrap());
        assert_eq!(s.get(b"k").unwrap().value, b"v3");
        assert!(!s.replace(b"absent", b"x", 0, 0).unwrap());
    }

    #[test]
    fn cas_flow() {
        let mut s = store(8 << 20);
        s.set(b"k", b"v", 0, 0).unwrap();
        let cas = s.get(b"k").unwrap().cas;
        assert_eq!(s.cas(b"k", b"v2", 0, 0, cas).unwrap(), CasResult::Stored);
        assert_eq!(s.cas(b"k", b"v3", 0, 0, cas).unwrap(), CasResult::Exists);
        assert_eq!(
            s.cas(b"nope", b"v", 0, 0, 1).unwrap(),
            CasResult::NotFound
        );
        assert_eq!(s.get(b"k").unwrap().value, b"v2");
    }

    #[test]
    fn delete_semantics() {
        let mut s = store(8 << 20);
        s.set(b"k", b"v", 0, 0).unwrap();
        assert!(s.delete(b"k"));
        assert!(!s.delete(b"k"));
        assert!(s.get(b"k").is_none());
        assert_eq!(s.len(), 0);
        // slab memory fully reclaimed
        assert_eq!(s.slab_stats().requested_bytes, 0);
    }

    #[test]
    fn incr_decr() {
        let mut s = store(8 << 20);
        s.set(b"n", b"10", 0, 0).unwrap();
        assert_eq!(s.incr_decr(b"n", 5, true).unwrap(), Some(15));
        assert_eq!(s.incr_decr(b"n", 20, false).unwrap(), Some(0)); // floors
        assert_eq!(s.incr_decr(b"absent", 1, true).unwrap(), None);
        s.set(b"t", b"text", 0, 0).unwrap();
        assert_eq!(s.incr_decr(b"t", 1, true), Err(StoreError::NonNumeric));
    }

    #[test]
    fn incr_growing_representation() {
        let mut s = store(8 << 20);
        s.set(b"n", b"9", 0, 0).unwrap();
        assert_eq!(s.incr_decr(b"n", 1, true).unwrap(), Some(10));
        assert_eq!(s.get(b"n").unwrap().value, b"10");
    }

    #[test]
    fn append_prepend() {
        let mut s = store(8 << 20);
        s.set(b"k", b"mid", 0, 0).unwrap();
        assert!(s.concat(b"k", b"-end", true).unwrap());
        assert!(s.concat(b"k", b"start-", false).unwrap());
        assert_eq!(s.get(b"k").unwrap().value, b"start-mid-end");
        assert!(!s.concat(b"absent", b"x", true).unwrap());
    }

    #[test]
    fn append_across_class_boundary() {
        let mut s = store(8 << 20);
        s.set(b"k", &[b'a'; 30], 0, 0).unwrap(); // 48+8+1+30+2=89 -> class 96
        let big = [b'b'; 200];
        assert!(s.concat(b"k", &big, true).unwrap()); // total 289 -> class 304
        let v = s.get(b"k").unwrap().value;
        assert_eq!(v.len(), 230);
        // hole accounting stays exact
        let st = s.slab_stats();
        assert_eq!(st.requested_bytes, total_item_size(1, 230, true) as u64);
    }

    #[test]
    fn expiry_lazy_reclaim() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s = KvStore::new(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            8 << 20,
            true,
            clock,
        )
        .unwrap();
        s.set(b"k", b"v", 0, 60).unwrap(); // relative 60s
        assert!(s.get(b"k").is_some());
        cell.store(1_000_061, Ordering::Relaxed);
        assert!(s.get(b"k").is_none());
        assert_eq!(s.stats().expired_reclaims, 1);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn exptime_absolute() {
        let (clock, cell) = Clock::manual(10_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"k", b"v", 0, 10_000_005).unwrap(); // absolute
        assert!(s.get(b"k").is_some());
        cell.store(10_000_006, Ordering::Relaxed);
        assert!(s.get(b"k").is_none());
    }

    #[test]
    fn touch_extends_life() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"k", b"v", 0, 60).unwrap();
        cell.store(1_000_050, Ordering::Relaxed);
        assert!(s.touch(b"k", 120));
        cell.store(1_000_100, Ordering::Relaxed);
        assert!(s.get(b"k").is_some(), "touched item survives old expiry");
    }

    #[test]
    fn flush_all_clears() {
        let mut s = store(8 << 20);
        for i in 0..100u32 {
            s.set(format!("k{i}").as_bytes(), b"v", 0, 0).unwrap();
        }
        s.flush_all();
        assert_eq!(s.len(), 0);
        assert!(s.get(b"k5").is_none());
        assert_eq!(s.slab_stats().requested_bytes, 0);
    }

    #[test]
    fn eviction_under_memory_pressure() {
        // tiny cache: 2 pages of 4096
        let mut s = KvStore::new(
            ChunkSizePolicy::Geometric {
                chunk_min: 96,
                factor: 1.25,
            },
            4096,
            8192,
            true,
            Clock::System,
        )
        .unwrap();
        // fill way beyond capacity with ~96-byte items
        for i in 0..500u32 {
            s.set(format!("key-{i:04}").as_bytes(), b"0123456789", 0, 0)
                .unwrap();
        }
        assert!(s.stats().evictions > 0, "must have evicted");
        // most recent items should still be present
        assert!(s.get(b"key-0499").is_some());
        assert!(s.get(b"key-0000").is_none(), "oldest evicted");
    }

    #[test]
    fn too_large_rejected() {
        let mut s = KvStore::new(
            ChunkSizePolicy::default(),
            4096,
            1 << 20,
            true,
            Clock::System,
        )
        .unwrap();
        let huge = vec![0u8; 8192];
        match s.set(b"k", &huge, 0, 0) {
            Err(StoreError::TooLarge { .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hole_accounting_matches_item_sizes() {
        let mut s = store(16 << 20);
        // 518-byte total items: key "kNNNN" (5) + value padding
        // total = 48 + 8 + 5 + vlen + 2 = 518 -> vlen = 455
        for i in 0..1000u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        let st = s.slab_stats();
        assert_eq!(st.requested_bytes, 518 * 1000);
        // default chain puts 518 into the 600 chunk: hole = 82/item
        assert_eq!(st.hole_bytes, 82 * 1000);
    }

    #[test]
    fn reconfigure_reduces_holes_and_keeps_items() {
        let mut s = store(32 << 20);
        for i in 0..2000u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        let before = s.slab_stats().hole_bytes;
        let report = s
            .reconfigure(ChunkSizePolicy::Explicit(vec![518]))
            .unwrap();
        assert_eq!(report.items_moved, 2000);
        assert_eq!(report.items_dropped, 0);
        assert_eq!(report.hole_bytes_before, before);
        assert_eq!(report.hole_bytes_after, 0, "exact-fit chunks -> no holes");
        assert!(report.waste_recovered_fraction() > 0.999);
        // data survives
        assert_eq!(s.get(b"k0000").unwrap().value.len(), 455);
        assert_eq!(s.get(b"k1999").unwrap().value.len(), 455);
        assert_eq!(s.len(), 2000);
    }

    #[test]
    fn reconfigure_preserves_recency() {
        let mut s = store(32 << 20);
        for i in 0..100u32 {
            s.set(format!("k{i:02}").as_bytes(), b"v", 0, 0).unwrap();
        }
        s.reconfigure(ChunkSizePolicy::Explicit(vec![96, 200]))
            .unwrap();
        // force eviction pressure on the new layout and confirm newest live
        for i in 0..100u32 {
            assert!(s.get(format!("k{i:02}").as_bytes()).is_some());
        }
    }

    #[test]
    fn peek_fast_path_semantics() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"k", b"hello", 3, 0).unwrap();

        // fresh item (set just now): peek serves it read-only
        let mut seen = Vec::new();
        match s.peek(b"k", &mut |v: ValueRef<'_>| {
            seen.extend_from_slice(v.data);
            v.flags
        }) {
            PeekOutcome::Hit(flags) => assert_eq!(flags, 3),
            _ => panic!("expected hit"),
        }
        assert_eq!(seen, b"hello");
        // peek counts nothing — it has no &mut
        assert_eq!(s.stats().cmd_get, 0);

        // absent key is a definitive miss
        assert!(matches!(
            s.peek(b"nope", &mut |_: ValueRef<'_>| ()),
            PeekOutcome::Miss
        ));

        // older than TOUCH_INTERVAL: needs the write path (LRU bump)
        cell.store(1_000_000 + TOUCH_INTERVAL as u64, Ordering::Relaxed);
        assert!(matches!(
            s.peek(b"k", &mut |_: ValueRef<'_>| ()),
            PeekOutcome::NeedsWrite
        ));
        // a write-path get refreshes the access time...
        assert!(s.get_with(b"k", |v| v.data.len()).is_some());
        // ...after which peek serves again
        assert!(matches!(
            s.peek(b"k", &mut |_: ValueRef<'_>| ()),
            PeekOutcome::Hit(())
        ));
    }

    #[test]
    fn peek_never_serves_expired() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"k", b"v", 0, 30).unwrap();
        cell.store(1_000_031, Ordering::Relaxed);
        // expired: peek defers to the write path, which lazily reclaims
        assert!(matches!(
            s.peek(b"k", &mut |_: ValueRef<'_>| ()),
            PeekOutcome::NeedsWrite
        ));
        assert!(s.get(b"k").is_none());
        assert_eq!(s.stats().expired_reclaims, 1);
    }

    #[test]
    fn get_with_visits_in_place() {
        let mut s = store(8 << 20);
        s.set(b"k", b"abcdef", 9, 0).unwrap();
        let len = s.get_with(b"k", |v| {
            assert_eq!(v.flags, 9);
            assert!(v.cas > 0);
            v.data.len()
        });
        assert_eq!(len, Some(6));
        assert_eq!(s.get_with(b"missing", |v| v.data.len()), None);
        assert_eq!(s.stats().get_hits, 1);
        assert_eq!(s.stats().get_misses, 1);
    }

    #[test]
    fn observer_sees_set_sizes() {
        use std::sync::Mutex;
        struct Rec(Mutex<Vec<usize>>);
        impl SizeObserver for Rec {
            fn observe(&self, n: usize) {
                self.0.lock().unwrap().push(n);
            }
        }
        let rec = Arc::new(Rec(Mutex::new(Vec::new())));
        let mut s = store(8 << 20);
        s.set_observer(rec.clone());
        s.set(b"abc", b"12345", 0, 0).unwrap();
        let want = total_item_size(3, 5, true);
        assert_eq!(*rec.0.lock().unwrap(), vec![want]);
    }

    // --------------------------------------------- meta-store surface

    #[test]
    fn meta_set_returns_cas_and_honors_explicit_cas() {
        let mut s = store(8 << 20);
        let SetOutcome::Stored { cas } = s.meta_set(b"k", b"v", &MetaSetOpts::set(0, 0)).unwrap()
        else {
            panic!()
        };
        assert_eq!(s.get(b"k").unwrap().cas, cas);
        // explicit CAS (meta E flag) sticks and future items stay unique
        let opts = MetaSetOpts {
            cas_set: Some(1000),
            ..MetaSetOpts::set(0, 0)
        };
        let SetOutcome::Stored { cas } = s.meta_set(b"e", b"v", &opts).unwrap() else {
            panic!()
        };
        assert_eq!(cas, 1000);
        assert_eq!(s.get(b"e").unwrap().cas, 1000);
        let SetOutcome::Stored { cas } = s.meta_set(b"f", b"v", &MetaSetOpts::set(0, 0)).unwrap()
        else {
            panic!()
        };
        assert!(cas > 1000, "counter advanced past the override");
    }

    #[test]
    fn meta_set_cas_guarded_concat() {
        let mut s = store(8 << 20);
        s.set(b"k", b"mid", 0, 0).unwrap();
        let cas = s.get(b"k").unwrap().cas;
        let bad = MetaSetOpts {
            mode: StoreMode::Append,
            cas_compare: Some(cas + 1),
            ..MetaSetOpts::set(0, 0)
        };
        assert_eq!(s.meta_set(b"k", b"-x", &bad).unwrap(), SetOutcome::Exists);
        assert_eq!(s.get(b"k").unwrap().value, b"mid");
        let good = MetaSetOpts {
            mode: StoreMode::Append,
            cas_compare: Some(cas),
            ..MetaSetOpts::set(0, 0)
        };
        assert!(matches!(
            s.meta_set(b"k", b"-end", &good).unwrap(),
            SetOutcome::Stored { .. }
        ));
        assert_eq!(s.get(b"k").unwrap().value, b"mid-end");
    }

    #[test]
    fn meta_set_binary_key_gate() {
        let mut s = store(8 << 20);
        let key = b"has space\x01";
        // text-protocol rules reject it...
        assert_eq!(
            s.meta_set(key, b"v", &MetaSetOpts::set(0, 0)),
            Err(StoreError::BadKey)
        );
        // ...the binary (base64-sourced) gate accepts it
        let opts = MetaSetOpts {
            binary_key: true,
            ..MetaSetOpts::set(0, 0)
        };
        assert!(matches!(
            s.meta_set(key, b"v", &opts).unwrap(),
            SetOutcome::Stored { .. }
        ));
        assert_eq!(s.get(key).unwrap().value, b"v");
        // length bound still applies
        assert_eq!(
            s.meta_set(&[b'k'; 251], b"v", &opts),
            Err(StoreError::BadKey)
        );
    }

    #[test]
    fn delete_cas_guard() {
        let mut s = store(8 << 20);
        s.set(b"k", b"v", 0, 0).unwrap();
        let cas = s.get(b"k").unwrap().cas;
        assert_eq!(s.delete_cas(b"k", Some(cas + 1), false), DeleteOutcome::Exists);
        assert!(s.get(b"k").is_some(), "mismatch must not delete");
        assert_eq!(s.delete_cas(b"k", Some(cas), false), DeleteOutcome::Deleted);
        assert_eq!(s.delete_cas(b"k", None, false), DeleteOutcome::NotFound);
    }

    #[test]
    fn invalidate_marks_stale_and_runs_the_win_race() {
        let mut s = store(8 << 20);
        s.set(b"k", b"v", 0, 0).unwrap();
        let cas = s.get(b"k").unwrap().cas;
        // md I: the item survives, stale, with a bumped CAS
        assert_eq!(s.delete_cas(b"k", None, true), DeleteOutcome::Deleted);
        let plain = MetaGetOpts::default();
        let h1 = s.meta_get(b"k", &plain, |v, h| {
            assert_eq!(v.data, b"v", "stale item still serves its bytes");
            assert!(v.cas > cas, "invalidation bumps the CAS");
            h
        });
        let h1 = h1.unwrap().unwrap();
        assert!(h1.stale && h1.won && !h1.lost, "first reader wins recache");
        // second reader: still stale, but the win is spent
        let h2 = s.meta_get(b"k", &plain, |_, h| h).unwrap().unwrap();
        assert!(h2.stale && !h2.won && h2.lost);
        // a CAS store against the pre-invalidation token loses — and
        // with I it re-arms the win instead of silently failing
        let lose = MetaSetOpts {
            cas_compare: Some(cas),
            invalidate: true,
            ..MetaSetOpts::set(0, 0)
        };
        assert_eq!(s.meta_set(b"k", b"old", &lose).unwrap(), SetOutcome::Exists);
        let h3 = s.meta_get(b"k", &plain, |_, h| h).unwrap().unwrap();
        assert!(h3.stale && h3.won, "losing ms I re-armed the win");
        // a rewrite clears staleness and the token
        s.set(b"k", b"fresh", 0, 0).unwrap();
        let h4 = s.meta_get(b"k", &plain, |v, h| {
            assert_eq!(v.data, b"fresh");
            h
        });
        let h4 = h4.unwrap().unwrap();
        assert!(!h4.stale && !h4.won && !h4.lost);
    }

    #[test]
    fn recache_threshold_hands_out_one_win() {
        let (clock, cell) = Clock::manual(5_000_000);
        let mut s = KvStore::new(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            8 << 20,
            true,
            clock,
        )
        .unwrap();
        s.set(b"k", b"v", 0, 100).unwrap();
        let r30 = MetaGetOpts {
            recache: Some(30),
            ..MetaGetOpts::default()
        };
        // plenty of TTL left: no win race at all
        let h = s.meta_get(b"k", &r30, |_, h| h).unwrap().unwrap();
        assert!(!h.won && !h.lost && !h.stale);
        // TTL sinks under the threshold: first reader wins, second loses
        cell.store(5_000_000 + 80, Ordering::Relaxed);
        let h = s.meta_get(b"k", &r30, |_, h| h).unwrap().unwrap();
        assert!(h.won && !h.lost && !h.stale);
        assert_eq!(h.ttl, 20);
        let h = s.meta_get(b"k", &r30, |_, h| h).unwrap().unwrap();
        assert!(!h.won && h.lost);
        // readers without R are untouched by the race
        let h = s
            .meta_get(b"k", &MetaGetOpts::default(), |_, h| h)
            .unwrap()
            .unwrap();
        assert!(!h.won && !h.lost);
        // a rewrite re-arms the threshold race
        s.set(b"k", b"v2", 0, 100).unwrap();
        cell.store(5_000_000 + 80 + 90, Ordering::Relaxed);
        let h = s.meta_get(b"k", &r30, |_, h| h).unwrap().unwrap();
        assert!(h.won, "rewrite re-armed the recache win");
    }

    #[test]
    fn arith_vivify_and_cas() {
        let mut s = store(8 << 20);
        // vivify on miss with initial value
        let opts = ArithOpts {
            vivify: Some((60, 5)),
            ..ArithOpts::classic(3, true)
        };
        match s.arith(b"n", &opts).unwrap() {
            ArithOutcome::Value { value: 5, ttl, cas } => {
                assert!((1..=60).contains(&ttl), "{ttl}");
                assert!(cas > 0);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.get(b"n").unwrap().value, b"5");
        // second call hits and applies the delta
        match s.arith(b"n", &opts).unwrap() {
            ArithOutcome::Value { value: 8, .. } => {}
            other => panic!("{other:?}"),
        }
        // CAS guard
        let cas = s.get(b"n").unwrap().cas;
        let guarded = ArithOpts {
            cas_compare: Some(cas + 1),
            ..ArithOpts::classic(1, true)
        };
        assert_eq!(s.arith(b"n", &guarded).unwrap(), ArithOutcome::Exists);
        assert_eq!(s.get(b"n").unwrap().value, b"8");
        // no vivify: plain miss
        assert_eq!(
            s.arith(b"absent", &ArithOpts::classic(1, true)).unwrap(),
            ArithOutcome::NotFound
        );
    }

    #[test]
    fn arith_new_ttl_refreshes_expiry() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"n", b"1", 0, 30).unwrap();
        let opts = ArithOpts {
            new_ttl: Some(300),
            ..ArithOpts::classic(1, true)
        };
        match s.arith(b"n", &opts).unwrap() {
            ArithOutcome::Value { value: 2, ttl, .. } => assert_eq!(ttl, 300),
            other => panic!("{other:?}"),
        }
        cell.store(1_000_100, Ordering::Relaxed);
        assert!(s.get(b"n").is_some(), "TTL refreshed past old expiry");
    }

    /// `MetaGetOpts` shorthand for the tests below.
    fn mg_opts(touch: Option<u32>, vivify: Option<u32>) -> MetaGetOpts {
        MetaGetOpts {
            touch,
            vivify,
            ..MetaGetOpts::default()
        }
    }

    #[test]
    fn meta_get_reports_ttl_and_touches() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"k", b"v", 3, 100).unwrap();
        // plain meta get: ttl reported, untouched
        let hit = s.meta_get(b"k", &mg_opts(None, None), |v, h| {
            assert_eq!(v.data, b"v");
            assert_eq!(v.flags, 3);
            h
        });
        let hit = hit.unwrap().unwrap();
        assert_eq!(hit.ttl, 100);
        assert!(!hit.won);
        // touch-on-read rewrites the TTL
        let hit = s
            .meta_get(b"k", &mg_opts(Some(500), None), |_, h| h)
            .unwrap()
            .unwrap();
        assert_eq!(hit.ttl, 500);
        assert_eq!(s.stats().touch_hits, 1);
        cell.store(1_000_200, Ordering::Relaxed);
        assert!(s.get(b"k").is_some(), "survives old expiry after touch");
        // unlimited TTL renders -1
        s.set(b"e", b"v", 0, 0).unwrap();
        assert_eq!(
            s.meta_get(b"e", &mg_opts(None, None), |_, h| h.ttl).unwrap(),
            Some(-1)
        );
    }

    #[test]
    fn meta_get_vivify_creates_empty_item() {
        let mut s = store(8 << 20);
        let hit = s
            .meta_get(b"fresh", &mg_opts(None, Some(60)), |v, h| {
                assert_eq!(v.data, b"");
                h
            })
            .unwrap()
            .unwrap();
        assert!(hit.won);
        assert!((1..=60).contains(&hit.ttl), "{}", hit.ttl);
        // the item is real: classic get sees it, second meta get is not won
        assert_eq!(s.get(b"fresh").unwrap().value, b"");
        let hit = s
            .meta_get(b"fresh", &mg_opts(None, Some(60)), |_, h| h)
            .unwrap()
            .unwrap();
        assert!(!hit.won);
        // plain miss without vivify
        assert!(s
            .meta_get(b"gone", &mg_opts(None, None), |_, h| h)
            .unwrap()
            .is_none());
        // explicit CAS on a vivified insert (mg E)
        let opts = MetaGetOpts {
            vivify: Some(60),
            vivify_cas: Some(7777),
            ..MetaGetOpts::default()
        };
        let cas = s
            .meta_get(b"lease", &opts, |v, _| v.cas)
            .unwrap()
            .unwrap();
        assert_eq!(cas, 7777);
    }

    #[test]
    fn meta_get_vivify_oom_surfaces_error() {
        // two 4 KiB pages, both filled by the big class: a vivify into
        // the small class has no page and nothing to evict — the
        // client must see an error, not a plain miss
        let mut s = KvStore::new(
            ChunkSizePolicy::Explicit(vec![96, 4000]),
            4096,
            8192,
            true,
            Clock::System,
        )
        .unwrap();
        s.set(b"big1", &vec![b'x'; 3000], 0, 0).unwrap();
        s.set(b"big2", &vec![b'x'; 3000], 0, 0).unwrap();
        match s.meta_get(b"small", &mg_opts(None, Some(60)), |_, h| h) {
            Err(StoreError::OutOfMemory) => {}
            other => panic!("expected OutOfMemory, got {other:?}"),
        }
    }

    #[test]
    fn explicit_cas_threads_through_concat_and_arith() {
        let mut s = store(8 << 20);
        s.set(b"k", b"mid", 0, 0).unwrap();
        let opts = MetaSetOpts {
            mode: StoreMode::Append,
            cas_set: Some(500),
            ..MetaSetOpts::set(0, 0)
        };
        assert_eq!(
            s.meta_set(b"k", b"-end", &opts).unwrap(),
            SetOutcome::Stored { cas: 500 }
        );
        assert_eq!(s.get(b"k").unwrap().cas, 500);
        s.set(b"n", b"1", 0, 0).unwrap();
        let opts = ArithOpts {
            cas_set: Some(900),
            ..ArithOpts::classic(1, true)
        };
        match s.arith(b"n", &opts).unwrap() {
            ArithOutcome::Value { value: 2, cas: 900, .. } => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(s.get(b"n").unwrap().cas, 900);
    }

    #[test]
    fn peek_meta_matches_peek_gating() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"k", b"hello", 7, 0).unwrap();
        let plain = MetaGetOpts::default();
        match s.peek_meta(b"k", &plain, &mut |v: ValueRef<'_>, h: MetaHit| (v.flags, h.ttl)) {
            PeekOutcome::Hit((7, -1)) => {}
            _ => panic!("expected hit"),
        }
        assert!(matches!(
            s.peek_meta(b"nope", &plain, &mut |_: ValueRef<'_>, _| ()),
            PeekOutcome::Miss
        ));
        cell.store(1_000_000 + TOUCH_INTERVAL as u64, Ordering::Relaxed);
        assert!(matches!(
            s.peek_meta(b"k", &plain, &mut |_: ValueRef<'_>, _| ()),
            PeekOutcome::NeedsWrite
        ));
        // a no-bump (`u`) read serves the stale item on the read path —
        // it asks for no LRU mutation, so no write lock is needed
        let nobump = MetaGetOpts {
            no_bump: true,
            ..MetaGetOpts::default()
        };
        match s.peek_meta(b"k", &nobump, &mut |_: ValueRef<'_>, h: MetaHit| h.la) {
            PeekOutcome::Hit(la) => assert_eq!(la, TOUCH_INTERVAL),
            _ => panic!("no-bump read must serve stale items read-only"),
        }
        // ...but never an expired one
        s.set(b"e", b"v", 0, 30).unwrap();
        cell.store(1_000_000 + TOUCH_INTERVAL as u64 + 40, Ordering::Relaxed);
        assert!(matches!(
            s.peek_meta(b"e", &nobump, &mut |_: ValueRef<'_>, _| ()),
            PeekOutcome::NeedsWrite
        ));
    }

    #[test]
    fn no_bump_read_leaves_recency_state_alone() {
        let (clock, cell) = Clock::manual(1_000_000);
        let mut s =
            KvStore::new(ChunkSizePolicy::default(), PAGE_SIZE, 8 << 20, true, clock).unwrap();
        s.set(b"k", b"v", 0, 0).unwrap();
        cell.store(1_000_030, Ordering::Relaxed);
        let nobump = MetaGetOpts {
            no_bump: true,
            ..MetaGetOpts::default()
        };
        let hit = s.meta_get(b"k", &nobump, |_, h| h).unwrap().unwrap();
        assert_eq!(hit.la, 30, "la reports the untouched access age");
        assert!(!hit.fetched, "u must not flip the fetched bit");
        // the access time did not move: a second no-bump read agrees
        let hit = s.meta_get(b"k", &nobump, |_, h| h).unwrap().unwrap();
        assert_eq!(hit.la, 30);
        assert!(!hit.fetched);
        // a normal read refreshes and marks it
        let hit = s
            .meta_get(b"k", &MetaGetOpts::default(), |_, h| h)
            .unwrap()
            .unwrap();
        assert_eq!(hit.la, 30, "echo is the pre-request age");
        assert!(!hit.fetched, "pre-request state");
        let hit = s
            .meta_get(b"k", &MetaGetOpts::default(), |_, h| h)
            .unwrap()
            .unwrap();
        assert_eq!(hit.la, 0, "previous read refreshed the access time");
        assert!(hit.fetched);
    }

    // ------------------------------------------ background maintenance

    #[test]
    fn set_path_does_zero_tier_rebalance_work() {
        // the acceptance guard: a steady-state set only ever links into
        // HOT — every demotion is performed (and counted) by maintain()
        let mut s = store(8 << 20);
        for i in 0..200u32 {
            s.set(format!("k{i:03}").as_bytes(), b"v", 0, 0).unwrap();
        }
        let tiers = s.lru_tier_sizes();
        let (hot, warm, cold): (usize, usize, usize) = tiers
            .iter()
            .fold((0, 0, 0), |a, t| (a.0 + t.0, a.1 + t.1, a.2 + t.2));
        assert_eq!((hot, warm, cold), (200, 0, 0), "sets must stay HOT-linked");
        assert!(!s.lru_balanced());
        assert_eq!(s.stats().maintainer_demoted, 0);
        // the maintainer does the deferred work, bounded per call
        let (demoted, _) = s.maintain(64);
        assert!(demoted <= 64);
        while s.maintain(64).0 > 0 {}
        assert!(s.lru_balanced());
        assert!(s.stats().maintainer_demoted >= 160, "80% must leave HOT");
        assert!(s.stats().maintainer_runs > 0);
        s.check_integrity().unwrap();
    }

    #[test]
    fn touch_promotion_defers_rebalance_to_maintainer() {
        let mut s = store(8 << 20);
        for i in 0..100u32 {
            s.set(format!("k{i:03}").as_bytes(), b"v", 0, 0).unwrap();
        }
        while s.maintain(usize::MAX).0 > 0 {}
        // hammer gets: COLD→WARM promotions happen inline (O(1)) but
        // the warm cap is only re-enforced by the next maintain pass
        for i in 0..100u32 {
            s.get(format!("k{i:03}").as_bytes()).unwrap();
        }
        while s.maintain(usize::MAX).0 > 0 {}
        assert!(s.lru_balanced());
        s.check_integrity().unwrap();
    }

    #[test]
    fn maintain_sheds_post_migration_slack_pages() {
        use crate::slab::allocator::MIGRATION_PAGE_SLACK;
        // full cache, then migrate to a denser geometry: the drain can
        // leave carved pages above the strict budget (≤ slack); the
        // maintainer must walk them back and return the memory
        let mut s = KvStore::new(
            ChunkSizePolicy::default(),
            64 << 10,
            1 << 20, // 16-page budget
            true,
            Clock::System,
        )
        .unwrap();
        for i in 0..4000u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        s.reconfigure(ChunkSizePolicy::Explicit(vec![520, 620, 950]))
            .unwrap();
        let budget = s.slab_stats().page_budget;
        let resident = s.slab_stats().pages_allocated + s.slab_stats().pages_free;
        assert!(resident <= budget + MIGRATION_PAGE_SLACK);
        // a bounded number of passes restores the strict budget
        for _ in 0..(MIGRATION_PAGE_SLACK + 2) {
            s.maintain(usize::MAX);
        }
        let st = s.slab_stats();
        assert!(
            st.pages_allocated + st.pages_free <= budget,
            "slack not shed: {} carved + {} free > {budget}",
            st.pages_allocated,
            st.pages_free
        );
        s.check_integrity().unwrap();
    }

    #[test]
    fn reset_stats_zeroes_counters_not_items() {
        let mut s = store(8 << 20);
        s.set(b"k", b"v", 0, 0).unwrap();
        s.get(b"k");
        s.get(b"missing");
        assert!(s.stats().cmd_get > 0);
        s.reset_stats();
        assert_eq!(s.stats().cmd_get, 0);
        assert_eq!(s.stats().cmd_set, 0);
        assert_eq!(s.len(), 1, "items survive a stats reset");
        assert_eq!(s.get(b"k").unwrap().value, b"v");
    }
}
