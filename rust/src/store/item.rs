//! Item size accounting — what "the size of an item" means.
//!
//! The paper (and memcached's wiki, its reference [1]) defines the
//! memory an item *requires* as `key + value + miscellaneous internal
//! data`. We reproduce memcached's accounting: a 48-byte item header,
//! an optional 8-byte CAS suffix, the key bytes, the value bytes, and
//! the trailing `\r\n` the text protocol stores with the data. This
//! total is what the slab class must cover, and what hole accounting
//! subtracts from the chunk size.

/// Size of memcached's `struct _stritem` header on 64-bit builds.
pub const ITEM_HEADER: usize = 48;

/// Extra bytes when CAS is enabled (`settings.use_cas`).
pub const CAS_SUFFIX: usize = 8;

/// The `\r\n` stored after the data block.
pub const TAIL_CRLF: usize = 2;

/// Total memory an item of `klen`-byte key and `vlen`-byte value
/// requires — the "item size" of the paper's distributions.
#[inline]
pub fn total_item_size(klen: usize, vlen: usize, use_cas: bool) -> usize {
    ITEM_HEADER + if use_cas { CAS_SUFFIX } else { 0 } + klen + vlen + TAIL_CRLF
}

/// Maximum key length (memcached: 250 bytes).
pub const MAX_KEY_LEN: usize = 250;

/// Length-only key bound: 1..=250 bytes. Binary keys (the meta
/// protocol's base64 `b` flag) are exempt from the text-protocol
/// character rules but still bounded.
pub fn key_len_ok(key: &[u8]) -> bool {
    !key.is_empty() && key.len() <= MAX_KEY_LEN
}

/// Validate a key per the text protocol: 1..=250 bytes, no whitespace
/// or control characters.
pub fn key_is_valid(key: &[u8]) -> bool {
    key_len_ok(key) && key.iter().all(|&b| b > 32 && b != 127)
}

/// The store's key gate: binary (base64-sourced) keys are only
/// length-bounded, text keys must satisfy the full protocol rules.
#[inline]
pub fn key_ok(key: &[u8], binary: bool) -> bool {
    if binary {
        key_len_ok(key)
    } else {
        key_is_valid(key)
    }
}

/// 64-bit FNV-1a — memcached's default hash since 1.4.x is murmur3,
/// but FNV remains in-tree and is adequate + dependency-free here.
#[inline]
pub fn hash_key(key: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_accounting_matches_memcached_wiki() {
        // 10-byte key + 100-byte value, CAS on:
        // 48 + 8 + 10 + 100 + 2 = 168
        assert_eq!(total_item_size(10, 100, true), 168);
        assert_eq!(total_item_size(10, 100, false), 160);
        assert_eq!(total_item_size(0, 0, false), 50);
    }

    #[test]
    fn key_validation() {
        assert!(key_is_valid(b"a"));
        assert!(key_is_valid(&[b'k'; 250]));
        assert!(!key_is_valid(b""));
        assert!(!key_is_valid(&[b'k'; 251]));
        assert!(!key_is_valid(b"has space"));
        assert!(!key_is_valid(b"has\nnewline"));
        assert!(!key_is_valid(b"has\ttab"));
        assert!(!key_is_valid(&[127u8]));
    }

    #[test]
    fn hash_stable_and_spreading() {
        assert_eq!(hash_key(b"hello"), hash_key(b"hello"));
        assert_ne!(hash_key(b"hello"), hash_key(b"hellp"));
        assert_ne!(hash_key(b"ab"), hash_key(b"ba"));
    }
}
